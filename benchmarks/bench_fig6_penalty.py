"""Fig. 6: complexity-based penalizing collapses the explored format space
while staying within a fraction of a percent of the unpruned optimum
(paper: >4×10⁵ → small subset, within 0.31%, 2–3 levels)."""

from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core.engine import EngineConfig, SearchStats, generate_candidates
from repro.core.sparsity import NM, Bernoulli, TensorSpec


def run() -> None:
    cfg = EngineConfig(max_levels=3, max_allocs_per_pattern=500, top_k=8)
    for tag, spec in [
        ("90pct", TensorSpec({"M": 4096, "N": 4096}, Bernoulli(0.1))),
        ("2to4", TensorSpec({"M": 4096, "N": 4096}, NM(2, 4))),
    ]:
        s_pen, s_all = SearchStats(), SearchStats()
        pen, dt_p = timed(generate_candidates, spec, cfg, True, s_pen)
        full, dt_f = timed(generate_candidates, spec, cfg, False, s_all)
        best_p = min(c.report.total_bits for c in pen)
        best_f = min(c.report.total_bits for c in full)
        gap = (best_p / best_f - 1) * 100
        emit(f"fig6_{tag}_explored_penalized", dt_p * 1e6,
             f"{s_pen.allocations_seen}")
        emit(f"fig6_{tag}_explored_full", dt_f * 1e6,
             f"{s_all.allocations_seen}")
        emit(f"fig6_{tag}_payload_gap", dt_p * 1e6,
             f"{gap:.2f}% (paper: ≤0.31%)")
        emit(f"fig6_{tag}_best_levels", dt_p * 1e6,
             f"{pen[0].fmt.compressed_levels} levels: {pen[0].fmt}")
        assert gap <= 1.0, gap


if __name__ == "__main__":
    run()
