"""Fig. 10: adaptive compression engine vs the four fixed baselines on
sparse LLMs (2048-token prefill + 128-token decode, Arch 3).

Paper targets: vs the best baseline (Bitmap at LLM-typical sparsity),
14.53% memory-energy saving / 1.18× speedup with activation sparsity and
21.95% / 1.30× with weight sparsity; 18.24% average.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import SPARSE_LLM_DENSITIES, emit, timed
from repro.core.arch import ARCH3
from repro.core.cosearch import CoSearchConfig, cosearch
from repro.core.engine import EngineConfig
from repro.core.formats import STANDARD_BASELINES
from repro.core.workload import (LLAMA2_13B, LLAMA2_7B, OPT_6_7B, OPT_13B,
                                 OPT_30B, build_llm)

MODELS = {"LLaMA2-7B": LLAMA2_7B, "LLaMA2-13B": LLAMA2_13B,
          "OPT-6.7B": OPT_6_7B, "OPT-13B": OPT_13B, "OPT-30B": OPT_30B}

CFG = CoSearchConfig(objective="edp",
                     engine=EngineConfig(max_levels=3,
                                         max_allocs_per_pattern=48),
                     spatial_top=2, max_pairs=10)


def _eval(name: str, spec, mode: str) -> dict:
    d = SPARSE_LLM_DENSITIES[name]
    if mode == "act":
        wl = build_llm(spec, seq=2048, decode_tokens=128,
                       act_density=d["act"], w_density=1.0,
                       fc2_act_density=d["fc2_act"])
    else:
        wl = build_llm(spec, seq=2048, decode_tokens=128,
                       act_density=1.0, w_density=d["w"])
    out = {}
    for fmt in STANDARD_BASELINES:
        pair = (fmt, None) if mode == "act" else (None, fmt)
        res = cosearch(wl, ARCH3, CFG, fixed_formats=pair)
        out[fmt] = (res.design.memory_energy, res.design.cycles)
    res, dt = timed(cosearch, wl, ARCH3, CFG)
    out["SnipSnap"] = (res.design.memory_energy, res.design.cycles)
    out["_t"] = dt
    out["_fmt"] = (res.design.pattern_i if mode == "act"
                   else res.design.pattern_w)
    return out


def run() -> None:
    all_savings = []
    for mode, paper in (("act", "14.53%/1.18x"), ("w", "21.95%/1.30x")):
        savings, speedups = [], []
        for name, spec in MODELS.items():
            r = _eval(name, spec, mode)
            # paper normalizes to Bitmap (best baseline at these sparsities)
            base_e, base_c = min(
                (r[f] for f in STANDARD_BASELINES), key=lambda t: t[0])
            snip_e, snip_c = r["SnipSnap"]
            sav = 1 - snip_e / base_e
            spd = base_c / snip_c
            savings.append(sav)
            speedups.append(spd)
            emit(f"fig10_{mode}_{name}", r["_t"] * 1e6,
                 f"save={sav*100:.1f}% speedup={spd:.2f}x fmt={r['_fmt']}")
        all_savings += savings
        emit(f"fig10_{mode}_avg", 0.0,
             f"save={np.mean(savings)*100:.2f}% "
             f"speedup={np.mean(speedups):.2f}x (paper: {paper})")
    emit("fig10_overall_avg_memory_saving", 0.0,
         f"{np.mean(all_savings)*100:.2f}% (paper: 18.24%)")


if __name__ == "__main__":
    run()
