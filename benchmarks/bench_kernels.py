"""Pallas kernel micro-bench (interpret mode on CPU — timing here is NOT
TPU performance; the meaningful derived columns are the HBM-traffic
compression ratios the kernels realize, which ARE hardware-true).

The ``kernel_*_pipeline`` rows compare the double-buffered streaming
kernels against the naive grid-walk path on the same inputs: results are
asserted bit-identical, so the ratio is pure memory-pipeline engineering.
Even in interpret mode the pipelined path wins — it walks only the
``counts[kj]`` REAL blocks of each stripe instead of every grid step —
which is why it is the default dispatch path (``pipeline=None``)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.kernels import ops


def run(quick: bool = False) -> None:
    rng = np.random.default_rng(0)
    n = k = 128 if quick else 256
    blk = 32 if quick else 64
    x = jnp.asarray(rng.normal(size=(64, n)).astype(np.float32))

    # block-sparse: 25% of blk×blk blocks kept
    gn, gk = n // blk, k // blk
    bitmap = rng.random((gn, gk)) < 0.25
    w = rng.normal(size=(n, k)).astype(np.float32)
    w *= np.repeat(np.repeat(bitmap, blk, 0), blk, 1)
    comp = ops.compress_bitmap(w, blk, blk)
    out, dt = timed(lambda: ops.bitmap_spmm(
        x, comp, bm=64).block_until_ready())
    emit(f"kernel_bitmap_spmm_{blk}x{blk}blocks", dt * 1e6,
         f"traffic_ratio={comp.compression_ratio:.3f} (dense=1.0)")

    # pipelined (default) vs naive on the same compressed weight; warm both
    # jits first so the ratio is steady-state execution, not compile time
    pipe = lambda: ops.bitmap_spmm(x, comp, bm=64,
                                   pipeline=True).block_until_ready()
    naive = lambda: ops.bitmap_spmm(x, comp, bm=64,
                                    pipeline=False).block_until_ready()
    pipe(), naive()
    y_pipe, t_pipe = timed(pipe, repeat=3)
    y_naive, t_naive = timed(naive, repeat=3)
    assert (np.asarray(y_pipe) == np.asarray(y_naive)).all(), \
        "pipelined bitmap kernel diverged from naive"
    emit("kernel_bitmap_spmm_pipeline", t_pipe * 1e6,
         f"naive/pipelined time={t_naive / max(t_pipe, 1e-9):.2f}x "
         "(bit-identical)")

    # 2:4 structured
    wg = rng.normal(size=(n // 4, 4, k)).astype(np.float32)
    order = np.argsort(-np.abs(wg), axis=1)
    mask = np.zeros_like(wg, dtype=bool)
    np.put_along_axis(mask, order[:, :2, :], True, axis=1)
    w24 = (wg * mask).reshape(n, k)
    comp24 = ops.compress_nm(w24)
    out, dt = timed(lambda: ops.nm_spmm(x, comp24, bm=64, bn=128,
                                        bk=128).block_until_ready())
    emit("kernel_nm_spmm_2to4", dt * 1e6,
         f"traffic_ratio={comp24.compression_ratio:.3f} (dense=1.0)")

    pipe = lambda: ops.nm_spmm(x, comp24, bm=64, bn=128, bk=128,
                               pipeline=True).block_until_ready()
    naive = lambda: ops.nm_spmm(x, comp24, bm=64, bn=128, bk=128,
                                pipeline=False).block_until_ready()
    pipe(), naive()
    y_pipe, t_pipe = timed(pipe, repeat=3)
    y_naive, t_naive = timed(naive, repeat=3)
    diff = float(np.max(np.abs(np.asarray(y_pipe) - np.asarray(y_naive))))
    assert diff <= 1e-6, f"pipelined N:M kernel drifted: {diff}"
    emit("kernel_nm_spmm_pipeline", t_pipe * 1e6,
         f"naive/pipelined time={t_naive / max(t_pipe, 1e-9):.2f}x "
         f"maxdiff={diff:.1e}")


if __name__ == "__main__":
    run()
