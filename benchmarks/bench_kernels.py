"""Pallas kernel micro-bench (interpret mode on CPU — timing here is NOT
TPU performance; the meaningful derived columns are the HBM-traffic
compression ratios the kernels realize, which ARE hardware-true)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.kernels import ops


def run() -> None:
    rng = np.random.default_rng(0)
    n = k = 256
    x = jnp.asarray(rng.normal(size=(64, n)).astype(np.float32))

    # block-sparse: 25% of 64×64 blocks kept
    gn, gk = n // 64, k // 64
    bitmap = rng.random((gn, gk)) < 0.25
    w = rng.normal(size=(n, k)).astype(np.float32)
    w *= np.repeat(np.repeat(bitmap, 64, 0), 64, 1)
    comp = ops.compress_bitmap(w, 64, 64)
    out, dt = timed(lambda: ops.bitmap_spmm(x, comp, bm=64).block_until_ready())
    emit("kernel_bitmap_spmm_64x64blocks", dt * 1e6,
         f"traffic_ratio={comp.compression_ratio:.3f} (dense=1.0)")

    # 2:4 structured
    wg = rng.normal(size=(n // 4, 4, k)).astype(np.float32)
    order = np.argsort(-np.abs(wg), axis=1)
    mask = np.zeros_like(wg, dtype=bool)
    np.put_along_axis(mask, order[:, :2, :], True, axis=1)
    w24 = (wg * mask).reshape(n, k)
    comp24 = ops.compress_nm(w24)
    out, dt = timed(lambda: ops.nm_spmm(x, comp24, bm=64, bn=128,
                                        bk=128).block_until_ready())
    emit("kernel_nm_spmm_2to4", dt * 1e6,
         f"traffic_ratio={comp24.compression_ratio:.3f} (dense=1.0)")


if __name__ == "__main__":
    run()
