"""Table I: exploration speed — progressive co-search vs the Sparseloop-style
stepwise workflow, Arch 1–4 × 5 LLMs, Fixed and Search modes.

Both workflows run against the SAME cost model, so the ratio isolates the
workflow-structure claim (§III-D).  Densities 0.75/0.75 as in the paper.
Paper: 2248.3× (Fixed) / 231.5× (Search) vs real Sparseloop — our stepwise
re-implementation is itself far faster than real Sparseloop (no YAML / no
process spawning / shared evaluator), so expect smaller but structural >1×
ratios here, plus the evaluation-count ratio which is machine-independent.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import emit
from repro.core import memo
from repro.core.arch import ALL_ARCHS
from repro.core.baselines import stepwise_search
from repro.core.cosearch import CoSearchConfig, cosearch
from repro.core.engine import EngineConfig
from repro.core.workload import (LLAMA2_13B, LLAMA2_7B, OPT_6_7B, OPT_13B,
                                 OPT_30B, build_llm)

MODELS = {"LLaMA2-7B": LLAMA2_7B, "LLaMA2-13B": LLAMA2_13B,
          "OPT-6.7B": OPT_6_7B, "OPT-13B": OPT_13B, "OPT-30B": OPT_30B}

CFG = CoSearchConfig(objective="edp",
                     engine=EngineConfig(max_levels=2,
                                         max_allocs_per_pattern=24),
                     spatial_top=2, max_pairs=8)


def run_evaluator_comparison() -> None:
    """Old-vs-new evaluator: the seed scalar path (per-candidate evaluate,
    all caches bypassed) against the batch path (evaluate_batch + the memo
    caches, cold start).  Same candidates, same results — the ratio is pure
    evaluator/caching engineering."""
    s_t, s_e = [], []
    scalar_cfg = dataclasses.replace(CFG, use_batch=False)
    for name, mode in (("LLaMA2-7B", "fixed"), ("LLaMA2-7B", "search"),
                       ("OPT-6.7B", "fixed")):
        wl = build_llm(MODELS[name], seq=2048, decode_tokens=128,
                       act_density=0.75, w_density=0.75)
        fixed = ("Bitmap", "Bitmap") if mode == "fixed" else None
        with memo.disabled():
            old = cosearch(wl, ALL_ARCHS[2], scalar_cfg, fixed_formats=fixed)
        memo.clear()                     # cold caches: honest new-path time
        new = cosearch(wl, ALL_ARCHS[2], CFG, fixed_formats=fixed)
        tr = old.runtime_s / max(new.runtime_s, 1e-9)
        s_t.append(tr)
        s_e.append(new.evaluations / max(new.runtime_s, 1e-9))
        assert new.design.edp == old.design.edp, "batch path changed results"
        emit(f"evaluator_{mode}_Arch3_{name}", new.runtime_s * 1e6,
             f"scalar/batch time={tr:.1f}x "
             f"old={old.evaluations / max(old.runtime_s, 1e-9):.0f}ev/s "
             f"new={new.evaluations / max(new.runtime_s, 1e-9):.0f}ev/s")
    emit("evaluator_avg", 0.0,
         f"batch+caches speedup={np.mean(s_t):.1f}x "
         f"throughput={np.mean(s_e):.0f}ev/s (target >=5x)")


def run() -> None:
    run_evaluator_comparison()
    t_ratios, e_ratios = [], []
    for arch in ALL_ARCHS:
        for name, spec in MODELS.items():
            wl = build_llm(spec, seq=2048, decode_tokens=128,
                           act_density=0.75, w_density=0.75)
            prog = cosearch(wl, arch, CFG, fixed_formats=("Bitmap", "Bitmap"))
            step = stepwise_search(wl, arch, CFG,
                                   fixed_formats=("Bitmap", "Bitmap"))
            tr = step.runtime_s / max(prog.runtime_s, 1e-9)
            er = step.evaluations / max(prog.evaluations, 1)
            t_ratios.append(tr)
            e_ratios.append(er)
            emit(f"tableI_fixed_{arch.name.replace(' ', '')}_{name}",
                 prog.runtime_s * 1e6,
                 f"stepwise/progressive time={tr:.1f}x evals={er:.1f}x "
                 f"quality={step.design.edp / prog.design.edp:.3f}")
    emit("tableI_fixed_avg", 0.0,
         f"time={np.mean(t_ratios):.1f}x evals={np.mean(e_ratios):.1f}x "
         "(paper vs real Sparseloop: 2248.3x)")

    # Search mode on one arch (budgeted stepwise sweep is the slow part)
    s_t, s_e, s_q = [], [], []
    for name in ("LLaMA2-7B", "OPT-6.7B"):
        wl = build_llm(MODELS[name], seq=2048, decode_tokens=128,
                       act_density=0.75, w_density=0.75)
        prog = cosearch(wl, ALL_ARCHS[2], CFG)
        step = stepwise_search(wl, ALL_ARCHS[2], CFG, search_formats=True,
                               budget_s_per_op=3.0)
        s_t.append(step.runtime_s / max(prog.runtime_s, 1e-9))
        s_e.append(step.evaluations / max(prog.evaluations, 1))
        s_q.append(step.design.edp / prog.design.edp)
        emit(f"tableI_search_Arch3_{name}", prog.runtime_s * 1e6,
             f"stepwise/progressive time={s_t[-1]:.1f}x "
             f"evals={s_e[-1]:.1f}x quality={s_q[-1]:.3f}")
    emit("tableI_search_avg", 0.0,
         f"time={np.mean(s_t):.1f}x evals={np.mean(s_e):.1f}x "
         f"stepwise_quality_loss={np.mean(s_q):.2f}x "
         "(paper vs Sparseloop search: 231.5x)")


if __name__ == "__main__":
    run()
