"""Table I: exploration speed — progressive co-search vs the Sparseloop-style
stepwise workflow, Arch 1–4 × 5 LLMs, Fixed and Search modes.

Both workflows run against the SAME cost model, so the ratio isolates the
workflow-structure claim (§III-D).  Densities 0.75/0.75 as in the paper.
Paper: 2248.3× (Fixed) / 231.5× (Search) vs real Sparseloop — our stepwise
re-implementation is itself far faster than real Sparseloop (no YAML / no
process spawning / shared evaluator), so expect smaller but structural >1×
ratios here, plus the evaluation-count ratio which is machine-independent.

Old-vs-new rows (``evaluator_*``, ``engine_*``, ``cosearch_gather_*``,
``eval_threads_*``, ``stepwise_batch_*``): the previous-generation paths
against the vectorized/gathered/threaded paths — results are asserted
bit-identical, so the ratios are pure evaluator/engine/sweep engineering.  Search-mode budgets are COUNT-based
(``budget_pairs_per_op``) so every row reproduces exactly run-to-run.
``memo_stats_*`` rows surface cache effectiveness (hits/lookups per cache).
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import emit, timed
from repro.core import memo
from repro.core.arch import ALL_ARCHS
from repro.core.baselines import stepwise_search
from repro.core.cosearch import CoSearchConfig, cosearch
from repro.core.engine import EngineConfig, SearchStats, generate_candidates
from repro.core.sparsity import NM, Bernoulli, TensorSpec
from repro.core.workload import (LLAMA2_13B, LLAMA2_7B, LLMSpec, MatMul,
                                 OPT_6_7B, OPT_13B, OPT_30B, Workload,
                                 build_llm)

MODELS = {"LLaMA2-7B": LLAMA2_7B, "LLaMA2-13B": LLAMA2_13B,
          "OPT-6.7B": OPT_6_7B, "OPT-13B": OPT_13B, "OPT-30B": OPT_30B}

TINY = LLMSpec("tiny", layers=2, d_model=256, d_ff=1024, heads=4)

CFG = CoSearchConfig(objective="edp",
                     engine=EngineConfig(max_levels=2,
                                         max_allocs_per_pattern=24),
                     spatial_top=2, max_pairs=8)

# The adaptive engine's own configuration (§III-C / Fig. 6: 3-level
# patterns over the full allocation space) for the candidate-generation
# old-vs-new comparison.
ENGINE_CFG = EngineConfig(max_levels=3, max_allocs_per_pattern=64)
ENGINE_SPECS = {
    "fig6_unstructured90": TensorSpec({"M": 4096, "N": 4096}, Bernoulli(0.1)),
    "fig6_nm24": TensorSpec({"M": 4096, "N": 4096}, NM(2, 4)),
    "llama7b_fc1_w75": TensorSpec({"N": 4096, "K": 11008}, Bernoulli(0.75)),
}


def _emit_memo_stats(tag: str) -> None:
    """Cache-effectiveness line: hits/lookups per registered cache."""
    emit(f"memo_stats_{tag}", 0.0, memo.stats_report())


def run_engine_comparison(quick: bool = False) -> None:
    """Old-vs-new candidate generation: the seed per-allocation analyze
    loop (use_batch=False, caches bypassed) against the vectorized
    analyze_batch path (cold caches).  Candidates and SearchStats counters
    are asserted identical — the ratio is pure vectorization."""
    specs = dict(list(ENGINE_SPECS.items())[:1]) if quick else ENGINE_SPECS
    ratios = []
    for name, spec in specs.items():
        s_old, s_new = SearchStats(), SearchStats()
        with memo.disabled():
            t0 = time.perf_counter()
            old = generate_candidates(spec, ENGINE_CFG, stats=s_old,
                                      use_batch=False)
            t_old = time.perf_counter() - t0
        memo.clear()                     # cold caches: honest new-path time
        t0 = time.perf_counter()
        new = generate_candidates(spec, ENGINE_CFG, stats=s_new,
                                  use_batch=True)
        t_new = time.perf_counter() - t0
        assert [(str(c.fmt), c.eq_data) for c in old] == \
               [(str(c.fmt), c.eq_data) for c in new], \
            "batched engine changed candidates"
        assert (s_old.patterns_seen, s_old.allocations_seen,
                s_old.pruned_patterns) == \
               (s_new.patterns_seen, s_new.allocations_seen,
                s_new.pruned_patterns), "batched engine changed counters"
        tr = t_old / max(t_new, 1e-9)
        ratios.append(tr)
        emit(f"engine_{name}", t_new * 1e6,
             f"scalar/batch time={tr:.1f}x "
             f"allocs={s_new.allocations_seen} "
             f"patterns={s_new.patterns_seen}")
    emit("engine_avg", 0.0,
         f"batched candidate generation speedup={np.mean(ratios):.1f}x "
         "(target >=3x)")


def run_evaluator_comparison(quick: bool = False) -> None:
    """Old-vs-new evaluator: the seed scalar path (per-candidate evaluate,
    all caches bypassed) against the batch path (evaluate_batch + the memo
    caches, cold start).  Same candidates, same results — the ratio is pure
    evaluator/caching engineering."""
    s_t, s_e = [], []
    scalar_cfg = dataclasses.replace(CFG, use_batch=False)
    cases = ((None, "fixed"),) if quick else (
        ("LLaMA2-7B", "fixed"), ("LLaMA2-7B", "search"),
        ("OPT-6.7B", "fixed"))
    for name, mode in cases:
        spec = TINY if name is None else MODELS[name]
        wl = build_llm(spec, seq=2048 if name else 128,
                       decode_tokens=128 if name else 8,
                       act_density=0.75, w_density=0.75)
        fixed = ("Bitmap", "Bitmap") if mode == "fixed" else None
        with memo.disabled():
            old = cosearch(wl, ALL_ARCHS[2], scalar_cfg, fixed_formats=fixed)
        memo.clear()                     # cold caches: honest new-path time
        memo.reset_stats()
        new = cosearch(wl, ALL_ARCHS[2], CFG, fixed_formats=fixed)
        tr = old.runtime_s / max(new.runtime_s, 1e-9)
        s_t.append(tr)
        s_e.append(new.evaluations / max(new.runtime_s, 1e-9))
        assert new.design.edp == old.design.edp, "batch path changed results"
        emit(f"evaluator_{mode}_Arch3_{name or 'tiny'}", new.runtime_s * 1e6,
             f"scalar/batch time={tr:.1f}x "
             f"old={old.evaluations / max(old.runtime_s, 1e-9):.0f}ev/s "
             f"new={new.evaluations / max(new.runtime_s, 1e-9):.0f}ev/s")
    _emit_memo_stats("evaluator_cold")
    emit("evaluator_avg", 0.0,
         f"batch+caches speedup={np.mean(s_t):.1f}x "
         f"throughput={np.mean(s_e):.0f}ev/s (target >=5x)")


def run_cosearch_gather_comparison(quick: bool = False) -> None:
    """Old-vs-new co-search evaluator plane: the PR-3 per-row format
    repack (``use_gather=False`` — every candidate row re-packs its
    CompiledFormat pair through ``evaluate_batch``) against the gather
    plane (per-op ``format_fetch_table`` over the UNIQUE derived formats +
    memoized ``mapping_ctx``, scored through ``evaluate_batch_gather``).
    Engine/compile/mapping caches stay warm for both paths and the
    ``search_op``/``mapping_ctx`` caches are cleared between runs, so the
    ratio isolates the per-candidate evaluator tail.  Results are asserted
    bit-identical."""
    spec = TINY if quick else MODELS["LLaMA2-7B"]
    wl = build_llm(spec, seq=128 if quick else 2048,
                   decode_tokens=8 if quick else 128,
                   act_density=0.75, w_density=0.75)
    arch = ALL_ARCHS[2]
    nogather = dataclasses.replace(CFG, use_gather=False)
    memo.clear()
    cosearch(wl, arch, nogather)         # warm engine/compile/mapping caches
    memo.clear(names=["search_op", "mapping_ctx", "fetch_table"])
    t0 = time.perf_counter()
    old = cosearch(wl, arch, nogather)
    t_old = time.perf_counter() - t0
    memo.clear(names=["search_op", "mapping_ctx", "fetch_table"])
    memo.reset_stats()
    t0 = time.perf_counter()
    new = cosearch(wl, arch, CFG)
    t_new = time.perf_counter() - t0
    assert old.design.edp == new.design.edp and \
        old.evaluations == new.evaluations and \
        [(str(o.mapping), str(o.fmt_i), str(o.fmt_w))
         for o in old.design.ops] == \
        [(str(o.mapping), str(o.fmt_i), str(o.fmt_w))
         for o in new.design.ops], "gather plane changed co-search results"
    tr = t_old / max(t_new, 1e-9)
    target = "smoke budget" if quick else "target >=2x"
    emit(f"cosearch_gather_Arch3_{spec.name}", t_new * 1e6,
         f"repack/gather time={tr:.1f}x evals={new.evaluations} ({target})")
    # fetch-table sharing across pattern pairs (PR-4 "next perf candidate"):
    # hits = per-pair table builds the new cache avoided on this cold run
    ft = memo.stats()["fetch_table"]
    emit("memo_stats_fetch_table", 0.0,
         f"fetch_table={ft.hits}/{ft.lookups}({100.0 * ft.hit_rate:.0f}%)")


def run_eval_threads_comparison(quick: bool = False) -> None:
    """Serial vs threaded ``_evaluate_terms`` tail on one large gather
    call (LLaMA2-7B fc1-sized op, named-format fetch tables, pseudo-random
    candidate rows).  The tail is elementwise per row, so the threaded
    result is asserted bit-identical — the ratio is pure chunk
    parallelism (NumPy releases the GIL; scales with physical cores)."""
    from repro.core.costmodel import (compile_format, dense_format,
                                      evaluate_batch_gather,
                                      format_fetch_table, mapping_ctx,
                                      pack_mappings, resolve_eval_threads)
    from repro.core.dataflow import enumerate_mappings
    from repro.core.formats import standard_formats
    op = MatMul("fc1", 256 if quick else 2048, 512 if quick else 4096,
                1024 if quick else 11008, Bernoulli(0.75), Bernoulli(0.75))
    arch = ALL_ARCHS[2]
    spec_i = TensorSpec(op.i_dims(), op.sp_i, op.value_bits)
    spec_w = TensorSpec(op.w_dims(), op.sp_w, op.value_bits)
    mappings = list(enumerate_mappings(op, arch, spatial_top=3))
    table = pack_mappings(mappings)
    cfs_i = [dense_format(spec_i)] + [compile_format(f, spec_i)
                                      for f in standard_formats(
                                          spec_i.dims).values()]
    cfs_w = [dense_format(spec_w)] + [compile_format(f, spec_w)
                                      for f in standard_formats(
                                          spec_w.dims).values()]
    ft_i = format_fetch_table(cfs_i, table)
    ft_w = format_fetch_table(cfs_w, table)
    ctx = mapping_ctx(op, arch, table, None)
    n = 100_000 if quick else 2_000_000
    rng = np.random.Generator(np.random.PCG64(0))
    map_idx = rng.integers(0, len(mappings), n)
    i_idx = rng.integers(0, len(cfs_i), n)
    w_idx = rng.integers(0, len(cfs_w), n)

    def tail(threads):
        return evaluate_batch_gather(op, arch, table, ft_i, i_idx, ft_w,
                                     w_idx, map_idx, None, ctx=ctx,
                                     eval_threads=threads)

    t_serial = min(timed(tail, 1)[1] for _ in range(3))
    t_auto = min(timed(tail, None)[1] for _ in range(3))
    bc1, bca = tail(1), tail(None)
    assert np.array_equal(bc1.energy, bca.energy) and \
        np.array_equal(bc1.cycles, bca.cycles) and \
        np.array_equal(bc1.edp, bca.edp), "threaded tail changed results"
    auto = resolve_eval_threads(None, n)
    emit("eval_threads_gather_tail", t_auto * 1e6,
         f"serial/auto({auto}t) time={t_serial / max(t_auto, 1e-9):.2f}x "
         f"rows={n} (bit-identical; scales with physical cores)")


def run_op_workers_comparison(quick: bool = False) -> None:
    """Serial vs threaded per-op loop (``CoSearchConfig.op_workers``): the
    same co-search with the pattern-pair inner loop fanned across a thread
    pool, cold ``search_op``/``mapping_ctx``/``fetch_table`` caches on both
    sides so every op really searches.  Design, evaluation counts, and the
    memo hit/miss counters are asserted identical — the ratio is pure
    intra-pair parallelism (NumPy releases the GIL in the evaluator
    tail)."""
    spec = TINY if quick else MODELS["LLaMA2-7B"]
    wl = build_llm(spec, seq=128 if quick else 2048,
                   decode_tokens=8 if quick else 128,
                   act_density=0.75, w_density=0.75)
    arch = ALL_ARCHS[2]
    workers = 4
    memo.clear()
    cosearch(wl, arch, CFG)              # warm engine/compile/mapping caches
    memo.clear(names=["search_op", "mapping_ctx", "fetch_table"])
    memo.reset_stats()
    t0 = time.perf_counter()
    serial = cosearch(wl, arch, CFG)
    t_serial = time.perf_counter() - t0
    stats_serial = {n: (s.hits, s.misses)
                    for n, s in memo.stats().items()}
    memo.clear(names=["search_op", "mapping_ctx", "fetch_table"])
    memo.reset_stats()
    t0 = time.perf_counter()
    par = cosearch(wl, arch,
                   dataclasses.replace(CFG, op_workers=workers))
    t_par = time.perf_counter() - t0
    stats_par = {n: (s.hits, s.misses) for n, s in memo.stats().items()}
    assert serial.design.edp == par.design.edp and \
        serial.evaluations == par.evaluations and \
        serial.stats.fresh_evaluations == par.stats.fresh_evaluations and \
        [(str(o.mapping), str(o.fmt_i), str(o.fmt_w))
         for o in serial.design.ops] == \
        [(str(o.mapping), str(o.fmt_i), str(o.fmt_w))
         for o in par.design.ops], "op_workers changed co-search results"
    assert stats_serial["search_op"] == stats_par["search_op"], \
        "op_workers changed search_op cache counters"
    tr = t_serial / max(t_par, 1e-9)
    emit(f"cosearch_op_workers_Arch3_{spec.name}", t_par * 1e6,
         f"serial/{workers}-worker time={tr:.2f}x "
         f"evals={par.evaluations} (bit-identical)")


def run_stepwise_comparison(quick: bool = False) -> None:
    """Old-vs-new Search-mode stepwise sweep (the Table-I baseline): the
    seed per-pair loop (use_batch=False, caches bypassed) against the
    vectorized sweep (cold caches), under the same count budget.  Designs,
    evaluation counts, and the pair visit order are asserted identical —
    the ratio is pure sweep engineering (batched side compilation,
    ratio-vector legality, gathered chunk evaluation)."""
    if quick:
        ops = (MatMul("m", 64, 96, 64, Bernoulli(0.75), Bernoulli(0.75)),)
        budget = 200
    else:
        # two representative LLaMA2-7B layers at the paper's 0.75/0.75;
        # the budget is large enough that the batch path's per-op fixed
        # costs (side compile + fetch tables) amortize as they would in a
        # full 600x600 sweep
        ops = (MatMul("attn_proj", 2048, 4096, 4096,
                      Bernoulli(0.75), Bernoulli(0.75)),
               MatMul("fc1", 2048, 4096, 11008,
                      Bernoulli(0.75), Bernoulli(0.75)))
        budget = 4000
    wl = Workload("stepwise-bench", ops)
    log_old: list = []
    log_new: list = []
    with memo.disabled():
        t0 = time.perf_counter()
        old = stepwise_search(wl, ALL_ARCHS[2], CFG, search_formats=True,
                              budget_pairs_per_op=budget, use_batch=False,
                              pair_log=log_old)
        t_old = time.perf_counter() - t0
    memo.clear()                         # cold caches: honest new-path time
    t0 = time.perf_counter()
    new = stepwise_search(wl, ALL_ARCHS[2], CFG, search_formats=True,
                          budget_pairs_per_op=budget, use_batch=True,
                          pair_log=log_new)
    t_new = time.perf_counter() - t0
    assert log_old == log_new, "batched sweep changed the pair visit order"
    assert old.evaluations == new.evaluations, "batched sweep changed evals"
    assert old.design.edp == new.design.edp and \
        [(str(o.mapping), str(o.fmt_i), str(o.fmt_w))
         for o in old.design.ops] == \
        [(str(o.mapping), str(o.fmt_i), str(o.fmt_w))
         for o in new.design.ops], "batched sweep changed designs"
    tr = t_old / max(t_new, 1e-9)
    target = "smoke budget" if quick else "target >=10x"
    emit("stepwise_batch_search", t_new * 1e6,
         f"scalar/batch time={tr:.1f}x pairs={len(log_new)} "
         f"evals={new.evaluations} ({target})")


def run(quick: bool = False) -> None:
    run_engine_comparison(quick=quick)
    run_evaluator_comparison(quick=quick)
    run_cosearch_gather_comparison(quick=quick)
    run_eval_threads_comparison(quick=quick)
    run_op_workers_comparison(quick=quick)
    run_stepwise_comparison(quick=quick)
    t_ratios, e_ratios = [], []
    archs = ALL_ARCHS[2:3] if quick else ALL_ARCHS
    models = ({"tiny": TINY} if quick else MODELS).items()
    memo.reset_stats()
    for arch in archs:
        for name, spec in models:
            wl = build_llm(spec, seq=128 if quick else 2048,
                           decode_tokens=8 if quick else 128,
                           act_density=0.75, w_density=0.75)
            prog = cosearch(wl, arch, CFG, fixed_formats=("Bitmap", "Bitmap"))
            step = stepwise_search(wl, arch, CFG,
                                   fixed_formats=("Bitmap", "Bitmap"))
            tr = step.runtime_s / max(prog.runtime_s, 1e-9)
            er = step.evaluations / max(prog.evaluations, 1)
            t_ratios.append(tr)
            e_ratios.append(er)
            emit(f"tableI_fixed_{arch.name.replace(' ', '')}_{name}",
                 prog.runtime_s * 1e6,
                 f"stepwise/progressive time={tr:.1f}x evals={er:.1f}x "
                 f"quality={step.design.edp / prog.design.edp:.3f}")
    emit("tableI_fixed_avg", 0.0,
         f"time={np.mean(t_ratios):.1f}x evals={np.mean(e_ratios):.1f}x "
         "(paper vs real Sparseloop: 2248.3x)")
    _emit_memo_stats("tableI_fixed")

    # Search mode on one arch (budgeted stepwise sweep is the slow part);
    # the count-based budget keeps the row reproducible run-to-run
    s_t, s_e, s_q = [], [], []
    search_models = ("tiny",) if quick else ("LLaMA2-7B", "OPT-6.7B")
    for name in search_models:
        spec = TINY if name == "tiny" else MODELS[name]
        wl = build_llm(spec, seq=128 if quick else 2048,
                       decode_tokens=8 if quick else 128,
                       act_density=0.75, w_density=0.75)
        prog = cosearch(wl, ALL_ARCHS[2], CFG)
        step = stepwise_search(wl, ALL_ARCHS[2], CFG, search_formats=True,
                               budget_pairs_per_op=150 if quick else 1500)
        s_t.append(step.runtime_s / max(prog.runtime_s, 1e-9))
        s_e.append(step.evaluations / max(prog.evaluations, 1))
        s_q.append(step.design.edp / prog.design.edp)
        emit(f"tableI_search_Arch3_{name}", prog.runtime_s * 1e6,
             f"stepwise/progressive time={s_t[-1]:.1f}x "
             f"evals={s_e[-1]:.1f}x quality={s_q[-1]:.3f}")
    emit("tableI_search_avg", 0.0,
         f"time={np.mean(s_t):.1f}x evals={np.mean(s_e):.1f}x "
         f"stepwise_quality_loss={np.mean(s_q):.2f}x "
         "(paper vs Sparseloop search: 231.5x)")


if __name__ == "__main__":
    run()
