"""Fig. 5: hierarchical 3-level bitmap beats flat bitmap by 16.7% on the
worked 3×6 example (exact, instance-level)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.core import formats as F
from repro.core.formats import Format, Level
from repro.core.primitives import Prim
from repro.core.sparsity import analyze_exact


def run() -> None:
    dims = {"M": 3, "N": 6}
    # instance: 1 empty row; 2 non-empty rows covering 3 non-empty thirds
    mask = np.zeros((3, 6), dtype=bool)
    mask[0, 0] = mask[0, 3] = True      # row 0: thirds {0, 1}
    mask[1, 4] = True                   # row 1: third {2}

    flat = analyze_exact(F.bitmap(dims), mask, dims)
    hier_fmt = Format.of(Level(Prim.B, "M", 3), Level(Prim.B, "N", 3),
                         Level(Prim.B, "N", 2))
    (hier, dt) = timed(analyze_exact, hier_fmt, mask, dims)

    red = 1.0 - hier.metadata_bits / flat.metadata_bits
    emit("fig5_flat_bitmap_bits", dt * 1e6, f"{flat.metadata_bits:.0f}")
    emit("fig5_hier_bitmap_bits", dt * 1e6, f"{hier.metadata_bits:.0f}")
    emit("fig5_metadata_reduction", dt * 1e6,
         f"{red * 100:.1f}% (paper: 16.7%)")
    assert flat.metadata_bits == 18 and hier.metadata_bits == 15, \
        (flat.metadata_bits, hier.metadata_bits)


if __name__ == "__main__":
    run()
