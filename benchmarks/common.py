"""Shared benchmark utilities: timing + CSV emission (+ row collection)."""

from __future__ import annotations

import time
from typing import Callable, Optional


def timed(fn: Callable, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt


_COLLECTOR: Optional[list] = None


def set_collector(rows: Optional[list]) -> None:
    """Install a list that :func:`emit` mirrors every row into (as dicts) —
    how ``benchmarks/run.py --json`` captures the machine-readable record.
    Pass ``None`` to detach."""
    global _COLLECTOR
    _COLLECTOR = rows


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
    if _COLLECTOR is not None:
        _COLLECTOR.append({"name": name, "us_per_call": us_per_call,
                           "derived": derived})


# Fig. 10 activation/weight density pairs.  Sources: [4] (ReLU Strikes
# Back) — OPT FFN activation sparsity up to 97%, FC1 35–70% sparse, larger
# models sparser; [5] (SparseLLM) — 70–85% weight sparsity at comparable
# accuracy.  "act"/"w" are NON-ZERO fractions (density).
SPARSE_LLM_DENSITIES = {
    "LLaMA2-7B": {"act": 0.40, "w": 0.20, "fc2_act": 0.15},
    "LLaMA2-13B": {"act": 0.35, "w": 0.15, "fc2_act": 0.10},
    "OPT-6.7B": {"act": 0.20, "w": 0.15, "fc2_act": 0.05},
    "OPT-13B": {"act": 0.15, "w": 0.12, "fc2_act": 0.04},
    "OPT-30B": {"act": 0.10, "w": 0.10, "fc2_act": 0.03},
}
