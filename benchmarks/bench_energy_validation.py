"""Fig. 8/9: cost-model validation.

No published raw numbers ship with this repo, so validation is against the
FIRST-PRINCIPLES reference the published curves themselves follow (and which
the paper's normalized figures encode):

  * Fig. 8 (SCNN-like energy): with sparse activations/weights on a
    skipping accelerator, energy ≈ dense_energy × (compute share · ρ_eff +
    memory share · compressed-traffic ratio).  We check the full cost model
    tracks this physical reference within a few % mean relative error
    (paper reports 4.33% against SCNN's published data).
  * Fig. 9 (DSTC-like latency on 4096² MatMul): with bidirectional
    skipping, cycles ≈ dense_cycles × max(ρ_I·ρ_W, bandwidth bound).
    Paper reports 6.26% vs DSTC (Sparseloop: 8.55%).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.arch import ARCH2, ARCH3
from repro.core.cosearch import CoSearchConfig, cosearch
from repro.core.sparsity import Bernoulli
from repro.core.workload import MatMul, Workload

CFG = CoSearchConfig(spatial_top=2)


def _energy(arch, rho_i, rho_w) -> float:
    op = MatMul("val", 1024, 1024, 1024,
                Bernoulli(rho_i), Bernoulli(rho_w))
    res = cosearch(Workload("v", (op,)), arch, CFG,
                   fixed_formats=("Bitmap", "Bitmap"))
    return res.design.energy


def _latency(arch, rho_i, rho_w) -> float:
    op = MatMul("val", 4096, 4096, 4096,
                Bernoulli(rho_i), Bernoulli(rho_w))
    res = cosearch(Workload("v", (op,)), arch, CFG,
                   fixed_formats=("Bitmap", "Bitmap"),)
    return res.design.cycles


def run() -> None:
    densities = [0.2, 0.4, 0.6, 0.8]

    # --- Fig. 8-style energy (SCNN: skipping, activation side) ------------
    e_dense = _energy(ARCH2, 1.0, 1.0)
    errs = []
    for case, (fi, fw) in {"SA": (True, False), "SW": (False, True),
                           "SA&SW": (True, True)}.items():
        for rho in densities:
            ri, rw = (rho if fi else 1.0), (rho if fw else 1.0)
            got = _energy(ARCH2, ri, rw) / e_dense
            # physical reference: compute scales with checked density;
            # memory with compressed traffic (bitmap: ρ payload + meta)
            rho_eff = ri  # Arch2 checks I
            traffic = (ri + 1 / 16) * 0.5 + (rw + 1 / 16) * 0.5
            ref = 0.45 * rho_eff + 0.55 * min(traffic, 1.0)
            errs.append(abs(got - ref) / ref)
        emit(f"fig8_energy_{case}", 0.0,
             f"model/ref tracked at densities {densities}")
    mre = float(np.mean(errs)) * 100
    emit("fig8_mean_rel_err", 0.0, f"{mre:.1f}% (paper: 4.33%)")

    # --- Fig. 9-style latency (DSTC: bidirectional skipping) --------------
    c_dense = _latency(ARCH3, 1.0, 1.0)
    lat_errs = []
    for rho in densities:
        got = _latency(ARCH3, rho, rho) / c_dense
        ref = max(rho * rho, 0.05)        # compute-bound skipping ideal
        lat_errs.append(abs(got - ref) / max(got, ref))
    mre_l = float(np.mean(lat_errs)) * 100
    emit("fig9_latency_mre", 0.0, f"{mre_l:.1f}% vs skipping ideal "
         "(paper: 6.26% vs DSTC, Sparseloop 8.55%)")


if __name__ == "__main__":
    run()
