"""Fig. 11: multi-model format selection with importance-based scoring.

Case 1: BERT-Base (256-token NLU) + OPT-125M (256 in / 32 out generation).
Case 2: speculative decoding — OPT-125M draft + OPT-6.7B verify, both
256 in / 32 out.  Baseline: best per-model-optimal FIXED format applied
shared.  Paper: 14.23% average energy saving.

The ``fig11_workers`` / ``fig11_workers_process`` rows compare serial vs
sharded ``cosearch_multi`` (the flat (pair, model) work-list across a
``concurrent.futures`` pool — threads share the ``_search_op`` cache,
processes shard past the GIL with per-process memo caches warmed from a
``memo.export_state`` snapshot; results are asserted identical either way —
the merge is deterministic by construction).  ``process_cache_return``
times a follow-up search after a process run whose workers shipped their
``_search_op``/compile/``mapping_ctx`` deltas back to the parent — every
per-op search replays (``fresh_evaluations`` = 0).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.core import memo
from repro.core.arch import ARCH3
from repro.core.cosearch import CoSearchConfig, cosearch, cosearch_multi
from repro.core.engine import EngineConfig
from repro.core.formats import STANDARD_BASELINES
from repro.core.workload import (BERT_BASE, LLMSpec, OPT_125M, OPT_6_7B,
                                 build_llm)

CFG = CoSearchConfig(objective="energy",
                     engine=EngineConfig(max_levels=2,
                                         max_allocs_per_pattern=32),
                     spatial_top=2, max_pairs=8)


def _case(name: str, workloads, importance, paper_hint: str) -> float:
    # baseline: best single FIXED format shared across both models
    best_fixed = None
    for fmt in STANDARD_BASELINES:
        tot = 0.0
        for wl in workloads:
            res = cosearch(wl, ARCH3, CFG, fixed_formats=(fmt, fmt))
            tot += importance[wl.name] * res.design.energy
        best_fixed = tot if best_fixed is None else min(best_fixed, tot)

    (designs, key, val), dt = timed(
        cosearch_multi, workloads, ARCH3, importance, CFG)
    saving = 1 - val / best_fixed
    emit(f"fig11_{name}", dt * 1e6,
         f"save={saving*100:.2f}% fmt={key} ({paper_hint})")
    return saving


def run_workers_comparison(workloads, importance) -> None:
    """Serial vs sharded cosearch_multi, cold caches each, same results."""
    memo.clear()
    (d1, k1, v1), t1 = timed(cosearch_multi, workloads, ARCH3,
                             importance, CFG)
    memo.clear()
    (d2, k2, v2), t2 = timed(cosearch_multi, workloads, ARCH3,
                             importance, CFG, workers=4)
    assert (k1, v1) == (k2, v2) and set(d1) == set(d2), \
        "sharded cosearch_multi changed results"
    for m in d1:
        assert d1[m].design.energy == d2[m].design.energy, m
    emit("fig11_workers", t2 * 1e6,
         f"serial/4-workers time={t1 / max(t2, 1e-9):.2f}x "
         f"(deterministic merge, shared _search_op cache)")
    memo.clear()
    (d3, k3, v3), t3 = timed(cosearch_multi, workloads, ARCH3,
                             importance, CFG, workers=4, executor="process")
    assert (k1, v1) == (k3, v3) and set(d1) == set(d3), \
        "process-sharded cosearch_multi changed results"
    for m in d1:
        assert d1[m].design.energy == d3[m].design.energy, m
        assert d1[m].evaluations == d3[m].evaluations, m
    emit("fig11_workers_process", t3 * 1e6,
         f"serial/4-procs time={t1 / max(t3, 1e-9):.2f}x "
         f"(per-process memo warmed from export_state snapshot; "
         f"scales with physical cores)")


def run_cache_return(workloads, importance) -> None:
    """Process workers ship their ``_search_op``/compile/``mapping_ctx``
    cache deltas back with each item result; the parent imports them, so a
    FOLLOW-UP search over the same op shapes replays every per-op search
    instead of recomputing (``fresh_evaluations`` = 0)."""
    memo.clear()
    (_, k1, v1), t_cold = timed(cosearch_multi, workloads, ARCH3,
                                importance, CFG, workers=2,
                                executor="process")
    (d2, k2, v2), t_warm = timed(cosearch_multi, workloads, ARCH3,
                                 importance, CFG)
    assert (k1, v1) == (k2, v2), "cache-return changed the winning pair"
    fresh = sum(r.stats.fresh_evaluations for r in d2.values())
    total = sum(r.stats.evaluations for r in d2.values())
    assert fresh == 0, f"parent caches missed shipped entries: {fresh}"
    emit("process_cache_return", t_warm * 1e6,
         f"cold-process/warm-followup time={t_cold / max(t_warm, 1e-9):.1f}x "
         f"fresh_evals={fresh}/{total} "
         f"(workers shipped memo deltas to the parent)")


def run(quick: bool = False) -> None:
    if quick:
        wl_a = build_llm(LLMSpec("A", 2, 256, 1024, 4), seq=64,
                         act_density=0.2, w_density=0.15)
        wl_b = build_llm(LLMSpec("B", 2, 256, 1024, 4), seq=64,
                         act_density=0.4, w_density=0.25)
        s = _case("quick_tiny_pair", [wl_a, wl_b], {"A": 80.0, "B": 20.0},
                  "quick smoke")
        run_workers_comparison([wl_a, wl_b], {"A": 80.0, "B": 20.0})
        run_cache_return([wl_a, wl_b], {"A": 80.0, "B": 20.0})
        emit("fig11_avg_saving", 0.0, f"{s*100:.2f}% (quick mode)")
        return

    # Fig-10-grade sparsity levels ([4],[5]): BERT is the sparsest (the
    # paper: "emphasizing BERT-Base boosts savings due to its higher
    # sparsity"); OPT-6.7B carries the cost in the speculative pair.
    wl_bert = build_llm(BERT_BASE, seq=256, act_density=0.15, w_density=0.10,
                        fc2_act_density=0.05)
    wl_opt125 = build_llm(OPT_125M, seq=256, decode_tokens=32,
                          act_density=0.40, w_density=0.25,
                          fc2_act_density=0.15)
    wl_opt67 = build_llm(OPT_6_7B, seq=256, decode_tokens=32,
                         act_density=0.20, w_density=0.15,
                         fc2_act_density=0.05)

    s1 = _case("case1_bert+opt125m", [wl_bert, wl_opt125],
               {"BERT-Base": 80.0, "OPT-125M": 20.0},
               "emphasizing BERT boosts savings")
    s2 = _case("case2_specdec_opt125m+6.7b", [wl_opt125, wl_opt67],
               {"OPT-125M": 50.0, "OPT-6.7B": 50.0},
               "format should prioritize OPT-6.7B")
    run_workers_comparison([wl_bert, wl_opt125],
                           {"BERT-Base": 80.0, "OPT-125M": 20.0})
    run_cache_return([wl_bert, wl_opt125],
                     {"BERT-Base": 80.0, "OPT-125M": 20.0})
    emit("fig11_avg_saving", 0.0,
         f"{np.mean([s1, s2])*100:.2f}% (paper: 14.23%)")


if __name__ == "__main__":
    run()
