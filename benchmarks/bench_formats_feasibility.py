"""§IV-E format feasibility: show the formats SnipSnap actually selects for
the showcase cases and their level counts / estimated decoder cost.

Paper showcases: weight-sparse OPT-6.7B → B(M)-B(N)-B(N) (the Fig. 5
format); BERT-Base → UOP(M)-B(N) (CSR with the CP level replaced by a
cheaper B).  Hardware overhead of 2–3-level formats: 1.56%–15.45% area in
published accelerators."""

from __future__ import annotations

from benchmarks.common import SPARSE_LLM_DENSITIES, emit, timed
from repro.core.arch import ARCH3
from repro.core.cosearch import CoSearchConfig, cosearch
from repro.core.engine import EngineConfig
from repro.core.workload import BERT_BASE, OPT_6_7B, build_llm

CFG = CoSearchConfig(objective="energy",
                     engine=EngineConfig(max_levels=3,
                                         max_allocs_per_pattern=64),
                     spatial_top=2, max_pairs=12)


def run() -> None:
    d = SPARSE_LLM_DENSITIES["OPT-6.7B"]
    wl = build_llm(OPT_6_7B, seq=2048, decode_tokens=128,
                   act_density=1.0, w_density=d["w"])
    res, dt = timed(cosearch, wl, ARCH3, CFG)
    lv = max((len([k for k in (res.design.pattern_w or ()) ])), 0)
    emit("feasibility_OPT6.7B_weight_fmt", dt * 1e6,
         f"levels={lv} fmt={res.design.pattern_w} "
         "(paper: B(M)-B(N)-B(N))")

    wl_b = build_llm(BERT_BASE, seq=256, act_density=0.25, w_density=1.0)
    res_b, dt_b = timed(cosearch, wl_b, ARCH3, CFG)
    lv_b = max((len([k for k in (res_b.design.pattern_i or ())])), 0)
    emit("feasibility_BERT_act_fmt", dt_b * 1e6,
         f"levels={lv_b} fmt={res_b.design.pattern_i} "
         "(paper: UOP(M)-B(N))")

    for tag, pat in (("OPT", res.design.pattern_w),
                     ("BERT", res_b.design.pattern_i)):
        n = len(pat or ())
        emit(f"feasibility_{tag}_levels_2to3", 0.0,
             f"{n} compressed levels — within the 2-3 range the paper ties "
             "to 1.56-15.45% decoder area")


if __name__ == "__main__":
    run()
