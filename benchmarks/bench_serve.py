"""Serving-plane benchmark: batched prefill + KV-cache decode throughput,
compressed (scan-compiled Pallas kernels) vs dense, per batch size.

Rows (us_per_call = warm wall-clock of the phase):

  * ``serve_prefill_{dense|comp}_b{B}`` — one batched prefill pass
    (``Model.prefill`` / ``CompressedModel.prefill``, jitted, warm);
    derived: tokens/sec and tokens/sec/device.
  * ``serve_decode_{dense|comp}_b{B}``  — one greedy decode step against
    the prefill-filled cache (jitted, warm); derived: tokens/sec(/device).
    Compressed rows also surface the plan's :class:`FallbackReason` counts
    and the kernel jit-cache stats (hits/misses/entries) — the whole
    serving trace should cost one kernel build per planned role, NOT
    ``n_layers ×`` that.
  * ``serve_pipeline_vs_naive``         — the scanned compressed forward
    with the double-buffered streaming kernels (the dispatch default)
    against the same forward forced onto the naive grid-walk kernels
    (``repro.kernels.ops.pipeline_default``), warm and trace-time, with
    the numerical diff (parity-pinned ≈ 0).
  * ``serve_scan_vs_unrolled``          — the tentpole comparison: the
    scanned compressed forward (one compiled block, HLO O(1) in depth)
    vs the previous revision's per-layer Python re-drive, first-call
    (trace + compile) and warm.
  * ``serve_guarded_vs_unguarded``      — the robustness-layer overhead:
    the guarded driver (store verification, per-step finite-logit check,
    undonated decode cache — :func:`repro.runtime.guard.guarded_generate`)
    vs the plain driver on the same healthy store, whole-generation
    decode seconds per token, plus the health summary and a token-
    equality check (guarded must change nothing when nothing is wrong).
  * ``serve_mixer_vs_static``           — continuous batching: a
    mixed-length request stream through the compressed plane's
    :class:`repro.launch.mixer.Mixer` (admit/evict into a running decode
    batch) vs the same requests as static lockstep chunks (left-padded,
    each chunk decoding to its longest budget).  Useful-token decode
    throughput for both, the ratio, and the structural win: the mixer
    refills freed slots instead of burning lockstep steps past short
    requests' budgets.

Dense rows serve the SAME pruned weight tree the compressed store was
built from, so the comparison isolates the execution path.  With more
than one device, the request batch shards over a ``make_serve_mesh`` data
axis and throughput is reported per device.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import time

import jax
import numpy as np

from benchmarks.common import emit


def _serve_times(model, params, prompts, gen: int, max_len: int):
    """(warm prefill seconds, warm per-decode-step seconds)."""
    import jax.numpy as jnp

    b, plen = prompts.shape
    prefill = jax.jit(functools.partial(model.prefill, max_len=max_len))
    step = jax.jit(model.decode_step, donate_argnums=(1,))

    logits, cache = prefill(params, prompts)        # warm (trace/compile)
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    logits, cache = step(params, cache, tok,        # warm the decode step
                         jnp.asarray(plen, jnp.int32))
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    jax.block_until_ready(tok)
    t1 = time.perf_counter()
    for t in range(plen + 1, plen + 1 + gen):
        logits, cache = step(params, cache, tok, jnp.asarray(t, jnp.int32))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    jax.block_until_ready(logits)
    t_step = (time.perf_counter() - t1) / gen
    return t_prefill, t_step


def _first_and_warm(fn, *args):
    """(first-call seconds — trace + compile —, warm-call seconds)."""
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    t_first = time.perf_counter() - t0
    t1 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    return t_first, time.perf_counter() - t1


def _rate(n: float, t: float) -> float:
    """n / t with a denominator floor — a quick run can time a warm phase
    at ~0s, which must not blow up the report."""
    return n / max(t, 1e-9)


def run(quick: bool = False) -> None:
    import jax.numpy as jnp

    from repro import exec as rexec
    from repro.configs import get_config
    from repro.core.cosearch import CoSearchConfig
    from repro.core.engine import EngineConfig
    from repro.core.sparsity import BlockBernoulli
    from repro.kernels import ops as kops
    from repro.launch.mesh import (axis_map_for, make_serve_mesh,
                                   mesh_axis_sizes)
    from repro.models.sharding import logical_axis_rules
    from repro.models.transformer import Model

    cfg = get_config("chatglm3-6b").reduced()
    if not quick:
        # deepen the stack so the scan-vs-unrolled trace gap is visible
        cfg = dataclasses.replace(cfg, n_layers=8)
    batches = (1, 2) if quick else (1, 8, 64)
    plen, gen = (8, 4) if quick else (32, 16)
    fast = CoSearchConfig(objective="edp",
                          engine=EngineConfig(max_levels=2,
                                              max_allocs_per_pattern=16),
                          spatial_top=2, max_pairs=6)

    model = Model(cfg)
    params = model.init(jax.random.key(0))
    plan = rexec.build_exec_plan(cfg, BlockBernoulli(0.5, 32 * 32),
                                 tokens=plen * max(batches),
                                 search_cfg=fast, value_bits=32)
    pruned = rexec.prune_params(params, plan, cfg)
    store = rexec.compress_params(pruned, plan, cfg)
    cm = rexec.CompressedModel(model, store)
    fb = plan.fallback_counts()
    rng = np.random.default_rng(0)

    kops.clear_kernel_cache()
    for b in batches:
        prompts = jnp.asarray(rng.integers(0, cfg.vocab, (b, plen)),
                              jnp.int32)
        mesh = make_serve_mesh(b)
        ndev = int(np.prod(list(mesh_axis_sizes(mesh).values()))) \
            if mesh is not None else 1
        ctx = contextlib.nullcontext() if mesh is None else mesh
        rules = contextlib.nullcontext() if mesh is None \
            else logical_axis_rules(axis_map_for(mesh))
        with ctx, rules:
            for label, m in (("dense", model), ("comp", cm)):
                t_prefill, t_step = _serve_times(m, pruned, prompts, gen,
                                                 plen + gen + 1)
                extra = ""
                if label == "comp":
                    kc = kops.kernel_cache_stats()
                    extra = (f" ratio={store.achieved_ratio():.3f}"
                             f" fallbacks={fb or 'none'}"
                             f" kcache=h{kc['hits']}/m{kc['misses']}"
                             f"/e{kc['entries']}")
                emit(f"serve_prefill_{label}_b{b}", t_prefill * 1e6,
                     f"tok/s={_rate(b * plen, t_prefill):.0f} "
                     f"tok/s/dev={_rate(b * plen, t_prefill) / ndev:.0f} "
                     f"plen={plen} ndev={ndev}{extra}")
                emit(f"serve_decode_{label}_b{b}", t_step * 1e6,
                     f"tok/s={_rate(b, t_step):.0f} "
                     f"tok/s/dev={_rate(b, t_step) / ndev:.0f} "
                     f"gen={gen} ndev={ndev}{extra}")

    # memory-pipeline row: the SAME scanned compressed forward with the
    # double-buffered streaming kernels (the default) vs the naive
    # grid-walk kernels, both jitted and warm — results are numerically
    # identical (the kernels are parity-pinned), so the ratio is what the
    # weight-streaming pipeline buys the serving plane end to end
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, plen)), jnp.int32)
    with kops.pipeline_default(True):
        pipe_first, pipe_warm = _first_and_warm(
            jax.jit(cm.hidden_states), pruned, tokens)
        y_pipe = jax.jit(cm.hidden_states)(pruned, tokens)
    with kops.pipeline_default(False):
        naive_first, naive_warm = _first_and_warm(
            jax.jit(cm.hidden_states), pruned, tokens)
        y_naive = jax.jit(cm.hidden_states)(pruned, tokens)
    maxdiff = float(jnp.max(jnp.abs(y_pipe - y_naive)))
    emit("serve_pipeline_vs_naive", pipe_warm * 1e6,
         f"naive/pipelined warm={naive_warm / max(pipe_warm, 1e-9):.2f}x "
         f"trace={naive_first / max(pipe_first, 1e-9):.2f}x "
         f"maxdiff={maxdiff:.1e}")

    # tentpole row: scanned compressed forward vs per-layer unrolled
    scan_first, scan_warm = _first_and_warm(
        jax.jit(cm.hidden_states), pruned, tokens)
    unr_first, unr_warm = _first_and_warm(
        jax.jit(cm.hidden_states_unrolled), pruned, tokens)
    emit("serve_scan_vs_unrolled", scan_warm * 1e6,
         f"scan_trace_ms={scan_first * 1e3:.0f} "
         f"unrolled_trace_ms={unr_first * 1e3:.0f} "
         f"unrolled_warm_us={unr_warm * 1e6:.0f} layers={cfg.n_layers} "
         f"speedup_trace={_rate(unr_first, scan_first):.2f}x "
         f"speedup_warm={_rate(unr_warm, scan_warm):.2f}x")

    # robustness row: the guarded serving path vs the plain driver on the
    # same healthy store.  Both drivers re-jit their decode step per
    # invocation, so each side's decode time includes one compile plus the
    # per-step work — the delta is the guard's real cost (finite-logit
    # host sync each step + the undonated cache copy)
    from repro.launch import serve as serve_mod
    from repro.runtime.guard import guarded_generate
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (2, plen)), jnp.int32)
    toks_u, _, t_gen_u = serve_mod.generate(cm, pruned, prompts, gen,
                                            plen + gen)
    toks_g, report = guarded_generate(cm, pruned, prompts, gen, plen + gen)
    step_u = t_gen_u / gen
    step_g = report.t_decode_s / max(report.steps, 1)
    emit("serve_guarded_vs_unguarded", step_g * 1e6,
         f"unguarded_us={step_u * 1e6:.0f} "
         f"overhead={step_g / max(step_u, 1e-9):.2f}x gen={gen} "
         f"healthy={report.healthy} verify_roles={len(report.verify)} "
         f"retries={report.retries} "
         f"fallbacks={report.fallback_counts() or 'none'} "
         f"tokens_match={bool(jnp.all(toks_u == toks_g))}")

    # observability row: the SAME static generate with telemetry off vs
    # with a tracer + metrics registry + kernel timer installed
    # (repro.obs).  The off path must stay bit-identical — the span/event
    # helpers reduce to one None check — and the on path's ratio is the
    # plane's real cost; both sides are warm (the guarded row above
    # already traced this shape)
    from repro.obs import metrics as omet
    from repro.obs import trace as otr
    from repro.obs.profile import kernel_timer
    toks_off, _, t_off = serve_mod.generate(cm, pruned, prompts, gen,
                                            plen + gen)
    tracer = otr.Tracer()
    reg = omet.MetricsRegistry()
    with otr.tracing(tracer), omet.collecting(reg), \
            kernel_timer(registry=reg, tracer=tracer):
        toks_on, _, t_on = serve_mod.generate(cm, pruned, prompts, gen,
                                              plen + gen)
    snap = reg.snapshot()
    emit("serve_telemetry_overhead", t_on / gen * 1e6,
         f"off_us={t_off / gen * 1e6:.0f} "
         f"overhead={t_on / max(t_off, 1e-9):.2f}x "
         f"trace_events={len(tracer.events)} "
         f"counter_series={len(snap['counters'])} "
         f"tokens_match={bool(jnp.all(toks_off == toks_on))}")

    # continuous-batching row: a mixed-length request stream through the
    # mixer vs the SAME requests served as static lockstep chunks.
    # Budgets alternate short/long so lockstep burns steps past the short
    # requests; the mixer refills those slots instead.  The mixer's decode
    # trace is warmed by a throwaway stream (its jitted step is per-Mixer);
    # the static driver re-jits per generate() call, the same caveat as
    # the guarded row above.
    from repro.launch.mixer import Mixer, Request
    slots = 2 if quick else 4
    n_req = 4 if quick else 8
    budgets = [gen if i % 2 else max(2, gen // 4) for i in range(n_req)]
    plens = [max(1, plen - (i % 4) * (plen // 5)) for i in range(n_req)]
    max_len = plen + gen + 1
    PAD = 0  # prompt pad id: prompts below draw from [1, vocab)

    def stream(tag):
        return [Request(uid=f"{tag}{i}",
                        prompt=jnp.asarray(rng.integers(
                            1, cfg.vocab, (plens[i],)), jnp.int32),
                        max_new=budgets[i])
                for i in range(n_req)]

    mx = Mixer(cm, pruned, slots=slots, max_len=max_len)
    mx.run(stream("warm"))                       # warm decode/prefill traces
    s0 = mx.stats()
    reqs = stream("req")
    mx.run(reqs)
    s1 = mx.stats()
    mix_tok = s1["tokens"] - s0["tokens"]
    mix_t = s1["t_decode_s"] - s0["t_decode_s"]
    mix_steps = s1["steps"] - s0["steps"]

    stat_tok, stat_t, stat_steps = 0, 0.0, 0
    for c0 in range(0, n_req, slots):
        idx = list(range(c0, min(c0 + slots, n_req)))
        cp = max(plens[i] for i in idx)
        cg = max(budgets[i] for i in idx)
        rows = [np.concatenate([np.full(cp - plens[i], PAD, np.int32),
                                np.asarray(reqs[i].prompt)]) for i in idx]
        batch = jnp.asarray(np.stack(rows))
        _, _, t_g = serve_mod.generate(cm, pruned, batch, cg, max_len,
                                       prompt_pad_id=PAD)
        stat_t += t_g
        stat_tok += sum(budgets[i] for i in idx)   # useful tokens only
        stat_steps += cg
    mix_rate = _rate(mix_tok, mix_t)
    stat_rate = _rate(stat_tok, stat_t)
    emit("serve_mixer_vs_static", mix_t / max(mix_tok, 1) * 1e6,
         f"mixer_tok_s={mix_rate:.0f} static_tok_s={stat_rate:.0f} "
         f"mixer/static={_rate(mix_rate, stat_rate):.2f}x "
         f"tok/s/dev={mix_rate / ndev:.0f} "
         f"slots={slots} requests={n_req} "
         f"mixer_steps={mix_steps} static_steps={stat_steps} "
         f"slot_reuse_admits={s1['slot_reuse_admits'] - s0['slot_reuse_admits']}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
