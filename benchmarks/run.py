"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Roofline terms come from the
dry-run artifacts (benchmarks/roofline.py builds the table; run
``python -m repro.launch.dryrun --all`` first for that one).
"""

from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (bench_dimo, bench_energy_validation,
                            bench_fig5_payload, bench_fig6_penalty,
                            bench_format_opt, bench_formats_feasibility,
                            bench_kernels, bench_multimodel, bench_speed)
    suites = [
        ("fig5", bench_fig5_payload.run),
        ("fig6", bench_fig6_penalty.run),
        ("fig8/9", bench_energy_validation.run),
        ("fig10", bench_format_opt.run),
        ("fig11", bench_multimodel.run),
        ("tableI", bench_speed.run),
        ("dimo", bench_dimo.run),
        ("feasibility", bench_formats_feasibility.run),
        ("kernels", bench_kernels.run),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        if only and only not in name:
            continue
        t0 = time.perf_counter()
        try:
            fn()
        except Exception:
            failures += 1
            print(f"{name},0,FAILED")
            traceback.print_exc()
        print(f"# suite {name} done in {time.perf_counter()-t0:.1f}s",
              flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
