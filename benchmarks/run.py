"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Roofline terms come from the
dry-run artifacts (benchmarks/roofline.py builds the table; run
``python -m repro.launch.dryrun --all`` first for that one).

``--quick`` runs a smoke pass (tiny model, one arch, reduced iterations)
through every suite whose ``run`` accepts a ``quick`` flag and skips the
rest — exercised by a tier-1 test so the benchmark drivers can't silently
rot.  ``--json PATH`` additionally writes every emitted row plus per-suite
wall-clocks to PATH as JSON; the convention across PRs is ``BENCH_<n>.json``
(n = PR number), so the perf trajectory stays machine-readable.
``--memo PATH`` loads a durable memo snapshot before the suites run and
saves the (grown) caches back afterwards — repeat runs replay the searches
they already paid for; a stale snapshot (different code) is ignored.
``python benchmarks/run.py [suite-substring] [--quick] [--json PATH]
[--memo PATH]``.
"""

from __future__ import annotations

import inspect
import json
import os
import sys
import time
import traceback

# direct `python benchmarks/run.py` bootstraps its own import roots (pytest
# gets the same paths from pytest.ini's `pythonpath = src .`)
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def main(argv=None) -> int:
    from benchmarks import (bench_dimo, bench_energy_validation, bench_exec,
                            bench_fig5_payload, bench_fig6_penalty,
                            bench_format_opt, bench_formats_feasibility,
                            bench_kernels, bench_multimodel, bench_serve,
                            bench_speed, common)
    suites = [
        ("fig5", bench_fig5_payload.run),
        ("fig6", bench_fig6_penalty.run),
        ("fig8/9", bench_energy_validation.run),
        ("fig10", bench_format_opt.run),
        ("fig11", bench_multimodel.run),
        ("tableI", bench_speed.run),
        ("dimo", bench_dimo.run),
        ("feasibility", bench_formats_feasibility.run),
        ("kernels", bench_kernels.run),
        ("exec", bench_exec.run),
        ("serve", bench_serve.run),
    ]
    argv = list(sys.argv[1:] if argv is None else argv)
    quick = "--quick" in argv
    argv = [a for a in argv if a != "--quick"]
    json_path = None
    if "--json" in argv:
        k = argv.index("--json")
        if k + 1 >= len(argv):
            print("error: --json requires a PATH", file=sys.stderr)
            return 1
        json_path = argv[k + 1]
        del argv[k:k + 2]
    memo_path = None
    if "--memo" in argv:
        k = argv.index("--memo")
        if k + 1 >= len(argv):
            print("error: --memo requires a PATH", file=sys.stderr)
            return 1
        memo_path = argv[k + 1]
        del argv[k:k + 2]
    only = argv[0] if argv else None
    if memo_path is not None:
        from repro.core import memo
        if os.path.exists(memo_path):
            loaded = memo.load(memo_path)
            print(f"# memo snapshot {memo_path}: "
                  f"{'loaded' if loaded else 'stale, ignored'}", flush=True)
    rows: list = []
    suite_s: dict[str, float] = {}
    if json_path is not None:
        common.set_collector(rows)
    print("name,us_per_call,derived")
    failures = 0
    try:
        for name, fn in suites:
            if only and only not in name:
                continue
            kwargs = {}
            if quick:
                if "quick" not in inspect.signature(fn).parameters:
                    print(f"# suite {name} skipped (no quick mode)",
                          flush=True)
                    continue
                kwargs["quick"] = True
            t0 = time.perf_counter()
            try:
                fn(**kwargs)
            except Exception:
                failures += 1
                common.emit(name, 0.0, "FAILED")   # mirrored into --json
                traceback.print_exc()
            suite_s[name] = time.perf_counter() - t0
            print(f"# suite {name} done in {suite_s[name]:.1f}s", flush=True)
        # whole-run cache telemetry (repro.obs sources): the memo and
        # kernel-cache counters accumulated ACROSS the suites that ran —
        # the `_run` suffix keeps these distinct from per-suite
        # `memo_stats_*` rows some suites emit themselves
        from repro.core import memo
        from repro.kernels import ops as kops
        for cname, st in sorted(memo.stats().items()):
            if st.lookups:
                common.emit(f"memo_stats_run_{cname}", 0.0,
                            f"hits={st.hits} misses={st.misses} "
                            f"hit_rate={st.hit_rate:.3f}")
        kc = kops.kernel_cache_stats()
        common.emit("kernel_cache_run", 0.0,
                    f"hits={kc['hits']} misses={kc['misses']} "
                    f"entries={kc['entries']}")
    finally:
        if json_path is not None:
            common.set_collector(None)
            with open(json_path, "w") as f:
                json.dump({"rows": rows, "suite_s": suite_s,
                           "quick": quick, "failures": failures},
                          f, indent=1)
            print(f"# wrote {len(rows)} rows to {json_path}", flush=True)
        if memo_path is not None:
            from repro.core import memo
            n = memo.save(memo_path)
            print(f"# memo snapshot {memo_path}: saved {n} entries",
                  flush=True)
    return failures


if __name__ == "__main__":
    raise SystemExit(1 if main() else 0)
