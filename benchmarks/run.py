"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Roofline terms come from the
dry-run artifacts (benchmarks/roofline.py builds the table; run
``python -m repro.launch.dryrun --all`` first for that one).

``--quick`` runs a smoke pass (tiny model, one arch, reduced iterations)
through every suite whose ``run`` accepts a ``quick`` flag and skips the
rest — exercised by a tier-1 test so the benchmark drivers can't silently
rot.  ``python benchmarks/run.py [suite-substring] [--quick]``.
"""

from __future__ import annotations

import inspect
import sys
import time
import traceback


def main(argv=None) -> int:
    from benchmarks import (bench_dimo, bench_energy_validation,
                            bench_fig5_payload, bench_fig6_penalty,
                            bench_format_opt, bench_formats_feasibility,
                            bench_kernels, bench_multimodel, bench_speed)
    suites = [
        ("fig5", bench_fig5_payload.run),
        ("fig6", bench_fig6_penalty.run),
        ("fig8/9", bench_energy_validation.run),
        ("fig10", bench_format_opt.run),
        ("fig11", bench_multimodel.run),
        ("tableI", bench_speed.run),
        ("dimo", bench_dimo.run),
        ("feasibility", bench_formats_feasibility.run),
        ("kernels", bench_kernels.run),
    ]
    argv = list(sys.argv[1:] if argv is None else argv)
    quick = "--quick" in argv
    argv = [a for a in argv if a != "--quick"]
    only = argv[0] if argv else None
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        if only and only not in name:
            continue
        kwargs = {}
        if quick:
            if "quick" not in inspect.signature(fn).parameters:
                print(f"# suite {name} skipped (no quick mode)", flush=True)
                continue
            kwargs["quick"] = True
        t0 = time.perf_counter()
        try:
            fn(**kwargs)
        except Exception:
            failures += 1
            print(f"{name},0,FAILED")
            traceback.print_exc()
        print(f"# suite {name} done in {time.perf_counter()-t0:.1f}s",
              flush=True)
    return failures


if __name__ == "__main__":
    raise SystemExit(1 if main() else 0)
