"""§IV-D DiMO-Sparse comparison: CNN workloads (conv-as-GEMM), preset
format, SnipSnap's progressive search vs an iterative mapping optimizer of
the DiMO kind (random-restart coordinate descent needing many model
evaluations).  Paper: 19.4× / 19.7× / 23.8× (AlexNet / VGG-16 / ResNet-18),
21.0× average.

The paper's ratio is about WORKFLOW cost — a DiMO-style tuner needs
thousands of model evaluations per op where the progressive search needs a
handful — so the machine-independent evaluation-count ratio is reported
alongside wall-clock.  The ``dimo_batch_*`` rows compare our own old-vs-new
DiMO implementation (seed per-draw scalar loop vs the batched replay, all
caches bypassed, designs asserted bit-identical): that ratio is pure
vectorization engineering and is what lets the full CNN sweep run at scale.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import memo
from repro.core.arch import ARCH3
from repro.core.baselines import dimo_like_search
from repro.core.cosearch import CoSearchConfig, cosearch
from repro.core.workload import MatMul, Workload, alexnet, resnet18, vgg16
from repro.core.sparsity import Bernoulli

CFG = CoSearchConfig(objective="edp", spatial_top=2)


def _fingerprint(res):
    return (res.design.energy, res.design.cycles, res.evaluations,
            tuple((str(o.mapping), str(o.fmt_i), str(o.fmt_w))
                  for o in res.design.ops))


def _tiny_cnn() -> Workload:
    return Workload("tinycnn", (
        MatMul("conv1", 64, 96, 64, Bernoulli(0.6), Bernoulli(0.35)),
        MatMul("conv2", 32, 128, 96, Bernoulli(0.6), Bernoulli(0.35)),
    ))


def run_batch_comparison(quick: bool = False) -> None:
    """Old-vs-new dimo_like_search: the seed scalar descent (one evaluate
    per draw) against the batched replay (one evaluate_batch per op + array
    indexing), caches bypassed for both, same seed — designs and eval
    counts bit-identical."""
    ratios = []
    workloads = (_tiny_cnn(),) if quick else (alexnet(), resnet18())
    iters = 200 if quick else 800
    for wl in workloads:
        with memo.disabled():
            old = dimo_like_search(wl, ARCH3, CFG, restarts=8, iters=iters,
                                   seed=0, use_batch=False)
            new = dimo_like_search(wl, ARCH3, CFG, restarts=8, iters=iters,
                                   seed=0, use_batch=True)
        assert _fingerprint(old) == _fingerprint(new), \
            "batched DiMO descent changed results"
        tr = old.runtime_s / max(new.runtime_s, 1e-9)
        ratios.append(tr)
        emit(f"dimo_batch_{wl.name}", new.runtime_s * 1e6,
             f"scalar/batch time={tr:.1f}x evals={new.evaluations}")
    emit("dimo_batch_avg", 0.0,
         f"batched descent speedup={np.mean(ratios):.1f}x (target >=5x)")


def run(quick: bool = False) -> None:
    run_batch_comparison(quick=quick)
    t_ratios, e_ratios = [], []
    workloads = (_tiny_cnn(),) if quick else (alexnet(), vgg16(), resnet18())
    iters = 400 if quick else 4000
    for wl in workloads:
        prog = cosearch(wl, ARCH3, CFG, fixed_formats=("Bitmap", "Bitmap"))
        # DiMO's differentiable-relaxation loop needs thousands of model
        # evaluations per op to converge (forward+backward per iterate)
        dimo = dimo_like_search(wl, ARCH3, CFG, restarts=16, iters=iters)
        tr = dimo.runtime_s / max(prog.runtime_s, 1e-9)
        er = dimo.evaluations / max(prog.evaluations, 1)
        q = dimo.design.edp / prog.design.edp
        t_ratios.append(tr)
        e_ratios.append(er)
        emit(f"dimo_{wl.name}", prog.runtime_s * 1e6,
             f"dimo/progressive time={tr:.1f}x evals={er:.1f}x "
             f"dimo_quality={q:.2f}x")
    emit("dimo_avg", 0.0,
         f"time={np.mean(t_ratios):.1f}x evals={np.mean(e_ratios):.1f}x "
         "(paper wall-clock vs DiMO-Sparse: 19.4-23.8x, avg 21.0x)")


if __name__ == "__main__":
    run()
