"""§IV-D DiMO-Sparse comparison: CNN workloads (conv-as-GEMM), preset
format, SnipSnap's progressive search vs an iterative mapping optimizer of
the DiMO kind (random-restart coordinate descent needing many model
evaluations).  Paper: 19.4× / 19.7× / 23.8× (AlexNet / VGG-16 / ResNet-18),
21.0× average."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.arch import ARCH3
from repro.core.baselines import dimo_like_search
from repro.core.cosearch import CoSearchConfig, cosearch
from repro.core.workload import alexnet, resnet18, vgg16

CFG = CoSearchConfig(objective="edp", spatial_top=2)


def run() -> None:
    ratios = []
    for wl in (alexnet(), vgg16(), resnet18()):
        prog = cosearch(wl, ARCH3, CFG, fixed_formats=("Bitmap", "Bitmap"))
        # DiMO's differentiable-relaxation loop needs thousands of model
        # evaluations per op to converge (forward+backward per iterate)
        dimo = dimo_like_search(wl, ARCH3, CFG, restarts=16, iters=4000)
        tr = dimo.runtime_s / max(prog.runtime_s, 1e-9)
        q = dimo.design.edp / prog.design.edp
        ratios.append(tr)
        emit(f"dimo_{wl.name}", prog.runtime_s * 1e6,
             f"dimo/progressive time={tr:.1f}x dimo_quality={q:.2f}x")
    emit("dimo_avg", 0.0,
         f"time={np.mean(ratios):.1f}x (paper: 19.4-23.8x, avg 21.0x)")


if __name__ == "__main__":
    run()
