"""Execution-plane benchmark: plan-driven compressed serving + calibration.

For each sparsity pattern: co-search an :class:`~repro.exec.plans.ExecPlan`
for the whole model, prune + compress the real weight pytree, run the
compressed forward through the Pallas kernels (interpret mode on CPU), and
report

  * ``exec_ratio_<pattern>``       — achieved compressed/dense stored bits
    (exact, from the realized store) next to the plan's predicted ratio,
    plus dense-vs-compressed forward wall-clock;
  * ``exec_calibration_<pattern>`` — the measured-vs-predicted fetch fit:
    DRAM energy-coefficient scale (distinct fetches), worst pre-fit error,
    worst post-fit residual, the PER-LEVEL half — the GLB scale fitted on
    the streaming pipeline's refetch residual (total streamed − distinct
    bits) with its own pre/post drift columns — and the re-searched
    predicted-energy drift.

The two patterns tell the calibration story from both ends: ``block50``
(block-clustered zeros, faithfully modeled by ``BlockBernoulli``) fits at
scale ≈ 1 with tight residuals; ``iid50`` (the same weights planned under
i.i.d. ``Bernoulli``) mispredicts what MXU-aligned blocks can realize and
needs a large corrective scale — exactly the drift the loop exists to
catch.  ``nm24`` exercises the N:M kernel path.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit


def _forward_s(fn, *args, repeat: int = 1) -> float:
    out = fn(*args)                       # warm (compile/trace)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeat):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / repeat


def run(quick: bool = False) -> None:
    import jax.numpy as jnp

    from repro import exec as rexec
    from repro.configs import get_config
    from repro.core.cosearch import CoSearchConfig
    from repro.core.engine import EngineConfig
    from repro.core.sparsity import NM, Bernoulli, BlockBernoulli
    from repro.models.transformer import Model

    cfg = get_config("chatglm3-6b").reduced()
    fast = CoSearchConfig(objective="edp",
                          engine=EngineConfig(max_levels=2,
                                              max_allocs_per_pattern=16),
                          spatial_top=2, max_pairs=6)
    b, s = (2, 16) if quick else (4, 64)
    patterns = {
        "block50": BlockBernoulli(0.5, 32 * 32),
        "nm24": NM(2, 4),
        "iid50": Bernoulli(0.5),
    }

    model = Model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)

    for name, sp in patterns.items():
        plan = rexec.build_exec_plan(cfg, sp, tokens=b * s, search_cfg=fast,
                                     value_bits=32)
        pruned = rexec.prune_params(params, plan, cfg)
        store = rexec.compress_params(pruned, plan, cfg)
        cm = rexec.CompressedModel(model, store)

        t_dense = _forward_s(
            lambda tk: model.hidden_states(pruned, tk, remat=False), tokens)
        t_comp = _forward_s(cm.hidden_states, pruned, tokens)
        with rexec.instrument() as counters:
            cm.hidden_states(pruned, tokens)

        kinds = sorted({op.choice.kind for op in plan.ops})
        pred_ratio = float(np.mean([op.choice.predicted_ratio
                                    for op in plan.ops]))
        emit(f"exec_ratio_{name}", t_comp * 1e6,
             f"stored/dense={store.achieved_ratio():.3f} "
             f"predicted={pred_ratio:.3f} kinds={'+'.join(kinds)} "
             f"dense_us={t_dense * 1e6:.0f} "
             f"fallbacks={len(plan.fallbacks())}")

        rep = rexec.calibrate(cfg, plan, counters, search_cfg=fast)
        emit(f"exec_calibration_{name}", 0.0,
             f"scale={rep.scale:.3f} pre_fit_err={rep.max_rel_err:.3f} "
             f"residual={rep.max_residual:.3f} "
             f"glb_scale={rep.glb_scale:.3f} "
             f"stream_err={rep.max_stream_rel_err:.3f} "
             f"refetch_residual={rep.max_refetch_residual:.3f} "
             f"energy_drift={rep.energy_drift:+.3f} "
             f"kinds_changed={len(rep.kinds_changed)}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
