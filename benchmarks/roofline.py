"""§Roofline: three-term roofline per (arch × shape) from dry-run artifacts.

  compute    = HLO_FLOPs_per_device / 197e12          (TPU v5e bf16 peak)
  memory     = HLO_bytes_per_device / 819e9           (HBM bandwidth)
  collective = collective_bytes_per_device / 50e9     (ICI per-link)

All inputs are PER-DEVICE post-SPMD numbers from the trip-count-aware HLO
analyzer (launch/hlo_cost.py), single-pod mesh.  MODEL_FLOPS uses 6·N·D for
training (N = params; active params for MoE), 2·N·D for prefill, 2·N·B for
one decode step (+ attention KV terms are part of HLO, not MODEL_FLOPS —
the ratio deliberately exposes attention/remat/dispatch overhead).

Usage: PYTHONPATH=src python -m benchmarks.roofline [artifacts/dryrun]
Writes artifacts/roofline.json + prints the markdown table.
"""

from __future__ import annotations

import json
import os
import sys

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,          # one token × batch
    "long_500k": 1,
}
TRAIN_MULT = {"train_4k": 6.0, "prefill_32k": 2.0,
              "decode_32k": 2.0, "long_500k": 2.0}


def model_flops(rec: dict) -> float:
    n = rec["active_params"]
    return TRAIN_MULT[rec["shape"]] * n * SHAPE_TOKENS[rec["shape"]]


def advise(rec: dict, dom: str) -> str:
    shape, arch = rec["shape"], rec["arch"]
    if dom == "collective":
        return ("overlap/reshard: move DP all-reduce off the critical path "
                "or shrink TP traffic (wo/w_down reduce-scatter)")
    if dom == "memory":
        if "decode" in shape or "500k" in shape:
            return "KV/state cache traffic dominates: shrink dtype, shard S"
        return "activation traffic: bigger fused blocks / less remat refetch"
    ratio = rec.get("useful_ratio", 0)
    if ratio and ratio < 0.5:
        return "compute-bound but wasteful: cut remat recompute / attention overhead"
    return "compute-bound near useful peak: increase per-chip batch if HBM allows"


def build(art_dir: str) -> list[dict]:
    rows = []
    for fname in sorted(os.listdir(art_dir)):
        if not fname.endswith(".json"):
            continue
        with open(os.path.join(art_dir, fname)) as f:
            rec = json.load(f)
        if rec.get("mesh") != "single" or rec.get("skipped") \
                or not rec.get("ok") or rec.get("opts"):
            continue        # baseline table: single-pod, un-optimized cells
        t_c = rec["flops"] / PEAK_FLOPS
        t_m = rec["hlo_bytes"] / HBM_BW
        t_x = rec["coll_bytes"] / ICI_BW
        dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
                  key=lambda kv: kv[1])[0]
        mf = model_flops(rec)
        hlo_global = rec["flops"] * rec["devices"]
        useful = mf / hlo_global if hlo_global else 0.0
        # roofline fraction: useful model flops per device vs the time the
        # dominant term implies
        t_dom = max(t_c, t_m, t_x)
        frac = (mf / rec["devices"] / PEAK_FLOPS) / t_dom if t_dom else 0.0
        row = dict(arch=rec["arch"], shape=rec["shape"],
                   compute_s=t_c, memory_s=t_m, collective_s=t_x,
                   dominant=dom, model_flops=mf, hlo_flops_global=hlo_global,
                   useful_ratio=useful, roofline_frac=frac)
        row["advice"] = advise({**rec, **row}, dom)
        rows.append(row)
    return rows


def main() -> None:
    art_dir = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        os.path.dirname(__file__), "..", "artifacts", "dryrun")
    rows = build(art_dir)
    out = os.path.join(os.path.dirname(art_dir), "roofline.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print("| arch | shape | compute s | memory s | collective s | dominant "
          "| MODEL/HLO | roofline frac | next lever |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
              f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
              f"{r['dominant']} | {r['useful_ratio']:.2f} | "
              f"{r['roofline_frac']:.2f} | {r['advice']} |")


if __name__ == "__main__":
    main()
