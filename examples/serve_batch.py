"""Batched serving of a reduced MoE model: one-pass batched prefill +
KV-cache greedy decode, dense AND compressed (the exec plane's
CompressedModel.generate drives the same launch.serve.generate path), with
per-phase throughput.

  PYTHONPATH=src python examples/serve_batch.py
"""

import subprocess
import sys


def main() -> None:
    base = [sys.executable, "-m", "repro.launch.serve",
            "--arch", "granite-moe-3b-a800m", "--reduced",
            "--batch", "4", "--prompt-len", "32", "--gen", "16"]
    for cmd in (base, base + ["--compressed"]):
        print("+", " ".join(cmd))
        rc = subprocess.call(cmd)
        if rc:
            raise SystemExit(rc)


if __name__ == "__main__":
    main()
