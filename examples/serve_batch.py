"""Batched serving of a reduced MoE model: prompt ingestion + greedy decode
with KV caches, throughput reported per phase.

  PYTHONPATH=src python examples/serve_batch.py
"""

import subprocess
import sys


def main() -> None:
    cmd = [sys.executable, "-m", "repro.launch.serve",
           "--arch", "granite-moe-3b-a800m", "--reduced",
           "--batch", "4", "--prompt-len", "32", "--gen", "16"]
    print("+", " ".join(cmd))
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
