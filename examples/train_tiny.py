"""End-to-end training: a reduced deepseek-family model for a few hundred
steps on CPU, with checkpoints, resume, and fault-tolerant stepping.

  PYTHONPATH=src python examples/train_tiny.py
"""

import subprocess
import sys


def main() -> None:
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "deepseek-coder-33b", "--reduced",
           "--steps", "200", "--batch", "8", "--seq", "128",
           "--ckpt-dir", "/tmp/repro_train_tiny", "--ckpt-every", "100",
           "--log-every", "20", "--resume"]
    print("+", " ".join(cmd))
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
