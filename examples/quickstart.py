"""Quickstart: run SnipSnap's joint format+dataflow co-search on a sparse
OPT-125M and print the chosen design.

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.arch import ARCH3
from repro.core.cosearch import CoSearchConfig, cosearch
from repro.core.engine import EngineConfig
from repro.core.formats import STANDARD_BASELINES
from repro.core.workload import OPT_125M, build_llm


def main() -> None:
    # OPT-125M, 256-token prefill + 32-token decode, ReLU-sparse FFN acts
    # + SparseLLM-grade pruned weights
    wl = build_llm(OPT_125M, seq=256, decode_tokens=32,
                   act_density=0.35, w_density=0.15, fc2_act_density=0.05)

    cfg = CoSearchConfig(objective="edp",
                         engine=EngineConfig(max_levels=3),
                         max_pairs=10)
    print(f"[snipsnap] co-searching {wl.name}: {len(wl.ops)} ops on {ARCH3.name}")
    res = cosearch(wl, ARCH3, cfg)
    d = res.design
    print(f"  explored {res.evaluations} design points in {res.runtime_s:.2f}s")
    print(f"  activation format: {d.pattern_i}")
    print(f"  weight     format: {d.pattern_w}")
    print(f"  energy={d.energy:.3e}  cycles={d.cycles:.3e}  EDP={d.edp:.3e}")
    print("  per-op dataflows:")
    for od in d.ops[:4]:
        print(f"    {od.op.name:14s} {od.mapping}")

    # compare against the four fixed baselines
    print("  baselines (memory energy, normalized to SnipSnap):")
    for fmt in STANDARD_BASELINES:
        r = cosearch(wl, ARCH3, cfg, fixed_formats=(fmt, fmt))
        print(f"    {fmt:7s} {r.design.memory_energy / d.memory_energy:.3f}x")


if __name__ == "__main__":
    main()
