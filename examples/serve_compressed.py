"""Plan-driven compressed serving, end to end:

  1. co-search an ExecPlan for EVERY projection of a real model config
     (attention QKV/O + FFN) against the TPUv5e hardware model;
  2. save the plan to JSON and load it back (search once, serve many);
  3. prune + compress the model's weight pytree into the plan's formats;
  4. run the compressed forward through the Pallas kernels (interpret mode
     on CPU) and check it against the dense forward on the same weights;
  5. close the loop: compare measured fetched-bits counters against the
     cost model's predictions, fit the energy coefficient, and report the
     re-searched prediction drift;
  6. SERVE: batched prefill + KV-cache greedy decode through
     CompressedModel.generate (the same launch.serve.generate driver the
     dense model uses), checked token-for-token against dense decode.

  PYTHONPATH=src python examples/serve_compressed.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import exec as rexec
from repro.configs import get_config
from repro.core.cosearch import CoSearchConfig
from repro.core.engine import EngineConfig
from repro.core.sparsity import BlockBernoulli
from repro.models.transformer import Model


def main() -> None:
    cfg = get_config("chatglm3-6b").reduced()
    fast = CoSearchConfig(objective="edp",
                          engine=EngineConfig(max_levels=2,
                                              max_allocs_per_pattern=16),
                          spatial_top=2, max_pairs=6)

    # ---- 1. search: whole-model plan -------------------------------------
    sparsity = BlockBernoulli(0.5, 32 * 32)     # 50% of weight blocks pruned
    plan = rexec.build_exec_plan(cfg, sparsity, tokens=64, search_cfg=fast,
                                 value_bits=32)
    for op in plan.ops:
        fb = f" fallback={op.choice.fallback.code}" if op.choice.fallback \
            else ""
        print(f"[plan] {op.role:<12} kernel={op.choice.kind:<6} "
              f"block=({op.choice.block_n},{op.choice.block_k}) "
              f"ratio={op.choice.predicted_ratio:.3f}{fb}")

    # ---- 2. JSON round trip ----------------------------------------------
    plan2 = rexec.ExecPlan.from_json(plan.to_json())
    assert plan2 == plan
    print(f"[plan] JSON round-trip OK ({len(plan.to_json())} bytes)")

    # ---- 3. prune + compress the real weights ----------------------------
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    pruned = rexec.prune_params(params, plan, cfg)
    store = rexec.compress_params(pruned, plan, cfg)
    print(f"[compress] {len(store)} tensors, achieved ratios: "
          f"{ {k: round(v, 3) for k, v in store.ratio_report().items()} }")

    # ---- 4. compressed forward vs dense ----------------------------------
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)
    dense_out = model.hidden_states(pruned, tokens, remat=False)
    cm = rexec.CompressedModel(model, store)
    with rexec.instrument() as counters:
        comp_out = cm.hidden_states(pruned, tokens)
    err = float(jnp.max(jnp.abs(comp_out.astype(jnp.float32)
                                - dense_out.astype(jnp.float32))))
    print(f"[exec] compressed forward max_err={err:.2e} "
          f"({sum(c.calls for c in counters.values())} dispatched matmuls)")

    # ---- 5. calibrate: measured vs predicted -----------------------------
    report = rexec.calibrate(cfg, plan, counters, search_cfg=fast)
    print(f"[calibrate] energy-coefficient scale={report.scale:.3f} "
          f"pre-fit err={report.max_rel_err:.3f} "
          f"post-fit residual={report.max_residual:.3f}")
    print(f"[calibrate] predicted-energy drift after re-search: "
          f"{report.energy_drift:+.3f} "
          f"(kernel kinds changed: {report.kinds_changed or 'none'})")

    # ---- 6. serve: batched prefill + greedy decode -----------------------
    from repro.launch import serve
    prompts = tokens                           # reuse the (2, 16) batch
    gen = 8
    toks_c, t_pref, t_gen = cm.generate(pruned, prompts, gen)
    toks_d, _, _ = serve.generate(model, pruned, prompts, gen,
                                  prompts.shape[1] + gen)
    match = bool(jnp.all(toks_c == toks_d))
    b, plen = prompts.shape
    print(f"[serve] prefill {b * plen} tok in {t_pref:.2f}s "
          f"({b * plen / t_pref:.0f} tok/s); decode {b * gen} tok in "
          f"{t_gen:.2f}s ({b * gen / t_gen:.0f} tok/s)")
    print(f"[serve] compressed tokens match dense decode: {match}")
    print(f"[serve] sample: {np.asarray(toks_c[0])}")


if __name__ == "__main__":
    main()
