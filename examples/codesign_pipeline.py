"""The full codesign loop, end to end:

  1. take a dense FFN weight from a real architecture config;
  2. prune it (block-sparse for the bitmap path, 2:4 for the N:M path);
  3. run SnipSnap's DSE against the TPUv5e hardware model to pick the
     compression format + block shape;
  4. compress the weights into that format;
  5. execute the matmul through the matching Pallas kernel (interpret mode
     on CPU) and check it against the dense reference.

  PYTHONPATH=src python examples/codesign_pipeline.py
"""

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.codesign import plan_for_model
from repro.core.cosearch import CoSearchConfig
from repro.core.engine import EngineConfig
from repro.core.sparsity import NM, Bernoulli
from repro.kernels import ops
from repro.sparse import masks


def main() -> None:
    cfg = get_config("deepseek-coder-33b").reduced()
    rng = np.random.default_rng(0)
    d, f = cfg.d_model, cfg.d_ff
    w = jnp.asarray(rng.normal(size=(d, f)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(32, d)).astype(np.float32))

    # ---- path A: unstructured→block sparsity + bitmap kernel -------------
    density = 0.2
    plan = plan_for_model(cfg, Bernoulli(density), tokens=256,
                          search_cfg=CoSearchConfig(
                              engine=EngineConfig(max_levels=3,
                                                  max_allocs_per_pattern=24),
                              spatial_top=2, max_pairs=8))
    ch = plan.for_op("ffn.up")
    print(f"[plan] ffn.up → kernel={ch.kind} block=({ch.block_n},{ch.block_k})"
          f" predicted_ratio={ch.predicted_ratio:.3f}")
    print(f"       format: {ch.format_str}")
    if ch.kind == "bitmap":
        bn = max(8, min(ch.block_n, 32))
        bk = max(8, min(ch.block_k, 32))
        wb = masks.block_prune(w, bn, bk, density)
        comp = ops.compress_bitmap(np.asarray(wb), bn, bk)
        y = ops.bitmap_spmm(x, comp, bm=32)
        ref = jnp.dot(x, wb)
        err = float(jnp.max(jnp.abs(y - ref)))
        print(f"[exec] bitmap_spmm blocks={comp.blocks.shape} "
              f"traffic_ratio={comp.compression_ratio:.3f} max_err={err:.2e}")

    # ---- path B: 2:4 structured + N:M kernel ------------------------------
    plan24 = plan_for_model(cfg, NM(2, 4), tokens=256,
                            search_cfg=CoSearchConfig(
                                engine=EngineConfig(max_levels=2,
                                                    max_allocs_per_pattern=8),
                                spatial_top=2, max_pairs=4))
    ch24 = plan24.for_op("ffn.up")
    print(f"[plan] 2:4 → kernel={ch24.kind} ratio={ch24.predicted_ratio:.3f}")
    w24 = masks.nm_prune(w)
    comp24 = ops.compress_nm(np.asarray(w24))
    y24 = ops.nm_spmm(x, comp24, bm=32, bn=min(128, d), bk=min(128, f))
    err24 = float(jnp.max(jnp.abs(y24 - jnp.dot(x, w24))))
    print(f"[exec] nm_spmm values={comp24.values.shape} "
          f"traffic_ratio={comp24.compression_ratio:.3f} max_err={err24:.2e}")


if __name__ == "__main__":
    main()
