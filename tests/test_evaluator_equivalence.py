"""Property-based equivalence suite for the evaluator planes (PR 4).

Every search plane now scores through one shared cost core, and the whole
PR stack rests on the planes being interchangeable: scalar
:func:`~repro.core.costmodel.evaluate` is a batch of one,
:func:`~repro.core.costmodel.evaluate_batch` materializes rows,
:func:`~repro.core.costmodel.evaluate_batch_gather` gathers (mapping,
format row) index triples over a packed table + fetch tables, and the
``_evaluate_terms`` tail optionally chunks across threads
(``eval_threads``).  These properties pin the contract on RANDOM op
shapes, sparsity levels, and format allocations: all paths are
BIT-identical — every metric and every breakdown term, not just energy.

Runs under real ``hypothesis`` when installed, else the fixed-seed
fallback in ``tests/_hypothesis_compat.py``.
"""

import dataclasses

import numpy as np
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.core import memo
from repro.core.arch import ARCH2, ARCH3
from repro.core.cosearch import CoSearchConfig, cosearch
from repro.core.costmodel import (BatchCost, compile_format, evaluate,
                                  evaluate_batch, evaluate_batch_gather,
                                  format_fetch_table, mapping_ctx,
                                  pack_mappings, resolve_eval_threads)
from repro.core.dataflow import enumerate_mappings
from repro.core.engine import EngineConfig
from repro.core.formats import allocate, enumerate_patterns, standard_formats
from repro.core.sparsity import Bernoulli, TensorSpec
from repro.core.workload import LLMSpec, MatMul, build_llm

_ARCHS = (ARCH2, ARCH3)
_FIELDS = ("energy", "cycles", "edp", "utilization", "dram_bits",
           "e_dram", "e_glb", "e_decode", "dram_cycles", "compute_cycles")


def _assert_batch_equal(a: BatchCost, b: BatchCost) -> None:
    assert len(a) == len(b)
    for f in _FIELDS:
        assert np.array_equal(getattr(a, f), getattr(b, f)), f
    assert a.e_rf == b.e_rf and a.e_mac == b.e_mac


def _format_pool(spec: TensorSpec) -> list:
    """Dense + every named standard format + a spread of allocated 1–2
    level patterns, compiled on ``spec`` — the population random format
    assignments draw from."""
    pool = [compile_format(None, spec)]
    pool += [compile_format(f, spec)
             for f in standard_formats(spec.dims).values()]
    for pat in list(enumerate_patterns(list(spec.dims), max_levels=2))[:8]:
        pool += [compile_format(f, spec)
                 for f in allocate(pat, spec.dims, max_allocs=2)]
    return pool


def _random_case(m, n, k, rho_i, rho_w, sparse_o, arch_idx, seed):
    """One random (op, arch, mappings, format pools, cf_o, assignments)
    evaluation case; returns None when the mapping space is empty."""
    op = MatMul("prop", m, n, k, Bernoulli(rho_i), Bernoulli(rho_w),
                sp_o=Bernoulli(0.5) if sparse_o else Bernoulli(1.0))
    arch = _ARCHS[arch_idx]
    mappings = list(enumerate_mappings(op, arch, spatial_top=2))[:48]
    if not mappings:
        return None
    spec_i = TensorSpec(op.i_dims(), op.sp_i, op.value_bits)
    spec_w = TensorSpec(op.w_dims(), op.sp_w, op.value_bits)
    pool_i = _format_pool(spec_i)
    pool_w = _format_pool(spec_w)
    cf_o = None
    if sparse_o:
        spec_o = TensorSpec(op.o_dims(), op.sp_o, op.value_bits)
        cf_o = compile_format(standard_formats(spec_o.dims)["Bitmap"],
                              spec_o)
    rng = np.random.default_rng(seed)
    i_sel = rng.integers(0, len(pool_i), len(mappings))
    w_sel = rng.integers(0, len(pool_w), len(mappings))
    return op, arch, mappings, pool_i, pool_w, cf_o, i_sel, w_sel, rng


@settings(max_examples=8, deadline=None)
@given(m=st.sampled_from([16, 32, 48, 64, 96]),
       n=st.sampled_from([16, 32, 64, 128]),
       k=st.sampled_from([16, 32, 64, 96]),
       rho_i=st.floats(0.05, 0.95), rho_w=st.floats(0.05, 0.95),
       sparse_o=st.booleans(), arch_idx=st.integers(0, 1),
       seed=st.integers(0, 2**31 - 1))
def test_scalar_vs_batch_all_metrics(m, n, k, rho_i, rho_w, sparse_o,
                                     arch_idx, seed):
    """∀ rows: ``evaluate_batch.report(j)`` == scalar ``evaluate`` of row
    ``j`` — the whole CostReport (energy/cycles/edp/utilization/dram_bits
    AND the breakdown dict), exactly."""
    case = _random_case(m, n, k, rho_i, rho_w, sparse_o, arch_idx, seed)
    if case is None:
        return
    op, arch, mappings, pool_i, pool_w, cf_o, i_sel, w_sel, _ = case
    cf_pairs = [(pool_i[a], pool_w[b]) for a, b in zip(i_sel, w_sel)]
    bc = evaluate_batch(op, arch, mappings, cf_pairs, cf_o)
    for j, (mapping, (cf_i, cf_w)) in enumerate(zip(mappings, cf_pairs)):
        assert bc.report(j) == evaluate(op, arch, mapping, cf_i, cf_w, cf_o)


@settings(max_examples=8, deadline=None)
@given(m=st.sampled_from([16, 32, 48, 64, 96]),
       n=st.sampled_from([16, 32, 64, 128]),
       k=st.sampled_from([16, 32, 64, 96]),
       rho_i=st.floats(0.05, 0.95), rho_w=st.floats(0.05, 0.95),
       sparse_o=st.booleans(), arch_idx=st.integers(0, 1),
       seed=st.integers(0, 2**31 - 1))
def test_batch_vs_gather_bit_identical(m, n, k, rho_i, rho_w, sparse_o,
                                       arch_idx, seed):
    """``evaluate_batch_gather`` over random (mapping, I-format, W-format)
    index triples == ``evaluate_batch`` on the materialized rows — every
    metric array, bit for bit."""
    case = _random_case(m, n, k, rho_i, rho_w, sparse_o, arch_idx, seed)
    if case is None:
        return
    op, arch, mappings, pool_i, pool_w, cf_o, _, _, rng = case
    rows = 3 * len(mappings)
    map_idx = rng.integers(0, len(mappings), rows)
    i_idx = rng.integers(0, len(pool_i), rows)
    w_idx = rng.integers(0, len(pool_w), rows)
    want = evaluate_batch(op, arch, [mappings[x] for x in map_idx],
                          [(pool_i[a], pool_w[b])
                           for a, b in zip(i_idx, w_idx)], cf_o)
    table = pack_mappings(mappings)
    got = evaluate_batch_gather(op, arch, table,
                                format_fetch_table(pool_i, table), i_idx,
                                format_fetch_table(pool_w, table), w_idx,
                                map_idx, cf_o)
    _assert_batch_equal(want, got)
    # a precomputed ctx (the sweep/co-search reuse path) changes nothing
    ctx = mapping_ctx(op, arch, table, cf_o)
    got_ctx = evaluate_batch_gather(op, arch, table,
                                    format_fetch_table(pool_i, table),
                                    i_idx,
                                    format_fetch_table(pool_w, table),
                                    w_idx, map_idx, cf_o, ctx=ctx)
    _assert_batch_equal(want, got_ctx)


@settings(max_examples=6, deadline=None)
@given(m=st.sampled_from([32, 64, 96]), n=st.sampled_from([32, 64, 128]),
       k=st.sampled_from([32, 64]),
       rho_i=st.floats(0.05, 0.95), rho_w=st.floats(0.05, 0.95),
       threads=st.integers(2, 7), arch_idx=st.integers(0, 1),
       seed=st.integers(0, 2**31 - 1))
def test_eval_threads_bit_identical(m, n, k, rho_i, rho_w, threads,
                                    arch_idx, seed):
    """``eval_threads=1`` vs ``eval_threads=N`` (and auto): the chunked
    ``_evaluate_terms`` tail concatenates to the identical arrays — the
    tail is elementwise per candidate row, so any chunking is exact."""
    case = _random_case(m, n, k, rho_i, rho_w, False, arch_idx, seed)
    if case is None:
        return
    op, arch, mappings, pool_i, pool_w, cf_o, _, _, rng = case
    # enough rows that every thread gets several chunks' worth of work
    rows = 50 * len(mappings)
    map_idx = rng.integers(0, len(mappings), rows)
    i_idx = rng.integers(0, len(pool_i), rows)
    w_idx = rng.integers(0, len(pool_w), rows)
    table = pack_mappings(mappings)
    ft_i = format_fetch_table(pool_i, table)
    ft_w = format_fetch_table(pool_w, table)
    serial = evaluate_batch_gather(op, arch, table, ft_i, i_idx, ft_w,
                                   w_idx, map_idx, cf_o, eval_threads=1)
    for t in (threads, None):
        chunked = evaluate_batch_gather(op, arch, table, ft_i, i_idx,
                                        ft_w, w_idx, map_idx, cf_o,
                                        eval_threads=t)
        _assert_batch_equal(serial, chunked)


def test_resolve_eval_threads_policy():
    """Explicit counts win (floored at 1); auto stays serial below the
    chunk threshold so small batches never pay pool overhead."""
    assert resolve_eval_threads(4, 10) == 4
    assert resolve_eval_threads(0, 10) == 1
    assert resolve_eval_threads(None, 100) == 1
    assert resolve_eval_threads(None, 10_000_000) >= 1


def test_cosearch_planes_bit_identical():
    """End-to-end on the co-search driver: the seed scalar loop
    (use_batch=False), the PR-3 repack plane (use_gather=False), the
    gather plane, and the gather plane with a forced thread count all
    produce the identical design, metric, and evaluation count."""
    fast = CoSearchConfig(engine=EngineConfig(max_levels=2,
                                              max_allocs_per_pattern=16),
                          spatial_top=2, max_pairs=6)
    wl = build_llm(LLMSpec("eq-test", 1, 128, 256, 4), seq=64,
                   act_density=0.4, w_density=0.25)

    def fingerprint(res):
        return (res.design.pattern_i, res.design.pattern_w,
                res.design.energy, res.design.cycles, res.evaluations,
                tuple((str(o.mapping), str(o.fmt_i), str(o.fmt_w))
                      for o in res.design.ops))

    with memo.disabled():
        fps = [fingerprint(cosearch(wl, ARCH3, cfg)) for cfg in (
            dataclasses.replace(fast, use_batch=False),
            dataclasses.replace(fast, use_gather=False),
            fast,
            dataclasses.replace(fast, eval_threads=3),
        )]
    assert fps[0] == fps[1] == fps[2] == fps[3]
