"""Optimization flags must preserve semantics (hillclimb changes are
perf-only): decode equivalence under gqagroup/maskedkv, padheads smoke,
sparse FFN path, HLO cost analyzer sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import optflags
from repro.models.transformer import Model


def _decode_logits(cfg, flags, steps=6):
    model = Model(cfg)
    with optflags.optimizations(flags):
        params = model.init(jax.random.key(0))
        cache = model.init_cache(2, 16)
        toks = jnp.array([3, 5], jnp.int32)
        outs = []
        for t in range(steps):
            lg, cache = model.decode_step(params, cache, toks + t,
                                          jnp.asarray(t, jnp.int32))
            outs.append(lg)
    return jnp.stack(outs)


@pytest.mark.parametrize("flag", ["gqagroup", "maskedkv"])
def test_decode_flags_preserve_logits(flag):
    cfg = get_config("deepseek-coder-33b").reduced()
    base = _decode_logits(cfg, ())
    opt = _decode_logits(cfg, (flag,))
    np.testing.assert_allclose(np.asarray(base), np.asarray(opt),
                               rtol=2e-2, atol=2e-2)


def test_padheads_trains_and_rounds_heads():
    from repro.models.layers import eff_heads
    with optflags.optimizations(("padheads",)):
        assert eff_heads(56) == 64 and eff_heads(32) == 32
        cfg = get_config("whisper-tiny").reduced()
        model = Model(cfg)
        params = model.init(jax.random.key(0))
        batch = {
            "tokens": jnp.zeros((2, 8), jnp.int32),
            "labels": jnp.zeros((2, 8), jnp.int32),
            "enc_frames": jnp.zeros((2, cfg.enc_seq, cfg.d_model)),
        }
        loss = model.loss(params, batch)
        assert jnp.isfinite(loss)
    assert eff_heads(56) == 56          # flag off outside the context


def test_sparseffn_decode_runs():
    cfg = get_config("deepseek-coder-33b").reduced()
    with optflags.optimizations(("sparseffn",)):
        model = Model(cfg)
        params = model.init(jax.random.key(1))
        assert "payload_gate" in jax.tree.leaves(
            {"k": list(params["blocks"]["ffn"].keys())})[0] or \
            "payload_gate" in params["blocks"]["ffn"]
        cache = model.init_cache(2, 8)
        lg, _ = model.decode_step(params, cache, jnp.array([1, 2], jnp.int32),
                                  jnp.asarray(0, jnp.int32))
        assert jnp.all(jnp.isfinite(lg))


def test_unknown_flag_rejected():
    with pytest.raises(ValueError):
        with optflags.optimizations(("nonsense",)):
            pass


def test_hlo_cost_trip_counts():
    """The analyzer must multiply while bodies by known trip counts."""
    from repro.launch import hlo_cost

    def scanned(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, None, length=8)
        return c

    x = jnp.zeros((64, 64))
    c = jax.jit(scanned).lower(x, x).compile()
    res = hlo_cost.analyze_compiled(c)
    assert res["flops"] == pytest.approx(8 * 2 * 64 ** 3, rel=0.01)
