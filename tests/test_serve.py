"""Serving-plane tests: scan-compiled compressed prefill / KV-cache decode.

Contracts pinned here:

  * dense ``Model.prefill`` fills the SAME cache the token-by-token decode
    ingest builds, and its logits match the full forward;
  * compressed ``decode_step`` logits match compressed ``prefill`` logits
    position by position (bitmap and N:M plans);
  * batch-of-N serving equals N stacked batch-of-1 runs;
  * ``CompressedModel.generate`` emits BIT-IDENTICAL tokens to the dense
    model's greedy decode at fp32 on an all-bitmap plan (the acceptance
    gate: compressed serving changes the numerics only by kernel
    accumulation order, which greedy argmax absorbs);
  * the scanned forward's instrument() counters equal the unrolled
    per-layer loop's (per-trace recording semantics);
  * the layer-stacked store pads bitmap payloads without changing exact
    accounting, and ``t_max`` keys the kernel jit cache.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import exec as rexec
from repro.configs import get_config
from repro.core.cosearch import CoSearchConfig
from repro.core.engine import EngineConfig
from repro.core.sparsity import NM, BlockBernoulli
from repro.exec.compress import stack_store
from repro.kernels import ops as kops
from repro.launch import serve
from repro.launch.mesh import make_serve_mesh
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models.transformer import Model

FAST = CoSearchConfig(objective="edp",
                      engine=EngineConfig(max_levels=2,
                                          max_allocs_per_pattern=16),
                      spatial_top=2, max_pairs=6)
BLOCK = BlockBernoulli(0.5, 32 * 32)


@pytest.fixture()
def fp32_compute(monkeypatch):
    monkeypatch.setattr(L, "COMPUTE_DTYPE", jnp.float32)
    monkeypatch.setattr(attn_mod, "COMPUTE_DTYPE", jnp.float32)


def _cfg():
    return get_config("chatglm3-6b").reduced()


def _serving(cfg, sp, seed=0):
    model = Model(cfg)
    params = model.init(jax.random.key(seed))
    plan = rexec.build_exec_plan(cfg, sp, tokens=64, search_cfg=FAST,
                                 value_bits=32)
    pruned = rexec.prune_params(params, plan, cfg)
    store = rexec.compress_params(pruned, plan, cfg)
    return model, plan, pruned, store


def _tokens(cfg, b=2, s=8, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)


# ---------------------------------------------------------------------------
# dense prefill
# ---------------------------------------------------------------------------

def test_prefill_matches_hidden_states_and_decode_ingest(fp32_compute):
    cfg = _cfg()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    toks = _tokens(cfg, b=2, s=8)
    max_len = 12

    logits, cache = model.prefill(params, toks, max_len)
    assert logits.shape == (2, 8, cfg.vocab)
    assert cache["self"]["k"].shape[2] == max_len

    # last-position logits == the full forward's logits head
    x = model.hidden_states(params, toks, remat=False)
    ref = jnp.einsum("btd,vd->btv", x,
                     params["embed"].astype(L.COMPUTE_DTYPE))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)

    # the cache equals the token-by-token decode ingest's
    cache2 = model.init_cache(2, max_len)
    lg = None
    for t in range(8):
        lg, cache2 = model.decode_step(params, cache2, toks[:, t],
                                       jnp.asarray(t, jnp.int32))
    np.testing.assert_allclose(np.asarray(cache["self"]["k"]),
                               np.asarray(cache2["self"]["k"]),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cache["self"]["v"]),
                               np.asarray(cache2["self"]["v"]),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(logits[:, -1]), np.asarray(lg),
                               rtol=1e-4, atol=1e-4)


def test_prefill_unsupported_families_fall_back():
    cfg = dataclasses.replace(_cfg(), window=16)   # ring cache → no prefill
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    toks = _tokens(cfg, b=1, s=4)
    with pytest.raises(NotImplementedError):
        model.prefill(params, toks, 8)
    # generate still serves via the exact token-by-token ingest
    out, t_pref, t_gen = serve.generate(model, params, toks, 3, 8)
    assert out.shape == (1, 3)


# ---------------------------------------------------------------------------
# compressed prefill / decode parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sp", [BLOCK, NM(2, 4)],
                         ids=["bitmap", "nm"])
def test_compressed_decode_matches_compressed_prefill(fp32_compute, sp):
    cfg = _cfg()
    model, plan, pruned, store = _serving(cfg, sp)
    cm = rexec.CompressedModel(model, store)
    toks = _tokens(cfg, b=2, s=8)
    max_len = 10

    logits, _ = cm.prefill(pruned, toks, max_len)
    cache = cm.init_cache(2, max_len)
    for t in range(8):
        lg, cache = cm.decode_step(pruned, cache, toks[:, t],
                                   jnp.asarray(t, jnp.int32))
        # decode_step at position t sees exactly prefill's prefix ≤ t
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(logits[:, t]),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"position {t}")


@pytest.mark.parametrize("sp", [BLOCK, NM(2, 4)],
                         ids=["bitmap", "nm"])
def test_batch_of_n_equals_stacked_batch_of_1(fp32_compute, sp):
    cfg = _cfg()
    model, plan, pruned, store = _serving(cfg, sp)
    cm = rexec.CompressedModel(model, store)
    prompts = _tokens(cfg, b=3, s=8)
    gen = 4

    batched, _, _ = cm.generate(pruned, prompts, gen)
    singles = [cm.generate(pruned, prompts[i:i + 1], gen)[0]
               for i in range(3)]
    stacked = jnp.concatenate(singles, axis=0)
    assert bool(jnp.all(batched == stacked)), (
        f"batched={np.asarray(batched)} singles={np.asarray(stacked)}")


def test_generate_bit_identical_dense_vs_compressed(fp32_compute):
    """Acceptance: greedy tokens from the compressed scan equal the dense
    model's, bit for bit, on an all-bitmap plan at fp32."""
    cfg = _cfg()
    model, plan, pruned, store = _serving(cfg, BLOCK)
    assert all(op.choice.kind == "bitmap" for op in plan.ops)
    cm = rexec.CompressedModel(model, store)
    prompts = _tokens(cfg, b=2, s=8)
    gen = 6

    toks_d, _, _ = serve.generate(model, pruned, prompts, gen, 8 + gen)
    toks_c, _, _ = cm.generate(pruned, prompts, gen)
    assert toks_c.shape == (2, gen)
    assert bool(jnp.all(toks_d == toks_c)), (
        f"dense={np.asarray(toks_d)} compressed={np.asarray(toks_c)}")


def test_serve_smoke_batched_decode():
    """Tiny end-to-end serve: batch 2, 4 decode steps, default dtypes,
    through the shared generate driver (mesh helper engaged when devices
    allow)."""
    cfg = _cfg()
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    prompts = _tokens(cfg, b=2, s=4, seed=3)
    mesh = make_serve_mesh(2)
    out, t_pref, t_gen = serve.generate(model, params, prompts, 4, 8,
                                        mesh=mesh)
    assert out.shape == (2, 4)
    assert out.dtype == jnp.int32
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab)))
    assert t_pref > 0 and t_gen > 0


# ---------------------------------------------------------------------------
# counters under scan
# ---------------------------------------------------------------------------

def test_instrument_scanned_matches_unrolled(fp32_compute):
    """Per-trace recording: ONE scanned forward records the same per-role
    totals as the unrolled per-layer loop — calibrate fits the same
    coefficients on either path."""
    cfg = _cfg()
    model, plan, pruned, store = _serving(cfg, BLOCK)
    cm = rexec.CompressedModel(model, store)
    toks = _tokens(cfg)

    with rexec.instrument() as scanned:
        cm.hidden_states(pruned, toks)
    with rexec.instrument() as unrolled:
        cm.hidden_states_unrolled(pruned, toks)

    assert set(scanned) == set(unrolled) == {op.role for op in plan.ops}
    for role in scanned:
        s, u = scanned[role], unrolled[role]
        assert s.calls == u.calls == cfg.n_layers
        assert s.w_fetch_bits == pytest.approx(u.w_fetch_bits)
        assert s.x_bits == pytest.approx(u.x_bits)
        assert s.y_bits == pytest.approx(u.y_bits)
        assert s.macs == pytest.approx(u.macs)
        assert s.decode_ops == pytest.approx(u.decode_ops)
        assert s.w_fetch_bits_per_call == pytest.approx(
            u.w_fetch_bits_per_call)


# ---------------------------------------------------------------------------
# stacked store
# ---------------------------------------------------------------------------

def test_stacked_store_padding_and_accounting():
    cfg = _cfg()
    model, plan, pruned, store = _serving(cfg, BLOCK)
    st = stack_store(store)
    assert st.n_layers == cfg.n_layers
    assert set(st.roles) == {op.role for op in plan.ops}
    extras = st.extras()
    for role, sr in st.roles.items():
        per_layer = [store.get(layer, role) for layer in range(cfg.n_layers)]
        assert sr.stored_bits == pytest.approx(
            sum(e.stored_bits for e in per_layer))
        assert sr.dense_bits == pytest.approx(
            sum(e.dense_bits for e in per_layer))
        if sr.kind == "bitmap":
            d = extras[role]
            # every stacked array leads with the layer axis
            assert all(a.shape[0] == cfg.n_layers for a in d.values())
            # padding never loses payload: the max layer fits exactly
            assert d["blocks"].shape[1] == max(
                max(int(e.data.blocks.shape[0]) for e in per_layer), 1)
            assert sr.padded_bits >= sr.stored_bits
            assert sr.t_max == max(e.data.max_per_col for e in per_layer)
    assert st.padding_overhead() >= 1.0


def test_tmax_keys_kernel_cache():
    """The stacked grid bound is part of the jitted-wrapper key: two
    dispatches differing only in t_max must not share a compiled kernel
    (one would run the wrong grid)."""
    kops.clear_kernel_cache()
    rng = np.random.default_rng(0)
    w = rng.normal(size=(64, 64)).astype(np.float32)
    comp = kops.compress_bitmap(w, 16, 16)
    x = jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32))
    y1 = kops.bitmap_spmm(x, comp, bm=16, t_max=comp.max_per_col)
    y2 = kops.bitmap_spmm(x, comp, bm=16, t_max=comp.max_per_col + 1)
    stats = kops.kernel_cache_stats()
    assert stats["misses"] == 2 and stats["entries"] == 2
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# mesh helper
# ---------------------------------------------------------------------------

def test_make_serve_mesh_degenerate_cases():
    ndev = len(jax.devices())
    if ndev == 1:
        assert make_serve_mesh(8) is None          # nothing to shard over
    assert make_serve_mesh(8, model=ndev + 1) is None
    mesh = make_serve_mesh(ndev)
    if ndev > 1:
        assert mesh is not None
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        assert sizes["data"] > 1 and ndev % sizes["data"] == 0
