"""Cost model + dataflow sanity tests: the model must reproduce the paper's
qualitative mechanisms before any search runs on top of it."""

import pytest

from repro.core import formats as F
from repro.core.arch import ARCH1, ARCH2, ARCH3, TPUV5E
from repro.core.costmodel import compile_format, dense_format, evaluate, memory_energy
from repro.core.dataflow import (Mapping, ORDERS, enumerate_mappings,
                                 irrelevant_refetch, spatial_candidates,
                                 tile_fits)
from repro.core.formats import Format, Level
from repro.core.primitives import Prim
from repro.core.sparsity import Bernoulli, TensorSpec
from repro.core.workload import MatMul


OP = MatMul("fc", M=256, N=512, K=256, sp_i=Bernoulli(0.5), sp_w=Bernoulli(0.3))


def _cf(op, fmt_i=None, fmt_w=None):
    spec_i = TensorSpec(op.i_dims(), op.sp_i)
    spec_w = TensorSpec(op.w_dims(), op.sp_w)
    cf_i = compile_format(fmt_i, spec_i) if fmt_i else dense_format(spec_i)
    cf_w = compile_format(fmt_w, spec_w) if fmt_w else dense_format(spec_w)
    return cf_i, cf_w


def _some_mapping(op, arch):
    return next(iter(enumerate_mappings(op, arch)))


def test_irrelevant_refetch_rule():
    bounds = {"M": 4, "N": 8, "K": 2}
    # I relevant to (M,N); with K innermost no refetch, K outermost → ×2.
    assert irrelevant_refetch(("M", "N", "K"), "I", bounds) == 1.0
    assert irrelevant_refetch(("K", "M", "N"), "I", bounds) == 2.0
    # O relevant to (M,K); N outer to K → refetch by N bound.
    assert irrelevant_refetch(("N", "M", "K"), "O", bounds) == 8.0
    assert irrelevant_refetch(("M", "K", "N"), "O", bounds) == 1.0


def test_compression_reduces_dram_energy():
    m = _some_mapping(OP, ARCH3)
    dense_i, dense_w = _cf(OP)
    comp_i, comp_w = _cf(OP, F.bitmap(OP.i_dims()), F.bitmap(OP.w_dims()))
    r_dense = evaluate(OP, ARCH3, m, dense_i, dense_w)
    r_comp = evaluate(OP, ARCH3, m, comp_i, comp_w)
    assert r_comp.breakdown["dram"] < r_dense.breakdown["dram"]


def test_skipping_beats_gating_on_cycles():
    m = _some_mapping(OP, ARCH1)
    cfs = _cf(OP, F.bitmap(OP.i_dims()), F.bitmap(OP.w_dims()))
    r_gate = evaluate(OP, ARCH1, m, *cfs)     # Arch1 = gating I→W
    r_skip = evaluate(OP, ARCH2, m, *cfs)     # Arch2 = skipping I→W
    assert r_skip.breakdown["compute_cycles"] < r_gate.breakdown["compute_cycles"]
    # gating still saves MAC energy
    dense = evaluate(OP, ARCH1, m, *_cf(OP))
    assert r_gate.breakdown["mac"] == pytest.approx(dense.breakdown["mac"])


def test_aligned_allocation_cheaper_than_oversized_blocks():
    """Efficiency-oriented allocating (§III-C2): level sizes matching the
    tile factors must not cost more than a mismatched allocation whose block
    exceeds the tile."""
    op = MatMul("p", M=64, N=96, K=64, sp_w=Bernoulli(0.2))
    spec_w = TensorSpec(op.w_dims(), op.sp_w)
    tile = {"M": 64, "N": 32, "K": 64}
    sp = {"M": 8, "N": 1, "K": 8}
    m = Mapping(spatial=sp, tile=tile, order=("M", "N", "K"))
    aligned = Format.of(Level(Prim.B, "N", 3), Level(Prim.NONE, "N", 32),
                        Level(Prim.NONE, "K", 64))
    oversized = Format.of(Level(Prim.B, "N", 2), Level(Prim.NONE, "N", 48),
                          Level(Prim.NONE, "K", 64))
    cf_i = dense_format(TensorSpec(op.i_dims(), op.sp_i))
    r_aligned = evaluate(op, ARCH3, m, cf_i, compile_format(aligned, spec_w))
    r_oversized = evaluate(op, ARCH3, m, cf_i, compile_format(oversized, spec_w))
    # blocks of 48 fetched into tiles of 32 over-fetch by 1.5×
    assert r_aligned.dram_bits < r_oversized.dram_bits


def test_rle_has_no_random_access():
    op = MatMul("p", M=64, N=64, K=64, sp_w=Bernoulli(0.2))
    spec_w = TensorSpec(op.w_dims(), op.sp_w)
    cf = compile_format(F.rle(op.w_dims()), spec_w)
    # fetching a half-row tile still decodes the whole K run-chain
    whole = cf.fetched_bits({"N": 64, "K": 64})
    half = cf.fetched_bits({"N": 64, "K": 32})
    assert half > whole / 2 * 1.5


def test_compression_aware_allocation_admits_larger_tiles():
    """§III-D2: compressed tile sizes make previously-illegal tilings legal."""
    op = MatMul("big", M=2048, N=2048, K=2048)
    tile = {"M": 512, "N": 1024, "K": 32}
    assert not tile_fits(op, tile, ARCH1, ratio_i=1.0, ratio_w=1.0)
    assert tile_fits(op, tile, ARCH1, ratio_i=0.1, ratio_w=0.1)


def test_spatial_candidates_respect_budget():
    for sp in spatial_candidates(OP, ARCH3):
        assert sp["M"] * sp["N"] * sp["K"] <= ARCH3.macs


def test_enumerate_mappings_nonempty_all_archs():
    for arch in (ARCH1, ARCH2, ARCH3, TPUV5E):
        assert _some_mapping(OP, arch) is not None


def test_memory_energy_components():
    m = _some_mapping(OP, ARCH3)
    r = evaluate(OP, ARCH3, m, *_cf(OP))
    # memory energy = hierarchy traffic (DRAM + GLB); RF is datapath-side
    assert memory_energy(r) == pytest.approx(
        r.breakdown["dram"] + r.breakdown["glb"])
    assert r.energy > memory_energy(r)
