"""Vectorized search-plane correctness (PR 2).

Pins the tentpole's contract: every batched hot path — allocation scoring
(``analyze_batch`` / ``analyze_batch_rows`` behind ``generate_candidates``),
mapping-derived allocation (``allocate_for_mappings``), the DiMO descent
replay, and the sharded ``cosearch_multi`` — is BIT-identical to the scalar
reference it replaces, counters included.  Plus ``memo.stats()`` counter
semantics and the ``SearchError`` failure mode.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import memo
from repro.core.arch import ARCH2, ARCH3
from repro.core.baselines import dimo_like_search
from repro.core.cosearch import (CoSearchConfig, SearchError, cosearch,
                                 cosearch_multi)
from repro.core.dataflow import Mapping, enumerate_mappings
from repro.core.engine import (EngineConfig, SearchStats,
                               allocate_for_mapping, allocate_for_mappings,
                               generate_candidates)
from repro.core.formats import Level, allocate, enumerate_patterns
from repro.core.primitives import Prim
from repro.core.sparsity import (NM, Bernoulli, TensorSpec, analyze,
                                 analyze_batch)
from repro.core.workload import LLMSpec, MatMul, Workload, alexnet, build_llm

FAST = CoSearchConfig(engine=EngineConfig(max_levels=2,
                                          max_allocs_per_pattern=16),
                      spatial_top=2, max_pairs=6)


def _design_fingerprint(res):
    return (res.design.pattern_i, res.design.pattern_w, res.design.energy,
            res.design.cycles, res.evaluations,
            tuple((str(o.mapping), str(o.fmt_i), str(o.fmt_w))
                  for o in res.design.ops))


# ---------------------------------------------------------------------------
# analyze_batch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sp", [Bernoulli(0.1), Bernoulli(0.5), NM(2, 4)])
def test_analyze_batch_bit_identical_to_scalar(sp):
    """∀ allocations of every 1–2-level pattern: one analyze_batch pass ==
    per-format analyze, exactly (payload/meta/decode/per-level)."""
    spec = TensorSpec({"M": 256, "N": 512}, sp)
    for pat in list(enumerate_patterns(["M", "N"], max_levels=2))[:40]:
        fmts = list(allocate(pat, spec.dims, max_allocs=24))
        if not fmts:
            continue
        br = analyze_batch(fmts, spec)
        assert len(br) == len(fmts)
        with memo.disabled():
            for i, f in enumerate(fmts):
                want = analyze(f, spec)
                got = br.report(i)
                assert got.payload_bits == want.payload_bits
                assert got.metadata_bits == want.metadata_bits
                assert got.decode_ops == want.decode_ops
                assert got.per_level == want.per_level


def test_analyze_batch_mixed_patterns():
    """Heterogeneous batches (formats from different patterns — exercises
    the mixed-column path) still match scalar analyze exactly."""
    spec = TensorSpec({"M": 256, "N": 512}, Bernoulli(0.3))
    fmts = []
    for pat in list(enumerate_patterns(["M", "N"], max_levels=2))[:24]:
        fmts.extend(allocate(pat, spec.dims, max_allocs=3))
    br = analyze_batch(fmts, spec)
    with memo.disabled():
        for i, f in enumerate(fmts):
            want = analyze(f, spec)
            got = br.report(i)
            assert (got.payload_bits, got.metadata_bits, got.decode_ops,
                    got.per_level) == (want.payload_bits, want.metadata_bits,
                                       want.decode_ops, want.per_level)


def test_analyze_batch_validates_and_rejects_bad_formats():
    spec = TensorSpec({"M": 8, "N": 8}, Bernoulli(0.5))
    bad = __import__("repro.core.formats", fromlist=["Format"]).Format(
        (Level(Prim.B, "M", 4), Level(Prim.NONE, "N", 8)))   # M covers 4 != 8
    with pytest.raises(ValueError):
        analyze_batch([bad], spec)


# ---------------------------------------------------------------------------
# generate_candidates: batched vs scalar scoring
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sp,penalize", [
    (Bernoulli(0.1), True), (Bernoulli(0.1), False),
    (NM(2, 4), True), (Bernoulli(0.75), True),
])
def test_generate_candidates_batch_matches_scalar(sp, penalize):
    """Same candidates, same EqData, same SearchStats counters (the
    early-exit pruning is replayed post hoc on the batched scores)."""
    spec = TensorSpec({"M": 512, "N": 1024}, sp)
    cfg = EngineConfig(max_levels=3, max_allocs_per_pattern=48)
    with memo.disabled():
        s_old, s_new = SearchStats(), SearchStats()
        old = generate_candidates(spec, cfg, penalize=penalize, stats=s_old,
                                  use_batch=False)
        new = generate_candidates(spec, cfg, penalize=penalize, stats=s_new,
                                  use_batch=True)
    assert [(str(c.fmt), c.eq_data, c.report) for c in old] == \
           [(str(c.fmt), c.eq_data, c.report) for c in new]
    assert (s_old.patterns_seen, s_old.allocations_seen,
            s_old.pruned_patterns) == \
           (s_new.patterns_seen, s_new.allocations_seen,
            s_new.pruned_patterns)


# ---------------------------------------------------------------------------
# allocate_for_mappings
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pattern,leaf", [
    ((Level(Prim.B, "M"), Level(Prim.B, "M")), None),
    ((Level(Prim.B, "N"), Level(Prim.CP, "M")), None),
    ((Level(Prim.B, "M"),), {"M": 4}),
    ((Level(Prim.UOP, "M"), Level(Prim.CP, "N")), None),
])
def test_allocate_for_mappings_matches_scalar(pattern, leaf):
    """Batched derivation over a real mapping set == per-mapping scalar
    derivation (including failures → None)."""
    op = MatMul("m", 128, 256, 64, Bernoulli(0.5), Bernoulli(0.3))
    dims = {"M": op.M, "N": op.N}
    mappings = list(enumerate_mappings(op, ARCH2, spatial_top=2))[:120]
    batch = allocate_for_mappings(pattern, dims, dims, mappings, leaf=leaf)
    assert len(batch) == len(mappings)
    got_some = False
    for mapping, got in zip(mappings, batch):
        want = allocate_for_mapping(pattern, dims, dims, mapping, leaf=leaf)
        if want is None:
            assert got is None
        else:
            assert got is not None and got.levels == want.levels
            got_some = True
    assert got_some, "degenerate test: no mapping produced an allocation"


def test_allocate_for_mappings_infeasible_dim_short_circuits():
    # 3 slots on a dim that cannot give three >1 factors → all None
    pattern = (Level(Prim.B, "M"), Level(Prim.B, "M"), Level(Prim.B, "M"))
    dims = {"M": 6, "N": 8}
    mapping = Mapping(spatial={"M": 1, "N": 1, "K": 1},
                      tile={"M": 6, "N": 8, "K": 1},
                      order=("M", "N", "K"))
    assert allocate_for_mappings(pattern, dims, dims, [mapping] * 3) == \
        [None, None, None]


# ---------------------------------------------------------------------------
# co-search: full legacy path (scalar engine + per-mapping derivation +
# scalar evaluator) vs the fully batched path
# ---------------------------------------------------------------------------

def test_cosearch_legacy_path_matches_batched():
    wl = build_llm(LLMSpec("vec-test", 1, 128, 256, 4), seq=64,
                   act_density=0.4, w_density=0.25)
    scalar_cfg = dataclasses.replace(FAST, use_batch=False)
    with memo.disabled():
        a = _design_fingerprint(cosearch(wl, ARCH3, scalar_cfg))
        b = _design_fingerprint(cosearch(wl, ARCH3, FAST))
    assert a == b


# ---------------------------------------------------------------------------
# DiMO descent replay
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 7])
def test_dimo_batched_descent_bit_identical(seed):
    """Same seed → same RNG stream → bit-identical design and eval count
    between the scalar walk and the batched replay."""
    wl = alexnet()
    with memo.disabled():
        old = dimo_like_search(wl, ARCH3, FAST, restarts=4, iters=80,
                               seed=seed, use_batch=False)
        new = dimo_like_search(wl, ARCH3, FAST, restarts=4, iters=80,
                               seed=seed, use_batch=True)
    assert _design_fingerprint(old) == _design_fingerprint(new)
    assert old.evaluations == new.evaluations == 4 * (1 + 80 // 4) * len(wl.ops)


# ---------------------------------------------------------------------------
# cosearch_multi: sharded work-list + per-model stats
# ---------------------------------------------------------------------------

def _two_tiny_workloads():
    wl_a = build_llm(LLMSpec("A", 2, 256, 1024, 4), seq=64,
                     act_density=0.2, w_density=0.2)
    wl_b = build_llm(LLMSpec("B", 2, 256, 1024, 4), seq=64,
                     act_density=0.8, w_density=0.8)
    return wl_a, wl_b


def test_cosearch_multi_workers_deterministic():
    wls = _two_tiny_workloads()
    imp = {"A": 99.0, "B": 1.0}
    memo.clear()
    d1, k1, v1 = cosearch_multi(list(wls), ARCH3, imp, FAST)
    memo.clear()
    d2, k2, v2 = cosearch_multi(list(wls), ARCH3, imp, FAST, workers=4)
    assert (k1, v1) == (k2, v2)
    assert set(d1) == set(d2)
    for name in d1:
        assert _design_fingerprint(d1[name])[:4] == \
            _design_fingerprint(d2[name])[:4]


def test_cosearch_multi_per_model_stats_not_aliased():
    """Each model's SearchResult reports its own candidate-generation
    counters (the seed handed ONE shared mutable SearchStats to every
    result)."""
    wls = _two_tiny_workloads()
    designs, _, _ = cosearch_multi(list(wls), ARCH3,
                                   {"A": 1.0, "B": 1.0}, FAST)
    sa, sb = designs["A"].stats, designs["B"].stats
    assert sa is not sb
    # each model generated candidates for both roles on its own counters
    assert sa.patterns_seen > 0 and sb.patterns_seen > 0
    # mutating one must not affect the other (true snapshot)
    sa.patterns_seen += 1000
    assert sb.patterns_seen < sa.patterns_seen


# ---------------------------------------------------------------------------
# SearchError
# ---------------------------------------------------------------------------

def _impossible_arch():
    tiny_glb = dataclasses.replace(ARCH3.levels[1], capacity_bits=8.0)
    return dataclasses.replace(
        ARCH3, name="tiny-glb",
        levels=(ARCH3.levels[0], tiny_glb, ARCH3.levels[2]))


def test_cosearch_raises_search_error_with_context():
    wl = Workload("doomed", (MatMul("big", 64, 64, 64,
                                    Bernoulli(0.5), Bernoulli(0.5)),))
    with pytest.raises(SearchError) as ei:
        cosearch(wl, _impossible_arch(), FAST,
                 fixed_formats=("Bitmap", "Bitmap"))
    assert ei.value.op == "big"
    assert "big" in str(ei.value)


def test_cosearch_multi_raises_search_error():
    wl = Workload("doomed", (MatMul("big", 64, 64, 64,
                                    Bernoulli(0.5), Bernoulli(0.5)),))
    with pytest.raises(SearchError) as ei:
        cosearch_multi([wl], _impossible_arch(), {"doomed": 1.0}, FAST)
    assert ei.value.op == "big"


# ---------------------------------------------------------------------------
# memo stats
# ---------------------------------------------------------------------------

def test_memo_stats_counts_hits_and_misses():
    cache = memo.register({}, "stats-test-cache")
    memo.reset_stats()
    memo.get_or(cache, "k", lambda: 1)          # miss
    memo.get_or(cache, "k", lambda: 1)          # hit
    memo.get_or(cache, None, lambda: 2)         # keyless: not counted
    with memo.disabled():
        memo.get_or(cache, "k", lambda: 3)      # disabled: not counted
    st = memo.stats()["stats-test-cache"]
    assert (st.hits, st.misses, st.lookups) == (1, 1, 2)
    assert st.hit_rate == 0.5
    # manual probes via note()
    memo.note(cache, True)
    memo.note(cache, False)
    assert (st.hits, st.misses) == (2, 2)
    # counters survive clear(), reset with reset_stats()
    memo.clear()
    assert memo.stats()["stats-test-cache"].lookups == 4
    memo.reset_stats()
    assert memo.stats()["stats-test-cache"].lookups == 0
    assert "stats-test-cache" in memo.stats_report(only_active=False)
