"""Observability-plane tests (PR 10 acceptance gates).

Contracts pinned here:

  * span begin/end nest strictly — a mismatched end RAISES instead of
    corrupting the stream — and with no tracer installed the module
    surface is a shared no-op (``span`` returns the same ``_NULL``
    object every call; ``trace_id`` is None);
  * :meth:`Tracer.chrome_trace` is valid Chrome trace-event JSON —
    ``B``/``E`` balanced, instants carry ``s``, ``X`` events carry
    ``dur``, every row JSON-serializable;
  * :meth:`Tracer.stable_trace` drops every timing field, keeps order
    and args, and excludes ``stable=False`` (timing-derived) events —
    two runs of the same seeded mixer stream (greedy AND sampled)
    produce byte-identical stable traces;
  * the metrics registry enforces its schema (a name is one type,
    counters only go up), histograms bucket with ``le`` semantics, the
    snapshot JSON round-trips, and the Prometheus text exposition
    parses with CUMULATIVE bucket series;
  * every ``ingest_*`` adapter reproduces its source of truth exactly
    (``instrument()`` OpCounters, HealthReport fields);
  * telemetry OFF leaves ``serve.generate`` results bit-identical, and
    telemetry ON does not change them either;
  * ``HealthReport``: ``stable_dict() | timings_dict() == to_dict()``,
    ``from_dict`` round-trips, ``trace_id`` links the report to its
    spans (``"t:<uid>"`` through the mixer, a tracer counter through
    the guarded driver) and stays None untraced;
  * a traced guarded run over a bit-flipped store emits the ``demote``
    event and the matching ``serve_verify_failures_total`` /
    ``serve_fallbacks_total`` counters;
  * :func:`kernel_timer` records sparse-kernel dispatches (trace-time)
    into both planes, as unstable ``X`` events.
"""

import json
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import exec as rexec
from repro.configs import get_config
from repro.core.cosearch import CoSearchConfig
from repro.core.engine import EngineConfig
from repro.core.sparsity import BlockBernoulli
from repro.launch import serve
from repro.launch.mixer import Mixer, Request
from repro.models.transformer import Model
from repro.obs import metrics as omet
from repro.obs import trace as otr
from repro.obs.profile import kernel_timer
from repro.runtime import inject
from repro.runtime.guard import HealthReport, guarded_generate

FAST = CoSearchConfig(objective="edp",
                      engine=EngineConfig(max_levels=2,
                                          max_allocs_per_pattern=16),
                      spatial_top=2, max_pairs=6)


def _cfg():
    return get_config("chatglm3-6b").reduced()


@pytest.fixture(scope="module")
def dense():
    cfg = _cfg()
    model = Model(cfg)
    return cfg, model, model.init(jax.random.key(0))


@pytest.fixture(scope="module")
def serving():
    """(cfg, model, plan, pruned, store) for an all-bitmap plan."""
    cfg = _cfg()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    plan = rexec.build_exec_plan(cfg, BlockBernoulli(0.5, 32 * 32),
                                 tokens=64, search_cfg=FAST, value_bits=32)
    pruned = rexec.prune_params(params, plan, cfg)
    store = rexec.compress_params(pruned, plan, cfg)
    return cfg, model, plan, pruned, store


def _stream(cfg, plens, max_new, seed=0, **kw):
    rng = np.random.default_rng(seed)
    return [Request(uid=f"r{i}",
                    prompt=jnp.asarray(
                        rng.integers(1, cfg.vocab, (p,)), jnp.int32),
                    max_new=max_new[i] if isinstance(max_new, list)
                    else max_new, **kw)
            for i, p in enumerate(plens)]


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------

def test_span_nesting_orders_events_and_mismatched_end_raises():
    tr = otr.Tracer()
    with otr.tracing(tr):
        with otr.span("outer", x=1):
            assert tr.depth == 1
            with otr.span("inner"):
                otr.event("mark", k=2)
                assert tr.depth == 2
        assert tr.depth == 0
    assert [e["ph"] for e in tr.events] == ["B", "B", "i", "E", "E"]
    assert [e["name"] for e in tr.events] == \
        ["outer", "inner", "mark", "inner", "outer"]
    assert tr.events[0]["args"] == {"x": 1}
    tr.begin("open")
    with pytest.raises(RuntimeError, match="does not match"):
        tr.end("outer")


def test_off_surface_is_a_shared_noop():
    assert otr.current_tracer() is None
    assert omet.current_metrics() is None
    s1, s2 = otr.span("a", x=1), otr.span("b")
    assert s1 is s2                           # the shared _NULL instance
    with s1:
        otr.event("nothing", v=3)
    assert otr.trace_id() is None and otr.trace_id("req9") is None
    # metrics module functions are silent no-ops too
    omet.counter_inc("c_total", 2.0)
    omet.gauge_set("g", 1.0)
    omet.observe("h_seconds", 0.5)


def test_chrome_trace_schema_valid():
    tr = otr.Tracer()
    with otr.tracing(tr):
        with otr.span("phase", batch=2):
            otr.event("mark", pos=3)
        tr.complete("kernel:bitmap", 0.001, {"kind": "bitmap"},
                    stable=False)
    doc = tr.chrome_trace()
    json.loads(json.dumps(doc))               # fully serializable
    assert doc["displayTimeUnit"] == "ms"
    rows = doc["traceEvents"]
    assert [r["ph"] for r in rows] == ["B", "i", "E", "X"]
    for r in rows:
        assert set(r) >= {"name", "ph", "ts", "pid", "tid"}
        assert r["ts"] >= 0.0
    assert sum(r["ph"] == "B" for r in rows) == \
        sum(r["ph"] == "E" for r in rows)
    assert rows[1]["s"] == "t"                # instants carry scope
    assert rows[3]["dur"] >= 0.0              # X events carry duration


def test_stable_trace_drops_timings_and_unstable_events(tmp_path):
    tr = otr.Tracer()
    with otr.tracing(tr):
        with otr.span("phase"):
            otr.event("kept", a=1)
            otr.event("spike", stable=False, dt_s=0.5)
    st = tr.stable_trace()
    assert [e["name"] for e in st] == ["phase", "kept", "phase"]
    assert all(set(e) == {"ph", "name", "args"} for e in st)
    chrome, stable = tmp_path / "t.json", tmp_path / "t.stable.json"
    tr.save_chrome(str(chrome))
    tr.save_stable(str(stable))
    assert json.loads(chrome.read_text())["traceEvents"]
    assert json.loads(stable.read_text()) == st


def test_trace_id_deterministic():
    tr = otr.Tracer()
    with otr.tracing(tr):
        assert otr.trace_id("req0") == "t:req0"
        assert otr.trace_id() == "t0001"
        assert otr.trace_id() == "t0002"


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_registry_schema_and_values():
    reg = omet.MetricsRegistry()
    reg.counter_inc("req_total", 1.0, kind="a")
    reg.counter_inc("req_total", 2.0, kind="b")
    reg.counter_inc("req_total", 1.0, kind="a")
    assert reg.value("req_total", kind="a") == 2.0
    assert reg.total("req_total") == 4.0
    assert len(reg.series("req_total")) == 2
    reg.gauge_set("occ", 3.0)
    reg.gauge_set("occ", 1.0)                 # gauges overwrite
    assert reg.value("occ") == 1.0
    with pytest.raises(ValueError, match="only go up"):
        reg.counter_inc("req_total", -1.0)
    with pytest.raises(ValueError, match="is a counter"):
        reg.gauge_set("req_total", 5.0)


def test_histogram_le_semantics_and_snapshot_roundtrip():
    reg = omet.MetricsRegistry()
    for v in (0.5, 1.0, 3.0):                 # 1.0 lands in the le=1 bucket
        reg.observe("lat_seconds", v, buckets=(1.0, 2.0))
    snap = reg.snapshot()
    h = snap["histograms"]["lat_seconds"]
    assert h["buckets"] == {"1.0": 2, "2.0": 0, "+Inf": 1}
    assert h["count"] == 3 and h["sum"] == pytest.approx(4.5)
    assert json.loads(reg.to_json()) == json.loads(
        json.dumps(snap, sort_keys=True))


def test_prometheus_exposition_parses_with_cumulative_buckets():
    reg = omet.MetricsRegistry()
    reg.counter_inc("req_total", 2.0, code="ok")
    reg.gauge_set("occ", 3.0)
    for v in (0.5, 1.0, 3.0):
        reg.observe("lat_seconds", v, buckets=(1.0, 2.0))
    text = reg.prometheus_text()
    sample = re.compile(
        r'^[a-zA-Z_:][a-zA-Z0-9_:]*'
        r'(\{[a-zA-Z_][a-zA-Z0-9_]*="[^"]*"'
        r'(,[a-zA-Z_][a-zA-Z0-9_]*="[^"]*")*\})? \S+$')
    for line in text.strip().splitlines():
        if line.startswith("# TYPE "):
            continue
        assert sample.match(line), f"unparseable sample line: {line!r}"
    assert "# TYPE req_total counter" in text
    assert "# TYPE occ gauge" in text
    assert "# TYPE lat_seconds histogram" in text
    buckets = [float(ln.rsplit(" ", 1)[1]) for ln in text.splitlines()
               if ln.startswith("lat_seconds_bucket")]
    assert buckets == [2.0, 2.0, 3.0]         # cumulative, +Inf == count
    assert 'lat_seconds_count 3' in text


def test_ingest_instrument_equals_opcounters(serving):
    cfg, model, plan, pruned, store = serving
    cm = rexec.CompressedModel(model, store)
    tokens = jnp.asarray(np.arange(2 * 8).reshape(2, 8) % cfg.vocab,
                         jnp.int32)
    with rexec.instrument() as counters:
        cm.hidden_states(pruned, tokens)
    assert counters
    reg = omet.MetricsRegistry()
    omet.ingest_instrument(reg, counters)
    for role, c in counters.items():
        assert reg.value("exec_dispatch_calls_total", role=role) == c.calls
        assert reg.value("exec_w_fetch_bits_total",
                         role=role) == c.w_fetch_bits
        assert reg.value("exec_macs_total", role=role) == c.macs
        assert reg.value("exec_refetch_factor",
                         role=role) == pytest.approx(c.refetch_factor)


def test_ingest_health_equals_report_fields():
    rep = HealthReport(gen=8, steps=5, retries=2, dense_steps=3,
                       deadline_hit=True, eos_hit=True,
                       verify={"attn_qkv": "ok", "mlp_up": "bad_digest"})
    rep.record_fallback("mlp_up", "integrity_violation")
    rep.record_fallback("*", "deadline_exceeded")
    reg = omet.MetricsRegistry()
    omet.ingest_health(reg, rep)
    assert reg.value("serve_requests_total") == 1
    assert reg.value("serve_tokens_generated_total") == 5
    assert reg.value("serve_retries_total") == 2
    assert reg.value("serve_dense_steps_total") == 3
    assert reg.value("serve_deadline_hits_total") == 1
    assert reg.value("serve_eos_hits_total") == 1
    assert reg.value("serve_fallbacks_total",
                     code="integrity_violation") == 1
    assert reg.value("serve_fallbacks_total", code="deadline_exceeded") == 1
    assert reg.value("serve_verify_failures_total", role="mlp_up") == 1
    with pytest.raises(KeyError):
        reg.value("serve_verify_failures_total", role="attn_qkv")


def test_collect_caches_matches_sources():
    from repro.core import memo
    from repro.kernels import ops as kops
    reg = omet.MetricsRegistry()
    omet.collect_caches(reg)
    kc = kops.kernel_cache_stats()
    assert reg.value("kernel_cache_hits_total") == kc["hits"]
    assert reg.value("kernel_cache_misses_total") == kc["misses"]
    assert reg.value("kernel_cache_entries") == kc["entries"]
    for name, st in memo.stats().items():
        if st.lookups:
            assert reg.value("memo_hits_total", cache=name) == st.hits
            assert reg.value("memo_misses_total", cache=name) == st.misses


# ---------------------------------------------------------------------------
# HealthReport projections
# ---------------------------------------------------------------------------

def test_health_report_stable_plus_timings_is_to_dict():
    rep = HealthReport(gen=4, steps=4, request_id="r1", trace_id="t:r1",
                       t_prefill_s=0.5, t_decode_s=1.5, t_total_s=2.0)
    rep.record_fallback("attn_qkv", "kernel_failure")
    assert rep.stable_dict() | rep.timings_dict() == rep.to_dict()
    assert "t_decode_s" not in rep.stable_dict()
    assert rep.stable_dict()["trace_id"] == "t:r1"
    assert set(rep.timings_dict()) == {"t_prefill_s", "t_decode_s",
                                       "t_total_s"}
    assert HealthReport.from_dict(rep.to_dict()) == rep
    assert HealthReport.from_json(rep.to_json()) == rep


def test_trace_id_none_when_untraced(dense):
    cfg, model, params = dense
    prompts = jnp.asarray(np.arange(2 * 4).reshape(2, 4) % cfg.vocab,
                          jnp.int32)
    _, rep = guarded_generate(model, params, prompts, 2, 8, verify=False)
    assert rep.trace_id is None
    assert "trace_id" in rep.stable_dict()


# ---------------------------------------------------------------------------
# serving integration: mixer
# ---------------------------------------------------------------------------

def _mixer_run(cfg, model, params, sampled: bool):
    kw = {"temperature": 0.8, "top_k": 8} if sampled else {}
    reqs = _stream(cfg, [6, 3, 5, 2], [4, 2, 3, 2], **kw)
    tracer = otr.Tracer()
    reg = omet.MetricsRegistry()
    with otr.tracing(tracer), omet.collecting(reg):
        mx = Mixer(model, params, slots=2, max_len=16)
        results = mx.run(reqs)
    return tracer, reg, results


@pytest.mark.parametrize("sampled", [False, True],
                         ids=["greedy", "sampled"])
def test_mixer_stable_trace_deterministic_across_runs(dense, sampled):
    cfg, model, params = dense
    tr1, _, res1 = _mixer_run(cfg, model, params, sampled)
    tr2, _, res2 = _mixer_run(cfg, model, params, sampled)
    assert tr1.stable_trace() == tr2.stable_trace()
    for a, b in zip(res1, res2):
        assert a.report.stable_dict() == b.report.stable_dict()


def test_mixer_trace_linkage_and_counter_parity(dense):
    cfg, model, params = dense
    tracer, reg, results = _mixer_run(cfg, model, params, sampled=False)
    uids = {r.uid for r in results}
    for res in results:
        assert res.report.trace_id == f"t:{res.uid}"
        named = [e for e in tracer.events
                 if e["args"].get("trace_id") == res.report.trace_id]
        kinds = {(e["ph"], e["name"]) for e in named}
        assert {("B", "admit"), ("B", "prefill"), ("B", "slot_write"),
                ("i", "token"), ("i", "evict")} <= kinds
        toks = [e for e in named if e["name"] == "token"]
        assert len(toks) == res.report.steps
    evicts = [e for e in tracer.events if e["name"] == "evict"]
    assert {e["args"]["request_id"] for e in evicts} == uids
    # live mixer counters and per-report ingestion agree with the reports
    assert reg.value("mixer_admissions_total") == len(results)
    assert reg.total("mixer_evictions_total") == len(results)
    assert reg.value("serve_requests_total") == len(results)
    assert reg.value("serve_tokens_generated_total") == \
        sum(r.report.steps for r in results)
    assert reg.value("mixer_slot_occupancy") == 0   # drained at the end
    # every decode step recorded its latency
    assert reg.value("mixer_decode_steps_total") > 0


def test_mixer_straggler_lands_in_stats_and_snapshot(dense):
    from repro.runtime.fault import StragglerMonitor
    cfg, model, params = dense
    mon = StragglerMonitor(threshold=0.0, warmup=0)   # flag every step
    reqs = _stream(cfg, [4, 3], 2)
    reg = omet.MetricsRegistry()
    tracer = otr.Tracer()
    with otr.tracing(tracer), omet.collecting(reg):
        mx = Mixer(model, params, slots=2, max_len=8, straggler=mon)
        mx.run(reqs)
    st = mx.stats()
    assert st["straggler_spikes"] == len(mon.flagged) > 0
    assert st["step_ewma_s"] == mon.ewma
    assert reg.value("mixer_straggler_spikes_total") == len(mon.flagged)
    omet.ingest_straggler(reg, mon)
    assert reg.value("straggler_ewma_seconds") == pytest.approx(mon.ewma)
    # spikes are timing-derived: visible in the raw trace, NOT the stable
    # projection, and never in Mixer.events (the CI determinism surface)
    assert any(e["name"] == "straggler_spike" for e in tracer.events)
    assert not any(e["name"] == "straggler_spike"
                   for e in tracer.stable_trace())
    assert not any(ev.get("event") == "straggler_spike" for ev in mx.events)


# ---------------------------------------------------------------------------
# serving integration: off-switch + guarded path + kernel timer
# ---------------------------------------------------------------------------

def test_telemetry_off_and_on_leave_tokens_bit_identical(dense):
    cfg, model, params = dense
    prompts = jnp.asarray(np.arange(2 * 6).reshape(2, 6) % cfg.vocab,
                          jnp.int32)
    toks_off, _, _ = serve.generate(model, params, prompts, 3, 12)
    with otr.tracing(otr.Tracer()) as tr, \
            omet.collecting(omet.MetricsRegistry()) as reg, \
            kernel_timer(registry=reg, tracer=tr):
        toks_on, _, _ = serve.generate(model, params, prompts, 3, 12)
    toks_off2, _, _ = serve.generate(model, params, prompts, 3, 12)
    np.testing.assert_array_equal(np.asarray(toks_off), np.asarray(toks_on))
    np.testing.assert_array_equal(np.asarray(toks_off), np.asarray(toks_off2))
    # the traced run actually recorded the serving spans
    names = {e["name"] for e in tr.events}
    assert {"prefill", "decode"} <= names
    assert reg.value("serve_static_tokens_total") == 2 * 3


def test_guarded_traced_run_emits_demote_and_matching_counters(serving):
    cfg, model, plan, pruned, store = serving
    role = next(op.role for op in plan.ops if op.choice.kind == "bitmap")
    bad = inject.bitflip_payload(store, role, seed=3)
    cm = rexec.CompressedModel(model, bad)
    prompts = jnp.asarray(np.arange(2 * 6).reshape(2, 6) % cfg.vocab,
                          jnp.int32)
    tracer = otr.Tracer()
    reg = omet.MetricsRegistry()
    with otr.tracing(tracer), omet.collecting(reg):
        toks, report = guarded_generate(cm, pruned, prompts, 3, 12)
    assert report.trace_id == "t0001"
    assert report.verify[role] == "checksum_mismatch"
    demotes = [e for e in tracer.events if e["name"] == "demote"]
    assert [d["args"]["role"] for d in demotes] == [role]
    assert demotes[0]["args"]["code"] == "integrity_violation"
    assert demotes[0]["args"]["trace_id"] == report.trace_id
    # the demote survives into the stable projection (it is stream-
    # determined, not timing-derived)
    assert any(e["name"] == "demote" for e in tracer.stable_trace())
    spans = {e["name"] for e in tracer.events if e["ph"] == "B"}
    assert {"guarded_request", "verify", "prefill", "decode"} <= spans
    assert reg.value("serve_verify_failures_total", role=role) == 1
    assert reg.value("serve_fallbacks_total", code="integrity_violation") \
        == report.fallback_counts()["integrity_violation"]
    assert reg.value("serve_tokens_generated_total") == report.steps


def test_kernel_timer_records_dispatches(serving):
    cfg, model, plan, pruned, store = serving
    cm = rexec.CompressedModel(model, store)
    tokens = jnp.asarray(np.arange(2 * 8).reshape(2, 8) % cfg.vocab,
                         jnp.int32)
    reg = omet.MetricsRegistry()
    tracer = otr.Tracer()
    with kernel_timer(registry=reg, tracer=tracer):
        # a FRESH jit object forces a trace, which is where dispatch runs
        jax.jit(cm.hidden_states)(pruned, tokens)
    assert reg.total("kernel_dispatch_total") > 0
    assert reg.value("kernel_dispatch_total", kind="bitmap") > 0
    snap = reg.snapshot()
    assert any(k.startswith("kernel_dispatch_seconds")
               for k in snap["histograms"])
    xs = [e for e in tracer.events if e["ph"] == "X"]
    assert xs and all(e["name"].startswith("kernel:") for e in xs)
    assert not tracer.stable_trace()          # all timing-derived
