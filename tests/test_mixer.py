"""Continuous-batching mixer tests + static-path bugfix regressions.

Contracts pinned here:

  * a mixed-length request stream through :class:`repro.launch.mixer
    .Mixer` emits, per request, the SAME tokens as the request served
    alone through the static driver at fp32 — dense AND compressed
    (all-bitmap plan), with slots genuinely reused mid-stream (the
    acceptance gate: admission into a freed slot must not perturb any
    resident request);
  * seeded temperature/top-k sampling replays exactly across runs (keys
    are a pure function of request seed + token index, independent of
    slot placement);
  * eviction leaves stale KV in the slot and isolation still holds (the
    per-slot length mask, not cache clearing, is the mechanism);
  * ``serve.generate`` accepts LEFT-padded ragged prompts via
    ``prompt_pad_id`` (per-row first-real-token offsets) and rejects
    right/interior padding loudly — the pre-fix driver silently decoded
    pad tokens as context;
  * ``eos_id=`` ends decode early in both the static and guarded drivers:
    EOS is emitted, the tail holds ``pad_id``, and decode_step stops
    running once every row is done (counted via an effectful callback —
    the pre-fix drivers burned the full ``gen`` budget);
  * throughput reports survive ~0-second phases (``_rate`` denominator
    floor) — the pre-fix CLI divided by raw wall-clock;
  * an all-equal position VECTOR decodes bit-identically to the scalar
    position (the mixer's decode primitive degenerates to lockstep).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import exec as rexec
from repro.configs import get_config
from repro.core.cosearch import CoSearchConfig
from repro.core.engine import EngineConfig
from repro.core.sparsity import BlockBernoulli
from repro.launch import serve
from repro.launch.mixer import Mixer, Request, sample_token
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models.transformer import Model
from repro.runtime.guard import guarded_generate

FAST = CoSearchConfig(objective="edp",
                      engine=EngineConfig(max_levels=2,
                                          max_allocs_per_pattern=16),
                      spatial_top=2, max_pairs=6)


@pytest.fixture()
def fp32_compute(monkeypatch):
    monkeypatch.setattr(L, "COMPUTE_DTYPE", jnp.float32)
    monkeypatch.setattr(attn_mod, "COMPUTE_DTYPE", jnp.float32)


def _cfg():
    return get_config("chatglm3-6b").reduced()


def _dense(seed=0):
    cfg = _cfg()
    model = Model(cfg)
    return cfg, model, model.init(jax.random.key(seed))


def _stream(cfg, plens, max_new, seed=0, **kw):
    rng = np.random.default_rng(seed)
    return [Request(uid=f"r{i}",
                    prompt=jnp.asarray(
                        rng.integers(1, cfg.vocab, (p,)), jnp.int32),
                    max_new=max_new[i] if isinstance(max_new, list)
                    else max_new, **kw)
            for i, p in enumerate(plens)]


def _assert_stream_matches_standalone(model, params, reqs, mx, max_len):
    results = mx.run(reqs)
    for req, res in zip(reqs, results):
        ref, _, _ = serve.generate(model, params,
                                   jnp.asarray(req.prompt)[None, :],
                                   req.max_new, max_len)
        np.testing.assert_array_equal(
            np.asarray(ref[0]), res.tokens,
            err_msg=f"{req.uid} (slot {res.slot}, admit_step "
                    f"{res.admit_step}) diverged from standalone")
    # the stream must actually exercise continuous batching: at least one
    # request admitted into a slot freed mid-decode
    reuse = [e for e in mx.events if e["event"] == "admit" and e["step"] > 0]
    assert reuse, f"no admit-into-freed-slot event: {mx.events}"
    return results


# ---------------------------------------------------------------------------
# mixer vs standalone (the acceptance gate)
# ---------------------------------------------------------------------------

def test_mixer_dense_stream_matches_standalone(fp32_compute):
    cfg, model, params = _dense()
    max_len = 48
    reqs = _stream(cfg, [3, 5, 7, 9, 11, 4, 6, 13],
                   [6, 7, 8, 6, 7, 8, 6, 7])
    mx = Mixer(model, params, slots=3, max_len=max_len)
    _assert_stream_matches_standalone(model, params, reqs, mx, max_len)
    st = mx.stats()
    assert st["admits"] == st["evictions"] == len(reqs)
    assert st["slot_reuse_admits"] >= 1
    assert st["tokens"] == sum(r.max_new for r in reqs)


def test_mixer_compressed_bitmap_stream_matches_standalone(fp32_compute):
    cfg, model, params = _dense()
    plan = rexec.build_exec_plan(cfg, BlockBernoulli(0.5, 32 * 32),
                                 tokens=64, search_cfg=FAST, value_bits=32)
    pruned = rexec.prune_params(params, plan, cfg)
    store = rexec.compress_params(pruned, plan, cfg)
    cm = rexec.CompressedModel(model, store)
    max_len = 48
    reqs = _stream(cfg, [3, 5, 7, 9, 11, 4, 6, 13],
                   [6, 7, 8, 6, 7, 8, 6, 7], seed=1)
    results, mx = cm.serve_mixed(pruned, reqs, slots=3, max_len=max_len)
    for req, res in zip(reqs, results):
        ref, _, _ = serve.generate(cm, pruned,
                                   jnp.asarray(req.prompt)[None, :],
                                   req.max_new, max_len)
        np.testing.assert_array_equal(np.asarray(ref[0]), res.tokens)
    assert mx.stats()["slot_reuse_admits"] >= 1


def test_mixer_sampled_stream_replays_exactly(fp32_compute):
    cfg, model, params = _dense()
    max_len = 32

    def one_run():
        reqs = _stream(cfg, [3, 6, 4, 8], 5, temperature=0.8, top_k=16)
        reqs = [Request(uid=r.uid, prompt=r.prompt, max_new=r.max_new,
                        temperature=r.temperature, top_k=r.top_k, seed=i)
                for i, r in enumerate(reqs)]
        mx = Mixer(model, params, slots=2, max_len=max_len)
        return [res.tokens for res in mx.run(reqs)]

    a, b = one_run(), one_run()
    for ta, tb in zip(a, b):
        np.testing.assert_array_equal(ta, tb)
    # sampling is actually on: different seeds draw different tokens
    # somewhere in the stream (astronomically unlikely to all collide)
    assert any(not np.array_equal(a[i], a[j])
               for i in range(len(a)) for j in range(i + 1, len(a)))


def test_mixer_slot_reuse_isolation(fp32_compute):
    # a long predecessor fills its slot's KV deep; the successor admitted
    # into the SAME slot must decode as if the cache were fresh
    cfg, model, params = _dense()
    max_len = 40
    long_req, short_req = _stream(cfg, [20, 4], [3, 8], seed=2)
    mx = Mixer(model, params, slots=1, max_len=max_len)
    results = mx.run([long_req, short_req])
    assert results[0].slot == results[1].slot == 0
    assert results[1].admit_step > 0
    alone = Mixer(model, params, slots=1, max_len=max_len)
    ref = alone.run([short_req])[0]
    np.testing.assert_array_equal(results[1].tokens, ref.tokens)


def test_mixer_eos_and_validation(fp32_compute):
    cfg, model, params = _dense()
    max_len = 24
    reqs = _stream(cfg, [4, 4], 6, seed=3)
    # probe the greedy stream to find a token to use as EOS
    probe = Mixer(model, params, slots=2, max_len=max_len)
    toks0 = probe.run(reqs)[0].tokens
    eos = int(toks0[2])

    mx = Mixer(model, params, slots=2, max_len=max_len, eos_id=eos,
               pad_id=-7)
    res = mx.run(reqs)[0]
    stop = int(np.nonzero(toks0 == eos)[0][0])
    np.testing.assert_array_equal(res.tokens[:stop + 1], toks0[:stop + 1])
    assert (res.tokens[stop + 1:] == -7).all()
    assert res.report.eos_hit and res.n_tokens == stop + 1

    with pytest.raises(ValueError, match="unique"):
        Mixer(model, params, slots=2, max_len=max_len).run(
            [reqs[0], reqs[0]])
    with pytest.raises(ValueError, match="exceeds max_len"):
        Mixer(model, params, slots=1, max_len=6).admit(
            _stream(cfg, [5], 6)[0])
    with pytest.raises(ValueError, match="at least one slot"):
        Mixer(model, params, slots=0, max_len=max_len)


def test_mixer_deadline_evicts_with_report(fp32_compute):
    cfg, model, params = _dense()
    mx = Mixer(model, params, slots=1, max_len=24, deadline_s=0.0)
    res = mx.run(_stream(cfg, [4], 6, seed=4))[0]
    # prefill emits the first token; the first decode step hits the
    # zero-second budget and evicts with the guarded driver's semantics
    assert res.n_tokens == 1
    assert (res.tokens[1:] == -1).all()
    assert res.report.deadline_hit
    assert res.report.fallback_counts().get("deadline_exceeded") == 1


def test_sample_token_greedy_and_topk():
    logits = jnp.asarray([0.1, 3.0, 2.0, -1.0])
    greedy = Request(uid="g", prompt=[1], max_new=1)
    assert sample_token(logits, greedy, 0) == 1
    # top-1 sampling can only ever pick the argmax, any temperature
    top1 = Request(uid="t", prompt=[1], max_new=1, temperature=5.0,
                   top_k=1, seed=9)
    assert all(sample_token(logits, top1, i) == 1 for i in range(8))


# ---------------------------------------------------------------------------
# static-path regressions (the three driver bugfixes)
# ---------------------------------------------------------------------------

def test_serve_ragged_left_padded_matches_per_row(fp32_compute):
    cfg, model, params = _dense()
    PAD = 0
    rng = np.random.default_rng(5)
    rows = [rng.integers(1, cfg.vocab, (p,)).astype(np.int32)
            for p in (3, 7, 5)]
    plen = max(len(r) for r in rows)
    batch = jnp.asarray(np.stack(
        [np.concatenate([np.full(plen - len(r), PAD, np.int32), r])
         for r in rows]))
    out, _, _ = serve.generate(model, params, batch, 5, plen + 5,
                               prompt_pad_id=PAD)
    for r, row in enumerate(rows):
        ref, _, _ = serve.generate(model, params, jnp.asarray(row)[None, :],
                                   5, plen + 5)
        np.testing.assert_array_equal(np.asarray(ref[0]),
                                      np.asarray(out[r]))


def test_serve_rejects_right_or_interior_padding(fp32_compute):
    cfg, model, params = _dense()
    right = jnp.asarray([[5, 6, 7, 0, 0], [1, 2, 3, 4, 5]], jnp.int32)
    with pytest.raises(ValueError, match="LEFT-padded"):
        serve.generate(model, params, right, 2, 10, prompt_pad_id=0)
    interior = jnp.asarray([[0, 5, 0, 7, 8]], jnp.int32)
    with pytest.raises(ValueError, match="LEFT-padded"):
        serve.generate(model, params, interior, 2, 10, prompt_pad_id=0)
    allpad = jnp.asarray([[0, 0, 0]], jnp.int32)
    with pytest.raises(ValueError, match="all padding"):
        serve.generate(model, params, allpad, 2, 10, prompt_pad_id=0)


class _CountingModel:
    """Serving surface that counts EXECUTED decode steps (an effectful
    callback, so jit caching can't hide repeat invocations)."""

    def __init__(self, model):
        self._m = model
        self.cfg = model.cfg
        self.calls = 0

    def prefill(self, *a, **k):
        return self._m.prefill(*a, **k)

    def init_cache(self, *a, **k):
        return self._m.init_cache(*a, **k)

    def decode_step(self, params, cache, tokens, pos):
        jax.debug.callback(self._bump)
        return self._m.decode_step(params, cache, tokens, pos)

    def _bump(self):
        self.calls += 1


def test_serve_eos_early_exit(fp32_compute):
    cfg, model, params = _dense()
    rng = np.random.default_rng(6)
    pp = jnp.asarray(rng.integers(0, cfg.vocab, (1, 6)), jnp.int32)
    gen = 8
    cm = _CountingModel(model)

    full, _, _ = serve.generate(cm, params, pp, gen, 20)
    base = cm.calls
    assert base == gen
    eos = int(np.asarray(full)[0, 3])

    cm.calls = 0
    toks, _, _ = serve.generate(cm, params, pp, gen, 20, eos_id=eos,
                                pad_id=-7)
    tn = np.asarray(toks)[0]
    stop = int(np.nonzero(np.asarray(full)[0] == eos)[0][0])
    np.testing.assert_array_equal(tn[:stop + 1],
                                  np.asarray(full)[0, :stop + 1])
    assert (tn[stop + 1:] == -7).all()
    assert cm.calls == stop < base  # decode stopped at the EOS row


def test_guarded_eos_early_exit_matches_static(fp32_compute):
    cfg, model, params = _dense()
    rng = np.random.default_rng(6)
    pp = jnp.asarray(rng.integers(0, cfg.vocab, (1, 6)), jnp.int32)
    gen = 8
    full, _, _ = serve.generate(model, params, pp, gen, 20)
    eos = int(np.asarray(full)[0, 3])

    cm = _CountingModel(model)
    toks, rep = guarded_generate(cm, params, pp, gen, 20, verify=False,
                                 eos_id=eos, pad_id=-7)
    ref, _, _ = serve.generate(model, params, pp, gen, 20, eos_id=eos,
                               pad_id=-7)
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(ref))
    assert rep.eos_hit and cm.calls < gen
    # eos_hit round-trips through the serialized report
    assert rep.to_dict()["eos_hit"] is True


def test_rate_guards_zero_durations():
    from benchmarks.bench_serve import _rate as bench_rate
    assert np.isfinite(serve._rate(100, 0.0))
    assert np.isfinite(bench_rate(100, 0.0))
    assert serve._rate(100, 2.0) == 50.0


# ---------------------------------------------------------------------------
# the decode primitive: vector positions
# ---------------------------------------------------------------------------

def test_vector_pos_degenerates_to_scalar(fp32_compute):
    cfg, model, params = _dense()
    toks = jnp.asarray(np.random.default_rng(7).integers(
        0, cfg.vocab, (2, 6)), jnp.int32)
    max_len = 12
    _, cache_a = model.prefill(params, toks, max_len)
    _, cache_b = model.prefill(params, toks, max_len)
    nxt = toks[:, -1]
    lg_s, c_s = model.decode_step(params, cache_a, nxt,
                                  jnp.asarray(6, jnp.int32))
    lg_v, c_v = model.decode_step(params, cache_b, nxt,
                                  jnp.asarray([6, 6], jnp.int32))
    np.testing.assert_array_equal(np.asarray(lg_s), np.asarray(lg_v))
    for a, b in zip(jax.tree.leaves(c_s), jax.tree.leaves(c_v)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_decode_step_rejects_bad_pos_shape(fp32_compute):
    cfg, model, params = _dense()
    cache = model.init_cache(2, 8)
    tok = jnp.asarray([1, 2], jnp.int32)
    with pytest.raises(ValueError, match="scalar or a per-slot vector"):
        model.decode_step(params, cache, tok, jnp.asarray([0, 0, 0],
                                                          jnp.int32))
