"""Substrate tests: data pipeline, optimizer, checkpointing, fault handling,
pruning, codesign bridge."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.core.codesign import plan_for_model
from repro.core.cosearch import CoSearchConfig
from repro.core.engine import EngineConfig
from repro.core.sparsity import NM, Bernoulli
from repro.configs import get_config
from repro.data.pipeline import PipelineState, TokenPipeline
from repro.optim import adamw
from repro.runtime import fault
from repro.sparse import masks


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_resumable():
    p = TokenPipeline(vocab=101, seq_len=16, global_batch=4)
    b1 = p.batch_at(5)
    b2 = p.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # resuming from a checkpointed state replays the same stream
    it = p.iterate(PipelineState(3))
    st, batch = next(it)
    np.testing.assert_array_equal(batch["tokens"], p.batch_at(3)["tokens"])
    assert st.step == 4


def test_pipeline_shards_disjoint_and_elastic():
    p = TokenPipeline(vocab=101, seq_len=8, global_batch=8, n_hosts=2,
                      host_id=0)
    q = p.reshard(2, 1)
    b0, b1 = p.batch_at(0), q.batch_at(0)
    assert b0["tokens"].shape == (4, 8)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_pipeline_labels_shifted():
    p = TokenPipeline(vocab=101, seq_len=16, global_batch=2)
    b = p.batch_at(0)
    # labels are next tokens — mostly the affine map of tokens
    nxt = (np.asarray(b["tokens"]) * 31 + 7) % 101
    match = np.mean(nxt == np.asarray(b["labels"]))
    assert match > 0.7


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def _toy_params():
    return {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}


def test_adamw_descends_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                            weight_decay=0.0)
    params = _toy_params()
    state = adamw.init(params, cfg)

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - 2.0)) + jnp.sum(jnp.square(p["b"]))

    l0 = loss(params)
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state = adamw.apply(params, g, state, cfg)
    assert loss(params) < l0 * 0.1


def test_adamw_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert adamw.schedule(jnp.asarray(5), cfg) == pytest.approx(0.5)
    assert adamw.schedule(jnp.asarray(10), cfg) == pytest.approx(1.0)
    assert adamw.schedule(jnp.asarray(100), cfg) == pytest.approx(
        cfg.lr * cfg.min_lr_frac)


def test_grad_compression_error_feedback_converges():
    cfg = adamw.AdamWConfig(lr=0.05, warmup_steps=0, total_steps=200,
                            weight_decay=0.0, grad_compress=True)
    params = _toy_params()
    state = adamw.init(params, cfg)

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - 1.5))

    for _ in range(80):
        g = jax.grad(loss)(params)
        params, state = adamw.apply(params, g, state, cfg)
    assert float(loss(params)) < 0.05
    assert state.err is not None


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_latest(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    ckpt.save(str(tmp_path), 3, tree, extra={"pipeline": {"step": 3}})
    ckpt.save(str(tmp_path), 7, tree)
    assert ckpt.latest_step(str(tmp_path)) == 7
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, extra = ckpt.restore(str(tmp_path), like, step=3)
    np.testing.assert_array_equal(restored["a"], tree["a"])
    assert extra["pipeline"]["step"] == 3


def test_checkpoint_prune_old(tmp_path):
    tree = {"x": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), s, tree)
    ckpt.prune_old(str(tmp_path), keep=2)
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000003", "step_00000004"]
    assert ckpt.latest_step(str(tmp_path)) == 4


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"x": jnp.zeros((2, 2))})
    with pytest.raises(AssertionError):
        ckpt.restore(str(tmp_path), {"x": jnp.zeros((3, 3))})


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_step_guard_retries_then_restores():
    calls = {"n": 0, "restored": False}

    def flaky():
        calls["n"] += 1
        raise RuntimeError("device lost")

    g = fault.StepGuard(max_retries=2,
                        on_restore=lambda: calls.__setitem__("restored", True))
    out = g.run(10, flaky)
    assert out is None and calls["n"] == 3 and calls["restored"]
    assert [e.action for e in g.events] == ["retry", "retry", "restore"]


def test_straggler_monitor_flags_spikes():
    m = fault.StragglerMonitor(warmup=3)
    for i in range(10):
        assert not m.observe(i, 1.0)
    assert m.observe(10, 5.0)          # 5× slower than EWMA
    assert m.flagged


def test_elastic_remesh_preserves_tp():
    assert fault.elastic_remesh(240, 16) == (15, 16)
    assert fault.elastic_remesh(512, 16, pod_size=256) == (2, 16, 16)
    # losing one pod's worth of nodes
    assert fault.elastic_remesh(384, 16, pod_size=256) == (1, 24, 16)
    with pytest.raises(ValueError):
        fault.elastic_remesh(8, 16)


def test_replay_range():
    assert list(fault.replay_steps(100, 103)) == [100, 101, 102]


# ---------------------------------------------------------------------------
# pruning + codesign
# ---------------------------------------------------------------------------

def test_prune_densities():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
    assert masks.density(masks.magnitude_prune(w, 0.3)) == pytest.approx(0.3, abs=0.02)
    assert masks.density(masks.nm_prune(w)) == pytest.approx(0.5, abs=0.01)
    assert masks.density(masks.block_prune(w, 16, 16, 0.25)) == pytest.approx(
        0.25, abs=0.05)


def test_codesign_plan_nm():
    cfg = get_config("deepseek-coder-33b").reduced()
    plan = plan_for_model(cfg, NM(2, 4), tokens=256,
                          search_cfg=CoSearchConfig(
                              engine=EngineConfig(max_levels=2,
                                                  max_allocs_per_pattern=8),
                              spatial_top=2, max_pairs=4))
    assert plan.for_op("ffn.up").kind == "nm"


def test_codesign_plan_block_sparse_maps_to_bitmap_kernel():
    cfg = get_config("deepseek-coder-33b").reduced()
    plan = plan_for_model(cfg, Bernoulli(0.15), tokens=256,
                          search_cfg=CoSearchConfig(
                              engine=EngineConfig(max_levels=2,
                                                  max_allocs_per_pattern=16),
                              spatial_top=2, max_pairs=6))
    ch = plan.for_op("ffn.up")
    assert ch.kind in ("bitmap", "dense")
    if ch.kind == "bitmap":
        assert cfg.d_model % ch.block_n == 0 or ch.block_n % 8 == 0
