"""Batch evaluator + memoization correctness (the search hot path).

Pins the two contracts the vectorized rewrite must keep:
  * ``evaluate_batch`` over a heterogeneous candidate set reproduces the
    scalar ``evaluate`` report for every candidate (1e-9 relative);
  * every cache (compile_format / analyze / mappings / candidates /
    _search_op) is semantically invisible — co-search results are identical
    with caching off, cold, and warm.
"""

import dataclasses
import math
import types

import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

import numpy as np

from repro.core import formats as F
from repro.core import memo
from repro.core.arch import ARCH1, ARCH2, ARCH3
from repro.core.cosearch import (CoSearchConfig, _dense_sentinel, _pair_rank,
                                 cosearch)
from repro.core.costmodel import (compile_format, dense_format, evaluate,
                                  evaluate_batch)
from repro.core.dataflow import enumerate_mappings, mappings_for
from repro.core.engine import EngineConfig
from repro.core.formats import Format, Level
from repro.core.primitives import Prim
from repro.core.sparsity import NM, Bernoulli, TensorSpec
from repro.core.workload import LLMSpec, MatMul, build_llm

OPS = (
    MatMul("mid", 128, 256, 128, Bernoulli(0.5), Bernoulli(0.25),
           Bernoulli(0.3)),
    MatMul("nm", 64, 512, 256, Bernoulli(0.9), NM(2, 4), Bernoulli(0.8),
           count=3.0),
    MatMul("decode", 1, 1024, 512, Bernoulli(0.2), Bernoulli(0.15)),
)
ARCHS = (ARCH1, ARCH2, ARCH3)


def _i_formats(op):
    spec = TensorSpec(op.i_dims(), op.sp_i, op.value_bits)
    n1 = 16 if op.N % 256 == 0 else 8
    hier = Format.of(Level(Prim.B, "N", n1), Level(Prim.NONE, "M", op.M),
                     Level(Prim.B, "N", op.N // n1))
    return [dense_format(spec),
            compile_format(F.bitmap(op.i_dims()), spec),
            compile_format(F.rle(op.i_dims()), spec),
            compile_format(hier, spec)]


def _w_formats(op):
    spec = TensorSpec(op.w_dims(), op.sp_w, op.value_bits)
    return [dense_format(spec),
            compile_format(F.bitmap(op.w_dims()), spec),
            compile_format(F.csr(op.w_dims()), spec),
            compile_format(F.coo(op.w_dims()), spec)]


def _assert_reports_close(got, want, rel=1e-9):
    for f in ("energy", "cycles", "edp", "utilization", "dram_bits"):
        assert math.isclose(getattr(got, f), getattr(want, f),
                            rel_tol=rel, abs_tol=1e-12), f
    assert set(got.breakdown) == set(want.breakdown)
    for k, v in want.breakdown.items():
        assert math.isclose(got.breakdown[k], v,
                            rel_tol=rel, abs_tol=1e-12), k


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_batch_matches_scalar_evaluate(seed):
    """∀ random (op, arch, mapping, format-pair) sets: one evaluate_batch
    call == per-candidate scalar evaluate, on every CostReport field."""
    rng = np.random.default_rng(seed)
    op = OPS[rng.integers(len(OPS))]
    arch = ARCHS[rng.integers(len(ARCHS))]
    cfs_i, cfs_w = _i_formats(op), _w_formats(op)
    all_mappings = list(enumerate_mappings(op, arch, spatial_top=2))
    take = rng.choice(len(all_mappings), size=min(40, len(all_mappings)),
                      replace=False)
    mappings = [all_mappings[i] for i in take]
    pairs = [(cfs_i[rng.integers(len(cfs_i))], cfs_w[rng.integers(len(cfs_w))])
             for _ in mappings]
    cf_o = None
    if rng.random() < 0.5 and op.sp_o.density < 1.0:
        cf_o = compile_format(F.bitmap(op.o_dims()),
                              TensorSpec(op.o_dims(), op.sp_o, op.value_bits))
    bc = evaluate_batch(op, arch, mappings, pairs, cf_o)
    assert len(bc) == len(mappings)
    for j, (mapping, (cf_i, cf_w)) in enumerate(zip(mappings, pairs)):
        _assert_reports_close(bc.report(j),
                              evaluate(op, arch, mapping, cf_i, cf_w, cf_o))


def test_batch_broadcasts_single_pair():
    op, arch = OPS[0], ARCH3
    cf_i, cf_w = _i_formats(op)[1], _w_formats(op)[1]
    mappings = list(enumerate_mappings(op, arch, spatial_top=2))[:10]
    bc = evaluate_batch(op, arch, mappings, [(cf_i, cf_w)])
    for j, m in enumerate(mappings):
        _assert_reports_close(bc.report(j), evaluate(op, arch, m, cf_i, cf_w))


def test_batch_rejects_misaligned_pairs():
    op, arch = OPS[0], ARCH3
    cf_i, cf_w = _i_formats(op)[0], _w_formats(op)[0]
    mappings = list(enumerate_mappings(op, arch, spatial_top=2))[:3]
    with pytest.raises(ValueError):
        evaluate_batch(op, arch, mappings, [(cf_i, cf_w)] * 2)


# ---------------------------------------------------------------------------
# Caching
# ---------------------------------------------------------------------------

_WL = build_llm(LLMSpec("cachetest", 2, 256, 1024, 4), seq=128,
                decode_tokens=8, act_density=0.4, w_density=0.25)
_CFG = CoSearchConfig(engine=EngineConfig(max_levels=2,
                                          max_allocs_per_pattern=16),
                      spatial_top=2, max_pairs=6)


def _design_fingerprint(res):
    return (res.design.pattern_i, res.design.pattern_w, res.design.energy,
            res.design.cycles, res.evaluations,
            tuple((str(o.mapping), str(o.fmt_i), str(o.fmt_w))
                  for o in res.design.ops))


def test_cosearch_unchanged_with_caching_on_off():
    """Caches must be semantically invisible: identical designs, metrics and
    evaluation counts with caching disabled, cold, and warm."""
    with memo.disabled():
        off = _design_fingerprint(cosearch(_WL, ARCH3, _CFG))
    memo.clear()
    cold = _design_fingerprint(cosearch(_WL, ARCH3, _CFG))
    warm = _design_fingerprint(cosearch(_WL, ARCH3, _CFG))
    assert cold == off
    assert warm == off


def test_scalar_path_matches_batch_path():
    """use_batch=False (legacy scalar loop) picks the same design."""
    wl = build_llm(LLMSpec("scalartest", 1, 128, 256, 4), seq=64,
                   act_density=0.4, w_density=0.25)
    cfg = CoSearchConfig(engine=_CFG.engine, spatial_top=2, max_pairs=4)
    scalar_cfg = dataclasses.replace(cfg, use_batch=False)
    with memo.disabled():
        a = _design_fingerprint(cosearch(wl, ARCH3, scalar_cfg))
        b = _design_fingerprint(cosearch(wl, ARCH3, cfg))
    assert a == b


def test_mappings_for_matches_enumerate_and_caches():
    op, arch = OPS[0], ARCH2
    want = tuple(enumerate_mappings(op, arch, 0.5, 0.25, spatial_top=2))
    got = mappings_for(op, arch, 0.5, 0.25, spatial_top=2)
    assert got == want
    assert mappings_for(op, arch, 0.5, 0.25, spatial_top=2) is got  # cached
    renamed = MatMul("other-name", op.M, op.N, op.K, op.sp_i, op.sp_w)
    assert mappings_for(renamed, arch, 0.5, 0.25, spatial_top=2) is got


# ---------------------------------------------------------------------------
# Pair-ranking sentinel (inf/4 fix)
# ---------------------------------------------------------------------------

def test_dense_sentinel_is_finite_and_orders_pairs():
    c = lambda e: types.SimpleNamespace(eq_data=e)
    ca, cb = c(100.0), c(300.0)
    sentinel = _dense_sentinel([ca, cb, None])
    assert math.isfinite(sentinel) and sentinel > cb.eq_data
    # part-dense pairs order by their compressed side's EqData...
    assert _pair_rank((None, ca), sentinel) < _pair_rank((None, cb), sentinel)
    assert _pair_rank((ca, None), sentinel) < _pair_rank((cb, None), sentinel)
    # ...and the fully-dense pair ranks after every part-dense pair
    assert _pair_rank((None, None), sentinel) > _pair_rank((None, cb), sentinel)
    # no candidates at all still yields a finite sentinel
    assert math.isfinite(_dense_sentinel([None, None]))
