"""Per-architecture smoke tests: REDUCED configs, one forward/train step and
one decode step on CPU, asserting output shapes and finite values."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_archs
from repro.models.transformer import Model, input_specs

# full end-to-end / many-model sweeps dominate suite wall-clock
pytestmark = pytest.mark.slow

ARCHS = list_archs()


def _batch(cfg, b=2, s=32, rng=None):
    rng = rng or np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    batch = {"tokens": tokens,
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                   jnp.int32)}
    if cfg.family == "encdec":
        batch["enc_frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.enc_seq, cfg.d_model)), jnp.float32)
    return batch


def test_all_archs_registered():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_params_count(arch):
    cfg = get_config(arch)
    n = cfg.params_count()
    assert n > 1e8 or arch == "whisper-tiny"
    assert cfg.active_params_count() <= n + 1


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    """Reduced config: one loss+grad step, finite outputs."""
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)

    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    leaves = jax.tree.leaves(grads)
    assert leaves, "no grads"
    for g in leaves:
        assert jnp.all(jnp.isfinite(g)), f"{arch}: non-finite grad"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    b, max_len = 2, 64
    cache = model.init_cache(b, max_len)
    step = jax.jit(model.decode_step)
    tokens = jnp.array([1, 2], jnp.int32)
    logits, cache = step(params, cache, tokens, jnp.asarray(0, jnp.int32))
    assert logits.shape == (b, cfg.vocab)
    assert jnp.all(jnp.isfinite(logits)), f"{arch}: non-finite logits"
    logits2, cache = step(params, cache, tokens + 1, jnp.asarray(1, jnp.int32))
    assert not jnp.allclose(logits, logits2), "decode ignores position/cache"


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_all_shapes(arch):
    cfg = get_config(arch)
    for shape in SHAPES.values():
        specs = input_specs(cfg, shape)
        assert "tokens" in specs
        if shape.kind == "train":
            assert specs["tokens"].shape == (shape.global_batch, shape.seq_len)
        if shape.kind == "decode":
            assert specs["tokens"].shape == (shape.global_batch,)


def test_decode_matches_forward_logits_dense():
    """Decoding token-by-token must agree with the parallel forward pass
    (teacher forcing) for a uniform dense arch."""
    cfg = get_config("deepseek-coder-33b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(2))
    rng = np.random.default_rng(3)
    b, s = 2, 8
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)

    # parallel hidden states → logits at each position
    hs = model.hidden_states(params, tokens, remat=False)
    from repro.models.layers import logits_head
    want = jax.vmap(lambda t: logits_head(hs[:, t], params["embed"]),
                    out_axes=1)(jnp.arange(s))

    cache = model.init_cache(b, s)
    outs = []
    for t in range(s):
        lg, cache = model.decode_step(params, cache, tokens[:, t],
                                      jnp.asarray(t, jnp.int32))
        outs.append(lg)
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0.15, atol=0.15)


def test_ssm_decode_matches_forward():
    """Mamba2: chunked SSD scan ≡ step-by-step recurrence."""
    cfg = get_config("mamba2-780m").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(4))
    rng = np.random.default_rng(5)
    b, s = 2, 16
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    hs = model.hidden_states(params, tokens, remat=False)
    from repro.models.layers import logits_head
    want = logits_head(hs[:, -1], params["embed"])
    cache = model.init_cache(b, s)
    for t in range(s):
        got, cache = model.decode_step(params, cache, tokens[:, t],
                                       jnp.asarray(t, jnp.int32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0.2, atol=0.2)
