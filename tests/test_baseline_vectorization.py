"""Vectorized stepwise-baseline + process-sharding correctness (PR 3).

Pins this PR's contracts: the batched ``stepwise_search`` Search-mode sweep
is BIT-identical to the seed per-pair loop (same designs, same
``evaluations``, same pair visit order under the count-based budget), the
``tile_fits_batch`` ratio-vector predicate replays scalar ``tile_fits``
exactly, ``cosearch_multi(executor="process")`` merges to the identical
result as the serial path, and ``memo.export_state``/``import_state``
round-trip the cache registry.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import memo
from repro.core.arch import ARCH2, ARCH3
from repro.core.baselines import stepwise_search
from repro.core.cosearch import CoSearchConfig, SearchError, cosearch_multi
from repro.core.dataflow import (DIMS, enumerate_mappings, tile_fits,
                                 tile_fits_batch)
from repro.core.engine import EngineConfig
from repro.core.sparsity import Bernoulli
from repro.core.workload import LLMSpec, MatMul, Workload, build_llm

FAST = CoSearchConfig(engine=EngineConfig(max_levels=2,
                                          max_allocs_per_pattern=16),
                      spatial_top=2, max_pairs=6)


def _two_op_workload():
    return Workload("two", (
        MatMul("m1", 64, 96, 64, Bernoulli(0.5), Bernoulli(0.3)),
        MatMul("m2", 128, 64, 96, Bernoulli(0.4), Bernoulli(0.6)),
    ))


def _fingerprint(res):
    return (res.evaluations, res.design.energy, res.design.cycles,
            tuple((str(o.mapping), str(o.fmt_i), str(o.fmt_w))
                  for o in res.design.ops))


# ---------------------------------------------------------------------------
# tile_fits_batch
# ---------------------------------------------------------------------------

def test_tile_fits_batch_matches_scalar():
    """Each (ratio pair, tile) cell of the legality matrix equals the
    scalar predicate — including ratios that flip tiles across the GLB
    capacity edge."""
    op = MatMul("m", 512, 512, 512, Bernoulli(0.5), Bernoulli(0.3))
    mappings = list(enumerate_mappings(op, ARCH2, spatial_top=2))[:150]
    tiles = np.array([[m.tile[d] for d in DIMS] for m in mappings], np.int64)
    # ratios above 1.0 model metadata overshooting dense (the stepwise
    # correction-loop case) and flip the largest tiles illegal
    ri = np.array([1.0, 0.42, 1.8, 0.08])
    rw = np.array([1.0, 0.77, 1.8, 0.05])
    got = tile_fits_batch(op, tiles, ARCH2, ri, rw)
    assert got.shape == (4, len(mappings))
    for p in range(4):
        want = [tile_fits(op, m.tile, ARCH2, float(ri[p]), float(rw[p]))
                for m in mappings]
        assert got[p].tolist() == want
    # both legality outcomes must occur somewhere, else the test is vacuous
    assert got.any() and not got.all()


# ---------------------------------------------------------------------------
# stepwise_search: batch vs scalar
# ---------------------------------------------------------------------------

def test_stepwise_search_mode_batch_bit_identical():
    """Search mode under the count-based budget: same designs, same
    evaluation count, same pair visit order."""
    wl = _two_op_workload()
    log_s, log_b = [], []
    memo.clear()
    with memo.disabled():
        scalar = stepwise_search(wl, ARCH2, FAST, search_formats=True,
                                 budget_pairs_per_op=120, use_batch=False,
                                 pair_log=log_s)
    memo.clear()
    batch = stepwise_search(wl, ARCH2, FAST, search_formats=True,
                            budget_pairs_per_op=120, use_batch=True,
                            pair_log=log_b)
    assert log_s == log_b
    assert len(log_s) == 120 * len(wl.ops)      # budget replayed exactly
    assert _fingerprint(scalar) == _fingerprint(batch)


def test_stepwise_fixed_mode_batch_bit_identical():
    wl = build_llm(LLMSpec("tiny", 2, 256, 1024, 4), seq=64,
                   act_density=0.4, w_density=0.25)
    memo.clear()
    with memo.disabled():
        scalar = stepwise_search(wl, ARCH3, FAST,
                                 fixed_formats=("Bitmap", "Bitmap"),
                                 use_batch=False)
    memo.clear()
    batch = stepwise_search(wl, ARCH3, FAST,
                            fixed_formats=("Bitmap", "Bitmap"),
                            use_batch=True)
    assert _fingerprint(scalar) == _fingerprint(batch)


@pytest.mark.parametrize("use_batch", [False, True])
def test_stepwise_count_budget_deterministic(use_batch):
    """budget_pairs_per_op visits exactly that many pairs per op, and two
    runs replay the identical visit order."""
    wl = Workload("one", (MatMul("m", 64, 96, 64,
                                 Bernoulli(0.5), Bernoulli(0.3)),))
    logs = []
    for _ in range(2):
        log: list = []
        memo.clear()
        stepwise_search(wl, ARCH2, FAST, search_formats=True,
                        budget_pairs_per_op=75, use_batch=use_batch,
                        pair_log=log)
        assert len(log) == 75
        logs.append(log)
    assert logs[0] == logs[1]


@pytest.mark.parametrize("use_batch", [False, True])
def test_stepwise_raises_search_error_with_op_context(use_batch):
    tiny_glb = dataclasses.replace(ARCH3.levels[1], capacity_bits=8.0)
    doomed_arch = dataclasses.replace(
        ARCH3, name="tiny-glb",
        levels=(ARCH3.levels[0], tiny_glb, ARCH3.levels[2]))
    wl = Workload("doomed", (MatMul("big", 64, 64, 64,
                                    Bernoulli(0.5), Bernoulli(0.5)),))
    with pytest.raises(SearchError) as ei:
        stepwise_search(wl, doomed_arch, FAST,
                        fixed_formats=("Bitmap", "Bitmap"),
                        use_batch=use_batch)
    assert ei.value.op == "big"
    assert "big" in str(ei.value)


# ---------------------------------------------------------------------------
# cosearch_multi: process executor
# ---------------------------------------------------------------------------

def _two_tiny_workloads():
    wl_a = build_llm(LLMSpec("A", 2, 256, 1024, 4), seq=64,
                     act_density=0.2, w_density=0.2)
    wl_b = build_llm(LLMSpec("B", 2, 256, 1024, 4), seq=64,
                     act_density=0.8, w_density=0.8)
    return wl_a, wl_b


@pytest.mark.slow
def test_cosearch_multi_process_executor_deterministic():
    """The process pool (picklable items + per-worker memo snapshot) merges
    to the identical result as the serial path — designs, eval counts,
    winning pair, weighted metric."""
    wls = list(_two_tiny_workloads())
    imp = {"A": 99.0, "B": 1.0}
    memo.clear()
    d1, k1, v1 = cosearch_multi(wls, ARCH3, imp, FAST)
    memo.clear()
    d2, k2, v2 = cosearch_multi(wls, ARCH3, imp, FAST, workers=2,
                                executor="process")
    assert (k1, v1) == (k2, v2)
    assert set(d1) == set(d2)
    for name in d1:
        assert _fingerprint(d1[name]) == _fingerprint(d2[name])


def test_second_model_sharing_shapes_replays_search():
    """Two models with identical op shapes/sparsity: the second one's
    per-op searches all hit the ``_search_op`` cache the first one filled
    — its ``evaluations`` replay the recorded counts while
    ``fresh_evaluations`` (work actually recomputed) drops to zero."""
    wl_a = build_llm(LLMSpec("A", 2, 256, 1024, 4), seq=64,
                     act_density=0.3, w_density=0.2)
    wl_b = build_llm(LLMSpec("B", 2, 256, 1024, 4), seq=64,
                     act_density=0.3, w_density=0.2)
    memo.clear()
    designs, _, _ = cosearch_multi([wl_a, wl_b], ARCH3,
                                   {"A": 1.0, "B": 1.0}, FAST)
    ra, rb = designs["A"], designs["B"]
    assert ra.evaluations == rb.evaluations > 0
    assert ra.stats.fresh_evaluations == ra.stats.evaluations
    assert rb.stats.fresh_evaluations == 0


@pytest.mark.slow
def test_process_cache_return_ships_results_to_parent():
    """PR-4 regression: process workers used to keep their ``_search_op``
    results to themselves, so the parent recomputed every shared-shape op
    on the next search.  Workers now ship their
    ``_search_op``/compile/``mapping_ctx`` memo deltas back with each item
    and the parent imports them — a follow-up co-search over the same
    models replays entirely (second run's ``SearchStats.fresh_evaluations``
    drops to zero; ``evaluations`` replays the identical counts, so
    results stay bit-identical)."""
    wls = list(_two_tiny_workloads())
    imp = {"A": 99.0, "B": 1.0}
    memo.clear()
    d1, k1, v1 = cosearch_multi(wls, ARCH3, imp, FAST, workers=2,
                                executor="process")
    # the parent registry absorbed the workers' per-op search results
    assert memo.export_state(names=["search_op"])["search_op"]
    d2, k2, v2 = cosearch_multi(wls, ARCH3, imp, FAST)
    assert (k1, v1) == (k2, v2)
    for name in d2:
        assert _fingerprint(d1[name]) == _fingerprint(d2[name])
        assert d2[name].stats.evaluations == d2[name].evaluations > 0
        assert d2[name].stats.fresh_evaluations == 0


@pytest.mark.slow
def test_process_workers_threaded_tail_after_parent_pool():
    """Fork-safety regression: a forked worker inherits the parent's
    evaluator thread-pool OBJECT but not its threads — submitting to it
    would block forever.  The at-fork reset makes each child lazily build
    its own pool, so a process run with ``eval_threads`` forced on still
    completes and matches the serial results."""
    from repro.core import costmodel
    cfg = dataclasses.replace(FAST, eval_threads=2)
    wls = list(_two_tiny_workloads())
    imp = {"A": 99.0, "B": 1.0}
    memo.clear()
    d1, k1, v1 = cosearch_multi(wls, ARCH3, imp, cfg)
    assert costmodel._EVAL_POOL is not None   # parent pool exists pre-fork
    memo.clear()
    d2, k2, v2 = cosearch_multi(wls, ARCH3, imp, cfg, workers=2,
                                executor="process")
    assert (k1, v1) == (k2, v2)
    for name in d1:
        assert _fingerprint(d1[name]) == _fingerprint(d2[name])


def test_cosearch_multi_rejects_unknown_executor():
    wls = list(_two_tiny_workloads())
    with pytest.raises(ValueError, match="executor"):
        cosearch_multi(wls, ARCH3, {"A": 1.0, "B": 1.0}, FAST,
                       workers=2, executor="greenlet")


# ---------------------------------------------------------------------------
# memo export/import
# ---------------------------------------------------------------------------

def test_memo_export_import_round_trip():
    cache = memo.register({}, "roundtrip-test-cache")
    cache[("k", 1)] = {"v": np.arange(3)}
    cache[("k", 2)] = 7
    state = memo.export_state(names=["roundtrip-test-cache"])
    assert set(state) == {"roundtrip-test-cache"}
    assert set(state["roundtrip-test-cache"]) == {("k", 1), ("k", 2)}
    cache.clear()
    memo.import_state(state)
    assert cache[("k", 2)] == 7
    assert cache[("k", 1)]["v"].tolist() == [0, 1, 2]


def test_memo_export_drops_unpicklable_entries():
    cache = memo.register({}, "unpicklable-test-cache")
    cache["ok"] = 1
    cache["bad"] = lambda: None          # lambdas do not pickle
    state = memo.export_state(names=["unpicklable-test-cache"])
    assert state["unpicklable-test-cache"] == {"ok": 1}


def test_memo_import_keeps_existing_and_ignores_unknown():
    cache = memo.register({}, "import-test-cache")
    cache["k"] = "existing"
    memo.import_state({"import-test-cache": {"k": "snapshot", "k2": 2},
                       "no-such-cache": {"x": 1}})
    assert cache["k"] == "existing"      # existing entries win
    assert cache["k2"] == 2
