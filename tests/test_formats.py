"""Unit + property tests for the hierarchical format encoding (§III-B)."""

import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.core import formats as F
from repro.core.formats import Format, Level
from repro.core.primitives import Prim, clog2
from repro.core.sparsity import Bernoulli, NM, TensorSpec, analyze, analyze_exact


DIMS = {"M": 16, "N": 32}


def test_standard_format_shapes():
    fmts = F.standard_formats(DIMS)
    assert set(fmts) == {"Bitmap", "RLE", "CSR", "COO"}
    for f in fmts.values():
        f.validate(DIMS)


def test_validate_rejects_bad_allocation():
    f = Format.of(Level(Prim.B, "M", 4), Level(Prim.B, "N", 32))
    with pytest.raises(ValueError):
        f.validate(DIMS)   # M covers 4 != 16


def test_csc_matches_paper_example():
    # §III-B: CSC over M×N is UOP(N)-CP(M), UOP at the higher level.
    f = F.csc({"M": 3, "N": 6})
    assert f.levels[0].prim is Prim.UOP and f.levels[0].dim == "N"
    assert f.levels[1].prim is Prim.CP and f.levels[1].dim == "M"


def test_factorizations_cover_and_multiply():
    for parts in (1, 2, 3):
        for fac in F.factorizations(24, parts):
            assert len(fac) == parts
            assert math.prod(fac) == 24


def test_allocate_splits_dims():
    pattern = (Level(Prim.UOP, "N"), Level(Prim.CP, "M"), Level(Prim.CP, "N"))
    allocs = list(F.allocate(pattern, {"M": 3, "N": 6}))
    assert allocs, "expected at least one allocation"
    for fmt in allocs:
        fmt.validate({"M": 3, "N": 6})
        # paper example: N split into subdims (3,2) must be present
    keys = {tuple(int(l.size) for l in fmt.levels) for fmt in allocs}
    assert any(k[0] == 3 and k[2] == 2 for k in keys)


def test_enumerate_patterns_no_leaf_uop():
    pats = list(F.enumerate_patterns(("M", "N"), max_levels=2))
    for p in pats:
        assert p[-1].prim is not Prim.UOP
    # 1-level: 2 dims × 3 prims (no UOP leaf) = 6; 2-level: 4 dim pairs ×
    # (4 prims × 3 prims) = 48 → 54 total.
    assert len(pats) == 54


# ---------------------------------------------------------------------------
# Size analytics: exact vs closed-form on hand-checkable cases
# ---------------------------------------------------------------------------

def test_bitmap_exact_bits():
    dims = {"M": 8, "N": 8}
    mask = np.zeros((8, 8), dtype=bool)
    mask[0, 0] = mask[3, 4] = True
    rep = analyze_exact(F.bitmap(dims), mask, dims, value_bits=16)
    assert rep.metadata_bits == 64          # one bit per element
    assert rep.payload_bits == 2 * 16


def test_csr_exact_bits():
    dims = {"M": 4, "N": 8}
    mask = np.zeros((4, 8), dtype=bool)
    mask[0, :3] = True                      # 3 nnz in row 0
    rep = analyze_exact(F.csr(dims), mask, dims, value_bits=16)
    # UOP: (4+1) pointers × clog2(max_row_nnz+1)=2 bits; CP: 3 × clog2(8)=3
    assert rep.metadata_bits == 5 * 2 + 3 * 3
    assert rep.payload_bits == 3 * 16


def test_hierarchical_bitmap_prunes_empty_groups():
    # Fig. 5 mechanism: an all-zero half costs 1 top bit, not its full bitmap.
    dims = {"M": 4, "N": 8}
    mask = np.zeros((4, 8), dtype=bool)
    mask[:, :4] = True                      # left half dense, right half empty
    flat = analyze_exact(F.bitmap(dims), mask, dims)
    hier = Format.of(Level(Prim.B, "N", 2), Level(Prim.NONE, "M", 4),
                     Level(Prim.B, "N", 4))
    h = analyze_exact(hier, mask, dims)
    assert h.metadata_bits < flat.metadata_bits


def test_expectation_matches_dense_limit():
    spec = TensorSpec({"M": 16, "N": 32}, Bernoulli(1.0))
    rep = analyze(F.bitmap(spec.dims), spec)
    assert rep.payload_bits == spec.dense_bits
    assert rep.metadata_bits == 16 * 32


@settings(max_examples=25, deadline=None)
@given(density=st.floats(0.05, 0.95), seed=st.integers(0, 2**31 - 1))
def test_expectation_matches_monte_carlo_bitmap(density, seed):
    """Law of large numbers: expectation model ≈ exact counts on random masks."""
    dims = {"M": 64, "N": 64}
    rng = np.random.default_rng(seed)
    mask = rng.random((64, 64)) < density
    fmt = F.bitmap(dims)
    exact = analyze_exact(fmt, mask, dims)
    est = analyze(fmt, TensorSpec(dims, Bernoulli(density)))
    assert est.metadata_bits == exact.metadata_bits          # bitmap is exact
    assert est.payload_bits == pytest.approx(exact.payload_bits, rel=0.25)


@settings(max_examples=20, deadline=None)
@given(density=st.floats(0.05, 0.9), seed=st.integers(0, 2**31 - 1))
def test_expectation_matches_monte_carlo_hierarchical(density, seed):
    dims = {"M": 64, "N": 64}
    rng = np.random.default_rng(seed)
    mask = rng.random((64, 64)) < density
    fmt = Format.of(Level(Prim.B, "M", 8), Level(Prim.B, "N", 8),
                    Level(Prim.B, "M", 8), Level(Prim.B, "N", 8))
    exact = analyze_exact(fmt, mask, dims)
    est = analyze(fmt, TensorSpec(dims, Bernoulli(density)))
    assert est.total_bits == pytest.approx(exact.total_bits, rel=0.2)


def test_nm_sparsity_model():
    nm = NM(2, 4)
    assert nm.density == 0.5
    assert nm.prob_nonempty(4) == 1.0
    assert nm.prob_nonempty(1) == pytest.approx(0.5)
    assert nm.prob_nonempty(2) == pytest.approx(1 - 1 / 6)
    assert nm.expected_nnz(8) == 4.0


def test_deeper_format_smaller_payload_at_high_sparsity():
    """Hierarchical formats beat flat bitmap when sparsity is high (Fig. 5)."""
    dims = {"M": 256, "N": 256}
    spec = TensorSpec(dims, Bernoulli(0.05))
    flat = analyze(F.bitmap(dims), spec)
    hier = Format.of(Level(Prim.B, "M", 16), Level(Prim.B, "N", 16),
                     Level(Prim.B, "M", 16), Level(Prim.B, "N", 16))
    h = analyze(hier, spec)
    assert h.metadata_bits < flat.metadata_bits
