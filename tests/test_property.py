"""Hypothesis property tests on system invariants.

These pin down the physics of the modeling plane: monotonicity in density,
conservation between exact and expected analyses, legality of every design
the searches emit, and idempotence/determinism guarantees the distributed
runtime depends on.
"""

import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.core import formats as F
from repro.core.arch import ARCH2, ARCH3
from repro.core.costmodel import compile_format, dense_format, evaluate
from repro.core.dataflow import enumerate_mappings, tile_fits
from repro.core.engine import EngineConfig, generate_candidates
from repro.core.sparsity import Bernoulli, TensorSpec, analyze
from repro.core.workload import MatMul


@settings(max_examples=20, deadline=None)
@given(rho=st.floats(0.02, 0.98))
def test_compressed_size_monotone_in_density(rho):
    """More non-zeros can never make the SAME format smaller."""
    dims = {"M": 256, "N": 512}
    lo = analyze(F.bitmap(dims), TensorSpec(dims, Bernoulli(rho)))
    hi = analyze(F.bitmap(dims), TensorSpec(dims, Bernoulli(min(rho + 0.01, 1.0))))
    assert hi.total_bits >= lo.total_bits - 1e-6


@settings(max_examples=20, deadline=None)
@given(rho=st.floats(0.05, 0.95), seed=st.integers(0, 2**31 - 1))
def test_csr_exact_vs_expected(rho, seed):
    """Expectation model tracks exact counts for CSR on random masks."""
    dims = {"M": 96, "N": 128}
    rng = np.random.default_rng(seed)
    mask = rng.random((96, 128)) < rho
    from repro.core.sparsity import analyze_exact
    exact = analyze_exact(F.csr(dims), mask, dims)
    est = analyze(F.csr(dims), TensorSpec(dims, Bernoulli(rho)))
    assert est.total_bits == pytest.approx(exact.total_bits, rel=0.25)


@settings(max_examples=10, deadline=None)
@given(rho_i=st.floats(0.1, 1.0), rho_w=st.floats(0.1, 1.0))
def test_energy_monotone_in_density(rho_i, rho_w):
    """Denser operands cost at least as much energy (same mapping/format)."""
    op_lo = MatMul("p", 128, 256, 128, Bernoulli(rho_i * 0.9),
                   Bernoulli(rho_w * 0.9))
    op_hi = MatMul("p", 128, 256, 128, Bernoulli(rho_i), Bernoulli(rho_w))
    m = next(iter(enumerate_mappings(op_hi, ARCH3)))

    def cost(op):
        cf_i = compile_format(F.bitmap(op.i_dims()),
                              TensorSpec(op.i_dims(), op.sp_i))
        cf_w = compile_format(F.bitmap(op.w_dims()),
                              TensorSpec(op.w_dims(), op.sp_w))
        return evaluate(op, ARCH3, m, cf_i, cf_w).energy

    assert cost(op_hi) >= cost(op_lo) * 0.999


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_every_enumerated_mapping_is_legal(seed):
    rng = np.random.default_rng(seed)
    m_, n_, k_ = (int(rng.choice([64, 128, 256, 384])) for _ in range(3))
    op = MatMul("r", m_, n_, k_)
    for mapping in enumerate_mappings(op, ARCH2, spatial_top=2):
        sp = mapping.spatial
        assert sp["M"] * sp["N"] * sp["K"] <= ARCH2.macs
        assert tile_fits(op, mapping.tile, ARCH2)
        for d in ("M", "N", "K"):
            assert mapping.tile[d] >= 1


@settings(max_examples=8, deadline=None)
@given(rho=st.floats(0.03, 0.5))
def test_candidates_never_worse_than_dense(rho):
    """Every surviving candidate compresses (EqData < dense bits)."""
    spec = TensorSpec({"M": 512, "N": 512}, Bernoulli(rho))
    cands = generate_candidates(spec, EngineConfig(max_levels=2,
                                                   max_allocs_per_pattern=16))
    assert cands
    for c in cands[:4]:
        assert c.report.total_bits < spec.dense_bits


@settings(max_examples=10, deadline=None)
@given(step=st.integers(0, 10_000), seed=st.integers(0, 2**31 - 1))
def test_pipeline_pure_function_of_step(step, seed):
    from repro.data.pipeline import TokenPipeline
    p = TokenPipeline(vocab=997, seq_len=8, global_batch=2, seed=seed)
    a = p.batch_at(step)
    b = p.batch_at(step)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


@settings(max_examples=10, deadline=None)
@given(rho=st.floats(0.05, 0.6), bn=st.sampled_from([8, 16, 32]))
def test_bitmap_compression_roundtrip_property(rho, bn):
    """compress → kernel-format metadata is self-consistent: counts sum to
    blocks, row ids are in range, reconstruction matches the mask."""
    from repro.kernels import ref
    rng = np.random.default_rng(int(rho * 1e6) + bn)
    n = k = 128
    gn, gk = n // bn, k // bn
    bitmap = rng.random((gn, gk)) < rho
    w = rng.normal(size=(n, k)).astype(np.float32)
    w *= np.repeat(np.repeat(bitmap, bn, 0), bn, 1)
    blocks, counts, row_ids, offsets, bm = ref.compress_bitmap_host(w, bn, bn)
    assert counts.sum() == bitmap.sum()
    assert (row_ids[: max(counts.sum(), 1)] < gn).all()
    assert (bm == bitmap).all()
