"""Progressive co-search + baseline workflow tests (§III-D, Table I)."""

import pytest

from repro.core.arch import ARCH2, ARCH3
from repro.core.baselines import dimo_like_search, stepwise_search
from repro.core.cosearch import CoSearchConfig, cosearch, cosearch_multi
from repro.core.engine import EngineConfig
from repro.core.sparsity import Bernoulli
from repro.core.workload import LLMSpec, MatMul, Workload, build_llm


TINY = LLMSpec("tiny", layers=2, d_model=256, d_ff=1024, heads=4)
FAST = CoSearchConfig(engine=EngineConfig(max_levels=2, max_allocs_per_pattern=16),
                      spatial_top=2, max_pairs=6)


def _wl():
    return build_llm(TINY, seq=128, decode_tokens=8,
                     act_density=0.4, w_density=0.25)


def test_cosearch_fixed_mode_runs():
    res = cosearch(_wl(), ARCH3, FAST, fixed_formats=("Bitmap", "Bitmap"))
    assert res.design.energy > 0 and res.design.cycles > 0
    assert len(res.design.ops) == len(_wl().ops)
    assert res.evaluations > 0


def test_cosearch_search_beats_or_matches_fixed():
    """Format search must never lose to the best preset format (it can
    always fall back to it)."""
    wl = _wl()
    searched = cosearch(wl, ARCH3, FAST)
    fixed_best = min(
        cosearch(wl, ARCH3, FAST, fixed_formats=(f, f)).design.metric("edp")
        for f in ("Bitmap", "RLE"))
    assert searched.design.metric("edp") <= fixed_best * 1.001


def test_cosearch_dense_workload_picks_no_format():
    wl = build_llm(TINY, seq=128, act_density=1.0, w_density=1.0)
    res = cosearch(wl, ARCH3, FAST)
    assert res.design.pattern_i is None and res.design.pattern_w is None


def test_compression_reduces_memory_energy_vs_dense():
    wl = _wl()
    comp = cosearch(wl, ARCH3, FAST, fixed_formats=("Bitmap", "Bitmap"))
    dense = cosearch(wl, ARCH3, FAST, fixed_formats=(None, None))
    assert comp.design.memory_energy < dense.design.memory_energy


def test_stepwise_matches_quality_but_costs_more_models():
    """The Table-I claim: same cost model, same fixed format — the stepwise
    workflow needs strictly more model evaluations than progressive."""
    wl = _wl()
    prog = cosearch(wl, ARCH3, FAST, fixed_formats=("Bitmap", "Bitmap"))
    step = stepwise_search(wl, ARCH3, FAST, fixed_formats=("Bitmap", "Bitmap"))
    assert step.evaluations > prog.evaluations
    # quality parity within a small factor (stepwise shortlists can miss)
    assert step.design.metric("edp") >= prog.design.metric("edp") * 0.95


def test_stepwise_search_mode_has_budget():
    # count-based budget: deterministic, the default for benchmarks/tests
    wl = Workload("one", (MatMul("m", 64, 96, 64,
                                 Bernoulli(0.5), Bernoulli(0.3)),))
    res = stepwise_search(wl, ARCH2, FAST, search_formats=True,
                          budget_pairs_per_op=60)
    assert res.design.energy > 0


@pytest.mark.parametrize("use_batch", [False, True])
def test_stepwise_wall_clock_budget_still_yields_design(use_batch):
    # a zero wall-clock budget cuts the sweep after its first pair/chunk
    # but must still return the best design seen so far
    wl = Workload("one", (MatMul("m", 64, 96, 64,
                                 Bernoulli(0.5), Bernoulli(0.3)),))
    res = stepwise_search(wl, ARCH2, FAST, search_formats=True,
                          budget_s_per_op=0.0, use_batch=use_batch)
    assert res.design.energy > 0


def test_dimo_like_search_runs():
    wl = _wl()
    res = dimo_like_search(wl, ARCH3, FAST, restarts=2, iters=20)
    assert res.design.energy > 0
    assert res.evaluations >= 2 * len(wl.ops)


@pytest.mark.slow
def test_multi_model_importance_selection():
    wl_a = build_llm(LLMSpec("A", 2, 256, 1024, 4), seq=64,
                     act_density=0.2, w_density=0.2)
    wl_b = build_llm(LLMSpec("B", 2, 256, 1024, 4), seq=64,
                     act_density=0.8, w_density=0.8)
    designs, key, val = cosearch_multi(
        [wl_a, wl_b], ARCH3, importance={"A": 99.0, "B": 1.0}, cfg=FAST)
    assert set(designs) == {"A", "B"}
    assert val > 0
