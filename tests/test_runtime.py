"""Robustness-layer tests: integrity, fault injection, guarded degradation.

Contracts pinned here (the PR-8 acceptance gates):

  * ``compress_params`` records per-role content checksums in the plan;
    the plan — version, checksums, fallbacks — JSON round-trips
    bit-identically, and a FUTURE schema version fails with a structured
    :class:`PlanVersionError`, not a ``KeyError``;
  * every injected fault class is DETECTED: payload bit-flips (per-layer
    and stacked stores) by checksum, structural corruption by the
    invariant checks alone (checksums stripped to prove it), NaN
    activations by the non-finite logit guard, kernel failures by the
    dispatch guard;
  * every injected fault class RECOVERS to the correct dense result:
    guarded greedy decode stays bit-identical to the dense model at fp32
    on bitmap plans, faults injected or not (dense fallbacks serve the
    same pruned tree the kernels encode);
  * the :class:`HealthReport` says exactly what happened, JSON
    round-trips, and its ``stable_dict`` projection is deterministic —
    two guarded runs with the same seed diff clean (the CI
    fault-injection job re-checks this end to end);
  * the previously train-only fault primitives are live: ``StepGuard``
    bounded retry, ``StragglerMonitor`` → ``elastic_remesh`` →
    ``degraded_serve_mesh``;
  * a killed ``cosearch_multi`` resumes from its ``memo_autosave``
    snapshot with bit-identical results;
  * a malformed model family raises a structured error instead of
    silently serving through the default dense cache path.
"""

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import exec as rexec
from repro.configs import get_config
from repro.core import memo
from repro.core import cosearch as cosearch_mod
from repro.core.arch import ARCH3
from repro.core.cosearch import CoSearchConfig, cosearch_multi
from repro.core.engine import EngineConfig
from repro.core.sparsity import NM, BlockBernoulli
from repro.core.workload import LLMSpec, build_llm
from repro.exec.plans import (PLAN_VERSION, ExecPlan, FallbackReason,
                              PlanVersionError)
from repro.launch import serve
from repro.launch.mesh import degraded_serve_mesh
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models.transformer import KNOWN_FAMILIES, Model
from repro.runtime import fault, inject, integrity
from repro.runtime.guard import HealthReport, guarded_generate

FAST = CoSearchConfig(objective="edp",
                      engine=EngineConfig(max_levels=2,
                                          max_allocs_per_pattern=16),
                      spatial_top=2, max_pairs=6)
BLOCK = BlockBernoulli(0.5, 32 * 32)


@pytest.fixture()
def fp32_compute(monkeypatch):
    monkeypatch.setattr(L, "COMPUTE_DTYPE", jnp.float32)
    monkeypatch.setattr(attn_mod, "COMPUTE_DTYPE", jnp.float32)


@pytest.fixture(scope="module")
def serving():
    """(cfg, model, plan, pruned, store) for an all-bitmap plan — built
    once; the store/plan are never mutated (injectors return new stores)."""
    cfg = get_config("chatglm3-6b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    plan = rexec.build_exec_plan(cfg, BLOCK, tokens=64, search_cfg=FAST,
                                 value_bits=32)
    pruned = rexec.prune_params(params, plan, cfg)
    store = rexec.compress_params(pruned, plan, cfg)
    return cfg, model, plan, pruned, store


@pytest.fixture(scope="module")
def serving_nm():
    """Same, for an N:M plan (exercises the nm digest + invariants)."""
    cfg = get_config("chatglm3-6b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    plan = rexec.build_exec_plan(cfg, NM(2, 4), tokens=64, search_cfg=FAST,
                                 value_bits=32)
    pruned = rexec.prune_params(params, plan, cfg)
    store = rexec.compress_params(pruned, plan, cfg)
    return cfg, model, plan, pruned, store


@pytest.fixture(scope="module")
def ref_tokens(serving):
    """Dense greedy reference at fp32 (the recovery target), plus the
    prompts that produced it — computed once for the whole module."""
    cfg, model, plan, pruned, store = serving
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)), jnp.int32)
    orig_l, orig_a = L.COMPUTE_DTYPE, attn_mod.COMPUTE_DTYPE
    L.COMPUTE_DTYPE = attn_mod.COMPUTE_DTYPE = jnp.float32
    try:
        toks, _, _ = serve.generate(model, pruned, prompts, 4, 12)
    finally:
        L.COMPUTE_DTYPE, attn_mod.COMPUTE_DTYPE = orig_l, orig_a
    return prompts, toks


def _bitmap_role(plan) -> str:
    return next(op.role for op in plan.ops if op.choice.kind == "bitmap")


def _strip_checksums(store):
    return rexec.CompressedStore(
        dataclasses.replace(store.plan, checksums={}), store.entries)


# ---------------------------------------------------------------------------
# checksums + plan schema
# ---------------------------------------------------------------------------

def test_checksums_recorded_and_plan_roundtrips(serving):
    cfg, model, plan_in, pruned, store = serving
    plan = store.plan
    assert plan.version == PLAN_VERSION
    assert set(plan.checksums) == {op.role for op in plan.ops}
    assert all(len(h) == 64 for h in plan.checksums.values())   # sha256 hex
    # the input plan object is untouched (compress returns a NEW plan)
    assert plan_in.checksums == {}

    rt = ExecPlan.from_json(plan.to_json())
    assert rt == plan
    assert rt.checksums == plan.checksums and rt.version == PLAN_VERSION


def test_verify_ok_on_clean_store_and_stacked(serving):
    cfg, model, plan, pruned, store = serving
    assert set(store.verify().values()) == {"ok"}
    cm = rexec.CompressedModel(model, store)
    stacked_ok = cm.stacked.verify()
    assert stacked_ok and set(stacked_ok.values()) == {"ok"}
    combined = cm.verify()
    assert set(combined) == set(store.verify())
    assert integrity.verify_report(store) == {r: "ok" for r in combined}


def test_future_plan_version_is_a_structured_error(serving):
    d = serving[4].plan.to_dict()
    d["version"] = PLAN_VERSION + 1
    d.pop("ops")    # version must be checked BEFORE any field access
    with pytest.raises(PlanVersionError) as ei:
        ExecPlan.from_dict(d)
    assert ei.value.found == PLAN_VERSION + 1
    assert ei.value.supported == PLAN_VERSION
    assert isinstance(ei.value, ValueError)
    assert str(PLAN_VERSION + 1) in str(ei.value)


def test_v1_plan_without_version_key_still_loads(serving):
    d = serving[4].plan.to_dict()
    del d["version"]
    del d["checksums"]
    p1 = ExecPlan.from_dict(d)
    assert p1.version == 1 and p1.checksums == {}
    # the declared version survives its own round trip (no silent upgrade)
    assert json.loads(p1.to_json())["version"] == 1
    assert ExecPlan.from_json(p1.to_json()) == p1
    # and a v1 store (no recorded digests) still gets structure checks
    assert set(_strip_checksums(serving[4]).verify().values()) == {"ok"}


def test_fallback_reason_json_roundtrip(serving):
    plan = serving[4].plan
    fb = FallbackReason("kernel_failure", "injected: bitmap")
    op0 = plan.ops[0]
    bad = dataclasses.replace(
        op0, choice=dataclasses.replace(op0.choice, fallback=fb))
    plan2 = dataclasses.replace(plan, ops=(bad,) + plan.ops[1:])
    rt = ExecPlan.from_json(plan2.to_json())
    assert rt == plan2
    assert rt.fallbacks()[op0.role] == fb
    assert rt.fallback_counts() == {"kernel_failure": 1}


# ---------------------------------------------------------------------------
# fault injection → detection
# ---------------------------------------------------------------------------

def test_bitflip_payload_detected_by_checksum(serving):
    cfg, model, plan, pruned, store = serving
    role = _bitmap_role(plan)
    bad = inject.bitflip_payload(store, role, seed=3)
    assert store.verify()     # the original is untouched
    with pytest.raises(integrity.IntegrityError) as ei:
        bad.verify()
    assert ei.value.role == role
    assert ei.value.reason == "checksum_mismatch"
    rep = integrity.verify_report(bad)
    assert rep[role] == "checksum_mismatch"
    assert all(v == "ok" for r, v in rep.items() if r != role)


def test_bitflip_stacked_detected(serving):
    cfg, model, plan, pruned, store = serving
    role = _bitmap_role(plan)
    cm = rexec.CompressedModel(model, store)
    bad = inject.bitflip_stacked(cm.stacked, role)
    with pytest.raises(integrity.IntegrityError) as ei:
        bad.verify()
    assert ei.value.role == role
    assert ei.value.reason == "checksum_mismatch"


@pytest.mark.parametrize("mode",
                         [m for m in inject.STRUCTURAL_MODES
                          if m != "nm_indices_oob"])
def test_structural_corruption_detected_without_checksums(serving, mode):
    """Structural breaks must be caught by the invariants ALONE — strip
    the recorded digests so a checksum match can't mask a weak check."""
    cfg, model, plan, pruned, store = serving
    role = _bitmap_role(plan)
    bad = inject.corrupt_structure(_strip_checksums(store), role, mode)
    with pytest.raises(integrity.IntegrityError) as ei:
        bad.verify()
    assert ei.value.reason == inject.STRUCTURAL_MODES[mode]
    assert ei.value.role == role and ei.value.layer == 0


def test_nm_corruption_detected(serving_nm):
    cfg, model, plan, pruned, store = serving_nm
    role = next(op.role for op in plan.ops if op.choice.kind == "nm")
    bad = inject.corrupt_structure(_strip_checksums(store), role,
                                   "nm_indices_oob")
    with pytest.raises(integrity.IntegrityError) as ei:
        bad.verify()
    assert ei.value.reason == "nm_index_out_of_range"
    flipped = inject.bitflip_payload(store, role, seed=1)
    with pytest.raises(integrity.IntegrityError) as ei:
        flipped.verify()
    assert ei.value.reason == "checksum_mismatch"
    assert set(store.verify().values()) == {"ok"}


def test_unknown_corruption_mode_rejected(serving):
    with pytest.raises(ValueError, match="unknown corruption mode"):
        inject.corrupt_structure(serving[4], _bitmap_role(serving[2]),
                                 "melt_the_weights")


# ---------------------------------------------------------------------------
# guarded serving: no-fault baseline
# ---------------------------------------------------------------------------

def test_guarded_bit_identical_to_dense_when_healthy(fp32_compute, serving,
                                                     ref_tokens):
    """Acceptance: the guarded path changes NOTHING when nothing is wrong
    — tokens bit-identical to dense greedy decode, report clean."""
    cfg, model, plan, pruned, store = serving
    prompts, toks_d = ref_tokens
    cm = rexec.CompressedModel(model, store)
    toks, rep = guarded_generate(cm, pruned, prompts, 4)
    assert bool(jnp.all(toks == toks_d))
    assert rep.healthy
    assert set(rep.verify.values()) == {"ok"}
    assert rep.fallbacks == [] and rep.retries == 0 and rep.dense_steps == 0
    assert rep.switched_to_dense_at is None
    assert rep.steps == rep.gen == 4
    assert rep.t_total_s >= rep.t_prefill_s + rep.t_decode_s > 0


def test_serve_generate_guarded_passthrough(fp32_compute, serving,
                                            ref_tokens):
    cfg, model, plan, pruned, store = serving
    prompts, toks_d = ref_tokens
    cm = rexec.CompressedModel(model, store)
    out = serve.generate(cm, pruned, prompts, 4, 12, guarded=True)
    assert len(out) == 4
    toks, t_pref, t_gen, rep = out
    assert isinstance(rep, HealthReport)
    assert bool(jnp.all(toks == toks_d))
    assert t_pref == rep.t_prefill_s and t_gen == rep.t_decode_s


def test_guarded_two_runs_are_deterministic(fp32_compute, serving,
                                            ref_tokens):
    """What the CI fault-injection job diffs: same seed → same tokens AND
    the same stable_dict projection."""
    cfg, model, plan, pruned, store = serving
    prompts, _ = ref_tokens
    cm = rexec.CompressedModel(model, store)
    toks1, rep1 = guarded_generate(cm, pruned, prompts, 4)
    toks2, rep2 = guarded_generate(cm, pruned, prompts, 4)
    assert bool(jnp.all(toks1 == toks2))
    assert rep1.stable_dict() == rep2.stable_dict()
    assert "t_decode_s" not in rep1.stable_dict()
    assert "t_decode_s" in rep1.to_dict()


# ---------------------------------------------------------------------------
# guarded serving: every fault class recovers to the dense result
# ---------------------------------------------------------------------------

def test_guarded_verify_demotes_corrupt_role(fp32_compute, serving,
                                             ref_tokens):
    """A checksum-failing role is served from dense weights; the rest of
    the store keeps its kernels.  Result: still bit-identical to dense."""
    cfg, model, plan, pruned, store = serving
    prompts, toks_d = ref_tokens
    role = _bitmap_role(plan)
    cm = rexec.CompressedModel(model, inject.bitflip_payload(store, role))
    toks, rep = guarded_generate(cm, pruned, prompts, 4)
    assert bool(jnp.all(toks == toks_d))
    assert rep.verify[role] == "checksum_mismatch"
    assert rep.fallback_counts() == {"integrity_violation": 1}
    assert rep.fallbacks[0]["role"] == role
    assert not rep.healthy
    # degraded, not dead: the whole generation still ran compressed
    assert rep.switched_to_dense_at is None and rep.dense_steps == 0


def test_guarded_recovers_nan_payload_without_verify(fp32_compute, serving,
                                                     ref_tokens):
    """Verification off (or a fault past it): the NaN reaches the logits,
    the step guard retries, then the request degrades to the dense model
    — which computes the CORRECT tokens from the pruned tree."""
    cfg, model, plan, pruned, store = serving
    prompts, toks_d = ref_tokens
    role = _bitmap_role(plan)
    cm = rexec.CompressedModel(model, inject.poison_payload_nan(store, role))
    toks, rep = guarded_generate(cm, pruned, prompts, 4, verify=False)
    assert bool(jnp.all(toks == toks_d))
    assert rep.switched_to_dense_at == -1       # poisoned from prefill on
    assert rep.dense_steps == 4
    assert rep.retries >= 1
    assert rep.fallback_counts() == {"nonfinite_logits": 1}
    assert rep.verify == {}                     # verification was skipped


def test_guarded_recovers_kernel_failure(fp32_compute, serving, ref_tokens):
    """Kernel dispatch failures demote per role at trace time (the
    ``kernel_guard`` sink) — the forward completes dense, bit-identical."""
    cfg, model, plan, pruned, store = serving
    prompts, toks_d = ref_tokens
    cm = rexec.CompressedModel(model, store)
    with inject.kernel_failure():
        toks, rep = guarded_generate(cm, pruned, prompts, 4)
    assert bool(jnp.all(toks == toks_d))
    codes = rep.fallback_counts()
    assert set(codes) == {"kernel_failure"}
    kernel_roles = {op.role for op in plan.ops
                    if op.choice.kind in ("bitmap", "nm")}
    assert {f["role"] for f in rep.fallbacks} == kernel_roles
    assert rep.switched_to_dense_at is None     # per-role, not whole-step


def test_guarded_recovers_activation_poison(fp32_compute, serving,
                                            ref_tokens):
    cfg, model, plan, pruned, store = serving
    prompts, toks_d = ref_tokens
    cm = rexec.CompressedModel(model, store)
    with inject.poison_activations("ffn.w_up"):
        toks, rep = guarded_generate(cm, pruned, prompts, 4)
    assert bool(jnp.all(toks == toks_d))
    assert rep.fallback_counts() == {"nonfinite_logits": 1}
    assert rep.dense_steps == 4


def test_guarded_deadline_pads_and_reports(fp32_compute, serving,
                                           ref_tokens):
    cfg, model, plan, pruned, store = serving
    prompts, _ = ref_tokens
    cm = rexec.CompressedModel(model, store)
    toks, rep = guarded_generate(cm, pruned, prompts, 4, deadline_s=0.0,
                                 pad_id=-7)
    assert rep.deadline_hit and not rep.healthy
    assert rep.steps < rep.gen == 4
    assert toks.shape == (2, 4)
    assert bool(jnp.all(toks[:, rep.steps:] == -7))
    assert rep.fallback_counts()["deadline_exceeded"] == 1


def test_demoted_roles_fall_through_to_dense(fp32_compute, serving,
                                             ref_tokens):
    """``CompressedModel.demoted`` drops the roles' store entries, so the
    dispatcher's dense einsum serves them from the pruned tree — still
    bit-identical (the mechanism the integrity demotion relies on)."""
    cfg, model, plan, pruned, store = serving
    prompts, toks_d = ref_tokens
    role = _bitmap_role(plan)
    cm = rexec.CompressedModel(model, store).demoted([role])
    assert all(key[1] != role for key in cm.store.entries)
    toks, _, _ = cm.generate(pruned, prompts, 4)
    assert bool(jnp.all(toks == toks_d))


def test_health_report_json_roundtrip():
    rep = HealthReport(verify={"attn.wq": "ok"}, retries=2, dense_steps=3,
                       switched_to_dense_at=-1, deadline_hit=True, steps=3,
                       gen=8, t_prefill_s=0.5, t_decode_s=1.5, t_total_s=2.0)
    rep.record_fallback("attn.wq", "integrity_violation",
                        detail="checksum_mismatch", layer=1)
    rt = HealthReport.from_json(rep.to_json())
    assert rt == rep
    assert not rep.healthy
    assert rep.fallback_counts() == {"integrity_violation": 1}
    assert rep.fallback_reasons() == [
        FallbackReason("integrity_violation", "checksum_mismatch")]
    assert rep.latency_per_token_s == pytest.approx(0.5)
    assert HealthReport().healthy


# ---------------------------------------------------------------------------
# malformed cache family: loud, not silently mis-served
# ---------------------------------------------------------------------------

def test_unknown_family_raises_instead_of_default_cache(serving):
    """The token-by-token ingest fallback must NOT serve an unknown family
    through the default dense cache path."""
    cfg, model, plan, pruned, store = serving
    assert "dense" in KNOWN_FAMILIES
    bad_model = Model(dataclasses.replace(cfg, family="bogus"))
    prompts = jnp.zeros((1, 4), jnp.int32)
    with pytest.raises(ValueError, match="bogus"):
        serve.generate(bad_model, pruned, prompts, 2, 6)
    with pytest.raises(ValueError, match="bogus"):
        bad_model.init_cache(1, 6)


# ---------------------------------------------------------------------------
# fault primitives, live
# ---------------------------------------------------------------------------

def test_step_guard_retries_then_succeeds():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient")
        return 42

    g = fault.StepGuard(max_retries=2)
    assert g.run(7, flaky) == 42
    assert [e.action for e in g.events] == ["retry"]
    assert g.events[0].step == 7 and "transient" in g.events[0].error


def test_step_guard_exhaustion_paths():
    def failing():
        raise RuntimeError("persistent")

    g = fault.StepGuard(max_retries=1, on_restore=lambda: None)
    assert g.run(0, failing) is None
    assert [e.action for e in g.events] == ["retry", "restore"]
    g2 = fault.StepGuard(max_retries=0)
    with pytest.raises(RuntimeError, match="persistent"):
        g2.run(0, failing)


def test_straggler_monitor_flags_persistent_spikes():
    mon = fault.StragglerMonitor(warmup=3)
    for s in range(3):
        assert not mon.observe(s, 0.1)
    assert not mon.should_remesh(tolerance=5)
    for s in range(3, 9):
        assert mon.observe(s, 1.0)          # 10× spikes flagged
    assert mon.should_remesh(window=20, tolerance=5)
    assert not mon.should_remesh(window=1, tolerance=5)


def test_elastic_remesh_proposals():
    assert fault.elastic_remesh(8, 2) == (4, 2)
    assert fault.elastic_remesh(7, 2) == (3, 2)          # odd survivor count
    assert fault.elastic_remesh(256, 16, pod_size=128) == (2, 8, 16)
    with pytest.raises(ValueError):
        fault.elastic_remesh(1, 2)                       # TP is pinned


def test_degraded_serve_mesh():
    ndev = len(jax.devices())
    with pytest.raises(ValueError, match="nothing left"):
        degraded_serve_mesh(4, lost=ndev)
    with pytest.raises(ValueError):
        degraded_serve_mesh(4, lost=0, model=ndev + 1)   # TP > survivors
    mesh = degraded_serve_mesh(4, lost=0)
    if ndev == 1:
        assert mesh is None        # degenerates to the unsharded path
    else:
        assert mesh is not None
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        assert sizes["model"] == 1 and 4 % sizes["data"] == 0
        lost_one = degraded_serve_mesh(4, lost=1)
        if lost_one is not None:
            assert int(np.prod(lost_one.devices.shape)) <= ndev - 1


# ---------------------------------------------------------------------------
# co-search checkpointing: kill + resume is bit-identical
# ---------------------------------------------------------------------------

def test_cosearch_autosave_resume_bit_identical(tmp_path, monkeypatch):
    wl_a = build_llm(LLMSpec("A", 1, 128, 512, 4), seq=32,
                     act_density=0.2, w_density=0.2)
    wl_b = build_llm(LLMSpec("B", 1, 128, 512, 4), seq=32,
                     act_density=0.8, w_density=0.8)
    cfg = dataclasses.replace(FAST, max_pairs=4)
    kw = dict(arch=ARCH3, importance={"A": 2.0, "B": 1.0}, cfg=cfg)
    path = str(tmp_path / "cosearch_autosave.pkl")

    memo.clear()
    ref_designs, ref_key, ref_val = cosearch_multi([wl_a, wl_b], **kw)

    # interrupted run from cold: die after 3 work items, autosaving after
    # every completed item
    memo.clear()
    real = cosearch_mod._multi_work_item
    calls = {"n": 0}

    def dying(item):
        calls["n"] += 1
        if calls["n"] > 3:
            raise RuntimeError("simulated kill")
        return real(item)

    monkeypatch.setattr(cosearch_mod, "_multi_work_item", dying)
    with pytest.raises(RuntimeError, match="simulated kill"):
        cosearch_multi([wl_a, wl_b], memo_autosave=path, autosave_every=1,
                       **kw)
    monkeypatch.setattr(cosearch_mod, "_multi_work_item", real)
    assert os.path.exists(path)

    # "fresh process": cold registry + snapshot load, then the same call —
    # completed items replay from the memo, results are bit-identical
    memo.clear()
    assert memo.load(path)
    designs, key, val = cosearch_multi([wl_a, wl_b], memo_autosave=path,
                                       autosave_every=1, **kw)
    assert key == ref_key and val == ref_val
    assert set(designs) == set(ref_designs)
    for name in ref_designs:
        assert designs[name].design == ref_designs[name].design
        assert designs[name].evaluations == ref_designs[name].evaluations
