"""End-to-end system tests: the paper's full pipeline + the execution
plane's training loop with fault injection and resume."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.core.arch import ARCH3, TPUV5E
from repro.core.codesign import plan_for_model
from repro.core.cosearch import CoSearchConfig, cosearch
from repro.core.engine import EngineConfig
from repro.core.sparsity import Bernoulli
from repro.core.workload import OPT_125M, build_llm
from repro.data.pipeline import PipelineState, TokenPipeline
from repro.kernels import ops
from repro.models.transformer import Model
from repro.optim import adamw
from repro.sparse import masks

# full end-to-end / many-model sweeps dominate suite wall-clock
pytestmark = pytest.mark.slow

FAST = CoSearchConfig(objective="edp",
                      engine=EngineConfig(max_levels=2,
                                          max_allocs_per_pattern=16),
                      spatial_top=2, max_pairs=6)


def test_paper_pipeline_end_to_end():
    """Workload → co-search → design with formats + dataflows, beating or
    matching every fixed baseline on the objective."""
    wl = build_llm(OPT_125M, seq=128, decode_tokens=8,
                   act_density=0.3, w_density=0.12, fc2_act_density=0.05)
    res = cosearch(wl, ARCH3, FAST)
    assert len(res.design.ops) == len(wl.ops)
    for fmt in ("Bitmap", "RLE", "CSR", "COO"):
        fixed = cosearch(wl, ARCH3, FAST, fixed_formats=(fmt, fmt))
        assert res.design.edp <= fixed.design.edp * 1.001, fmt


def test_codesign_to_kernel_execution():
    """DSE decision → compressed weights → Pallas kernel ≡ dense matmul."""
    cfg = get_config("chatglm3-6b").reduced()
    plan = plan_for_model(cfg, Bernoulli(0.2), tokens=128,
                          hardware=TPUV5E, search_cfg=FAST)
    ch = plan.for_op("ffn.up")
    assert ch.kind in ("bitmap", "dense")
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(cfg.d_model, cfg.d_ff)),
                    jnp.float32)
    bn = bk = 32
    wb = masks.block_prune(w, bn, bk, 0.2)
    comp = ops.compress_bitmap(np.asarray(wb), bn, bk)
    x = jnp.asarray(rng.normal(size=(16, cfg.d_model)), jnp.float32)
    y = ops.bitmap_spmm(x, comp, bm=16)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ wb),
                               rtol=1e-4, atol=1e-4)
    assert comp.compression_ratio < 0.5


def test_train_loop_with_failure_and_resume(tmp_path):
    """Loss decreases over a short run; a mid-run restore replays exactly."""
    cfg = get_config("chatglm3-6b").reduced()
    model = Model(cfg)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=40)
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=32, global_batch=4)
    params = model.init(jax.random.key(0))
    state = adamw.init(params, opt_cfg)

    @jax.jit
    def step(params, state, batch):
        loss, g = jax.value_and_grad(model.loss)(params, batch)
        params, state = adamw.apply(params, g, state, opt_cfg)
        return loss, params, state

    losses = []
    for i in range(20):
        loss, params, state = step(params, state, pipe.batch_at(i))
        losses.append(float(loss))
        if i == 9:
            ckpt.save(str(tmp_path), 10, {"p": params, "o": state},
                      extra={"pipeline": PipelineState(10).to_dict()})
    assert np.mean(losses[-5:]) < np.mean(losses[:5])

    # crash after step 14 → restore from step 10 → replay is exact
    restored, extra = ckpt.restore(str(tmp_path),
                                   {"p": params, "o": state})
    rp, ro = restored["p"], restored["o"]
    ps = PipelineState.from_dict(extra["pipeline"])
    assert ps.step == 10
    replay = []
    for i in range(ps.step, 13):
        loss, rp, ro = step(rp, ro, pipe.batch_at(i))
        replay.append(float(loss))
    np.testing.assert_allclose(replay, losses[10:13], rtol=1e-5)


def test_dryrun_small_mesh_lowering():
    """A miniature version of the dry-run: lower+compile a train step with
    explicit shardings on a 1-device mesh (structure check; the 512-device
    run happens in launch/dryrun.py)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models.partition import batch_specs, param_specs

    cfg = get_config("granite-moe-3b-a800m").reduced()
    model = Model(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    axes = {"data": 1, "model": 1}
    params_abs = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    p_specs = param_specs(params_abs, axes)
    batch = {"tokens": jax.ShapeDtypeStruct((2, 16), jnp.int32),
             "labels": jax.ShapeDtypeStruct((2, 16), jnp.int32)}
    b_specs = batch_specs(batch, axes)

    def loss_fn(params, batch):
        return model.loss(params, batch)

    named = lambda t: jax.tree.map(  # noqa: E731
        lambda s: NamedSharding(mesh, s), t,
        is_leaf=lambda x: isinstance(x, P))
    with mesh:
        lowered = jax.jit(loss_fn, in_shardings=(named(p_specs),
                                                 named(b_specs))
                          ).lower(params_abs, batch)
        compiled = lowered.compile()
    assert compiled.cost_analysis() is not None
