"""Memo-registry invariants (PR 4).

Pins the cache behaviors the search planes now lean on: ``export_state`` /
``import_state`` round-trip the new ``mapping_ctx`` and return-shipped
caches, ``key_snapshot`` + ``export_delta`` ship exactly the entries a
worker computed, ``stats()`` counters stay monotone (and untouched) across
``import_state``, selective ``clear(names=)`` cools only the named planes,
and ``memo.disabled()`` still yields identical search results on the
gather plane.
"""

import numpy as np

from repro.core import memo
from repro.core.arch import ARCH3
from repro.core.cosearch import CoSearchConfig, cosearch
from repro.core.engine import EngineConfig
from repro.core.workload import LLMSpec, build_llm

FAST = CoSearchConfig(engine=EngineConfig(max_levels=2,
                                          max_allocs_per_pattern=16),
                      spatial_top=2, max_pairs=6)


def _tiny_workload():
    return build_llm(LLMSpec("memo-test", 1, 128, 256, 4), seq=64,
                     act_density=0.4, w_density=0.25)


def _fingerprint(res):
    return (res.design.pattern_i, res.design.pattern_w, res.design.energy,
            res.design.cycles, res.evaluations,
            tuple((str(o.mapping), str(o.fmt_i), str(o.fmt_w))
                  for o in res.design.ops))


# ---------------------------------------------------------------------------
# mapping_ctx + return-shipped caches: export/import round trip
# ---------------------------------------------------------------------------

def test_mapping_ctx_cache_round_trips():
    """A co-search populates the ``mapping_ctx`` cache; its entries
    survive an export → clear → import cycle and satisfy the follow-up
    search (every per-op result replays, zero fresh evaluations)."""
    wl = _tiny_workload()
    memo.clear()
    want = _fingerprint(cosearch(wl, ARCH3, FAST))
    state = memo.export_state(names=["mapping_ctx", "search_op",
                                     "compile_format"])
    assert state["mapping_ctx"], "gather plane did not populate mapping_ctx"
    assert state["search_op"], "co-search did not populate search_op"
    n_ctx = len(state["mapping_ctx"])
    memo.clear()
    memo.import_state(state)
    memo.reset_stats()
    res = cosearch(wl, ARCH3, FAST)
    assert _fingerprint(res) == want
    assert res.stats.fresh_evaluations == 0
    assert res.stats.evaluations == res.evaluations > 0
    # the imported entries are the ones being hit, not rebuilt copies
    assert len(memo.export_state(names=["mapping_ctx"])["mapping_ctx"]) \
        == n_ctx


def test_mapping_ctx_entries_are_picklable():
    """The mapping_ctx values (packed table + context arrays) must cross
    the process boundary — the default picklable-only export keeps them
    all."""
    wl = _tiny_workload()
    memo.clear()
    cosearch(wl, ARCH3, FAST)
    strict = memo.export_state(names=["mapping_ctx"], picklable_only=True)
    loose = memo.export_state(names=["mapping_ctx"], picklable_only=False)
    assert set(strict["mapping_ctx"]) == set(loose["mapping_ctx"])


# ---------------------------------------------------------------------------
# key_snapshot + export_delta
# ---------------------------------------------------------------------------

def test_export_delta_ships_only_new_entries():
    cache = memo.register({}, "delta-test-cache")
    cache["old"] = 1
    base = memo.key_snapshot(["delta-test-cache"])
    assert base == {"delta-test-cache": {"old"}}
    delta = memo.export_delta(base, ["delta-test-cache"])
    assert delta == {}                       # nothing new → nothing shipped
    cache["new"] = 2
    cache["bad"] = lambda: None              # unpicklable: silently dropped
    delta = memo.export_delta(base, ["delta-test-cache"])
    assert delta == {"delta-test-cache": {"new": 2}}
    # the worker loop advances its baseline past shipped entries
    base["delta-test-cache"].update(delta["delta-test-cache"])
    cache["newer"] = 3
    assert memo.export_delta(base, ["delta-test-cache"]) == \
        {"delta-test-cache": {"newer": 3}}


def test_export_delta_skips_unknown_and_unsnapshotted_caches():
    memo.register({"k": 1}, "delta-other-cache")
    # cache registered but absent from the baseline → skipped, not crashed
    assert memo.export_delta({}, ["delta-other-cache"]) == {}


# ---------------------------------------------------------------------------
# stats counters across import_state
# ---------------------------------------------------------------------------

def test_stats_monotone_across_import_state():
    """``import_state`` merges entries without touching the hit/miss
    counters; counters only ever grow."""
    cache = memo.register({}, "monotone-test-cache")
    memo.get_or(cache, "a", lambda: 1)       # miss
    memo.get_or(cache, "a", lambda: 1)       # hit
    before = {name: (st.hits, st.misses)
              for name, st in memo.stats().items()}
    memo.import_state({"monotone-test-cache": {"b": 2, "a": 9},
                       "no-such-cache": {"x": 1}})
    after = memo.stats()
    for name, (h, m) in before.items():
        assert after[name].hits == h and after[name].misses == m
    assert cache["a"] == 1                   # existing entries win
    memo.get_or(cache, "b", lambda: 3)       # imported entry → a HIT
    st = memo.stats()["monotone-test-cache"]
    assert (st.hits, st.misses) == (2, 1)
    assert cache["b"] == 2


# ---------------------------------------------------------------------------
# selective clear
# ---------------------------------------------------------------------------

def test_clear_names_is_selective():
    a = memo.register({"x": 1}, "clear-test-a")
    b = memo.register({"y": 2}, "clear-test-b")
    memo.clear(names=["clear-test-a"])
    assert not a and b == {"y": 2}
    memo.clear()
    assert not a and not b


def test_clear_rejects_unknown_names():
    """A typo'd name must raise, not silently leave the plane warm (a
    cold-cache benchmark would quietly compare warm-vs-warm)."""
    import pytest
    with pytest.raises(KeyError, match="no-such-cache-name"):
        memo.clear(names=["no-such-cache-name"])


# ---------------------------------------------------------------------------
# disabled() still yields identical search results
# ---------------------------------------------------------------------------

def test_memo_disabled_identical_search_results():
    """Caching is an optimization, never a semantic: the gather-plane
    co-search returns the identical design/metrics/eval counts with every
    cache bypassed, and counts all work as fresh."""
    wl = _tiny_workload()
    memo.clear()
    warm = cosearch(wl, ARCH3, FAST)
    with memo.disabled():
        cold = cosearch(wl, ARCH3, FAST)
    assert _fingerprint(warm) == _fingerprint(cold)
    assert cold.stats.fresh_evaluations == cold.stats.evaluations \
        == cold.evaluations
