"""Compression-aware memory pipeline tests.

Contracts pinned here:

  * the double-buffered streaming kernels (``pipeline=True``, the dispatch
    default) are BIT-identical to the naive grid-walk kernels at fp32 —
    same accumulation order, same dot widening — across densities
    including fully-empty and fully-dense weights;
  * ``repro.kernels.ops.pipeline_default`` swaps what ``pipeline=None``
    resolves to, and restores on exit;
  * the cost model's reuse term (``HardwareConfig.glb_resident_frac``)
    changes NOTHING at frac 0, moves refetch traffic DRAM→GLB at frac > 0
    with exact bit conservation, and is bit-identical across all four
    evaluator planes (scalar / batch / gather / threaded);
  * ``with_streaming_reuse`` names round-trip through ``arch_by_name``;
  * ``instrument()`` splits W traffic into distinct vs streamed bits with
    ``M / tile_M`` passes, and the per-level calibration fit
    (``fit_glb_scale`` / ``calibrated_hardware``) recovers a planted GLB
    coefficient from the refetch residual;
  * durable memo snapshots (``memo.save`` / ``memo.load``) replay across a
    clear and reject stale code fingerprints without touching caches;
  * ``CoSearchConfig.op_workers`` is bit-identical to the serial per-op
    loop (designs AND SearchStats) and normalized out of the search cache
    key;
  * scanned and unrolled serving share jitted-kernel cache entries even on
    a store whose layers realize very different sparsity;
  * ``StackedStore.padding_overhead`` accounts a dense-layer outlier
    exactly, and the padded scanned forward still decodes bit-identically
    to the per-layer dispatch.
"""

import dataclasses
import pickle

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import exec as rexec
from repro.configs import get_config
from repro.core import memo
from repro.core.arch import ARCH3, arch_by_name, with_streaming_reuse
from repro.core.cosearch import (CoSearchConfig, _search_op_key, cosearch)
from repro.core.costmodel import compile_format, evaluate_batch
from repro.core.dataflow import enumerate_mappings, irrelevant_refetch
from repro.core.engine import EngineConfig
from repro.core.formats import standard_formats
from repro.core.sparsity import Bernoulli, BlockBernoulli, TensorSpec
from repro.core.workload import LLMSpec, MatMul, build_llm
from repro.exec.calibrate import (CalibRow, calibrated_hardware,
                                  fit_glb_scale)
from repro.exec.compress import _role_path, stack_store
from repro.kernels import ops as kops
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models.transformer import Model

FAST = CoSearchConfig(objective="edp",
                      engine=EngineConfig(max_levels=2,
                                          max_allocs_per_pattern=16),
                      spatial_top=2, max_pairs=6)


@pytest.fixture()
def fp32_compute(monkeypatch):
    monkeypatch.setattr(L, "COMPUTE_DTYPE", jnp.float32)
    monkeypatch.setattr(attn_mod, "COMPUTE_DTYPE", jnp.float32)


def _block_sparse_w(rng, n, k, bn, bk, density):
    gn, gk = n // bn, k // bk
    bitmap = rng.random((gn, gk)) < density
    w = rng.normal(size=(n, k)).astype(np.float32)
    mask = np.repeat(np.repeat(bitmap, bn, 0), bk, 1)
    return (w * mask).astype(np.float32)


def _nm_sparse_w(rng, n, k):
    wg = rng.normal(size=(n // 4, 4, k)).astype(np.float32)
    order = np.argsort(-np.abs(wg), axis=1)
    mask = np.zeros_like(wg, dtype=bool)
    np.put_along_axis(mask, order[:, :2, :], True, axis=1)
    return (wg * mask).reshape(n, k).astype(np.float32)


# ---------------------------------------------------------------------------
# pipelined kernels ≡ naive kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,k,bn,bk", [
    (16, 32, 32, 8, 8),
    (32, 64, 32, 16, 16),
    (8, 128, 256, 32, 64),
    (128, 128, 128, 128, 128),     # single block
])
@pytest.mark.parametrize("density", [0.0, 0.5, 1.0])
def test_bitmap_pipelined_bit_identical(m, n, k, bn, bk, density):
    """Same per-``kj`` block walk, same widened fp32 dot: the streaming
    kernel's output must equal the naive kernel's BIT for bit, including
    the all-empty and all-dense extremes."""
    rng = np.random.default_rng(m + n + k)
    w = _block_sparse_w(rng, n, k, bn, bk, density)
    x = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    comp = kops.compress_bitmap(w, bn, bk)
    y_pipe = kops.bitmap_spmm(x, comp, bm=min(128, m), pipeline=True)
    y_naive = kops.bitmap_spmm(x, comp, bm=min(128, m), pipeline=False)
    assert np.array_equal(np.asarray(y_pipe), np.asarray(y_naive))


@pytest.mark.parametrize("m,n,k", [
    (16, 32, 32), (32, 64, 128), (8, 256, 64), (128, 128, 128),
])
def test_nm_pipelined_bit_identical(m, n, k):
    rng = np.random.default_rng(n + k)
    w = _nm_sparse_w(rng, n, k)
    x = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    comp = kops.compress_nm(w)
    kw = dict(bm=min(128, m), bn=min(128, n), bk=min(128, k))
    y_pipe = kops.nm_spmm(x, comp, pipeline=True, **kw)
    y_naive = kops.nm_spmm(x, comp, pipeline=False, **kw)
    # acceptance bound is ≤ 1e-6; the shared decode + stripe order makes
    # it exact in practice
    assert np.array_equal(np.asarray(y_pipe), np.asarray(y_naive))


def test_pipeline_default_override():
    assert kops.resolve_pipeline(None) is True
    assert kops.resolve_pipeline(False) is False
    with kops.pipeline_default(False):
        assert kops.resolve_pipeline(None) is False
        assert kops.resolve_pipeline(True) is True
    assert kops.resolve_pipeline(None) is True


# ---------------------------------------------------------------------------
# reuse-aware cache term
# ---------------------------------------------------------------------------

def _eval_case(arch):
    op = MatMul("reuse", 64, 128, 96, Bernoulli(0.6), Bernoulli(0.3))
    mappings = list(enumerate_mappings(op, arch, spatial_top=2))[:32]
    spec_i = TensorSpec(op.i_dims(), op.sp_i, op.value_bits)
    spec_w = TensorSpec(op.w_dims(), op.sp_w, op.value_bits)
    cf_i = compile_format(standard_formats(spec_i.dims)["Bitmap"], spec_i)
    cf_w = compile_format(standard_formats(spec_w.dims)["RLE"], spec_w)
    return op, mappings, [(cf_i, cf_w)] * len(mappings)


def test_reuse_term_zero_frac_is_identity():
    """frac = 0 keeps every metric bit-identical to the base arch — the
    term is guarded, not just numerically small."""
    op, mappings, pairs = _eval_case(ARCH3)
    base = evaluate_batch(op, ARCH3, mappings, pairs)
    zero = evaluate_batch(op, with_streaming_reuse(ARCH3, 0.0), mappings,
                          pairs)
    for f in ("energy", "cycles", "edp", "dram_bits", "e_dram", "e_glb"):
        assert np.array_equal(getattr(base, f), getattr(zero, f)), f


def test_reuse_term_moves_refetch_dram_to_glb():
    """frac > 0 only ever lowers DRAM traffic, adds the same bits to GLB
    (conservation), and never increases total energy (GLB pJ/bit < DRAM
    pJ/bit on every shipped arch)."""
    arch = with_streaming_reuse(ARCH3, 0.75)
    op, mappings, pairs = _eval_case(ARCH3)
    base = evaluate_batch(op, ARCH3, mappings, pairs)
    reuse = evaluate_batch(op, arch, mappings, pairs)
    assert np.all(reuse.dram_bits <= base.dram_bits)
    assert np.any(reuse.dram_bits < base.dram_bits)
    assert np.all(reuse.e_dram <= base.e_dram)
    assert np.all(reuse.energy <= base.energy)
    # monotone in frac: more residency can only absorb more refetch
    mid = evaluate_batch(op, with_streaming_reuse(ARCH3, 0.25), mappings,
                         pairs)
    assert np.all(reuse.dram_bits <= mid.dram_bits)


def test_reuse_term_bit_identical_across_planes():
    """The four evaluator planes agree bit-for-bit with the reuse term
    enabled — same contract the equivalence suite pins for the base
    model."""
    arch = with_streaming_reuse(ARCH3, 0.5)
    fast = dataclasses.replace(FAST, max_pairs=4)
    wl = build_llm(LLMSpec("reuse-eq", 1, 128, 256, 4), seq=64,
                   act_density=0.5, w_density=0.25)

    def fingerprint(res):
        return (res.design.pattern_i, res.design.pattern_w,
                res.design.energy, res.design.cycles, res.evaluations,
                tuple((str(o.mapping), str(o.fmt_i), str(o.fmt_w))
                      for o in res.design.ops))

    with memo.disabled():
        fps = [fingerprint(cosearch(wl, arch, cfg)) for cfg in (
            dataclasses.replace(fast, use_batch=False),
            dataclasses.replace(fast, use_gather=False),
            fast,
            dataclasses.replace(fast, eval_threads=3),
        )]
    assert fps[0] == fps[1] == fps[2] == fps[3]


def test_with_streaming_reuse_roundtrip():
    arch = with_streaming_reuse(ARCH3, 0.5)
    assert arch.glb_resident_frac == 0.5
    again = arch_by_name(arch.name)
    assert again == arch
    with pytest.raises(ValueError):
        with_streaming_reuse(ARCH3, 1.5)


# ---------------------------------------------------------------------------
# per-level calibration
# ---------------------------------------------------------------------------

def test_fit_glb_scale_recovers_planted_coefficient():
    """Measured refetch = 1.7 × predicted on every row → the least-squares
    GLB fit is exactly 1.7 and the post-fit refetch residual collapses;
    rows with no refetch on either side leave the fit at identity."""
    rows = [CalibRow(role=f"r{i}", kind="bitmap",
                     measured_bits=100.0, predicted_bits=100.0,
                     measured_stream_bits=100.0 + 1.7 * p,
                     predicted_stream_bits=100.0 + p)
            for i, p in enumerate((50.0, 200.0, 800.0))]
    g = fit_glb_scale(rows)
    assert g == pytest.approx(1.7)
    assert all(abs(r.refetch_residual(g)) < 1e-12 for r in rows)
    assert fit_glb_scale([CalibRow(role="x", kind="nm",
                                   measured_bits=10.0, predicted_bits=10.0,
                                   measured_stream_bits=10.0,
                                   predicted_stream_bits=10.0)]) == 1.0


def test_calibrated_hardware_scales_glb_level():
    cal = calibrated_hardware(ARCH3, 1.25, glb_scale=2.0)
    assert cal.levels[0].pj_per_bit_read == pytest.approx(
        ARCH3.levels[0].pj_per_bit_read * 1.25)
    assert cal.levels[1].pj_per_bit_read == pytest.approx(
        ARCH3.levels[1].pj_per_bit_read * 2.0)
    assert cal.levels[1].pj_per_bit_write == pytest.approx(
        ARCH3.levels[1].pj_per_bit_write * 2.0)
    assert cal.levels[2:] == ARCH3.levels[2:]
    assert "+glb2" in cal.name
    # glb_scale=1 leaves the on-chip levels untouched (and unnamed)
    only_dram = calibrated_hardware(ARCH3, 1.25)
    assert only_dram.levels[1:] == ARCH3.levels[1:]
    assert "+glb" not in only_dram.name


def test_instrument_splits_distinct_vs_streamed(fp32_compute):
    """A 256-token forward tiles M at 128 → every kernel-backed role
    streams its payload exactly twice per call while crossing DRAM once:
    refetch_factor == 2, stream bits == 2 × distinct bits."""
    cfg = get_config("chatglm3-6b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    plan = rexec.build_exec_plan(cfg, BlockBernoulli(0.5, 32 * 32),
                                 tokens=256, search_cfg=FAST,
                                 value_bits=32)
    pruned = rexec.prune_params(params, plan, cfg)
    store = rexec.compress_params(pruned, plan, cfg)
    cm = rexec.CompressedModel(model, store)
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (1, 256)), jnp.int32)
    with rexec.instrument() as counters:
        cm.hidden_states(pruned, toks)
    kernel_roles = [op.role for op in plan.ops
                    if op.choice.kind in ("bitmap", "nm")]
    assert kernel_roles
    for role in kernel_roles:
        c = counters[role]
        assert c.refetch_factor == pytest.approx(2.0)
        assert c.w_stream_bits == pytest.approx(2.0 * c.w_distinct_bits)
        assert c.w_stream_bits_per_call == pytest.approx(
            2.0 * c.w_distinct_bits / c.calls)


def test_predicted_stream_bits_use_mapping_refetch():
    """The plan's predicted stream traffic is distinct fetch × the
    mapping's W-irrelevant outer-loop product — spot-check the
    ``irrelevant_refetch`` helper the plan builder uses."""
    # W is (N, K): loops over M outside W's innermost relevant loop refetch
    assert irrelevant_refetch(("M", "N", "K"), "W",
                              {"M": 4, "N": 2, "K": 3}) == 4.0
    assert irrelevant_refetch(("N", "K", "M"), "W",
                              {"M": 4, "N": 2, "K": 3}) == 1.0
    cfg = get_config("chatglm3-6b").reduced()
    plan = rexec.build_exec_plan(cfg, BlockBernoulli(0.5, 32 * 32),
                                 tokens=64, search_cfg=FAST, value_bits=32)
    for op in plan.ops:
        assert op.predicted_w_stream_bits >= op.predicted_w_fetch_bits > 0


# ---------------------------------------------------------------------------
# durable memo snapshots
# ---------------------------------------------------------------------------

def _small_search():
    wl = build_llm(LLMSpec("memo-snap", 1, 128, 256, 4), seq=64,
                   act_density=0.5, w_density=0.25)
    return cosearch(wl, ARCH3, dataclasses.replace(FAST, max_pairs=4))


def test_memo_snapshot_roundtrip(tmp_path):
    """save → clear → load replays the search entirely from the snapshot
    (zero fresh evaluations), bit-identically."""
    path = str(tmp_path / "memo.pkl")
    memo.clear()
    cold = _small_search()
    n = memo.save(path)
    assert n > 0
    memo.clear()
    assert memo.load(path) is True
    warm = _small_search()
    assert warm.stats.fresh_evaluations == 0
    assert (warm.design.energy, warm.design.cycles, warm.evaluations) == \
        (cold.design.energy, cold.design.cycles, cold.evaluations)


def test_memo_snapshot_rejects_stale(tmp_path):
    path = str(tmp_path / "memo.pkl")
    memo.clear()
    _small_search()
    keys_before = memo.key_snapshot(["search_op"])["search_op"]
    memo.save(path)
    with open(path, "rb") as f:
        snap = pickle.load(f)
    # a snapshot written by different code must be ignored, not replayed
    snap["fingerprint"] = "0" * 64
    with open(path, "wb") as f:
        pickle.dump(snap, f)
    memo.clear()
    assert memo.load(path) is False
    assert memo.key_snapshot(["search_op"])["search_op"] == set()
    # wrong version and unreadable files are equally non-fatal
    snap["fingerprint"] = memo.code_fingerprint()
    snap["version"] = -1
    with open(path, "wb") as f:
        pickle.dump(snap, f)
    assert memo.load(path) is False
    with open(path, "wb") as f:
        f.write(b"not a pickle")
    assert memo.load(path) is False
    assert memo.load(str(tmp_path / "missing.pkl")) is False
    # sanity: an untampered snapshot still round-trips (re-search first —
    # the stale loads above left the cleared caches empty)
    _small_search()
    memo.save(path)
    memo.clear()
    assert memo.load(path) is True
    assert memo.key_snapshot(["search_op"])["search_op"] == keys_before


# ---------------------------------------------------------------------------
# threaded per-op search
# ---------------------------------------------------------------------------

def test_op_workers_bit_identical():
    """Serial vs threaded per-op loop: same design, same metric, same
    SearchStats, same memo counters — for several worker counts, warm and
    cold."""
    wl = build_llm(LLMSpec("op-workers", 2, 128, 256, 4), seq=64,
                   act_density=0.5, w_density=0.25)
    base = dataclasses.replace(FAST, max_pairs=4)

    def run(cfg):
        memo.clear()
        memo.reset_stats()
        res = cosearch(wl, ARCH3, cfg)
        st = memo.stats()["search_op"]
        return (res.design.energy, res.design.cycles, res.design.edp,
                res.evaluations, res.stats.evaluations,
                res.stats.fresh_evaluations, st.hits, st.misses,
                tuple((o.op.name, str(o.mapping), str(o.fmt_i),
                       str(o.fmt_w)) for o in res.design.ops))

    serial = run(base)
    for w in (2, 5):
        assert run(dataclasses.replace(base, op_workers=w)) == serial
    with memo.disabled():
        s = cosearch(wl, ARCH3, base)
        p = cosearch(wl, ARCH3, dataclasses.replace(base, op_workers=3))
    assert (s.design.edp, s.evaluations, s.stats.fresh_evaluations) == \
        (p.design.edp, p.evaluations, p.stats.fresh_evaluations)


def test_op_workers_normalized_out_of_cache_key():
    op = MatMul("m", 64, 96, 64, Bernoulli(0.5), Bernoulli(0.5))
    k1 = _search_op_key(op, ARCH3, None, None, FAST)
    k2 = _search_op_key(op, ARCH3, None, None,
                        dataclasses.replace(FAST, op_workers=8,
                                            eval_threads=2))
    assert k1 is not None and k1 == k2


# ---------------------------------------------------------------------------
# serving-plane regression: kernel cache sharing + padding extremes
# ---------------------------------------------------------------------------

def _mixed_serving(cfg, density=0.1):
    """A serving setup whose layer 0 weights are fully DENSE while the
    remaining layers realize ``density`` — the worst case for the stacked
    store's pad-to-max layout and for per-layer kernel-cache keying."""
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    plan = rexec.build_exec_plan(cfg, BlockBernoulli(density, 32 * 32),
                                 tokens=64, search_cfg=FAST, value_bits=32)
    pruned = rexec.prune_params(params, plan, cfg)
    mixed = dict(pruned)
    mixed["blocks"] = dict(pruned["blocks"])
    for op in plan.ops:
        if op.choice.kind != "bitmap":
            continue
        group, leaf = _role_path(op.role)
        mixed["blocks"][group] = dict(mixed["blocks"][group])
        w = mixed["blocks"][group][leaf]
        mixed["blocks"][group][leaf] = w.at[0].set(
            params["blocks"][group][leaf][0])
    store = rexec.compress_params(mixed, plan, cfg)
    return model, plan, mixed, store


def test_kernel_cache_shared_between_scanned_and_unrolled(fp32_compute):
    """Both serving paths dispatch every role with the per-role
    ACROSS-LAYERS max ``t_max``, so the unrolled forward reuses exactly
    the scanned forward's jitted-kernel entries — even when layer 0 is
    dense and the rest are 90% sparse (maximally different per-layer
    bounds).  A per-layer ``t_max`` would fork entries here."""
    cfg = get_config("chatglm3-6b").reduced()
    model, plan, mixed, store = _mixed_serving(cfg)
    assert any(op.choice.kind == "bitmap" for op in plan.ops)
    cm = rexec.CompressedModel(model, store)
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (2, 8)), jnp.int32)

    kops.clear_kernel_cache()
    cm.hidden_states(mixed, toks)
    after_scan = kops.kernel_cache_stats()
    cm.hidden_states_unrolled(mixed, toks)
    after_both = kops.kernel_cache_stats()
    assert after_scan["entries"] > 0
    assert after_both["entries"] == after_scan["entries"], \
        "unrolled forward forked new kernel configurations"
    # the unrolled pass made only cache HITS (n_layers per role beyond
    # the scanned trace's own lookups)
    assert after_both["misses"] == after_scan["misses"]
    assert after_both["hits"] > after_scan["hits"]


def test_padding_overhead_extreme_accounted_exactly(fp32_compute):
    """One dense layer forces the stacked bitmap payloads to pad every
    sparse layer up to the full block count: the overhead is large, its
    accounting matches a by-hand recomputation from the per-layer store,
    and the padded scanned forward still decodes bit-identically to the
    per-layer dispatch."""
    cfg = get_config("chatglm3-6b").reduced()
    model, plan, mixed, store = _mixed_serving(cfg)
    st = stack_store(store)

    checked = 0
    for role, sr in st.roles.items():
        if sr.kind != "bitmap":
            continue
        per_layer = [store.get(layer, role)
                     for layer in range(cfg.n_layers)]
        nnzbs = [int(np.asarray(e.data.counts).sum()) for e in per_layer]
        full = (sr.n // sr.bn) * (sr.k // sr.bk)
        assert nnzbs[0] == full, "layer 0 should keep every block"
        assert max(nnzbs[1:]) < full, "sparse layers should drop blocks"
        # pad-to-max layout: every layer's payload slab is layer 0's size
        assert sr.data["blocks"].shape[:2] == (cfg.n_layers, full)
        # exact accounting: padded = stored + zero-fill payload bits
        vb = sr.data["blocks"].dtype.itemsize * 8
        pad_blocks = cfg.n_layers * full - sum(nnzbs)
        assert sr.padded_bits == pytest.approx(
            sr.stored_bits + pad_blocks * sr.bn * sr.bk * vb)
        assert sr.stored_bits == pytest.approx(
            sum(e.stored_bits for e in per_layer))
        checked += 1
    assert checked > 0
    # one dense + one ~10%-dense layer: padding inflates the store well
    # past the per-layer encoding (the reduced model's small per-tensor
    # block counts quantize the sparse layers' realized density upward,
    # which caps the contrast below the asymptotic ~2x)
    assert st.padding_overhead() > 1.25

    # padded zero blocks sit beyond every column's counts, so decoding a
    # padded layer slice is BIT-identical to the layer's own unpadded
    # encoding — kernel-level, where "identical" is well-defined
    rng = np.random.default_rng(1)
    for role, sr in st.roles.items():
        if sr.kind != "bitmap":
            continue
        x = jnp.asarray(rng.normal(size=(16, sr.n)).astype(np.float32))
        for layer in range(cfg.n_layers):
            own = store.get(layer, role).data
            padded = kops.BitmapCompressed(
                blocks=sr.data["blocks"][layer],
                counts=sr.data["counts"][layer],
                row_ids=sr.data["row_ids"][layer],
                offsets=sr.data["offsets"][layer],
                n=sr.n, k=sr.k, bn=sr.bn, bk=sr.bk, max_per_col=sr.t_max)
            y_pad = kops.bitmap_spmm(x, padded, bm=16, t_max=sr.t_max)
            y_own = kops.bitmap_spmm(x, own, bm=16, t_max=sr.t_max)
            assert np.array_equal(np.asarray(y_pad), np.asarray(y_own)), \
                (role, layer)

    # end-to-end the padded scanned forward tracks the per-layer dispatch
    # (bitwise equality is NOT guaranteed here — XLA fuses the dense glue
    # differently under scan — so pin a tight tolerance)
    cm = rexec.CompressedModel(model, store)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)), jnp.int32)
    y_scan = cm.hidden_states(mixed, toks)
    y_unrolled = cm.hidden_states_unrolled(mixed, toks)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_unrolled),
                               rtol=1e-5, atol=1e-5)
