"""Benchmark-driver smoke: ``benchmarks/run.py --quick`` must run clean.

The quick mode pushes a tiny model through one arch in every suite that
implements it (fig11 / tableI / dimo — the search-plane drivers this repo's
perf claims rest on), asserting old-vs-new equivalence along the way, so
the benchmark drivers can't silently rot between full runs.
"""

import pytest

from repro.core import memo


def test_run_quick_smoke(capsys):
    from benchmarks import run as bench_run
    memo.clear()
    memo.reset_stats()
    failures = bench_run.main(["--quick"])
    out = capsys.readouterr().out
    assert failures == 0, f"quick benchmark suites failed:\n{out}"
    # the three quick-capable suites emitted their headline rows
    assert "fig11_avg_saving" in out
    assert "engine_avg" in out
    assert "evaluator_avg" in out
    assert "tableI_fixed_avg" in out
    assert "dimo_batch_avg" in out
    # cache effectiveness is surfaced
    assert "memo_stats_" in out


def test_run_quick_skips_suites_without_quick_mode(capsys):
    from benchmarks import run as bench_run
    failures = bench_run.main(["kernels", "--quick"])
    out = capsys.readouterr().out
    assert failures == 0
    assert "skipped (no quick mode)" in out
