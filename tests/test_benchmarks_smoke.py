"""Benchmark-driver smoke: ``benchmarks/run.py --quick`` must run clean.

The quick mode pushes a tiny model through one arch in every suite that
implements it (fig11 / tableI / dimo — the search-plane drivers this repo's
perf claims rest on), asserting old-vs-new equivalence along the way, so
the benchmark drivers can't silently rot between full runs.  ``--json``
pins the machine-readable record (``BENCH_<n>.json`` across PRs).
"""

import json

import pytest

from repro.core import memo


def test_run_quick_smoke(capsys, tmp_path):
    from benchmarks import run as bench_run
    memo.clear()
    memo.reset_stats()
    json_path = tmp_path / "BENCH_smoke.json"
    failures = bench_run.main(["--quick", "--json", str(json_path)])
    out = capsys.readouterr().out
    assert failures == 0, f"quick benchmark suites failed:\n{out}"
    # the quick-capable suites emitted their headline rows
    assert "fig11_avg_saving" in out
    assert "fig11_workers_process" in out
    assert "engine_avg" in out
    assert "evaluator_avg" in out
    assert "stepwise_batch_search" in out
    assert "tableI_fixed_avg" in out
    assert "dimo_batch_avg" in out
    # execution plane: compressed-vs-dense ratio rows for two kernel-backed
    # sparsity patterns + the measured-vs-predicted calibration fits
    assert "exec_ratio_block50" in out
    assert "exec_ratio_nm24" in out
    assert "exec_calibration_block50" in out
    assert "exec_calibration_iid50" in out
    # serving plane: prefill + decode throughput, compressed vs dense, and
    # the scanned-vs-unrolled forward comparison; compressed rows surface
    # fallback counts + kernel-cache stats
    for b in (1, 2):
        assert f"serve_prefill_dense_b{b}" in out
        assert f"serve_prefill_comp_b{b}" in out
        assert f"serve_decode_dense_b{b}" in out
        assert f"serve_decode_comp_b{b}" in out
    assert "serve_scan_vs_unrolled" in out
    assert "fallbacks=" in out
    assert "kcache=" in out
    # continuous batching: mixed-length stream vs static lockstep chunks
    assert "serve_mixer_vs_static" in out
    assert "slot_reuse_admits=" in out
    # memory pipeline: pipelined-vs-naive kernel + serving rows, the
    # threaded per-op search comparison, and the per-level GLB fit
    assert "kernel_bitmap_spmm_pipeline" in out
    assert "kernel_nm_spmm_pipeline" in out
    assert "serve_pipeline_vs_naive" in out
    assert "cosearch_op_workers" in out
    assert "glb_scale=" in out
    # cache effectiveness is surfaced
    assert "memo_stats_" in out
    assert "memo_stats_fetch_table" in out
    # --json mirrors every CSV row plus per-suite wall-clocks
    doc = json.loads(json_path.read_text())
    assert doc["failures"] == 0 and doc["quick"] is True
    names = [r["name"] for r in doc["rows"]]
    for expected in ("fig11_avg_saving", "engine_avg", "evaluator_avg",
                     "stepwise_batch_search", "tableI_fixed_avg",
                     "dimo_batch_avg", "exec_ratio_block50",
                     "exec_ratio_nm24", "exec_calibration_block50",
                     "serve_prefill_comp_b1", "serve_decode_comp_b2",
                     "serve_scan_vs_unrolled", "serve_mixer_vs_static",
                     "memo_stats_fetch_table"):
        assert expected in names
    for row in doc["rows"]:
        assert set(row) == {"name", "us_per_call", "derived"}
        assert isinstance(row["us_per_call"], float)
    assert doc["suite_s"] and all(s >= 0 for s in doc["suite_s"].values())


def test_run_json_requires_path(capsys):
    from benchmarks import run as bench_run
    assert bench_run.main(["--json"]) == 1


def test_run_quick_skips_suites_without_quick_mode(capsys):
    from benchmarks import run as bench_run
    failures = bench_run.main(["fig5", "--quick"])
    out = capsys.readouterr().out
    assert failures == 0
    assert "skipped (no quick mode)" in out
