"""``hypothesis`` compatibility layer for the property-test modules.

When hypothesis is installed, this re-exports the real ``given`` /
``settings`` / ``st``.  When it is not (the package cannot be installed in
every environment this suite runs in), a minimal fallback runs each
property against a FIXED-SEED set of pseudo-random examples, so the
modules still collect and exercise the invariants everywhere — just
without shrinking or example databases.

Only the strategy constructors the suite actually uses are shimmed:
``st.floats(lo, hi)``, ``st.integers(lo, hi)``, ``st.sampled_from(seq)``,
``st.booleans()``.
"""

from __future__ import annotations

import random

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    _DEFAULT_EXAMPLES = 10
    _SEED = 0x5EED

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda r: elements[r.randrange(len(elements))])

        @staticmethod
        def booleans():
            return _Strategy(lambda r: bool(r.getrandbits(1)))

    st = _Strategies()

    def given(**strategies):
        def deco(fn):
            # NOTE: not functools.wraps — pytest would follow __wrapped__
            # to the original signature and demand fixtures for the
            # strategy parameters.  The wrapper must look zero-argument.
            def wrapper():
                rng = random.Random(_SEED)
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                for _ in range(n):
                    drawn = {k: s.example(rng) for k, s in strategies.items()}
                    fn(**drawn)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper._is_fallback_property = True
            return wrapper

        return deco

    def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_ignored):
        def deco(fn):
            if getattr(fn, "_is_fallback_property", False):
                fn._max_examples = max_examples
            return fn

        return deco
