"""Pallas kernel tests: shape/dtype sweeps + hypothesis properties, always
against the ref.py pure-jnp oracles (interpret mode on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.kernels import ops, ref


def _block_sparse_w(rng, n, k, bn, bk, density):
    gn, gk = n // bn, k // bk
    bitmap = rng.random((gn, gk)) < density
    w = rng.normal(size=(n, k)).astype(np.float32)
    mask = np.repeat(np.repeat(bitmap, bn, 0), bk, 1)
    return (w * mask).astype(np.float32), bitmap


# ---------------------------------------------------------------------------
# bitmap_spmm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,k,bn,bk", [
    (16, 32, 32, 8, 8),
    (32, 64, 32, 16, 16),
    (8, 128, 256, 32, 64),
    (128, 128, 128, 128, 128),     # single block
])
@pytest.mark.parametrize("density", [0.0, 0.3, 1.0])
def test_bitmap_spmm_shapes(m, n, k, bn, bk, density):
    rng = np.random.default_rng(m * 1000 + n + k)
    w, bitmap = _block_sparse_w(rng, n, k, bn, bk, density)
    x = rng.normal(size=(m, n)).astype(np.float32)
    comp = ops.compress_bitmap(w, bn, bk)
    got = ops.bitmap_spmm(jnp.asarray(x), comp, bm=min(128, m))
    want = ref.bitmap_spmm_ref(jnp.asarray(x), jnp.asarray(w),
                               jnp.asarray(bitmap), bn, bk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bitmap_spmm_dtypes(dtype):
    rng = np.random.default_rng(7)
    w, bitmap = _block_sparse_w(rng, 64, 64, 16, 16, 0.4)
    x = rng.normal(size=(32, 64)).astype(np.float32)
    comp = ops.compress_bitmap(w.astype(dtype), 16, 16)
    got = ops.bitmap_spmm(jnp.asarray(x, dtype), comp, bm=32)
    want = ref.bitmap_spmm_ref(jnp.asarray(x, dtype),
                               jnp.asarray(w, dtype),
                               jnp.asarray(bitmap), 16, 16)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), density=st.floats(0.0, 1.0))
def test_bitmap_spmm_property(seed, density):
    """∀ random block patterns: kernel ≡ dense-masked matmul."""
    rng = np.random.default_rng(seed)
    w, bitmap = _block_sparse_w(rng, 64, 32, 16, 8, density)
    x = rng.normal(size=(16, 64)).astype(np.float32)
    comp = ops.compress_bitmap(w, 16, 8)
    got = ops.bitmap_spmm(jnp.asarray(x), comp, bm=16)
    want = jnp.dot(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_bitmap_compression_ratio_tracks_density():
    rng = np.random.default_rng(3)
    w_sparse, _ = _block_sparse_w(rng, 256, 256, 32, 32, 0.25)
    w_dense, _ = _block_sparse_w(rng, 256, 256, 32, 32, 1.0)
    r_s = ops.compress_bitmap(w_sparse, 32, 32).compression_ratio
    r_d = ops.compress_bitmap(w_dense, 32, 32).compression_ratio
    assert r_s < 0.5 and r_d >= 1.0


# ---------------------------------------------------------------------------
# nm_spmm (2:4)
# ---------------------------------------------------------------------------

def _nm_sparse_w(rng, n, k):
    w = rng.normal(size=(n, k)).astype(np.float32)
    # prune to exact 2:4 along N
    wg = w.reshape(n // 4, 4, k)
    order = np.argsort(-np.abs(wg), axis=1)
    mask = np.zeros_like(wg, dtype=bool)
    np.put_along_axis(mask, order[:, :2, :], True, axis=1)
    return (wg * mask).reshape(n, k).astype(np.float32)


@pytest.mark.parametrize("m,n,k", [
    (16, 32, 32), (32, 64, 128), (8, 256, 64), (128, 128, 128),
])
def test_nm_spmm_shapes(m, n, k):
    rng = np.random.default_rng(n + k)
    w = _nm_sparse_w(rng, n, k)
    x = rng.normal(size=(m, n)).astype(np.float32)
    comp = ops.compress_nm(w)
    got = ops.nm_spmm(jnp.asarray(x), comp, bm=min(128, m),
                      bn=min(128, n), bk=min(128, k))
    want = jnp.dot(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_nm_expand_roundtrip():
    rng = np.random.default_rng(11)
    w = _nm_sparse_w(rng, 64, 16)
    comp = ops.compress_nm(w)
    dense = ref.nm_expand_ref(comp.values, comp.indices)
    np.testing.assert_allclose(np.asarray(dense), w, rtol=1e-6, atol=1e-6)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_nm_spmm_property(seed):
    rng = np.random.default_rng(seed)
    w = _nm_sparse_w(rng, 32, 16)
    x = rng.normal(size=(8, 32)).astype(np.float32)
    comp = ops.compress_nm(w)
    got = ops.nm_spmm(jnp.asarray(x), comp, bm=8, bn=32, bk=16)
    want = jnp.dot(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_nm_compression_ratio():
    assert ops.NMCompressed(jnp.zeros((2, 2)), jnp.zeros((2, 2), jnp.int8),
                            4, 2).compression_ratio < 0.6


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bh,sq,skv,d,bq,bk", [
    (2, 64, 64, 32, 16, 16),
    (4, 128, 128, 64, 32, 64),
    (1, 32, 32, 128, 32, 32),      # single tile
    (3, 96, 96, 16, 32, 32),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_shapes(bh, sq, skv, d, bq, bk, causal):
    rng = np.random.default_rng(sq + d)
    q = jnp.asarray(rng.normal(size=(bh, sq, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(bh, skv, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(bh, skv, d)).astype(np.float32))
    got = ops.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_flash_attention_property(seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(2, 64, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 64, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 64, 32)).astype(np.float32))
    got = ops.flash_attention(q, k, v, causal=True, bq=16, bk=32)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 64, 32)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(2, 64, 32)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(2, 64, 32)), jnp.bfloat16)
    got = ops.flash_attention(q, k, v, causal=True, bq=32, bk=32)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)
