"""Execution-plane tests: plan round-trips, compressed-forward numerics,
measured-vs-predicted calibration, kernel jit-cache reuse, fallbacks.

The numerics contract: with fp32 compute (COMPUTE_DTYPE patched), the
compressed forward routes every planned projection through the Pallas
kernels (interpret mode on CPU) and must match the dense forward on the
SAME pruned weights within fp32 tolerance — the surrounding forward is the
dense model's own code path, so any disagreement is kernel error."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import exec as rexec
from repro.configs import get_config
from repro.core.cosearch import CoSearchConfig
from repro.core.engine import EngineConfig
from repro.core.formats import standard_formats
from repro.core.sparsity import NM, Bernoulli, BlockBernoulli
from repro.core.workload import MatMul
from repro.exec import plans
from repro.exec.calibrate import calibrated_hardware
from repro.kernels import ops as kops
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models.transformer import Model

FAST = CoSearchConfig(objective="edp",
                      engine=EngineConfig(max_levels=2,
                                          max_allocs_per_pattern=16),
                      spatial_top=2, max_pairs=6)

BLOCK = BlockBernoulli(0.5, 32 * 32)


@pytest.fixture()
def fp32_compute(monkeypatch):
    """Run the model layers in fp32 so kernel-vs-einsum comparisons are
    accumulation-order-only (the bf16 default adds cast noise)."""
    monkeypatch.setattr(L, "COMPUTE_DTYPE", jnp.float32)
    monkeypatch.setattr(attn_mod, "COMPUTE_DTYPE", jnp.float32)


def _cfg():
    return get_config("chatglm3-6b").reduced()


def _plan(cfg, sp):
    return rexec.build_exec_plan(cfg, sp, tokens=64, search_cfg=FAST,
                                 value_bits=32)


def _serving(cfg, sp, seed=0):
    model = Model(cfg)
    params = model.init(jax.random.key(seed))
    plan = _plan(cfg, sp)
    pruned = rexec.prune_params(params, plan, cfg)
    store = rexec.compress_params(pruned, plan, cfg)
    return model, plan, pruned, store


def _tokens(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)


# ---------------------------------------------------------------------------
# plans
# ---------------------------------------------------------------------------

def test_exec_plan_covers_all_model_roles():
    cfg = _cfg()
    plan = _plan(cfg, BLOCK)
    assert {op.role for op in plan.ops} == \
        {r.role for r in cfg.matmul_roles()}
    for op in plan.ops:
        assert op.choice.kind in ("bitmap", "nm", "dense")
        if op.choice.kind == "bitmap":
            assert op.n % op.choice.block_n == 0
            assert op.k % op.choice.block_k == 0


def test_exec_plan_moe_fanout():
    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    wl = plans.model_workload(cfg, tokens=64, w_sparsity=BLOCK)
    by_name = {op.name: op for op in wl.ops}
    assert "moe.w_gate" in by_name and "ffn.w_gate" not in by_name
    moe_op = by_name["moe.w_gate"]
    assert moe_op.count == cfg.n_layers * cfg.moe.n_experts
    # per-expert routed tokens, not the full batch
    assert moe_op.M == max(1, int(64 * cfg.moe.top_k / cfg.moe.n_experts))


def test_exec_plan_json_roundtrip_bit_identical(tmp_path):
    cfg = _cfg()
    plan = _plan(cfg, BLOCK)
    path = tmp_path / "plan.json"
    path.write_text(plan.to_json())
    loaded = rexec.ExecPlan.from_json(path.read_text())
    # dataclass equality covers every field bit-exactly (floats round-trip
    # through repr); `search` is excluded from equality by design
    assert loaded == plan
    assert [op.choice for op in loaded.ops] == [op.choice for op in plan.ops]
    assert loaded.to_json() == plan.to_json()


def test_fallback_reason_recorded_for_unservable_formats():
    op = MatMul("w", 64, 128, 128, Bernoulli(0.5), Bernoulli(0.3))
    rle = standard_formats({"N": 128, "K": 128})["RLE"]
    ch = plans.translate(op, rle, Bernoulli(0.3))
    assert ch.kind == "dense"
    assert ch.fallback is not None and ch.fallback.code == "no_tpu_kernel"
    assert "RLE" in ch.fallback.detail
    # the search itself choosing dense is NOT a fallback
    assert plans.translate(op, None, Bernoulli(0.3)).fallback is None
    # fallbacks surface on the plan
    plan = dataclasses.replace(
        _plan(_cfg(), BLOCK),
        ops=(plans.OpPlan(role="x", m=1, n=128, k=128, count=1.0, choice=ch,
                          tile={}, predicted_w_fetch_bits=0.0,
                          predicted_i_fetch_bits=0.0, predicted_dram_bits=0.0,
                          predicted_energy=0.0),))
    assert plan.fallbacks() == {"x": ch.fallback}


# ---------------------------------------------------------------------------
# compress
# ---------------------------------------------------------------------------

def test_compress_store_exact_ratio_accounting():
    cfg = _cfg()
    model, plan, pruned, store = _serving(cfg, BLOCK)
    assert len(store) == cfg.n_layers * len(plan.ops)
    for e in store:
        if e.kind != "bitmap":
            continue
        d = e.data
        nnzb = int(np.asarray(d.counts).sum())
        gn, gk = d.n // d.bn, d.k // d.bk
        # exact: realized payload bits + one bitmap bit per grid block
        assert e.stored_bits == nnzb * d.bn * d.bk * 32 + gn * gk
        assert e.dense_bits == d.n * d.k * 32
    # block pruning at density 0.5 halves the payload (+ metadata epsilon)
    total = store.achieved_ratio()
    assert 0.45 < total < 0.55


# ---------------------------------------------------------------------------
# dispatch numerics (the acceptance contract)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sp,kinds", [(BLOCK, {"bitmap"}),
                                      (NM(2, 4), {"nm"})])
def test_compressed_forward_matches_dense(fp32_compute, sp, kinds):
    cfg = _cfg()
    model, plan, pruned, store = _serving(cfg, sp)
    assert {op.choice.kind for op in plan.ops} == kinds
    tokens = _tokens(cfg)
    dense_out = model.hidden_states(pruned, tokens, remat=False)
    comp_out = rexec.CompressedModel(model, store).hidden_states(pruned,
                                                                 tokens)
    np.testing.assert_allclose(np.asarray(comp_out), np.asarray(dense_out),
                               rtol=1e-4, atol=1e-4)


def test_compressed_forward_bf16_default_close():
    """Without the fp32 patch the only divergence is kernel fp32
    accumulation vs bf16 einsum — bounded by bf16 resolution."""
    cfg = _cfg()
    model, plan, pruned, store = _serving(cfg, BLOCK)
    tokens = _tokens(cfg)
    dense_out = model.hidden_states(pruned, tokens, remat=False)
    comp_out = rexec.CompressedModel(model, store).hidden_states(pruned,
                                                                 tokens)
    np.testing.assert_allclose(np.asarray(comp_out, np.float32),
                               np.asarray(dense_out, np.float32),
                               rtol=5e-2, atol=1e-1)


def test_dispatch_jit_cache_shared_across_layers(fp32_compute):
    cfg = _cfg()
    model, plan, pruned, store = _serving(cfg, BLOCK)
    cm = rexec.CompressedModel(model, store)
    # unrolled reference path: every (layer, role) projection dispatched,
    # but only the distinct static configurations built a wrapper —
    # repeated layers are hits (one per-role t_max shared across layers)
    kops.clear_kernel_cache()
    cm.hidden_states_unrolled(pruned, _tokens(cfg))
    stats = kops.kernel_cache_stats()
    assert stats["hits"] > 0
    assert stats["entries"] <= len(plan.ops)
    assert stats["hits"] + stats["misses"] == cfg.n_layers * len(plan.ops)
    # scanned path: the hook runs ONCE per role per trace (the compiled
    # scan replays it per layer), so a whole forward costs len(plan.ops)
    # cache lookups — not n_layers × that
    kops.clear_kernel_cache()
    cm.hidden_states(pruned, _tokens(cfg))
    stats = kops.kernel_cache_stats()
    assert stats["hits"] + stats["misses"] == len(plan.ops)
    assert stats["entries"] <= len(plan.ops)


def test_kernel_wrapper_cache_reuses_jit():
    kops.clear_kernel_cache()
    rng = np.random.default_rng(0)
    w = rng.normal(size=(64, 64)).astype(np.float32)
    comp = kops.compress_bitmap(w, 16, 16)
    x = jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32))
    y1 = kops.bitmap_spmm(x, comp, bm=16)
    st1 = kops.kernel_cache_stats()
    y2 = kops.bitmap_spmm(x, comp, bm=16)
    st2 = kops.kernel_cache_stats()
    assert st1 == {"hits": 0, "misses": 1, "entries": 1}
    assert st2 == {"hits": 1, "misses": 1, "entries": 1}
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2))


# ---------------------------------------------------------------------------
# calibration (end-to-end acceptance)
# ---------------------------------------------------------------------------

def test_end_to_end_plan_dispatch_calibration(fp32_compute):
    """Searched plan → compressed forward → measured counters vs the cost
    model's predicted fetch terms, within the calibrated bound."""
    cfg = _cfg()
    model, plan, pruned, store = _serving(cfg, BLOCK)
    tokens = _tokens(cfg)
    dense_out = model.hidden_states(pruned, tokens, remat=False)
    with rexec.instrument() as counters:
        comp_out = rexec.CompressedModel(model, store).hidden_states(
            pruned, tokens)
    # (a) outputs match the dense forward
    np.testing.assert_allclose(np.asarray(comp_out), np.asarray(dense_out),
                               rtol=1e-4, atol=1e-4)
    # every planned role was dispatched once per layer
    assert {r for r in counters} == {op.role for op in plan.ops}
    assert all(c.calls == cfg.n_layers for c in counters.values())

    # (b) measured fetched bits vs predicted fetch terms
    report = rexec.calibrate(cfg, plan, counters, search_cfg=FAST)
    rows = report.rows
    assert {r.role for r in rows} == {op.role for op in plan.ops}
    for r in rows:
        assert r.measured_bits > 0 and r.predicted_bits > 0
    # the BlockBernoulli spec models block pruning faithfully: the fitted
    # energy coefficient is ~1 and post-fit residuals are tight
    assert 0.9 < report.scale < 1.1
    assert report.max_residual < 0.05
    assert abs(report.energy_drift) < 0.1
    assert report.calibrated_plan.ops


def test_calibration_catches_iid_model_drift(fp32_compute):
    """A plan searched under i.i.d. Bernoulli expects fine-grained
    compression wins the MXU-aligned executable blocks cannot realize
    (whole 128-wide blocks are kept once any element survives), so
    measured traffic comes in well ABOVE prediction; the fitted scale
    raises the DRAM coefficient and the re-searched predicted energy
    drifts up accordingly."""
    cfg = _cfg()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    plan = _plan(cfg, Bernoulli(0.5))
    assert any(op.choice.kind == "bitmap" for op in plan.ops)
    pruned = rexec.prune_params(params, plan, cfg)
    store = rexec.compress_params(pruned, plan, cfg)
    with rexec.instrument() as counters:
        rexec.CompressedModel(model, store).hidden_states(pruned,
                                                          _tokens(cfg))
    report = rexec.calibrate(cfg, plan, counters, search_cfg=FAST)
    assert report.scale > 1.3                  # measured ≫ predicted
    assert report.max_rel_err > 0.5            # the drift was real…
    assert report.max_residual < report.max_rel_err   # …the fit shrinks it
    assert report.energy_drift > 0.2           # calibrated search sees it


def test_calibrated_hardware_scales_dram_only():
    arch = plans.TPUV5E
    cal2 = calibrated_hardware(arch, 0.5)
    assert cal2.dram.pj_per_bit == pytest.approx(arch.dram.pj_per_bit * 0.5)
    assert cal2.glb.pj_per_bit == arch.glb.pj_per_bit
    assert cal2.name.startswith(arch.name)


def test_calibrated_plan_resolves_hardware_after_roundtrip(fp32_compute):
    """A calibrated plan keeps the BASE arch name + the fit as
    ``energy_scale``, so hardware() resolves (with the scale re-applied)
    even after a JSON round trip — and a second calibration composes."""
    cfg = _cfg()
    model, plan, pruned, store = _serving(cfg, BLOCK)
    with rexec.instrument() as counters:
        rexec.CompressedModel(model, store).hidden_states(pruned,
                                                          _tokens(cfg))
    report = rexec.calibrate(cfg, plan, counters, search_cfg=FAST)
    cal_plan = report.calibrated_plan
    assert cal_plan.arch == plan.arch                  # base name kept
    assert cal_plan.energy_scale == pytest.approx(report.scale)
    hw = cal_plan.hardware()
    assert hw.dram.pj_per_bit == pytest.approx(
        plan.hardware().dram.pj_per_bit * report.scale)
    loaded = rexec.ExecPlan.from_json(cal_plan.to_json())
    assert loaded == cal_plan
    assert loaded.hardware().dram.pj_per_bit == hw.dram.pj_per_bit
    # round 2 uses the same counters: composes on top of round 1's scale
    report2 = rexec.calibrate(cfg, cal_plan, counters, search_cfg=FAST)
    assert report2.calibrated_plan.energy_scale == pytest.approx(
        report.scale * report2.scale)


def test_nm_plan_parameters_thread_through_prune_and_compress(fp32_compute):
    """An NM(1, 4) plan must serve 1:4 weights, not the 2:4 defaults."""
    from repro.sparse import masks

    cfg = _cfg()
    model, plan, pruned, store = _serving(cfg, NM(1, 4))
    assert all(op.choice.kind == "nm" for op in plan.ops)
    assert plan.ops[0].choice.format_str == "CP(1:4)"
    w = pruned["blocks"]["attn"]["wq"][0]
    assert masks.density(w) == pytest.approx(0.25, abs=0.01)
    e = store.get(0, "attn.wq")
    assert e.data.n_sel == 1 and e.data.m_group == 4
    # 1/4 of values at fp32 + 2-bit indices ≈ 0.266 — and the plan's
    # predicted ratio (value_bits=32) says the same
    assert e.achieved_ratio == pytest.approx(0.25 * (1 + 2 / 32), rel=1e-3)
    assert plan.ops[0].choice.predicted_ratio == pytest.approx(
        e.achieved_ratio, rel=1e-3)
    # and the 1:4 forward still matches dense
    tokens = _tokens(cfg)
    dense_out = model.hidden_states(pruned, tokens, remat=False)
    comp_out = rexec.CompressedModel(model, store).hidden_states(pruned,
                                                                 tokens)
    np.testing.assert_allclose(np.asarray(comp_out), np.asarray(dense_out),
                               rtol=1e-4, atol=1e-4)
