"""Adaptive compression engine tests (§III-C)."""

import pytest

from repro.core.dataflow import Mapping
from repro.core.engine import (EngineConfig, SearchStats, allocate_for_mapping,
                               eq_data, generate_candidates, select_shared)
from repro.core.formats import Level
from repro.core.primitives import Prim
from repro.core.sparsity import Bernoulli, NM, TensorSpec


SPEC_90 = TensorSpec({"M": 4096, "N": 4096}, Bernoulli(0.1))   # Fig. 6 left
SPEC_24 = TensorSpec({"M": 4096, "N": 4096}, NM(2, 4))         # Fig. 6 right


def test_eq_data_penalty_grows_with_levels():
    assert eq_data(100.0, 3, 1.05) > eq_data(100.0, 2, 1.05) > eq_data(100.0, 1, 1.05)


def test_penalizing_prunes_most_patterns():
    """Fig. 6: penalty cuts the explored space sharply while staying at the
    unpruned optimum (paper: within 0.31%)."""
    cfg = EngineConfig(max_levels=3, max_allocs_per_pattern=200)
    s_pen, s_all = SearchStats(), SearchStats()
    pen = generate_candidates(SPEC_90, cfg, penalize=True, stats=s_pen)
    full = generate_candidates(SPEC_90, cfg, penalize=False, stats=s_all)
    assert s_pen.allocations_seen < s_all.allocations_seen / 2
    best_pen = min(c.report.total_bits for c in pen)
    best_full = min(c.report.total_bits for c in full)
    assert best_pen <= best_full * 1.01     # within ~1% (paper: 0.31%)


def test_candidates_have_few_levels():
    """Penalized winners use 2–3 levels (paper §III-C1/IV-E)."""
    cands = generate_candidates(SPEC_90, EngineConfig(max_levels=3))
    assert all(c.fmt.compressed_levels <= 3 for c in cands)
    assert cands[0].fmt.compressed_levels >= 1


def test_candidates_beat_flat_bitmap_at_high_sparsity():
    from repro.core import formats as F
    from repro.core.sparsity import analyze
    flat = analyze(F.bitmap(SPEC_90.dims), SPEC_90)
    cands = generate_candidates(SPEC_90, EngineConfig(max_levels=3))
    assert cands[0].report.total_bits < flat.total_bits


def test_nm_sparsity_candidates():
    cands = generate_candidates(SPEC_24, EngineConfig(max_levels=2))
    assert cands, "2:4 tensors must yield candidates"
    dense_bits = SPEC_24.dense_bits
    assert cands[0].report.total_bits < dense_bits


def test_allocate_for_mapping_uses_tiling_factors():
    """§III-C2 example: M=8 outer, M=32 inner ⇒ B(M1,8)-B(M2,32)."""
    pattern = (Level(Prim.B, "M"), Level(Prim.B, "M"))
    dims = {"M": 256, "N": 64}
    mapping = Mapping(spatial={"M": 1, "N": 1, "K": 1},
                      tile={"M": 32, "N": 64, "K": 64},
                      order=("M", "N", "K"))
    fmt = allocate_for_mapping(pattern, dims, dims, mapping)
    assert fmt is not None
    sizes = [l.size for l in fmt.levels if l.prim is Prim.B]
    assert sizes == [8, 32]


def test_allocate_for_mapping_merges_excess_chain():
    pattern = (Level(Prim.B, "M"),)
    dims = {"M": 256}
    mapping = Mapping(spatial={"M": 4, "N": 1, "K": 1},
                      tile={"M": 32, "N": 1, "K": 1},
                      order=("M", "N", "K"))
    fmt = allocate_for_mapping(pattern, dims, dims, mapping)
    assert fmt is not None
    fmt.validate(dims)


def test_select_shared_importance_weighting():
    table = {
        "A": {"f1": 10.0, "f2": 20.0},
        "B": {"f1": 100.0, "f2": 50.0},
    }
    # A dominant → f1 wins; B dominant → f2 wins.
    k_a, _ = select_shared(table, {"A": 99, "B": 1})
    k_b, _ = select_shared(table, {"A": 1, "B": 99})
    assert k_a == "f1" and k_b == "f2"


def test_select_shared_requires_common_formats():
    with pytest.raises(ValueError):
        select_shared({"A": {"f1": 1.0}, "B": {"f2": 1.0}}, {})
