"""Jit'd public wrappers for the sparse kernels.

On CPU (this container) the Pallas kernels run in ``interpret=True`` mode;
on TPU they compile natively.  Compression runs host-side (numpy) — it is
the SnipSnap format decoder's software half: the chosen format's metadata
becomes scalar-prefetch arrays whose layout mirrors the kernel tiling.

The jitted wrappers are CACHED per static-knob tuple (``_jitted``): the
seed rebuilt ``jax.jit(functools.partial(...))`` on every call, which made
every invocation a fresh jit object and threw away XLA's compile cache —
repeated layers of a served model each paid a retrace.  Now the partial is
built once per (kernel, static args) key and jax's own per-shape cache does
the rest; :func:`kernel_cache_stats` exposes hit counters so tests can pin
that the second call of a shape reuses the first's compilation.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.bitmap_spmm import bitmap_spmm_pallas
from repro.kernels.nm_spmm import nm_spmm_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Jitted-wrapper cache (per-op: repeated layers share one compiled kernel)
# ---------------------------------------------------------------------------

_JIT_CACHE: dict[tuple, object] = {}
_JIT_STATS = {"hits": 0, "misses": 0}


def _jitted(kind: str, builder, *static) -> object:
    """The jitted kernel wrapper for ``(kind, *static)``, built once.

    ``builder`` receives the static args and returns the function to jit.
    jax.jit's own signature cache then handles per-shape retraces, so a
    model whose layers share a kernel configuration compiles it once."""
    key = (kind,) + static
    fn = _JIT_CACHE.get(key)
    if fn is None:
        _JIT_STATS["misses"] += 1
        fn = _JIT_CACHE[key] = jax.jit(builder(*static))
    else:
        _JIT_STATS["hits"] += 1
    return fn


def kernel_cache_stats() -> dict[str, int]:
    """Hit/miss counters of the jitted-wrapper cache (plus its size)."""
    return dict(_JIT_STATS, entries=len(_JIT_CACHE))


def clear_kernel_cache() -> None:
    _JIT_CACHE.clear()
    _JIT_STATS["hits"] = _JIT_STATS["misses"] = 0


# ---------------------------------------------------------------------------
# Bitmap block-sparse
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BitmapCompressed:
    """`B(N₁)-B(K₁)-None(N₂,K₂)` weights: payload + pre-decoded metadata."""

    blocks: jax.Array          # (nnzb, bn, bk)
    counts: jax.Array          # (K/bk,) int32
    row_ids: jax.Array         # (nnzb,) int32
    offsets: jax.Array         # (K/bk,) int32
    n: int
    k: int
    bn: int
    bk: int
    max_per_col: int

    @property
    def compression_ratio(self) -> float:
        dense = self.n * self.k
        stored = self.blocks.shape[0] * self.bn * self.bk
        meta = (self.n // self.bn) * (self.k // self.bk) / 8 / 2  # bits→bytes/2B
        return (stored + meta) / dense


def compress_bitmap(w, bn: int = 128, bk: int = 128) -> BitmapCompressed:
    blocks, counts, row_ids, offsets, bitmap = ref.compress_bitmap_host(
        np.asarray(w), bn, bk)
    return BitmapCompressed(
        blocks=jnp.asarray(blocks), counts=jnp.asarray(counts),
        row_ids=jnp.asarray(row_ids), offsets=jnp.asarray(offsets),
        n=w.shape[0], k=w.shape[1], bn=bn, bk=bk,
        max_per_col=int(counts.max()) if counts.size else 1)


def _bitmap_builder(k: int, bm: int, t_max: int, interpret: bool):
    return functools.partial(bitmap_spmm_pallas, k=k, bm=bm, t_max=t_max,
                             interpret=interpret)


def bitmap_spmm(x: jax.Array, w: BitmapCompressed, bm: int = 128,
                t_max: int | None = None) -> jax.Array:
    """Y = X @ W_blocksparse; dispatches to the Pallas kernel.

    ``t_max`` (default: ``w.max_per_col``) is part of the static cache key,
    so the grid's innermost bound is always the statically-known tightest —
    even under jit/scan, where ``counts`` is a tracer and the kernel's own
    inference would have to assume every stored block.  A layer-stacked
    store passes its shared across-layers bound here, which is what keys
    the cache on the STACKED configuration instead of per-layer values."""
    if t_max is None:
        t_max = w.max_per_col
    fn = _jitted("bitmap", _bitmap_builder, w.k, bm, max(int(t_max), 1),
                 _interpret())
    return fn(x, w.blocks, w.counts, w.row_ids, w.offsets)


# ---------------------------------------------------------------------------
# N:M structured
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class NMCompressed:
    values: jax.Array          # (N·n/m, K)
    indices: jax.Array         # (N·n/m, K) int8 ∈ [0, m)
    n: int
    k: int
    n_sel: int = 2
    m_group: int = 4

    @property
    def compression_ratio(self) -> float:
        # values halve; 2-bit indices ≈ n_sel/m_group · 2/16 of dense bits
        return self.n_sel / self.m_group * (1 + 2 / 16)


def compress_nm(w, n_sel: int = 2, m_group: int = 4) -> NMCompressed:
    vals, idx = ref.compress_nm_host(np.asarray(w), n_sel, m_group)
    return NMCompressed(values=jnp.asarray(vals), indices=jnp.asarray(idx),
                        n=w.shape[0], k=w.shape[1],
                        n_sel=n_sel, m_group=m_group)


def _nm_builder(n_sel: int, m_group: int, bm: int, bn: int, bk: int,
                interpret: bool):
    return functools.partial(nm_spmm_pallas, n_sel=n_sel, m_group=m_group,
                             bm=bm, bn=bn, bk=bk, interpret=interpret)


def nm_spmm(x: jax.Array, w: NMCompressed, bm: int = 128, bn: int = 128,
            bk: int = 128) -> jax.Array:
    fn = _jitted("nm", _nm_builder, w.n_sel, w.m_group, bm, bn, bk,
                 _interpret())
    return fn(x, w.values, w.indices)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

def _flash_builder(causal: bool, bq: int, bk: int, interpret: bool):
    from repro.kernels.flash_attention import flash_attention_pallas
    return functools.partial(flash_attention_pallas, causal=causal,
                             bq=bq, bk=bk, interpret=interpret)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, bq: int = 128, bk: int = 128
                    ) -> jax.Array:
    fn = _jitted("flash", _flash_builder, causal, bq, bk, _interpret())
    return fn(q, k, v)
