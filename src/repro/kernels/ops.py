"""Jit'd public wrappers for the sparse kernels.

On CPU (this container) the Pallas kernels run in ``interpret=True`` mode;
on TPU they compile natively.  Compression runs host-side (numpy) — it is
the SnipSnap format decoder's software half: the chosen format's metadata
becomes scalar-prefetch arrays whose layout mirrors the kernel tiling.

The jitted wrappers are CACHED per static-knob tuple (``_jitted``): the
seed rebuilt ``jax.jit(functools.partial(...))`` on every call, which made
every invocation a fresh jit object and threw away XLA's compile cache —
repeated layers of a served model each paid a retrace.  Now the partial is
built once per (kernel, static args) key and jax's own per-shape cache does
the rest; :func:`kernel_cache_stats` exposes hit counters so tests can pin
that the second call of a shape reuses the first's compilation.

Cache keying, precisely: every knob that changes the compiled grid or body
is in the key — for bitmap that is ``(k, bm, t_max, pipeline, interpret)``,
for N:M ``(n_sel, m_group, bm, bn, bk, pipeline, interpret)``.  The
``t_max`` entry is what lets the scanned serving path and the unrolled
per-layer loop SHARE entries: both dispatch with the per-role
across-layers max (the scanned path because the stacked store pads every
layer to one bound, the unrolled path because ``_Dispatcher`` pre-computes
the same max), so the key tuples coincide.  Dispatching a role with a
per-layer ``t_max`` instead would fork one cache entry per distinct layer
bound and silently recompile under scan — the regression test
``test_kernel_cache_shared_between_scanned_and_unrolled`` pins the shared
count.  ``pipeline`` is in the key even though the streaming kernel
ignores ``t_max``: two wrappers differing only in path choice must never
alias.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.bitmap_spmm import bitmap_spmm_pallas
from repro.kernels.nm_spmm import nm_spmm_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


_PIPELINE_DEFAULT = True


def resolve_pipeline(pipeline: bool | None) -> bool:
    """Resolve the dispatch-level ``pipeline`` knob (None → default).

    The double-buffered streaming path is the default on every backend: on
    TPU it overlaps HBM→VMEM payload DMAs with the MXU, and even the
    interpret-mode discharge on CPU wins because its per-``kj`` loop walks
    only ``counts[kj]`` real blocks instead of the naive path's full
    ``t_max`` grid steps.  ``pipeline=False`` keeps the seed's naive
    BlockSpec-driven kernels for parity tests and benchmarks."""
    return _PIPELINE_DEFAULT if pipeline is None else bool(pipeline)


@contextlib.contextmanager
def pipeline_default(on: bool):
    """Temporarily change what ``pipeline=None`` resolves to.

    Lets whole execution paths that never thread the knob (the serving
    dispatchers) be timed against the naive kernels — both settings share
    the jit-wrapper cache because the RESOLVED value is what enters the
    key."""
    global _PIPELINE_DEFAULT
    prev = _PIPELINE_DEFAULT
    _PIPELINE_DEFAULT = bool(on)
    try:
        yield
    finally:
        _PIPELINE_DEFAULT = prev


# ---------------------------------------------------------------------------
# Kernel fault hook (deterministic failure injection for robustness tests)
# ---------------------------------------------------------------------------

_FAULT_HOOK = None


@contextlib.contextmanager
def kernel_fault_hook(fn):
    """Install a hook called as ``fn(kind)`` at every sparse-kernel dispatch
    (``kind`` ∈ {"bitmap", "nm"}) — raising from the hook simulates a kernel
    failure at trace/dispatch time, which is where a real lowering or launch
    failure surfaces.  The serving dispatchers' ``kernel_guard`` turns such
    failures into per-role dense fallbacks; :mod:`repro.runtime.inject`
    builds its ``kernel_failure`` harness on this hook."""
    global _FAULT_HOOK
    prev = _FAULT_HOOK
    _FAULT_HOOK = fn
    try:
        yield
    finally:
        _FAULT_HOOK = prev


def _fault_check(kind: str) -> None:
    if _FAULT_HOOK is not None:
        _FAULT_HOOK(kind)


# ---------------------------------------------------------------------------
# Kernel dispatch hook (per-dispatch timing for the observability plane)
# ---------------------------------------------------------------------------

_DISPATCH_HOOK = None


@contextlib.contextmanager
def kernel_dispatch_hook(fn):
    """Install a hook called as ``fn(kind, seconds)`` after every kernel
    dispatch (``kind`` ∈ {"bitmap", "nm", "flash"}) — the observation twin
    of :func:`kernel_fault_hook`.  Under jit the dispatch runs at TRACE
    time, so a warm cache hit never reaches the hook; what it times is the
    dispatch/trace cost a forward actually pays (on CPU interpret mode
    that includes execution).  :func:`repro.obs.profile.kernel_timer`
    layers the metrics/trace recording on top.  Zero cost uninstalled:
    one ``None`` check per dispatch."""
    global _DISPATCH_HOOK
    prev = _DISPATCH_HOOK
    _DISPATCH_HOOK = fn
    try:
        yield
    finally:
        _DISPATCH_HOOK = prev


def _dispatch(kind: str, fn, *args):
    if _DISPATCH_HOOK is None:
        return fn(*args)
    t0 = time.perf_counter()
    out = fn(*args)
    _DISPATCH_HOOK(kind, time.perf_counter() - t0)
    return out


# ---------------------------------------------------------------------------
# Jitted-wrapper cache (per-op: repeated layers share one compiled kernel)
# ---------------------------------------------------------------------------

_JIT_CACHE: dict[tuple, object] = {}
_JIT_STATS = {"hits": 0, "misses": 0}


def _jitted(kind: str, builder, *static) -> object:
    """The jitted kernel wrapper for ``(kind, *static)``, built once.

    ``builder`` receives the static args and returns the function to jit.
    jax.jit's own signature cache then handles per-shape retraces, so a
    model whose layers share a kernel configuration compiles it once."""
    key = (kind,) + static
    fn = _JIT_CACHE.get(key)
    if fn is None:
        _JIT_STATS["misses"] += 1
        fn = _JIT_CACHE[key] = jax.jit(builder(*static))
    else:
        _JIT_STATS["hits"] += 1
    return fn


def kernel_cache_stats() -> dict[str, int]:
    """Hit/miss counters of the jitted-wrapper cache (plus its size)."""
    return dict(_JIT_STATS, entries=len(_JIT_CACHE))


def clear_kernel_cache() -> None:
    _JIT_CACHE.clear()
    _JIT_STATS["hits"] = _JIT_STATS["misses"] = 0


# ---------------------------------------------------------------------------
# Bitmap block-sparse
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BitmapCompressed:
    """`B(N₁)-B(K₁)-None(N₂,K₂)` weights: payload + pre-decoded metadata."""

    blocks: jax.Array          # (nnzb, bn, bk)
    counts: jax.Array          # (K/bk,) int32
    row_ids: jax.Array         # (nnzb,) int32
    offsets: jax.Array         # (K/bk,) int32
    n: int
    k: int
    bn: int
    bk: int
    max_per_col: int

    @property
    def compression_ratio(self) -> float:
        dense = self.n * self.k
        stored = self.blocks.shape[0] * self.bn * self.bk
        meta = (self.n // self.bn) * (self.k // self.bk) / 8 / 2  # bits→bytes/2B
        return (stored + meta) / dense


def compress_bitmap(w, bn: int = 128, bk: int = 128) -> BitmapCompressed:
    blocks, counts, row_ids, offsets, bitmap = ref.compress_bitmap_host(
        np.asarray(w), bn, bk)
    return BitmapCompressed(
        blocks=jnp.asarray(blocks), counts=jnp.asarray(counts),
        row_ids=jnp.asarray(row_ids), offsets=jnp.asarray(offsets),
        n=w.shape[0], k=w.shape[1], bn=bn, bk=bk,
        max_per_col=int(counts.max()) if counts.size else 1)


def _bitmap_builder(k: int, bm: int, t_max: int, pipeline: bool,
                    interpret: bool):
    return functools.partial(bitmap_spmm_pallas, k=k, bm=bm, t_max=t_max,
                             pipeline=pipeline, interpret=interpret)


def bitmap_spmm(x: jax.Array, w: BitmapCompressed, bm: int = 128,
                t_max: int | None = None,
                pipeline: bool | None = None) -> jax.Array:
    """Y = X @ W_blocksparse; dispatches to the Pallas kernel.

    ``t_max`` (default: ``w.max_per_col``) is part of the static cache key,
    so the naive path's innermost grid bound is always the statically-known
    tightest — even under jit/scan, where ``counts`` is a tracer and the
    kernel's own inference would have to assume every stored block.  A
    layer-stacked store passes its shared across-layers bound here, which
    is what keys the cache on the STACKED configuration instead of
    per-layer values (and what lets scanned and unrolled forwards share
    entries — see the module docstring).  The streaming path ignores
    ``t_max`` (its loop bound is the runtime ``counts[kj]``) but keeps it
    in the key so switching paths never aliases a wrapper."""
    _fault_check("bitmap")
    if t_max is None:
        t_max = w.max_per_col
    fn = _jitted("bitmap", _bitmap_builder, w.k, bm, max(int(t_max), 1),
                 resolve_pipeline(pipeline), _interpret())
    return _dispatch("bitmap", fn, x, w.blocks, w.counts, w.row_ids,
                     w.offsets)


# ---------------------------------------------------------------------------
# N:M structured
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class NMCompressed:
    values: jax.Array          # (N·n/m, K)
    indices: jax.Array         # (N·n/m, K) int8 ∈ [0, m)
    n: int
    k: int
    n_sel: int = 2
    m_group: int = 4

    @property
    def compression_ratio(self) -> float:
        # values halve; 2-bit indices ≈ n_sel/m_group · 2/16 of dense bits
        return self.n_sel / self.m_group * (1 + 2 / 16)


def compress_nm(w, n_sel: int = 2, m_group: int = 4) -> NMCompressed:
    vals, idx = ref.compress_nm_host(np.asarray(w), n_sel, m_group)
    return NMCompressed(values=jnp.asarray(vals), indices=jnp.asarray(idx),
                        n=w.shape[0], k=w.shape[1],
                        n_sel=n_sel, m_group=m_group)


def _nm_builder(n_sel: int, m_group: int, bm: int, bn: int, bk: int,
                pipeline: bool, interpret: bool):
    return functools.partial(nm_spmm_pallas, n_sel=n_sel, m_group=m_group,
                             bm=bm, bn=bn, bk=bk, pipeline=pipeline,
                             interpret=interpret)


def nm_spmm(x: jax.Array, w: NMCompressed, bm: int = 128, bn: int = 128,
            bk: int = 128, pipeline: bool | None = None) -> jax.Array:
    _fault_check("nm")
    fn = _jitted("nm", _nm_builder, w.n_sel, w.m_group, bm, bn, bk,
                 resolve_pipeline(pipeline), _interpret())
    return _dispatch("nm", fn, x, w.values, w.indices)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

def _flash_builder(causal: bool, bq: int, bk: int, interpret: bool):
    from repro.kernels.flash_attention import flash_attention_pallas
    return functools.partial(flash_attention_pallas, causal=causal,
                             bq=bq, bk=bk, interpret=interpret)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, bq: int = 128, bk: int = 128
                    ) -> jax.Array:
    fn = _jitted("flash", _flash_builder, causal, bq, bk, _interpret())
    return _dispatch("flash", fn, q, k, v)
