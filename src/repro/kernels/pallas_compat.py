"""Compatibility shims across JAX / Pallas releases.

The TPU compiler-params dataclass was renamed ``TPUCompilerParams`` →
``CompilerParams`` across JAX releases; resolve whichever the pinned JAX
ships so the kernels import everywhere.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = (getattr(pltpu, "CompilerParams", None)
                  or pltpu.TPUCompilerParams)
