"""Pure-jnp oracles for the sparse kernels.

These are the ground truth for every Pallas kernel test (assert_allclose on
shape/dtype sweeps) and the CPU fallback for small problems.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def bitmap_mask(bitmap: jnp.ndarray, bn: int, bk: int) -> jnp.ndarray:
    """Expand a block bitmap (N/bn, K/bk) to an element mask (N, K)."""
    return jnp.repeat(jnp.repeat(bitmap, bn, axis=0), bk, axis=1)


def bitmap_spmm_ref(x: jnp.ndarray, w: jnp.ndarray, bitmap: jnp.ndarray,
                    bn: int, bk: int) -> jnp.ndarray:
    """Y = X @ (W ⊙ block_mask).  x: (M, N), w: (N, K)."""
    mask = bitmap_mask(bitmap, bn, bk).astype(w.dtype)
    return jnp.dot(x, w * mask, preferred_element_type=jnp.float32)


def nm_expand_ref(wc: jnp.ndarray, idx: jnp.ndarray, m_group: int = 4
                  ) -> jnp.ndarray:
    """Decompress N:M values+indices to dense.

    wc/idx: (N·n/m, K) — for 2:4, (N/2, K); idx ∈ [0, m).  Returns (N, K).
    """
    half, k = wc.shape
    n_sel = 2  # 2:4
    groups = half // n_sel
    n = groups * m_group
    wc3 = wc.reshape(groups, n_sel, k)
    idx3 = idx.reshape(groups, n_sel, k)
    eq = idx3[:, :, None, :] == jnp.arange(m_group)[None, None, :, None]
    dense = jnp.sum(jnp.where(eq, wc3[:, :, None, :], 0), axis=1)
    return dense.reshape(n, k)


def nm_spmm_ref(x: jnp.ndarray, wc: jnp.ndarray, idx: jnp.ndarray
                ) -> jnp.ndarray:
    """Y = X @ expand(wc, idx).  x: (M, N)."""
    return jnp.dot(x, nm_expand_ref(wc, idx).astype(x.dtype),
                   preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Host-side compressors (numpy; used by ops.py and tests)
# ---------------------------------------------------------------------------

def compress_bitmap_host(w: np.ndarray, bn: int, bk: int):
    """Block-compress a dense matrix: returns (blocks, col_counts, row_ids,
    col_offsets, bitmap).

    Layout is CSC over the block grid (per block-COLUMN lists of non-zero
    block-rows) — matches the kernel's reduction order over N for each
    output tile column.  This is the `B(N₁)-B(K₁)-None(N₂,K₂)` hierarchical
    format realized as scalar-prefetch metadata.
    """
    n, k = w.shape
    assert n % bn == 0 and k % bk == 0, (w.shape, bn, bk)
    gn, gk = n // bn, k // bk
    wb = w.reshape(gn, bn, gk, bk).transpose(0, 2, 1, 3)     # (gn, gk, bn, bk)
    bitmap = np.any(wb != 0, axis=(2, 3))                    # (gn, gk)
    counts = bitmap.sum(axis=0).astype(np.int32)             # per block-col
    offsets = np.zeros(gk, np.int32)
    offsets[1:] = np.cumsum(counts)[:-1]
    total = int(counts.sum())
    blocks = np.zeros((max(total, 1), bn, bk), w.dtype)
    row_ids = np.zeros(max(total, 1), np.int32)
    t = 0
    for j in range(gk):
        for i in range(gn):
            if bitmap[i, j]:
                blocks[t] = wb[i, j]
                row_ids[t] = i
                t += 1
    return blocks, counts, row_ids, offsets, bitmap


def compress_nm_host(w: np.ndarray, n_sel: int = 2, m_group: int = 4):
    """Compress an (already N:M-pruned) matrix along its first axis.

    Keeps the ``n_sel`` largest-magnitude entries per ``m_group`` (ties →
    first), returning (values (N·n/m, K), indices int8).  Exact for inputs
    that are genuinely N:M sparse; otherwise it acts as an N:M pruner.
    """
    n, k = w.shape
    assert n % m_group == 0
    groups = n // m_group
    wg = w.reshape(groups, m_group, k)
    order = np.argsort(-np.abs(wg), axis=1, kind="stable")[:, :n_sel, :]
    order = np.sort(order, axis=1)                           # ascending pos
    vals = np.take_along_axis(wg, order, axis=1)
    return (vals.reshape(groups * n_sel, k).astype(w.dtype),
            order.reshape(groups * n_sel, k).astype(np.int8))


def flash_attention_ref(q, k, v, causal: bool = True):
    """Dense softmax attention oracle.  q/k/v: (BH, S, D)."""
    import jax
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k) / np.sqrt(d)
    if causal:
        sq, sk = s.shape[-2], s.shape[-1]
        mask = np.tril(np.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(mask[None], s, -1e30)
    w = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w.astype(q.dtype), v)
