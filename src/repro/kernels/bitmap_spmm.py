"""Block-bitmap compressed matmul — Pallas TPU kernel.

Executes SnipSnap's TPU-native hierarchical format
``B(N₁)-B(K₁)-None(N₂,K₂)``: a bitmap over the (N/bn, K/bk) block grid with
dense MXU-aligned payload blocks, stored COMPRESSED (only non-zero blocks
travel HBM→VMEM).  The bitmap is pre-decoded on the host into CSC-style
scalar-prefetch metadata (per-block-column counts / offsets / row ids), so
the kernel's grid walks exactly the non-zero blocks — the TPU analogue of
"Skipping I←W" at block granularity (DESIGN.md §4).

Grid: (M/bm, K/bk, T) with T = max non-zero blocks in any block-column.
The accumulator tile Y[mi, kj] stays resident in VMEM across the T axis
(innermost grid dim revisits the same output block).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _kernel(counts_ref, offs_ref, rows_ref, x_ref, w_ref, y_ref):
    kj = pl.program_id(1)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    @pl.when(t < counts_ref[kj])
    def _acc():
        y_ref[...] += jnp.dot(x_ref[...], w_ref[0],
                              preferred_element_type=jnp.float32)


def bitmap_spmm_pallas(x: jax.Array, blocks: jax.Array, counts: jax.Array,
                       row_ids: jax.Array, offsets: jax.Array,
                       *, k: int, bm: int = 128, t_max: int | None = None,
                       interpret: bool = False) -> jax.Array:
    """x: (M, N) dense; blocks: (nnzb, bn, bk) compressed payload;
    counts/offsets: (K/bk,) per-block-column metadata; row_ids: (nnzb,).
    Returns Y = X @ W_sparse, (M, K) float32.

    ``t_max`` is the static innermost grid bound (the max non-zero blocks in
    any block-column).  Pass it explicitly whenever ``counts`` may be a
    tracer (jit / scan): the fallback inference must then assume ``nnzb``,
    which walks EVERY stored block per block-column.  A padded layer-stacked
    store passes one shared bound so every scanned layer runs the same grid.
    """
    m, n = x.shape
    nnzb, bn, bk = blocks.shape
    gk = k // bk
    if t_max is None:
        t_max = 1 if nnzb == 0 else int(counts.max()) \
            if hasattr(counts, "max") \
            and not isinstance(counts, jax.core.Tracer) else nnzb
    # static grid bound: tightest statically-known T
    t_max = max(int(t_max), 1)
    bm = min(bm, m)
    grid = (m // bm, gk, t_max)

    def x_map(mi, kj, t, counts, offs, rows):
        safe_t = jnp.minimum(offs[kj] + t, nnzb - 1)
        return (mi, rows[safe_t])

    def w_map(mi, kj, t, counts, offs, rows):
        return (jnp.minimum(offs[kj] + t, nnzb - 1), 0, 0)

    def y_map(mi, kj, t, counts, offs, rows):
        return (mi, kj)

    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bn), x_map),
                pl.BlockSpec((1, bn, bk), w_map),
            ],
            out_specs=pl.BlockSpec((bm, bk), y_map),
        ),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(counts, offsets, row_ids, x, blocks)
