"""Block-bitmap compressed matmul — Pallas TPU kernel.

Executes SnipSnap's TPU-native hierarchical format
``B(N₁)-B(K₁)-None(N₂,K₂)``: a bitmap over the (N/bn, K/bk) block grid with
dense MXU-aligned payload blocks, stored COMPRESSED (only non-zero blocks
travel HBM→VMEM).  The bitmap is pre-decoded on the host into CSC-style
scalar-prefetch metadata (per-block-column counts / offsets / row ids), so
the kernel's grid walks exactly the non-zero blocks — the TPU analogue of
"Skipping I←W" at block granularity (DESIGN.md §4).

Two execution paths, selected by ``pipeline``:

* **naive** (the seed path, kept as the parity/benchmark reference):
  grid (M/bm, K/bk, T) with T = max non-zero blocks in any block-column.
  Every block-column walks the full T steps (`pl.when` masks the short
  ones), and each step's payload fetch is issued by the BlockSpec machinery
  one grid step at a time.
* **pipelined** (the streaming path): grid (M/bm, K/bk) with a manual
  double-buffered async-copy pipeline inside the kernel.  Payload and
  input blocks live in HBM (``memory_space=ANY``); the kernel walks ONLY
  ``counts[kj]`` real blocks per column and overlaps the next block's
  HBM→VMEM DMA with the current block's MAC via two-slot VMEM buffers.
  The per-``kj`` block loop also reads ``row_ids[offsets[kj] : +counts]``
  as one coalesced stripe instead of the naive path's per-grid-step
  scalar gathers.

Both paths accumulate into Y[mi, kj] in the SAME block order with the same
``jnp.dot(..., preferred_element_type=f32)``, so their fp32 results are
bit-identical — in interpret mode (CPU CI) and compiled alike.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _kernel(counts_ref, offs_ref, rows_ref, x_ref, w_ref, y_ref):
    kj = pl.program_id(1)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    @pl.when(t < counts_ref[kj])
    def _acc():
        y_ref[...] += jnp.dot(x_ref[...], w_ref[0],
                              preferred_element_type=jnp.float32)


def _pipelined_kernel(counts_ref, offs_ref, rows_ref, x_hbm, w_hbm, y_ref,
                      *, bm: int, bn: int, bk: int):
    """Double-buffered streaming body: two VMEM slots per operand + DMA
    semaphores; slot ``(t+1) % 2`` prefetches block ``t+1`` while slot
    ``t % 2`` feeds the MXU.  All DMA src/dst indexing is rank-preserving
    (``pl.ds`` slices) so the interpret-mode discharge produces the exact
    same copies the TPU DMA engine would."""
    mi = pl.program_id(0)
    kj = pl.program_id(1)
    n_blk = counts_ref[kj]
    off = offs_ref[kj]
    y_ref[...] = jnp.zeros_like(y_ref)

    def body(xbuf, wbuf, sems):
        def dma_x(slot, t):
            r = rows_ref[off + t]
            return pltpu.make_async_copy(
                x_hbm.at[pl.ds(mi, 1), :, pl.ds(r * bn, bn)],
                xbuf.at[pl.ds(slot, 1)], sems.at[0, slot])

        def dma_w(slot, t):
            return pltpu.make_async_copy(
                w_hbm.at[pl.ds(off + t, 1)], wbuf.at[pl.ds(slot, 1)],
                sems.at[1, slot])

        @pl.when(n_blk > 0)
        def _warm():
            dma_x(0, 0).start()
            dma_w(0, 0).start()

        def loop(t, carry):
            slot = jax.lax.rem(t, 2)
            nxt = jax.lax.rem(t + 1, 2)

            @pl.when(t + 1 < n_blk)
            def _prefetch():
                dma_x(nxt, t + 1).start()
                dma_w(nxt, t + 1).start()

            dma_x(slot, t).wait()
            dma_w(slot, t).wait()
            y_ref[...] += jnp.dot(xbuf[slot], wbuf[slot],
                                  preferred_element_type=jnp.float32)
            return carry

        jax.lax.fori_loop(0, n_blk, loop, 0)

    pl.run_scoped(
        body,
        xbuf=pltpu.VMEM((2, bm, bn), x_hbm.dtype),
        wbuf=pltpu.VMEM((2, bn, bk), w_hbm.dtype),
        sems=pltpu.SemaphoreType.DMA((2, 2)),
    )


def _bitmap_spmm_pipelined(x: jax.Array, blocks: jax.Array,
                           counts: jax.Array, row_ids: jax.Array,
                           offsets: jax.Array, *, k: int, bm: int,
                           interpret: bool) -> jax.Array:
    m, n = x.shape
    nnzb, bn, bk = blocks.shape
    gk = k // bk
    bm = min(bm, m)
    # Rank-3 HBM view of X: DMA src (1, bm, bn) slices match the VMEM slot
    # rank exactly (a rank-preservation requirement of the copy discharge).
    x3 = x.reshape(m // bm, bm, n)
    kernel = functools.partial(_pipelined_kernel, bm=bm, bn=bn, bk=bk)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(m // bm, gk),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec(memory_space=pltpu.ANY),
            ],
            out_specs=pl.BlockSpec((bm, bk), lambda mi, kj, *_: (mi, kj)),
        ),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
    )(counts, offsets, row_ids, x3, blocks)


def bitmap_spmm_pallas(x: jax.Array, blocks: jax.Array, counts: jax.Array,
                       row_ids: jax.Array, offsets: jax.Array,
                       *, k: int, bm: int = 128, t_max: int | None = None,
                       interpret: bool = False,
                       pipeline: bool = False) -> jax.Array:
    """x: (M, N) dense; blocks: (nnzb, bn, bk) compressed payload;
    counts/offsets: (K/bk,) per-block-column metadata; row_ids: (nnzb,).
    Returns Y = X @ W_sparse, (M, K) float32.

    ``pipeline=True`` selects the double-buffered streaming path (see the
    module docstring); it needs no ``t_max`` — the in-kernel loop bound is
    the runtime ``counts[kj]``, so short block-columns never pay for the
    longest one.

    ``t_max`` is the NAIVE path's static innermost grid bound (the max
    non-zero blocks in any block-column).  Pass it explicitly whenever
    ``counts`` may be a tracer (jit / scan): the fallback inference must
    then assume ``nnzb``, which walks EVERY stored block per block-column.
    A padded layer-stacked store passes one shared bound so every scanned
    layer runs the same grid.
    """
    if pipeline:
        return _bitmap_spmm_pipelined(x, blocks, counts, row_ids, offsets,
                                      k=k, bm=bm, interpret=interpret)
    m, n = x.shape
    nnzb, bn, bk = blocks.shape
    gk = k // bk
    if t_max is None:
        t_max = 1 if nnzb == 0 else int(counts.max()) \
            if hasattr(counts, "max") \
            and not isinstance(counts, jax.core.Tracer) else nnzb
    # static grid bound: tightest statically-known T
    t_max = max(int(t_max), 1)
    bm = min(bm, m)
    grid = (m // bm, gk, t_max)

    def x_map(mi, kj, t, counts, offs, rows):
        safe_t = jnp.minimum(offs[kj] + t, nnzb - 1)
        return (mi, rows[safe_t])

    def w_map(mi, kj, t, counts, offs, rows):
        return (jnp.minimum(offs[kj] + t, nnzb - 1), 0, 0)

    def y_map(mi, kj, t, counts, offs, rows):
        return (mi, kj)

    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bn), x_map),
                pl.BlockSpec((1, bn, bk), w_map),
            ],
            out_specs=pl.BlockSpec((bm, bk), y_map),
        ),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(counts, offsets, row_ids, x, blocks)
