"""N:M (2:4) structured sparse matmul — Pallas TPU kernel.

Weights travel HBM→VMEM compressed: values (N/2, K) + 2-bit positions
(stored int8).  Decompression happens at the VMEM→VREG boundary — each tile
is expanded to a dense (bn, bk) MXU operand with vectorized compares
(no gather), then fed to the systolic matmul.  This is the paper's
``CP``-at-the-innermost-level primitive mapped onto the TPU memory
hierarchy: metadata decode cost sits next to the compute unit, and the
format's group size (4) nests inside the BlockSpec tile exactly as
SnipSnap's efficiency-oriented allocation prescribes.

Grid: (M/bm, K/bk, N/bn), accumulating over the N axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _kernel(x_ref, wc_ref, idx_ref, y_ref, *, n_sel: int, m_group: int):
    ni = pl.program_id(2)

    @pl.when(ni == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    wc = wc_ref[...]                      # (bn·n/m, bk)
    idx = idx_ref[...].astype(jnp.int32)
    half, bk = wc.shape
    groups = half // n_sel
    wc3 = wc.reshape(groups, n_sel, bk)
    idx3 = idx.reshape(groups, n_sel, bk)
    # dense[g, p, k] = Σ_j (idx[g,j,k] == p) · wc[g,j,k]
    pos = jax.lax.broadcasted_iota(jnp.int32, (groups, n_sel, m_group, bk), 2)
    eq = idx3[:, :, None, :] == pos
    dense = jnp.sum(jnp.where(eq, wc3[:, :, None, :], 0), axis=1)
    dense = dense.reshape(groups * m_group, bk)
    y_ref[...] += jnp.dot(x_ref[...], dense,
                          preferred_element_type=jnp.float32)


def nm_spmm_pallas(x: jax.Array, wc: jax.Array, idx: jax.Array,
                   *, n_sel: int = 2, m_group: int = 4,
                   bm: int = 128, bn: int = 128, bk: int = 128,
                   interpret: bool = False) -> jax.Array:
    """x: (M, N); wc/idx: (N·n/m, K).  Returns (M, K) float32."""
    m, n = x.shape
    half, k = wc.shape
    assert half * m_group == n * n_sel, (x.shape, wc.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    bh = bn * n_sel // m_group            # compressed rows per tile
    grid = (m // bm, k // bk, n // bn)

    kernel = functools.partial(_kernel, n_sel=n_sel, m_group=m_group)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda mi, kj, ni: (mi, ni)),
            pl.BlockSpec((bh, bk), lambda mi, kj, ni: (ni, kj)),
            pl.BlockSpec((bh, bk), lambda mi, kj, ni: (ni, kj)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda mi, kj, ni: (mi, kj)),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(x, wc, idx)
