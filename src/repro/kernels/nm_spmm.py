"""N:M (2:4) structured sparse matmul — Pallas TPU kernel.

Weights travel HBM→VMEM compressed: values (N/2, K) + 2-bit positions
(stored int8).  Decompression happens at the VMEM→VREG boundary — each tile
is expanded to a dense (bn, bk) MXU operand with vectorized compares
(no gather), then fed to the systolic matmul.  This is the paper's
``CP``-at-the-innermost-level primitive mapped onto the TPU memory
hierarchy: metadata decode cost sits next to the compute unit, and the
format's group size (4) nests inside the BlockSpec tile exactly as
SnipSnap's efficiency-oriented allocation prescribes.

Two execution paths, selected by ``pipeline`` (mirrors ``bitmap_spmm``):

* **naive**: grid (M/bm, K/bk, N/bn) with BlockSpec-driven per-step
  fetches, accumulating over the N axis.
* **pipelined**: grid (M/bm, K/bk) with a ``fori_loop`` over the N stripes
  and three double-buffered HBM→VMEM DMA streams (x tile, compressed
  values, position indices) so the next stripe's payload transfers overlap
  the current stripe's decode + MAC.

Both paths decode and accumulate the N stripes in the same order with the
same fp32 ``jnp.dot``, so they are bit-identical in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _decode_tile(wc, idx, *, n_sel: int, m_group: int):
    """Expand a compressed (bh, bk) tile to its dense (bn, bk) operand via
    vectorized position compares — shared by both kernel paths."""
    idx = idx.astype(jnp.int32)
    half, bk = wc.shape
    groups = half // n_sel
    wc3 = wc.reshape(groups, n_sel, bk)
    idx3 = idx.reshape(groups, n_sel, bk)
    # dense[g, p, k] = Σ_j (idx[g,j,k] == p) · wc[g,j,k]
    pos = jax.lax.broadcasted_iota(jnp.int32, (groups, n_sel, m_group, bk), 2)
    eq = idx3[:, :, None, :] == pos
    dense = jnp.sum(jnp.where(eq, wc3[:, :, None, :], 0), axis=1)
    return dense.reshape(groups * m_group, bk)


def _kernel(x_ref, wc_ref, idx_ref, y_ref, *, n_sel: int, m_group: int):
    ni = pl.program_id(2)

    @pl.when(ni == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    dense = _decode_tile(wc_ref[...], idx_ref[...],
                         n_sel=n_sel, m_group=m_group)
    y_ref[...] += jnp.dot(x_ref[...], dense,
                          preferred_element_type=jnp.float32)


def _pipelined_kernel(x_hbm, wc_hbm, idx_hbm, y_ref, *, n_sel: int,
                      m_group: int, bm: int, bn: int, bk: int, gn: int):
    """Double-buffered streaming body: three DMA streams (x / values /
    indices), two VMEM slots each; stripe ``ni+1`` prefetches while stripe
    ``ni`` decodes and MACs."""
    mi = pl.program_id(0)
    kj = pl.program_id(1)
    bh = bn * n_sel // m_group
    y_ref[...] = jnp.zeros_like(y_ref)

    def body(xbuf, wcbuf, idxbuf, sems):
        def dmas(slot, ni):
            return (
                pltpu.make_async_copy(
                    x_hbm.at[pl.ds(mi, 1), :, pl.ds(ni * bn, bn)],
                    xbuf.at[pl.ds(slot, 1)], sems.at[0, slot]),
                pltpu.make_async_copy(
                    wc_hbm.at[pl.ds(ni, 1), :, pl.ds(kj * bk, bk)],
                    wcbuf.at[pl.ds(slot, 1)], sems.at[1, slot]),
                pltpu.make_async_copy(
                    idx_hbm.at[pl.ds(ni, 1), :, pl.ds(kj * bk, bk)],
                    idxbuf.at[pl.ds(slot, 1)], sems.at[2, slot]),
            )

        for c in dmas(0, 0):
            c.start()

        def loop(ni, carry):
            slot = jax.lax.rem(ni, 2)
            nxt = jax.lax.rem(ni + 1, 2)

            @pl.when(ni + 1 < gn)
            def _prefetch():
                for c in dmas(nxt, ni + 1):
                    c.start()

            for c in dmas(slot, ni):
                c.wait()
            dense = _decode_tile(wcbuf[slot], idxbuf[slot],
                                 n_sel=n_sel, m_group=m_group)
            y_ref[...] += jnp.dot(xbuf[slot], dense,
                                  preferred_element_type=jnp.float32)
            return carry

        jax.lax.fori_loop(0, gn, loop, 0)

    bh = bn * n_sel // m_group
    pl.run_scoped(
        body,
        xbuf=pltpu.VMEM((2, bm, bn), x_hbm.dtype),
        wcbuf=pltpu.VMEM((2, bh, bk), wc_hbm.dtype),
        idxbuf=pltpu.VMEM((2, bh, bk), idx_hbm.dtype),
        sems=pltpu.SemaphoreType.DMA((3, 2)),
    )


def _nm_spmm_pipelined(x: jax.Array, wc: jax.Array, idx: jax.Array,
                       *, n_sel: int, m_group: int, bm: int, bn: int,
                       bk: int, interpret: bool) -> jax.Array:
    m, n = x.shape
    half, k = wc.shape
    gn = n // bn
    bh = bn * n_sel // m_group
    # Rank-3 HBM views so DMA src slices are rank-preserving; wc is
    # (half, k) with half == gn·bh, so the reshape is contiguous.
    x3 = x.reshape(m // bm, bm, n)
    wc3 = wc.reshape(gn, bh, k)
    idx3 = idx.reshape(gn, bh, k)
    kernel = functools.partial(_pipelined_kernel, n_sel=n_sel,
                               m_group=m_group, bm=bm, bn=bn, bk=bk, gn=gn)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, k // bk),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)] * 3,
        out_specs=pl.BlockSpec((bm, bk), lambda mi, kj: (mi, kj)),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
    )(x3, wc3, idx3)


def nm_spmm_pallas(x: jax.Array, wc: jax.Array, idx: jax.Array,
                   *, n_sel: int = 2, m_group: int = 4,
                   bm: int = 128, bn: int = 128, bk: int = 128,
                   interpret: bool = False,
                   pipeline: bool = False) -> jax.Array:
    """x: (M, N); wc/idx: (N·n/m, K).  Returns (M, K) float32.

    ``pipeline=True`` selects the double-buffered streaming path (see the
    module docstring)."""
    m, n = x.shape
    half, k = wc.shape
    assert half * m_group == n * n_sel, (x.shape, wc.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    if pipeline:
        return _nm_spmm_pipelined(x, wc, idx, n_sel=n_sel, m_group=m_group,
                                  bm=bm, bn=bn, bk=bk, interpret=interpret)
    bh = bn * n_sel // m_group            # compressed rows per tile
    grid = (m // bm, k // bk, n // bn)

    kernel = functools.partial(_kernel, n_sel=n_sel, m_group=m_group)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda mi, kj, ni: (mi, ni)),
            pl.BlockSpec((bh, bk), lambda mi, kj, ni: (ni, kj)),
            pl.BlockSpec((bh, bk), lambda mi, kj, ni: (ni, kj)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda mi, kj, ni: (mi, kj)),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(x, wc, idx)
