"""Flash attention — Pallas TPU kernel (online softmax, causal).

The chunked-attention schedule of ``models/attention.py`` (lax.scan online
softmax) pinned into VMEM: one (bq, d) query tile stays resident while the
KV axis streams through in (bk, d) tiles; the running (max, denom,
accumulator) lives in VMEM scratch.  Causal blocks strictly above the
diagonal are skipped with ``pl.when`` (no FLOPs, no traffic).

Grid: (B·H, Sq/bq, Skv/bk), KV innermost ("arbitrary" — sequential per
output tile); q/o tiles are revisited across the KV axis.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, scale: float, causal: bool, bq: int, bk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: the whole KV tile is masked when its first row starts after
    # the query tile's last position → skip compute AND traffic
    live = (not causal) or (ki * bk <= qi * bq + bq - 1)

    @pl.when(live)
    def _step():
        q = q_ref[0]                            # (bq, d)
        k = k_ref[0]                            # (bk, d)
        v = v_ref[0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32,
                                                      (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32,
                                                      (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           *, causal: bool = True, bq: int = 128,
                           bk: int = 128, interpret: bool = False
                           ) -> jax.Array:
    """q/k/v: (BH, S, D) — batch and heads pre-flattened (GQA repeat done by
    the caller or avoided via grouped layouts).  Returns (BH, Sq, D)."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, skv, bq, bk)
    grid = (bh, sq // bq, skv // bk)
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               bq=bq, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(q, k, v)
