"""Unified serving telemetry: tracing, metrics, profiling hooks.

One observability plane over the search/exec/serve stacks — zero cost
when off, deterministic where it must be:

  * :mod:`repro.obs.trace` — span API (``span(...)`` context manager +
    ``event(...)`` instant marks) wired through the mixer, the serving
    drivers, the guarded runtime, and calibration; exports Chrome
    trace-event JSON and a deterministic ``stable_trace`` projection.
  * :mod:`repro.obs.metrics` — named counters/gauges/histograms with
    adapters over the five pre-existing measurement sources
    (``instrument()``, memo stats, kernel-cache stats, StragglerMonitor,
    HealthReport); JSON + Prometheus text exposition exports.
  * :mod:`repro.obs.profile` — opt-in ``jax.profiler`` capture and a
    per-kernel-dispatch timing hook.

Surfaced by the serve CLI's ``--trace PATH`` / ``--metrics PATH`` flags
and measured by ``bench_serve``'s ``serve_telemetry_overhead`` row.
"""

from repro.obs.metrics import (MetricsRegistry, collect_caches, collecting,
                               current_metrics, ingest_health,
                               ingest_instrument, ingest_kernel_cache,
                               ingest_memo_stats, ingest_straggler)
from repro.obs.profile import jax_trace, kernel_timer
from repro.obs.trace import (Tracer, current_tracer, event, span, trace_id,
                             tracing)

__all__ = [
    "MetricsRegistry", "Tracer",
    "collect_caches", "collecting", "current_metrics", "current_tracer",
    "event", "ingest_health", "ingest_instrument", "ingest_kernel_cache",
    "ingest_memo_stats", "ingest_straggler", "jax_trace", "kernel_timer",
    "span", "trace_id", "tracing",
]
