"""Span tracing for the serving/search/exec planes — zero cost when off.

One ambient :class:`Tracer` (installed with :func:`tracing`) collects an
ordered stream of span begin/end marks and instant events.  The module
functions :func:`span` / :func:`event` are the instrumentation surface the
rest of the codebase calls: with no tracer installed they resolve to a
shared no-op (one ``None`` check — the serving hot loops pay nothing, and
the off path's tokens are bit-identical, pinned by ``tests/test_obs.py``).

Two exports, two purposes:

  * :meth:`Tracer.chrome_trace` — the Chrome trace-event JSON dialect
    (load the saved file in ``chrome://tracing`` or Perfetto): ``B``/``E``
    span pairs, ``i`` instants, ``X`` complete events (used by the
    kernel-dispatch timing hook), microsecond timestamps relative to the
    tracer's epoch.
  * :meth:`Tracer.stable_trace` — the deterministic projection: timings
    dropped, ordering and args kept, timing-derived events (recorded with
    ``stable=False``, e.g. straggler spikes) excluded.  Two runs of the
    same seeded stream produce IDENTICAL stable traces — the trace-plane
    analogue of :meth:`repro.runtime.guard.HealthReport.stable_dict`,
    and what the CI observability job diffs.

Request linkage: :func:`trace_id` mints the id a
:class:`~repro.runtime.guard.HealthReport` carries in ``trace_id`` —
derived from the request id when there is one (``"t:req0"``), a tracer
counter otherwise — and every span/event belonging to that request carries
the same id in its args, so "why was request 417 slow" is one filter over
the trace.
"""

from __future__ import annotations

import contextlib
import json
import time
from typing import Any, Iterator, Optional


class Tracer:
    """Ordered in-memory trace collector.

    ``events`` is the raw record stream: dicts with ``ph`` (``"B"`` begin
    span / ``"E"`` end span / ``"i"`` instant / ``"X"`` complete),
    ``name``, ``ts`` (seconds since the tracer's epoch), ``args`` and —
    for ``"X"`` — ``dur``.  Span begin/end must nest strictly (LIFO);
    a mismatched :meth:`end` raises instead of silently corrupting the
    stream."""

    def __init__(self) -> None:
        self._t0 = time.perf_counter()
        self.events: list[dict] = []
        self._stack: list[str] = []
        self._n_ids = 0

    # -- recording -----------------------------------------------------------
    def _now(self) -> float:
        return time.perf_counter() - self._t0

    def begin(self, name: str, args: Optional[dict] = None) -> None:
        self.events.append({"ph": "B", "name": name, "ts": self._now(),
                            "args": dict(args or {})})
        self._stack.append(name)

    def end(self, name: str) -> None:
        if not self._stack or self._stack[-1] != name:
            open_ = self._stack[-1] if self._stack else None
            raise RuntimeError(f"span end {name!r} does not match the "
                               f"innermost open span {open_!r}")
        self._stack.pop()
        self.events.append({"ph": "E", "name": name, "ts": self._now(),
                            "args": {}})

    def instant(self, name: str, args: Optional[dict] = None,
                stable: bool = True) -> None:
        ev = {"ph": "i", "name": name, "ts": self._now(),
              "args": dict(args or {})}
        if not stable:
            ev["stable"] = False
        self.events.append(ev)

    def complete(self, name: str, dur_s: float,
                 args: Optional[dict] = None, stable: bool = True) -> None:
        """Record an already-finished region ending now (``dur_s`` long) —
        the shape hook-based timers produce (kernel dispatch)."""
        ev = {"ph": "X", "name": name, "ts": max(self._now() - dur_s, 0.0),
              "dur": dur_s, "args": dict(args or {})}
        if not stable:
            ev["stable"] = False
        self.events.append(ev)

    @property
    def depth(self) -> int:
        """Current span nesting depth (0 outside every span)."""
        return len(self._stack)

    def new_trace_id(self) -> str:
        """A fresh deterministic id (per-tracer counter, not wall-clock)."""
        self._n_ids += 1
        return f"t{self._n_ids:04d}"

    # -- export --------------------------------------------------------------
    def chrome_trace(self) -> dict:
        """The trace as a ``chrome://tracing``-loadable document."""
        out = []
        for ev in self.events:
            row: dict[str, Any] = {"name": ev["name"], "ph": ev["ph"],
                                   "ts": round(ev["ts"] * 1e6, 3),
                                   "pid": 0, "tid": 0}
            if ev["ph"] == "X":
                row["dur"] = round(ev["dur"] * 1e6, 3)
            if ev["ph"] == "i":
                row["s"] = "t"
            if ev["args"]:
                row["args"] = ev["args"]
            out.append(row)
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def stable_trace(self) -> list[dict]:
        """The deterministic projection: timings dropped, order and args
        kept, ``stable=False`` (timing-derived) events excluded."""
        return [{"ph": ev["ph"], "name": ev["name"], "args": ev["args"]}
                for ev in self.events if ev.get("stable", True)]

    def save_chrome(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, indent=1)

    def save_stable(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.stable_trace(), f, indent=1, sort_keys=True)


# ---------------------------------------------------------------------------
# Ambient tracer + the zero-cost instrumentation surface
# ---------------------------------------------------------------------------

_TRACER: Optional[Tracer] = None


def current_tracer() -> Optional[Tracer]:
    return _TRACER


@contextlib.contextmanager
def tracing(tracer: Optional[Tracer] = None) -> Iterator[Tracer]:
    """Install ``tracer`` (or a fresh one) as the ambient tracer."""
    global _TRACER
    prev = _TRACER
    t = tracer if tracer is not None else Tracer()
    _TRACER = t
    try:
        yield t
    finally:
        _TRACER = prev


class _Null:
    """The shared no-op span (tracing off)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _Null()


class _Span:
    __slots__ = ("_t", "_name")

    def __init__(self, tracer: Tracer, name: str, args: dict) -> None:
        self._t = tracer
        self._name = name
        tracer.begin(name, args)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self._t.end(self._name)
        return False


def span(name: str, **args):
    """Context manager marking a span; a shared no-op when tracing is off.

    The span BEGINS at the call (not at ``__enter__``), so exceptions
    between construction and entry still nest correctly in practice —
    always use it as ``with span(...):``."""
    t = _TRACER
    if t is None:
        return _NULL
    return _Span(t, name, args)


def event(name: str, stable: bool = True, **args) -> None:
    """Record an instant event; no-op when tracing is off.  Pass
    ``stable=False`` for timing-derived events (straggler spikes) that
    must not appear in :meth:`Tracer.stable_trace`."""
    t = _TRACER
    if t is not None:
        t.instant(name, args, stable=stable)


def trace_id(request_id: Optional[str] = None) -> Optional[str]:
    """The id linking a request's :class:`HealthReport` to its spans.

    Deterministic: derived from ``request_id`` when given (``"t:req0"``),
    a per-tracer counter otherwise.  ``None`` when tracing is off — so
    ``HealthReport.stable_dict`` stays byte-identical for untraced runs."""
    t = _TRACER
    if t is None:
        return None
    return f"t:{request_id}" if request_id is not None else t.new_trace_id()
