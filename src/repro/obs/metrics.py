"""Metrics registry: named counters / gauges / histograms, one snapshot.

The repo grew five disconnected measurement mechanisms — the execution
plane's :func:`repro.exec.dispatch.instrument` traffic counters, the memo
registry's hit/miss stats, the kernel jit-wrapper cache stats, the
:class:`~repro.runtime.fault.StragglerMonitor` EWMA, and the per-request
:class:`~repro.runtime.guard.HealthReport`.  This module is the single
consumer: live metrics flow in through the ambient registry (installed
with :func:`collecting`; the module-level :func:`counter_inc` /
:func:`gauge_set` / :func:`observe` are no-ops when none is — same
zero-cost-when-off contract as :mod:`repro.obs.trace`), and the
``ingest_*`` adapters fold each existing source into the same registry
without touching its source of truth (adapter values equal the source
exactly — pinned by ``tests/test_obs.py``).

Exports: :meth:`MetricsRegistry.snapshot` (JSON-able dict, saved with
:meth:`save`) and :meth:`MetricsRegistry.prometheus_text` (Prometheus
text exposition format — ``# TYPE`` headers, ``name{label="v"} value``
samples, ``_bucket``/``_sum``/``_count`` histogram series).

Metric-name conventions: counters end in ``_total``, histograms in their
unit (``_seconds``); label keys are plain identifiers.  The serving
counters (``serve_tokens_generated_total``, ``serve_fallbacks_total{code=}``,
``mixer_evictions_total{reason=}`` …) are listed in the README's
Observability section.
"""

from __future__ import annotations

import bisect
import contextlib
import json
from typing import Iterator, Optional, Sequence


# decode-step / dispatch latencies land between 100us and seconds on the
# configs this repo serves; buckets are seconds
DEFAULT_BUCKETS = (0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01,
                   0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def _series_key(name: str, labels: dict) -> tuple:
    return (name, tuple(sorted(labels.items())))


def _series_str(key: tuple) -> str:
    name, labels = key
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


def _prom_label_value(v) -> str:
    s = str(v)
    return s.replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")


class MetricsRegistry:
    """Counters, gauges and histograms keyed by (name, sorted labels).

    A name belongs to exactly one metric type — re-registering it as
    another raises (the registry is the schema).  Counters only go up;
    histograms bucket against a per-name bucket tuple fixed at first
    observation."""

    def __init__(self) -> None:
        self._types: dict[str, str] = {}
        self._counters: dict[tuple, float] = {}
        self._gauges: dict[tuple, float] = {}
        self._hists: dict[tuple, dict] = {}
        self._hist_buckets: dict[str, tuple] = {}

    def _check_type(self, name: str, kind: str) -> None:
        have = self._types.setdefault(name, kind)
        if have != kind:
            raise ValueError(f"metric {name!r} is a {have}, not a {kind}")

    # -- recording -----------------------------------------------------------
    def counter_inc(self, name: str, value: float = 1.0, **labels) -> None:
        if value < 0:
            raise ValueError(f"counter {name!r}: counters only go up "
                             f"(got {value})")
        self._check_type(name, "counter")
        k = _series_key(name, labels)
        self._counters[k] = self._counters.get(k, 0.0) + float(value)

    def gauge_set(self, name: str, value: float, **labels) -> None:
        self._check_type(name, "gauge")
        self._gauges[_series_key(name, labels)] = float(value)

    def observe(self, name: str, value: float,
                buckets: Optional[Sequence[float]] = None, **labels) -> None:
        self._check_type(name, "histogram")
        bks = self._hist_buckets.setdefault(
            name, tuple(buckets) if buckets is not None else DEFAULT_BUCKETS)
        k = _series_key(name, labels)
        h = self._hists.get(k)
        if h is None:
            h = self._hists[k] = {"counts": [0] * (len(bks) + 1),
                                  "sum": 0.0, "count": 0}
        h["counts"][bisect.bisect_left(bks, value)] += 1
        h["sum"] += float(value)
        h["count"] += 1

    # -- reading -------------------------------------------------------------
    def value(self, name: str, **labels) -> float:
        """Current value of one counter/gauge series (KeyError if absent)."""
        k = _series_key(name, labels)
        if name in self._types and self._types[name] == "gauge":
            return self._gauges[k]
        return self._counters[k]

    def total(self, name: str) -> float:
        """Sum of a counter's series across all label values (0 if none)."""
        return sum(v for (n, _), v in self._counters.items() if n == name)

    def series(self, name: str) -> dict[tuple, float]:
        """All ``{sorted-label-items: value}`` series of a counter/gauge."""
        src = self._gauges if self._types.get(name) == "gauge" \
            else self._counters
        return {labels: v for (n, labels), v in src.items() if n == name}

    # -- export --------------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-able state: every series, deterministically ordered."""
        hists = {}
        for k in sorted(self._hists):
            name = k[0]
            bks = self._hist_buckets[name]
            h = self._hists[k]
            hists[_series_str(k)] = {
                "buckets": {**{str(b): c for b, c in
                               zip(bks, h["counts"])},
                            "+Inf": h["counts"][-1]},
                "sum": h["sum"], "count": h["count"]}
        return {"counters": {_series_str(k): self._counters[k]
                             for k in sorted(self._counters)},
                "gauges": {_series_str(k): self._gauges[k]
                           for k in sorted(self._gauges)},
                "histograms": hists}

    def to_json(self, indent: Optional[int] = 1) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    def prometheus_text(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines: list[str] = []

        def fmt(name: str, labels: tuple, value, extra: dict = ()) -> str:
            items = list(labels) + list(dict(extra).items())
            if not items:
                return f"{name} {value}"
            inner = ",".join(f'{k}="{_prom_label_value(v)}"'
                             for k, v in items)
            return f"{name}{{{inner}}} {value}"

        for name in sorted(self._types):
            kind = self._types[name]
            lines.append(f"# TYPE {name} {kind}")
            if kind == "counter":
                for k in sorted(s for s in self._counters if s[0] == name):
                    lines.append(fmt(name, k[1], self._counters[k]))
            elif kind == "gauge":
                for k in sorted(s for s in self._gauges if s[0] == name):
                    lines.append(fmt(name, k[1], self._gauges[k]))
            else:
                bks = self._hist_buckets[name]
                for k in sorted(s for s in self._hists if s[0] == name):
                    h = self._hists[k]
                    cum = 0
                    for b, c in zip(bks, h["counts"]):
                        cum += c
                        lines.append(fmt(f"{name}_bucket", k[1], cum,
                                         {"le": b}))
                    lines.append(fmt(f"{name}_bucket", k[1], h["count"],
                                     {"le": "+Inf"}))
                    lines.append(fmt(f"{name}_sum", k[1], h["sum"]))
                    lines.append(fmt(f"{name}_count", k[1], h["count"]))
        return "\n".join(lines) + ("\n" if lines else "")


# ---------------------------------------------------------------------------
# Ambient registry (same pattern as obs.trace's ambient tracer)
# ---------------------------------------------------------------------------

_METRICS: Optional[MetricsRegistry] = None


def current_metrics() -> Optional[MetricsRegistry]:
    return _METRICS


@contextlib.contextmanager
def collecting(registry: Optional[MetricsRegistry] = None
               ) -> Iterator[MetricsRegistry]:
    """Install ``registry`` (or a fresh one) as the ambient registry."""
    global _METRICS
    prev = _METRICS
    reg = registry if registry is not None else MetricsRegistry()
    _METRICS = reg
    try:
        yield reg
    finally:
        _METRICS = prev


def counter_inc(name: str, value: float = 1.0, **labels) -> None:
    m = _METRICS
    if m is not None:
        m.counter_inc(name, value, **labels)


def gauge_set(name: str, value: float, **labels) -> None:
    m = _METRICS
    if m is not None:
        m.gauge_set(name, value, **labels)


def observe(name: str, value: float, **labels) -> None:
    m = _METRICS
    if m is not None:
        m.observe(name, value, **labels)


# ---------------------------------------------------------------------------
# Adapters over the five existing measurement sources
# ---------------------------------------------------------------------------

def ingest_instrument(reg: MetricsRegistry, counters: dict) -> None:
    """Fold :func:`repro.exec.dispatch.instrument` per-role traffic
    counters in, one labelled series per role — values equal the
    ``OpCounters`` fields exactly."""
    for role in sorted(counters):
        c = counters[role]
        reg.counter_inc("exec_dispatch_calls_total", c.calls, role=role)
        reg.counter_inc("exec_w_fetch_bits_total", c.w_fetch_bits, role=role)
        reg.counter_inc("exec_w_distinct_bits_total", c.w_distinct_bits,
                        role=role)
        reg.counter_inc("exec_w_stream_bits_total", c.w_stream_bits,
                        role=role)
        reg.counter_inc("exec_x_bits_total", c.x_bits, role=role)
        reg.counter_inc("exec_y_bits_total", c.y_bits, role=role)
        reg.counter_inc("exec_macs_total", c.macs, role=role)
        reg.counter_inc("exec_decode_ops_total", c.decode_ops, role=role)
        reg.gauge_set("exec_refetch_factor", c.refetch_factor, role=role)


def ingest_memo_stats(reg: MetricsRegistry, stats: Optional[dict] = None,
                      only_active: bool = True) -> None:
    """Fold the memo registry's per-cache hit/miss counters in
    (:func:`repro.core.memo.stats`)."""
    if stats is None:
        from repro.core import memo
        stats = memo.stats()
    for name in sorted(stats):
        st = stats[name]
        if only_active and not st.lookups:
            continue
        reg.counter_inc("memo_hits_total", st.hits, cache=name)
        reg.counter_inc("memo_misses_total", st.misses, cache=name)


def ingest_kernel_cache(reg: MetricsRegistry,
                        stats: Optional[dict] = None) -> None:
    """Fold the kernel jit-wrapper cache counters in
    (:func:`repro.kernels.ops.kernel_cache_stats`)."""
    if stats is None:
        from repro.kernels import ops as kops
        stats = kops.kernel_cache_stats()
    reg.counter_inc("kernel_cache_hits_total", stats["hits"])
    reg.counter_inc("kernel_cache_misses_total", stats["misses"])
    reg.gauge_set("kernel_cache_entries", stats["entries"])


def ingest_straggler(reg: MetricsRegistry, monitor) -> None:
    """Fold a :class:`~repro.runtime.fault.StragglerMonitor` in: the EWMA
    step time as a gauge, the flagged-spike count as a counter."""
    reg.gauge_set("straggler_ewma_seconds", monitor.ewma)
    reg.counter_inc("straggler_flagged_total", len(monitor.flagged))


def ingest_health(reg: MetricsRegistry, report) -> None:
    """Fold one request's :class:`~repro.runtime.guard.HealthReport` in.

    The serving paths call this once per finished request (the mixer at
    evict, the guarded driver at return), so ``serve_tokens_generated_total``
    equals the sum of ``report.steps`` over the run — the snapshot's
    counters exactly match the reports they came from."""
    reg.counter_inc("serve_requests_total")
    reg.counter_inc("serve_tokens_generated_total", report.steps)
    reg.counter_inc("serve_retries_total", report.retries)
    reg.counter_inc("serve_dense_steps_total", report.dense_steps)
    if report.deadline_hit:
        reg.counter_inc("serve_deadline_hits_total")
    if report.eos_hit:
        reg.counter_inc("serve_eos_hits_total")
    fc = report.fallback_counts()
    for code in sorted(fc):
        reg.counter_inc("serve_fallbacks_total", fc[code], code=code)
    for role in sorted(report.verify):
        if report.verify[role] != "ok":
            reg.counter_inc("serve_verify_failures_total", 1.0, role=role)


def collect_caches(reg: MetricsRegistry) -> None:
    """Convenience: ingest both global cache sources (memo + kernel)."""
    ingest_memo_stats(reg)
    ingest_kernel_cache(reg)
