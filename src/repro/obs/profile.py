"""Opt-in profiling hooks: jax.profiler capture + kernel-dispatch timing.

Two layers, both off by default and free when off:

  * :func:`jax_trace` — wraps a region in a ``jax.profiler`` trace capture
    (TensorBoard/Perfetto-loadable artifacts under ``log_dir``).  A no-op
    when ``log_dir`` is falsy, best-effort when the profiler backend is
    unavailable (interpret-mode CPU containers) — serving never fails
    because profiling could not start.
  * :func:`kernel_timer` — installs a
    :func:`repro.kernels.ops.kernel_dispatch_hook` (the observation twin
    of the fault-injection ``kernel_fault_hook``) that records every
    sparse-kernel dispatch into the ambient metrics registry
    (``kernel_dispatch_total{kind=}`` counter +
    ``kernel_dispatch_seconds`` histogram) and as ``X`` complete events
    in the ambient trace.  Dispatch happens at TRACE time under jit, so
    warm cache hits record nothing — the hook measures what a forward
    actually pays, which is exactly the jit-cache contract the serving
    plane is built on.
"""

from __future__ import annotations

import contextlib
from typing import Iterator, Optional

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace


@contextlib.contextmanager
def jax_trace(log_dir: Optional[str]) -> Iterator[None]:
    """Capture a ``jax.profiler`` trace of the region into ``log_dir``
    (no-op when ``log_dir`` is None/empty, tolerant of missing backends)."""
    if not log_dir:
        yield
        return
    import jax
    started = False
    try:
        jax.profiler.start_trace(str(log_dir))
        started = True
    except Exception:  # noqa: BLE001 — profiling is best-effort by contract
        pass
    try:
        yield
    finally:
        if started:
            try:
                jax.profiler.stop_trace()
            except Exception:  # noqa: BLE001
                pass


def annotation(name: str):
    """A ``jax.profiler.TraceAnnotation`` naming a region inside a
    :func:`jax_trace` capture (null context when unavailable)."""
    import jax
    try:
        return jax.profiler.TraceAnnotation(name)
    except Exception:  # noqa: BLE001
        return contextlib.nullcontext()


@contextlib.contextmanager
def kernel_timer(registry: Optional[_metrics.MetricsRegistry] = None,
                 tracer: Optional[_trace.Tracer] = None) -> Iterator[None]:
    """Record every sparse-kernel dispatch while active.

    ``registry`` / ``tracer`` default to the AMBIENT ones at dispatch
    time, so ``kernel_timer()`` composes with :func:`repro.obs.metrics
    .collecting` / :func:`repro.obs.trace.tracing` without re-plumbing.
    Trace events are complete (``X``) events named ``kernel:<kind>`` —
    their wall-clock is timing-derived, so they are excluded from
    :meth:`~repro.obs.trace.Tracer.stable_trace`."""
    from repro.kernels import ops as kops

    def hook(kind: str, dt: float) -> None:
        reg = registry if registry is not None else \
            _metrics.current_metrics()
        if reg is not None:
            reg.counter_inc("kernel_dispatch_total", 1.0, kind=kind)
            reg.observe("kernel_dispatch_seconds", dt, kind=kind)
        tr = tracer if tracer is not None else _trace.current_tracer()
        if tr is not None:
            tr.complete(f"kernel:{kind}", dt, {"kind": kind}, stable=False)

    with kops.kernel_dispatch_hook(hook):
        yield
