"""Hierarchical compression format encoding (paper §III-B).

A *compression pattern* (Definition 1) is an ordered sequence of primitives,
outer level first, each bound to a dimension or subdimension:

    CompPat(n) = [prim_1(dim_1), ..., prim_n(dim_n)]

A *dimension allocation* (Definition 2) assigns a concrete size to every
(sub)dimension, drawn from the prime factorization of the original dimension:

    DimAlloc(CompPat) = {(dim_ij, size_ij)}

A fully-specified :class:`Format` is a pattern + allocation; e.g. CSC over an
M×N tensor is ``UOP(N)-CP(M)`` and, with sizes, ``UOP(N,6)-CP(M,3)``.

The format is interpreted as a fiber tree: level 1 partitions the tensor into
``size_1`` units along ``dim_1``; each unit is recursively partitioned by the
next level.  The product of sizes bound to each named dimension must equal
that dimension's extent, and every tensor dimension must appear (possibly as a
single ``None`` level for dense dims).
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Iterable, Iterator, Optional, Sequence

from repro.core import memo
from repro.core.primitives import Prim


@dataclasses.dataclass(frozen=True)
class Level:
    """One level of a compression format: primitive + dimension (+ size)."""

    prim: Prim
    dim: str                 # dimension name, e.g. "M" or "N"
    size: Optional[int] = None   # None until dimension allocation

    def with_size(self, size: int) -> "Level":
        return Level(self.prim, self.dim, size)

    def __str__(self) -> str:
        if self.size is None:
            return f"{self.prim}({self.dim})"
        return f"{self.prim}({self.dim},{self.size})"


@dataclasses.dataclass(frozen=True)
class Format:
    """An ordered (outer→inner) sequence of levels over a named-dim tensor."""

    levels: tuple[Level, ...]
    name: Optional[str] = None   # human name for standard formats

    # -- constructors ------------------------------------------------------
    @staticmethod
    def of(*levels: Level, name: Optional[str] = None) -> "Format":
        return Format(tuple(levels), name=name)

    # -- basic properties --------------------------------------------------
    @property
    def n_levels(self) -> int:
        return len(self.levels)

    @property
    def compressed_levels(self) -> int:
        """Number of levels that actually compress (exclude ``None``)."""
        return sum(1 for l in self.levels if l.prim is not Prim.NONE)

    def is_allocated(self) -> bool:
        return all(l.size is not None for l in self.levels)

    def pattern_key(self) -> tuple[tuple[str, str], ...]:
        """Hashable identity of the compression pattern (sizes stripped)."""
        return tuple((l.prim.value, l.dim) for l in self.levels)

    def __str__(self) -> str:
        base = "-".join(str(l) for l in self.levels)
        return f"{self.name}[{base}]" if self.name else base

    # -- validation --------------------------------------------------------
    def validate(self, dims: dict[str, int]) -> None:
        """Check the allocation covers ``dims`` exactly (product per dim)."""
        if not self.is_allocated():
            raise ValueError(f"format {self} is not fully allocated")
        prod: dict[str, int] = {}
        for l in self.levels:
            prod[l.dim] = prod.get(l.dim, 1) * int(l.size)  # type: ignore[arg-type]
        for d, extent in dims.items():
            if prod.get(d, 1) != extent:
                raise ValueError(
                    f"format {self}: dim {d} covers {prod.get(d, 1)} != {extent}")
        for d in prod:
            if d not in dims:
                raise ValueError(f"format {self}: unknown dim {d}")


# ---------------------------------------------------------------------------
# Standard named formats (the four baselines of §IV-A plus CSC/CSB).
# ---------------------------------------------------------------------------

def bitmap(dims: dict[str, int]) -> Format:
    """Flat bitmap: one bit per element.  ``None`` outer dims + B innermost
    (equivalent to B over the flattened tensor)."""
    names = list(dims)
    levels = [Level(Prim.NONE, d, dims[d]) for d in names[:-1]]
    levels.append(Level(Prim.B, names[-1], dims[names[-1]]))
    return Format(tuple(levels), name="Bitmap")


def rle(dims: dict[str, int]) -> Format:
    """Flat run-length encoding along the innermost dimension."""
    names = list(dims)
    levels = [Level(Prim.NONE, d, dims[d]) for d in names[:-1]]
    levels.append(Level(Prim.RLE, names[-1], dims[names[-1]]))
    return Format(tuple(levels), name="RLE")


def csr(dims: dict[str, int]) -> Format:
    """CSR over (row, col): UOP(row)-CP(col)."""
    (r, rs), (c, cs) = list(dims.items())
    return Format((Level(Prim.UOP, r, rs), Level(Prim.CP, c, cs)), name="CSR")


def csc(dims: dict[str, int]) -> Format:
    """CSC over (row, col): UOP(col)-CP(row) — Fig. 4(b), Flexagon."""
    (r, rs), (c, cs) = list(dims.items())
    return Format((Level(Prim.UOP, c, cs), Level(Prim.CP, r, rs)), name="CSC")


def coo(dims: dict[str, int]) -> Format:
    """COO: nested coordinate payloads (row then col coordinates)."""
    (r, rs), (c, cs) = list(dims.items())
    return Format((Level(Prim.CP, r, rs), Level(Prim.CP, c, cs)), name="COO")


def csb(dims: dict[str, int], block: dict[str, int]) -> Format:
    """Compressed Sparse Block (Procrustes, Fig. 4(b)): bitmap over the block
    grid with dense blocks below."""
    levels = []
    for d, extent in dims.items():
        b = block[d]
        if extent % b:
            raise ValueError(f"block {b} does not divide {d}={extent}")
        levels.append(Level(Prim.B, d, extent // b))
    for d, b in block.items():
        levels.append(Level(Prim.NONE, d, b))
    return Format(tuple(levels), name="CSB")


STANDARD_BASELINES = ("Bitmap", "RLE", "CSR", "COO")


def standard_formats(dims: dict[str, int]) -> dict[str, Format]:
    """The four widely-used baseline formats of §IV-A2."""
    return {
        "Bitmap": bitmap(dims),
        "RLE": rle(dims),
        "CSR": csr(dims),
        "COO": coo(dims),
    }


# ---------------------------------------------------------------------------
# Pattern / allocation enumeration (the two subspaces of §III-B).
# ---------------------------------------------------------------------------

SEARCH_PRIMS = (Prim.B, Prim.CP, Prim.RLE, Prim.UOP)


def enumerate_patterns(dims: Sequence[str], max_levels: int,
                       prims: Sequence[Prim] = SEARCH_PRIMS,
                       min_levels: int = 1,
                       ) -> Iterator[tuple[Level, ...]]:
    """Enumerate compression patterns (sizes unassigned).

    A pattern of ``n`` levels chooses, per level, a primitive and a dimension;
    dimensions may repeat (subdimensions).  Trailing ``None`` (dense-block)
    variants are generated by the allocator, not here.  Constraints applied:
      * UOP only meaningful as a non-leaf (it indexes children payloads);
      * at least one level per tensor dimension overall is implied by the
        allocator (a dim absent from the pattern is stored dense/flattened).
    """
    for n in range(min_levels, max_levels + 1):
        for dim_choice in itertools.product(dims, repeat=n):
            for prim_choice in itertools.product(prims, repeat=n):
                if prim_choice and prim_choice[-1] is Prim.UOP:
                    continue  # UOP at the leaf has nothing to offset into
                yield tuple(Level(p, d) for p, d in zip(prim_choice, dim_choice))


def factorizations(extent: int, parts: int) -> Iterator[tuple[int, ...]]:
    """All ordered factorizations of ``extent`` into ``parts`` integer factors
    (>=1 each, product == extent).  Derived from the prime factorization as in
    Definition 2."""
    if parts == 1:
        yield (extent,)
        return
    for first in sorted(_divisors(extent)):
        for rest in factorizations(extent // first, parts - 1):
            yield (first,) + rest


def _divisors(x: int) -> list[int]:
    out = []
    i = 1
    while i * i <= x:
        if x % i == 0:
            out.append(i)
            if i != x // i:
                out.append(x // i)
        i += 1
    return out


_FACTORIZATIONS_CACHE: dict = memo.register({}, "factorizations")


def factorizations_cached(extent: int, parts: int) -> tuple[tuple[int, ...], ...]:
    """Materialized, memoized :func:`factorizations` (identical order).

    The allocation planes — :func:`allocate` and the mapping-derived chain
    splitting in :mod:`repro.core.engine` — revisit the same (extent, parts)
    pairs constantly (tensor dims come from a handful of layer shapes), so
    the recursive enumeration runs once per pair."""
    return memo.get_or(
        _FACTORIZATIONS_CACHE, (extent, parts),
        lambda: tuple(factorizations(extent, parts)))


def _alloc_key(opt: tuple[tuple[int, ...], Optional[int]]) -> float:
    # Order allocations by innermost-level size proximity to ~8: the
    # innermost compressed level dominates metadata cost per non-zero
    # (CP/RLE field width, B group amortization), and sizes 4–16 are
    # the sweet spot across densities — so capped/early-bailed
    # enumeration visits the likely winners first.
    factors, leaf = opt
    inner = leaf if leaf is not None else factors[-1]
    return abs(math.log2(max(inner, 1)) - 3.0)


_ALLOC_OPTS_CACHE: dict = memo.register({}, "alloc_opts")


def _dim_alloc_options(extent: int, k: int, allow_dense_leaf: bool
                       ) -> tuple[tuple[tuple[int, ...], Optional[int]], ...]:
    """Per-dim allocation options: (factors_for_slots, leaf_size or None),
    sorted by :func:`_alloc_key`.  Depends only on (extent, slot count,
    leaf policy), which recur for every pattern touching the dim — memoized."""
    def build():
        opts = [(f, None) for f in factorizations_cached(extent, k)
                if all(x > 1 for x in f)]
        if allow_dense_leaf:
            opts += [(f[:-1], f[-1])
                     for f in factorizations_cached(extent, k + 1)
                     if all(x > 1 for x in f)]
        opts.sort(key=_alloc_key)
        return tuple(opts)
    return memo.get_or(_ALLOC_OPTS_CACHE, (extent, k, allow_dense_leaf), build)


@dataclasses.dataclass(frozen=True)
class AllocPlan:
    """One dimension allocation in raw form — the hot-path view of
    :func:`allocate` (same enumeration order).  Carries the level sizes as
    plain integers so batch analyzers can score thousands of allocations
    without constructing :class:`Format`/:class:`Level` objects; the full
    format is materialized lazily via :meth:`build` for winners only."""

    pattern: tuple[Level, ...]
    dense_head: tuple[Level, ...]
    slot_sizes: tuple[int, ...]             # per pattern slot, slot order
    leaves: tuple[tuple[str, int], ...]     # trailing dense-leaf (dim, size)

    def row_sizes(self) -> list[int]:
        """Level sizes outer→inner: dense head + pattern slots + leaves."""
        return ([int(l.size) for l in self.dense_head]   # type: ignore[arg-type]
                + list(self.slot_sizes) + [s for _, s in self.leaves])

    def prim_row(self, width: int) -> list[Prim]:
        """Per-level primitive row aligned with :meth:`row_sizes`, padded
        with ``NONE`` to ``width`` (leaves and padding are both dense) —
        what the batch analyzers feed ``analyze_batch_rows``.  Shared by
        every allocation of one pattern."""
        head = len(self.dense_head)
        return [Prim.NONE] * head + [l.prim for l in self.pattern] \
            + [Prim.NONE] * (width - head - len(self.pattern))

    def build(self) -> Format:
        levels = tuple(l.with_size(s)
                       for l, s in zip(self.pattern, self.slot_sizes))
        leaf_levels = tuple(Level(Prim.NONE, d, s) for d, s in self.leaves)
        return Format(self.dense_head + levels + leaf_levels)


def allocation_plans(pattern: Sequence[Level], dims: dict[str, int],
                     max_allocs: Optional[int] = None,
                     allow_dense_leaf: bool = True) -> Iterator[AllocPlan]:
    """Enumerate dimension allocations for a pattern (Definition 2), as
    lightweight :class:`AllocPlan` rows.

    Dims not referenced by the pattern are prepended as dense ``None``
    levels (outermost), matching the paper's treatment of uncompressed dims.
    With ``allow_dense_leaf``, each pattern dim may optionally keep an extra
    innermost dense factor (``None`` leaf) — this expresses block-sparse
    formats such as CSB/Procrustes (dense blocks indexed by compressed
    outer levels).  Factors of 1 are disallowed (a size-1 level encodes
    nothing).
    """
    pattern = tuple(pattern)
    per_dim_slots: dict[str, list[int]] = {}
    for i, l in enumerate(pattern):
        per_dim_slots.setdefault(l.dim, []).append(i)

    # per dim: list of (factors_for_slots, leaf_size or None)
    choices: list[tuple[tuple[tuple[int, ...], Optional[int]], ...]] = []
    dim_order: list[str] = []
    for d, slots in per_dim_slots.items():
        if d not in dims:
            raise ValueError(f"pattern references unknown dim {d}")
        opts = _dim_alloc_options(dims[d], len(slots), allow_dense_leaf)
        if not opts:
            return  # cannot split this dim into that many >1 factors
        choices.append(opts)
        dim_order.append(d)

    dense_head = tuple(Level(Prim.NONE, d, dims[d]) for d in dims
                       if d not in per_dim_slots)

    count = 0
    n = len(pattern)
    for combo in itertools.product(*choices):
        sizes: dict[int, int] = {}
        leaves: list[tuple[str, int]] = []
        for d, (alloc, leaf) in zip(dim_order, combo):
            for slot, size in zip(per_dim_slots[d], alloc):
                sizes[slot] = size
            if leaf is not None:
                leaves.append((d, leaf))
        yield AllocPlan(pattern, dense_head,
                        tuple(sizes[i] for i in range(n)), tuple(leaves))
        count += 1
        if max_allocs is not None and count >= max_allocs:
            return


def allocate(pattern: Sequence[Level], dims: dict[str, int],
             max_allocs: Optional[int] = None,
             allow_dense_leaf: bool = True) -> Iterator[Format]:
    """:func:`allocation_plans`, materialized to :class:`Format` objects."""
    for plan in allocation_plans(pattern, dims, max_allocs=max_allocs,
                                 allow_dense_leaf=allow_dense_leaf):
        yield plan.build()
