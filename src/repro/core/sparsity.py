"""Sparsity Analyzer (paper §III-A Evaluator, left half).

Estimates compressed data sizes and computation reduction using statistical
expectations.  Two entry points:

  * :func:`analyze`        — expectation model over a sparsity distribution
                             (the fast path used inside the search loop);
  * :func:`analyze_exact`  — exact bit counts for a concrete binary mask
                             (oracle for tests and for the Fig. 5 example).

Both walk the format's fiber tree outer→inner, tracking how many units are
*stored* at each level (compressed primitives prune empty children; ``None``
levels keep everything) and summing per-primitive metadata bits.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

from repro.core import memo
from repro.core.formats import AllocPlan, Format
from repro.core.primitives import (DECODE_COST, LevelStats, Prim, clog2,
                                   keeps_only_nonempty, metadata_bits)


# ---------------------------------------------------------------------------
# Sparsity distributions
# ---------------------------------------------------------------------------

class Sparsity:
    """Base class: a statistical model of where zeros fall in a tensor."""

    density: float

    def prob_nonempty(self, block_elems: float) -> float:
        raise NotImplementedError

    def expected_nnz(self, block_elems: float) -> float:
        return self.density * block_elems


@dataclasses.dataclass(frozen=True)
class Bernoulli(Sparsity):
    """I.i.d. zeros with the given density of non-zeros (paper's default
    statistical-expectation model for unstructured sparsity)."""

    density: float

    def prob_nonempty(self, block_elems: float) -> float:
        if self.density <= 0.0:
            return 0.0
        if self.density >= 1.0:
            return 1.0
        return 1.0 - (1.0 - self.density) ** block_elems


@dataclasses.dataclass(frozen=True)
class NM(Sparsity):
    """N:M structured sparsity: exactly ``n`` non-zeros per ``m`` consecutive
    elements along the innermost dimension (e.g. 2:4)."""

    n: int
    m: int

    @property
    def density(self) -> float:  # type: ignore[override]
        return self.n / self.m

    def prob_nonempty(self, block_elems: float) -> float:
        c = block_elems
        if c >= self.m:
            return 1.0  # every m-group carries n>=1 non-zeros
        # Probability that a sub-group window of c elements is all-zero:
        # hypergeometric — choose positions of the (m-n) zeros.
        c = int(c)
        num = math.comb(self.m - self.n, c) if c <= self.m - self.n else 0
        return 1.0 - num / math.comb(self.m, c)


@dataclasses.dataclass(frozen=True)
class BlockBernoulli(Sparsity):
    """Zeros clustered into whole blocks of ``block_elems`` elements: a
    block is entirely non-zero with probability ``density``, entirely zero
    otherwise (what block pruning produces — element density equals block
    density, but the zeros are NOT i.i.d.).

    The distinction matters to the cost model: under i.i.d. ``Bernoulli``
    a bn×bk tile is almost surely non-empty at any useful density, so a
    block-bitmap format predicts near-dense payload traffic; under the
    clustered model ``prob_nonempty`` of a within-block window is just
    ``density``, matching what the execution plane measures on real
    block-pruned weights (see :mod:`repro.exec.calibrate`)."""

    density: float
    block_elems: int            # elements per pruning block (bn · bk)

    def prob_nonempty(self, elems: float) -> float:
        if self.density <= 0.0:
            return 0.0
        if self.density >= 1.0:
            return 1.0
        # a window of `elems` elements touches ~max(1, elems/block) blocks;
        # it is empty only if every touched block is pruned
        touched = max(1.0, elems / self.block_elems)
        return 1.0 - (1.0 - self.density) ** touched


DENSE = Bernoulli(1.0)


# ---------------------------------------------------------------------------
# Tensor spec + size report
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """A named-dimension tensor with a sparsity model."""

    dims: dict[str, int]               # ordered, e.g. {"M": 4096, "N": 4096}
    sparsity: Sparsity = DENSE
    value_bits: int = 16               # bf16/int16 payload by default

    @property
    def elems(self) -> int:
        out = 1
        for v in self.dims.values():
            out *= v
        return out

    @property
    def dense_bits(self) -> float:
        return float(self.elems * self.value_bits)


def spec_key(spec: "TensorSpec") -> Optional[tuple]:
    """Hashable cache key for a TensorSpec (None if the sparsity model is
    unhashable — callers then skip their cache)."""
    try:
        hash(spec.sparsity)
    except TypeError:
        return None
    return (tuple(spec.dims.items()), spec.sparsity, spec.value_bits)


@dataclasses.dataclass(frozen=True)
class SizeReport:
    """Compressed-size analysis for (format, tensor)."""

    payload_bits: float
    metadata_bits: float
    decode_ops: float                  # metadata-processing work (cost model)
    per_level: tuple[float, ...]       # metadata bits per level

    @property
    def total_bits(self) -> float:
        return self.payload_bits + self.metadata_bits


# ---------------------------------------------------------------------------
# Expectation model
# ---------------------------------------------------------------------------

_ANALYZE_CACHE: dict = memo.register({}, "analyze")


def gather_scalar(fn, vals: np.ndarray, as_int: bool = True,
                  cache: Optional[dict] = None) -> np.ndarray:
    """Evaluate an arbitrary Python scalar function over an array by unique
    value: distribution models and bit-width rules are plain Python, but the
    values they see in the search plane (level sizes, tile extents, block
    products) come from small divisor sets, so ``fn`` runs once per distinct
    value and the results are gathered back.

    ``as_int`` converts each unique value to a Python ``int`` before the
    call, matching the scalar paths (which pass exact integer block counts);
    the values must then be integral.  ``cache`` optionally persists results
    across calls (caller-owned dict)."""
    if cache is None:
        cache = {}
    get = cache.get
    if vals.size > 64:
        # large batches dedupe in C first: fn still runs once per distinct
        # value, each element still receives exactly fn(int(v))
        uniq, inv = np.unique(vals.ravel(), return_inverse=True)
        out_u = np.empty(len(uniq))
        for i, v in enumerate(uniq.tolist()):
            k = int(v) if as_int else v
            hit = get(k, _GATHER_MISS)
            if hit is _GATHER_MISS:
                hit = cache[k] = fn(k)
            out_u[i] = hit
        return out_u[inv].reshape(vals.shape)
    flat = vals.ravel().tolist()
    out = np.empty(len(flat))
    for i, v in enumerate(flat):
        k = int(v) if as_int else v
        hit = get(k, _GATHER_MISS)
        if hit is _GATHER_MISS:
            hit = cache[k] = fn(k)
        out[i] = hit
    return out.reshape(vals.shape)


_GATHER_MISS = object()


def analyze(fmt: Format, spec: TensorSpec) -> SizeReport:
    """Expected compressed size of ``spec`` under ``fmt``.

    Memoized by (format, dims, sparsity, value_bits) — the engine's
    allocation scoring and the co-search's format compilation revisit the
    same (format, tensor) pairs constantly."""
    key = (fmt, tuple(spec.dims.items()), spec.sparsity, spec.value_bits)
    return memo.get_or(_ANALYZE_CACHE, key, lambda: _analyze_impl(fmt, spec))


def _analyze_impl(fmt: Format, spec: TensorSpec) -> SizeReport:
    """Walk levels outer→inner.  Invariants maintained:
      stored   — expected number of stored units entering level i
                 (the level's parents);
      covered  — elements covered by ONE unit at the parent level.
    """
    fmt.validate(spec.dims)
    sp = spec.sparsity

    # elements covered by one position at each level = product of inner sizes
    sizes = [int(l.size) for l in fmt.levels]  # type: ignore[arg-type]
    inner = [1] * (len(sizes) + 1)
    for i in range(len(sizes) - 1, -1, -1):
        inner[i] = inner[i + 1] * sizes[i]
    # inner[i] = elements covered by one unit at level i (levels 1-indexed via i-1)

    stored = 1.0
    dense_positions = 1.0
    meta: list[float] = []
    decode = 0.0
    for i, level in enumerate(fmt.levels):
        s = sizes[i]
        c_child = inner[i + 1]          # elements under one child position
        p_child = sp.prob_nonempty(c_child)
        dense_positions *= s
        # Expected non-empty positions at this level is the GLOBAL dense
        # count × p (linearity of expectation) — every non-empty position
        # necessarily lies under a non-empty (hence stored) parent, so this
        # is exactly the number of children materialized below compressed
        # parents, regardless of pruning decisions above.
        total_positions = stored * s
        nonempty = dense_positions * p_child
        st = LevelStats(
            stored_parents=stored,
            fanout=s,
            nonempty_positions=nonempty,
            child_nnz=sp.expected_nnz(inner[i]),
        )
        bits = metadata_bits(level.prim, st)
        meta.append(bits)
        decode += DECODE_COST[level.prim] * bits
        stored = nonempty if keeps_only_nonempty(level.prim) else total_positions

    payload = stored * spec.value_bits  # leaf units cover exactly 1 element
    return SizeReport(payload_bits=payload,
                      metadata_bits=float(sum(meta)),
                      decode_ops=decode,
                      per_level=tuple(meta))


# ---------------------------------------------------------------------------
# Batched expectation model (SoA over many allocations of one tensor)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BatchSizeReport:
    """Vectorized :class:`SizeReport` over ``m`` formats of one tensor.

    Arrays are length ``m``; ``per_level`` is padded to the deepest format
    with zero-bit levels.  :meth:`report` reconstitutes the exact scalar
    :class:`SizeReport` for one row."""

    payload_bits: np.ndarray
    metadata_bits: np.ndarray
    decode_ops: np.ndarray
    per_level: np.ndarray               # (m, L) padded with 0.0
    n_levels: tuple[int, ...]           # true level count per format

    @property
    def total_bits(self) -> np.ndarray:
        return self.payload_bits + self.metadata_bits

    def __len__(self) -> int:
        return len(self.n_levels)

    def report(self, i: int) -> SizeReport:
        k = self.n_levels[i]
        return SizeReport(
            payload_bits=float(self.payload_bits[i]),
            metadata_bits=float(self.metadata_bits[i]),
            decode_ops=float(self.decode_ops[i]),
            per_level=tuple(float(b) for b in self.per_level[i, :k]))


_PRIM_CODE = {Prim.B: 0, Prim.CP: 1, Prim.RLE: 2, Prim.UOP: 3, Prim.NONE: 4}
_PRIM_BY_CODE = (Prim.B, Prim.CP, Prim.RLE, Prim.UOP, Prim.NONE)
_DECODE_BY_CODE = np.array([DECODE_COST[p] for p in _PRIM_BY_CODE])
_B_CODE, _CP_CODE, _RLE_CODE = _PRIM_CODE[Prim.B], _PRIM_CODE[Prim.CP], \
    _PRIM_CODE[Prim.RLE]
_UOP_CODE, _NONE_CODE = _PRIM_CODE[Prim.UOP], _PRIM_CODE[Prim.NONE]


def analyze_batch(fmts: Sequence[Format], spec: TensorSpec,
                  validate: bool = True) -> BatchSizeReport:
    """Expected compressed sizes of ``spec`` under many formats at once.

    Bit-identical to per-format :func:`analyze`: the level walk runs
    column-wise over a (format, level) matrix padded with size-1 ``None``
    levels (a no-op for every invariant), with the same operations in the
    same order, and the Python-level distribution/bit-width functions
    (``prob_nonempty`` / ``expected_nnz`` / :func:`clog2`) evaluated once
    per unique operand via :func:`gather_scalar`.  ``validate=False`` skips
    per-format validation for callers whose formats are correct by
    construction (:func:`repro.core.formats.allocate`)."""
    m = len(fmts)
    if m == 0:
        z = np.zeros(0)
        return BatchSizeReport(z, z, z, np.zeros((0, 1)), ())
    if validate:
        for f in fmts:
            f.validate(spec.dims)
    n_levels = tuple(len(f.levels) for f in fmts)
    L = max(n_levels)
    sizes = np.ones((m, L))
    prims = np.full((m, L), _NONE_CODE, np.int64)
    for i, f in enumerate(fmts):
        for j, l in enumerate(f.levels):
            if l.prim is Prim.CUSTOM:
                raise ValueError("Custom primitive requires a custom bit "
                                 "model; analyze_batch does not support it")
            sizes[i, j] = int(l.size)   # type: ignore[arg-type]
            prims[i, j] = _PRIM_CODE[l.prim]
    return _analyze_rows(sizes, prims, n_levels, spec)


def analyze_batch_rows(sizes: np.ndarray, prims: Sequence[Prim],
                       n_levels: Sequence[int], spec: TensorSpec
                       ) -> BatchSizeReport:
    """Raw-array entry point of :func:`analyze_batch` for batches whose
    formats all share one primitive row (every allocation of one pattern:
    identical dense head, identical pattern levels, ``None`` leaves and
    padding).  ``sizes`` is the (m, L) level-size matrix padded with 1s;
    ``prims`` the shared per-level primitive row; ``n_levels`` the true
    level count per row.  Lets the hot path skip building ``Format``
    objects for allocations that lose the scan."""
    m, L = sizes.shape
    if len(prims) != L:
        raise ValueError(f"prim row length {len(prims)} != {L} levels")
    if any(p is Prim.CUSTOM for p in prims):
        raise ValueError("Custom primitive requires a custom bit model; "
                         "analyze_batch_rows does not support it")
    row = np.array([_PRIM_CODE[p] for p in prims], np.int64)
    return _analyze_rows(sizes, row.reshape(1, L), tuple(n_levels), spec)


def analyze_plans(plans: Sequence["AllocPlan"], spec: TensorSpec
                  ) -> BatchSizeReport:
    """Score a group of :class:`repro.core.formats.AllocPlan` rows — every
    allocation of ONE pattern on one tensor — in a single
    :func:`analyze_batch_rows` pass, without constructing
    :class:`~repro.core.formats.Format` objects.

    All plans must share the same ``dense_head`` and ``pattern`` (which is
    what :func:`repro.core.formats.allocation_plans` yields); trailing
    dense leaves may vary per plan and pad as ``None`` levels.  Used by the
    engine's batched allocation scoring and the stepwise baseline's format
    sweep."""
    if not plans:
        z = np.zeros(0)
        return BatchSizeReport(z, z, z, np.zeros((0, 1)), ())
    rows = [p.row_sizes() for p in plans]
    width = max(len(r) for r in rows)
    sizes = np.array([r + [1] * (width - len(r)) for r in rows], float)
    return analyze_batch_rows(sizes, plans[0].prim_row(width),
                              [len(r) for r in rows], spec)


def _analyze_rows(sizes: np.ndarray, prims: np.ndarray,
                  n_levels: tuple[int, ...], spec: TensorSpec
                  ) -> BatchSizeReport:
    """Shared level walk; ``prims`` is (m, L), or (1, L) when every row has
    the same primitive at every level."""
    sp = spec.sparsity
    m, L = sizes.shape

    # inner[:, j] = elements covered by one unit at level j (suffix product)
    inner = np.ones((m, L + 1))
    for j in range(L - 1, -1, -1):
        inner[:, j] = inner[:, j + 1] * sizes[:, j]
    # dense positions through level j (prefix product, sequential like the
    # scalar ``dense_positions *= s``)
    dp = np.multiply.accumulate(sizes, axis=1)

    p_cache: dict = {}
    cl_cache: dict = {}
    nnz_cache: dict = {}
    stored = np.ones(m)
    meta_total = np.zeros(m)
    decode = np.zeros(m)
    per_level = np.zeros((m, L))
    zeros = np.zeros(m)
    uniform = prims.shape[0] == 1
    for j in range(L):
        s = sizes[:, j]
        code = prims[:, j]
        c0 = int(code[0])
        # Allocations of one pattern share the prim at every column (same
        # dense head, same pattern levels, NONE leaves/padding), so the
        # homogeneous fast paths below are the common case.
        homo = uniform or bool((code == c0).all())
        if homo and c0 == _NONE_CODE:
            # dense level: zero metadata bits, every child kept
            stored = stored * s
            continue
        nonempty = dp[:, j] * gather_scalar(sp.prob_nonempty,
                                            inner[:, j + 1], cache=p_cache)
        if homo:
            if c0 == _B_CODE:
                bits = stored * s
            elif c0 == _CP_CODE:
                bits = nonempty * gather_scalar(clog2, s, cache=cl_cache)
            elif c0 == _RLE_CODE:
                bits = nonempty * gather_scalar(clog2, s + 1.0,
                                                cache=cl_cache)
            else:                       # UOP
                child_nnz = gather_scalar(sp.expected_nnz, inner[:, j],
                                          cache=nnz_cache)
                field = gather_scalar(clog2, child_nnz + 1.0, as_int=False)
                bits = stored * (s + 1.0) * field
            stored_next = nonempty
            dc = DECODE_COST[_PRIM_BY_CODE[c0]]
        else:                           # mixed column: general path
            total_positions = stored * s
            if (code == _UOP_CODE).any():
                child_nnz = gather_scalar(sp.expected_nnz, inner[:, j],
                                          cache=nnz_cache)
                field = gather_scalar(clog2, child_nnz + 1.0, as_int=False)
                uop_bits = stored * (s + 1.0) * field
            else:
                uop_bits = zeros
            bits = np.choose(code, (
                total_positions,                                      # B
                nonempty * gather_scalar(clog2, s, cache=cl_cache),   # CP
                nonempty * gather_scalar(clog2, s + 1.0,
                                         cache=cl_cache),             # RLE
                uop_bits,                                             # UOP
                zeros,                                                # NONE
            ))
            stored_next = np.where(code != _NONE_CODE, nonempty,
                                   total_positions)
            dc = _DECODE_BY_CODE[code]
        per_level[:, j] = bits
        meta_total = meta_total + bits
        decode = decode + dc * bits
        stored = stored_next

    payload = stored * spec.value_bits
    return BatchSizeReport(payload_bits=payload, metadata_bits=meta_total,
                           decode_ops=decode, per_level=per_level,
                           n_levels=n_levels)


# ---------------------------------------------------------------------------
# Exact model (concrete mask)
# ---------------------------------------------------------------------------

def analyze_exact(fmt: Format, mask: np.ndarray, dims: dict[str, int],
                  value_bits: int = 16) -> SizeReport:
    """Exact bit counts of ``fmt`` applied to a concrete 0/1 ``mask``.

    ``mask`` axes must follow ``dims`` order.  The mask is reshaped so its
    axes match the level order (splitting repeated dims into subdims), then
    the fiber tree is walked with boolean occupancy arrays.
    """
    fmt.validate(dims)
    if tuple(mask.shape) != tuple(dims.values()):
        raise ValueError(f"mask shape {mask.shape} != dims {dims}")
    mask = mask.astype(bool)

    # Split each dim axis into its per-level sizes (outer→inner for that dim),
    # then transpose so axes follow the global level order.
    dim_names = list(dims)
    split_shapes: list[list[int]] = []
    level_axis: list[tuple[int, int]] = []   # per level: (dim_index, split_slot)
    slot_count = {d: 0 for d in dim_names}
    per_dim_sizes: dict[str, list[int]] = {d: [] for d in dim_names}
    for l in fmt.levels:
        per_dim_sizes[l.dim].append(int(l.size))  # type: ignore[arg-type]
        level_axis.append((dim_names.index(l.dim), slot_count[l.dim]))
        slot_count[l.dim] += 1
    for d in dim_names:
        split_shapes.append(per_dim_sizes[d] if per_dim_sizes[d] else [dims[d]])

    new_shape: list[int] = []
    axis_of: dict[tuple[int, int], int] = {}
    for di, shp in enumerate(split_shapes):
        for si, s in enumerate(shp):
            axis_of[(di, si)] = len(new_shape)
            new_shape.append(s)
    arr = mask.reshape(new_shape)
    perm = [axis_of[key] for key in level_axis]
    # any dims without levels were given a single implicit axis already in
    # split_shapes — formats from allocate() always carry a dense tail, so
    # every dim has at least one level after validate(); perm covers all axes.
    arr = np.transpose(arr, perm)

    n = len(fmt.levels)
    nonempty = [np.any(arr, axis=tuple(range(i + 1, n))) if i + 1 < n else arr
                for i in range(n)]
    # nonempty[i] has shape sizes[:i+1]; True where the unit holds any nnz.

    stored_parent = np.ones((), dtype=bool)   # level-0 root
    meta: list[float] = []
    decode = 0.0
    for i, level in enumerate(fmt.levels):
        s = int(level.size)  # type: ignore[arg-type]
        parents = float(np.sum(stored_parent))
        ne_mask = nonempty[i] & stored_parent[..., None]
        ne = float(np.sum(ne_mask))
        if level.prim is Prim.B:
            bits = parents * s
        elif level.prim is Prim.CP:
            bits = ne * clog2(s)
        elif level.prim is Prim.RLE:
            bits = ne * clog2(s + 1)
        elif level.prim is Prim.UOP:
            # field width: max non-zero count under any stored parent
            axes = tuple(range(i, arr.ndim))
            child_nnz = np.sum(arr, axis=axes) * stored_parent
            width = clog2(float(np.max(child_nnz)) + 1.0)
            bits = parents * (s + 1) * width
        else:  # NONE / CUSTOM-dense
            bits = 0.0
        meta.append(bits)
        decode += DECODE_COST[level.prim] * bits
        stored_parent = ne_mask if keeps_only_nonempty(level.prim) \
            else np.broadcast_to(stored_parent[..., None],
                                 stored_parent.shape + (s,)).copy()

    payload = float(np.sum(stored_parent)) * value_bits
    return SizeReport(payload_bits=payload,
                      metadata_bits=float(sum(meta)),
                      decode_ops=decode,
                      per_level=tuple(meta))


# ---------------------------------------------------------------------------
# Computation reduction (paper §II-B2): gating / skipping, uni/bidirectional
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ComputeReduction:
    """One of the five strategies: none, {gating,skipping} × {→, ←, ↔}.

    ``check_i``/``check_w`` state which operand's zeros are detected; the
    checked operands' densities multiply into the effective MAC fraction.
    ``skipping`` saves cycles as well as energy; gating saves energy only.
    """

    kind: str = "none"                 # "none" | "gating" | "skipping"
    check_i: bool = False
    check_w: bool = False

    def mac_fraction(self, rho_i: float, rho_w: float) -> float:
        if self.kind == "none":
            return 1.0
        f = 1.0
        if self.check_i:
            f *= rho_i
        if self.check_w:
            f *= rho_w
        return f

    def cycle_fraction(self, rho_i: float, rho_w: float) -> float:
        """Upfront estimate of temporal loop-bound shrinkage (§III-D1)."""
        if self.kind == "skipping":
            return self.mac_fraction(rho_i, rho_w)
        return 1.0

    def label(self) -> str:
        if self.kind == "none":
            return "none"
        arrow = {"10": "I→W", "01": "W→I", "11": "I↔W"}[
            f"{int(self.check_i)}{int(self.check_w)}"]
        return f"{self.kind} {arrow}"


NO_REDUCTION = ComputeReduction()


def reduction(kind: str, direction: str) -> ComputeReduction:
    """Factory: direction in {'I', 'W', 'IW'} = which operands are checked."""
    return ComputeReduction(kind=kind,
                            check_i="I" in direction,
                            check_w="W" in direction)
