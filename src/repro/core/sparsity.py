"""Sparsity Analyzer (paper §III-A Evaluator, left half).

Estimates compressed data sizes and computation reduction using statistical
expectations.  Two entry points:

  * :func:`analyze`        — expectation model over a sparsity distribution
                             (the fast path used inside the search loop);
  * :func:`analyze_exact`  — exact bit counts for a concrete binary mask
                             (oracle for tests and for the Fig. 5 example).

Both walk the format's fiber tree outer→inner, tracking how many units are
*stored* at each level (compressed primitives prune empty children; ``None``
levels keep everything) and summing per-primitive metadata bits.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

from repro.core import memo
from repro.core.formats import Format
from repro.core.primitives import (DECODE_COST, LevelStats, Prim, clog2,
                                   keeps_only_nonempty, metadata_bits)


# ---------------------------------------------------------------------------
# Sparsity distributions
# ---------------------------------------------------------------------------

class Sparsity:
    """Base class: a statistical model of where zeros fall in a tensor."""

    density: float

    def prob_nonempty(self, block_elems: float) -> float:
        raise NotImplementedError

    def expected_nnz(self, block_elems: float) -> float:
        return self.density * block_elems


@dataclasses.dataclass(frozen=True)
class Bernoulli(Sparsity):
    """I.i.d. zeros with the given density of non-zeros (paper's default
    statistical-expectation model for unstructured sparsity)."""

    density: float

    def prob_nonempty(self, block_elems: float) -> float:
        if self.density <= 0.0:
            return 0.0
        if self.density >= 1.0:
            return 1.0
        return 1.0 - (1.0 - self.density) ** block_elems


@dataclasses.dataclass(frozen=True)
class NM(Sparsity):
    """N:M structured sparsity: exactly ``n`` non-zeros per ``m`` consecutive
    elements along the innermost dimension (e.g. 2:4)."""

    n: int
    m: int

    @property
    def density(self) -> float:  # type: ignore[override]
        return self.n / self.m

    def prob_nonempty(self, block_elems: float) -> float:
        c = block_elems
        if c >= self.m:
            return 1.0  # every m-group carries n>=1 non-zeros
        # Probability that a sub-group window of c elements is all-zero:
        # hypergeometric — choose positions of the (m-n) zeros.
        c = int(c)
        num = math.comb(self.m - self.n, c) if c <= self.m - self.n else 0
        return 1.0 - num / math.comb(self.m, c)


DENSE = Bernoulli(1.0)


# ---------------------------------------------------------------------------
# Tensor spec + size report
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """A named-dimension tensor with a sparsity model."""

    dims: dict[str, int]               # ordered, e.g. {"M": 4096, "N": 4096}
    sparsity: Sparsity = DENSE
    value_bits: int = 16               # bf16/int16 payload by default

    @property
    def elems(self) -> int:
        out = 1
        for v in self.dims.values():
            out *= v
        return out

    @property
    def dense_bits(self) -> float:
        return float(self.elems * self.value_bits)


@dataclasses.dataclass(frozen=True)
class SizeReport:
    """Compressed-size analysis for (format, tensor)."""

    payload_bits: float
    metadata_bits: float
    decode_ops: float                  # metadata-processing work (cost model)
    per_level: tuple[float, ...]       # metadata bits per level

    @property
    def total_bits(self) -> float:
        return self.payload_bits + self.metadata_bits


# ---------------------------------------------------------------------------
# Expectation model
# ---------------------------------------------------------------------------

_ANALYZE_CACHE: dict = memo.register({})


def analyze(fmt: Format, spec: TensorSpec) -> SizeReport:
    """Expected compressed size of ``spec`` under ``fmt``.

    Memoized by (format, dims, sparsity, value_bits) — the engine's
    allocation scoring and the co-search's format compilation revisit the
    same (format, tensor) pairs constantly."""
    key = (fmt, tuple(spec.dims.items()), spec.sparsity, spec.value_bits)
    return memo.get_or(_ANALYZE_CACHE, key, lambda: _analyze_impl(fmt, spec))


def _analyze_impl(fmt: Format, spec: TensorSpec) -> SizeReport:
    """Walk levels outer→inner.  Invariants maintained:
      stored   — expected number of stored units entering level i
                 (the level's parents);
      covered  — elements covered by ONE unit at the parent level.
    """
    fmt.validate(spec.dims)
    sp = spec.sparsity

    # elements covered by one position at each level = product of inner sizes
    sizes = [int(l.size) for l in fmt.levels]  # type: ignore[arg-type]
    inner = [1] * (len(sizes) + 1)
    for i in range(len(sizes) - 1, -1, -1):
        inner[i] = inner[i + 1] * sizes[i]
    # inner[i] = elements covered by one unit at level i (levels 1-indexed via i-1)

    stored = 1.0
    dense_positions = 1.0
    meta: list[float] = []
    decode = 0.0
    for i, level in enumerate(fmt.levels):
        s = sizes[i]
        c_child = inner[i + 1]          # elements under one child position
        p_child = sp.prob_nonempty(c_child)
        dense_positions *= s
        # Expected non-empty positions at this level is the GLOBAL dense
        # count × p (linearity of expectation) — every non-empty position
        # necessarily lies under a non-empty (hence stored) parent, so this
        # is exactly the number of children materialized below compressed
        # parents, regardless of pruning decisions above.
        total_positions = stored * s
        nonempty = dense_positions * p_child
        st = LevelStats(
            stored_parents=stored,
            fanout=s,
            nonempty_positions=nonempty,
            child_nnz=sp.expected_nnz(inner[i]),
        )
        bits = metadata_bits(level.prim, st)
        meta.append(bits)
        decode += DECODE_COST[level.prim] * bits
        stored = nonempty if keeps_only_nonempty(level.prim) else total_positions

    payload = stored * spec.value_bits  # leaf units cover exactly 1 element
    return SizeReport(payload_bits=payload,
                      metadata_bits=float(sum(meta)),
                      decode_ops=decode,
                      per_level=tuple(meta))


# ---------------------------------------------------------------------------
# Exact model (concrete mask)
# ---------------------------------------------------------------------------

def analyze_exact(fmt: Format, mask: np.ndarray, dims: dict[str, int],
                  value_bits: int = 16) -> SizeReport:
    """Exact bit counts of ``fmt`` applied to a concrete 0/1 ``mask``.

    ``mask`` axes must follow ``dims`` order.  The mask is reshaped so its
    axes match the level order (splitting repeated dims into subdims), then
    the fiber tree is walked with boolean occupancy arrays.
    """
    fmt.validate(dims)
    if tuple(mask.shape) != tuple(dims.values()):
        raise ValueError(f"mask shape {mask.shape} != dims {dims}")
    mask = mask.astype(bool)

    # Split each dim axis into its per-level sizes (outer→inner for that dim),
    # then transpose so axes follow the global level order.
    dim_names = list(dims)
    split_shapes: list[list[int]] = []
    level_axis: list[tuple[int, int]] = []   # per level: (dim_index, split_slot)
    slot_count = {d: 0 for d in dim_names}
    per_dim_sizes: dict[str, list[int]] = {d: [] for d in dim_names}
    for l in fmt.levels:
        per_dim_sizes[l.dim].append(int(l.size))  # type: ignore[arg-type]
        level_axis.append((dim_names.index(l.dim), slot_count[l.dim]))
        slot_count[l.dim] += 1
    for d in dim_names:
        split_shapes.append(per_dim_sizes[d] if per_dim_sizes[d] else [dims[d]])

    new_shape: list[int] = []
    axis_of: dict[tuple[int, int], int] = {}
    for di, shp in enumerate(split_shapes):
        for si, s in enumerate(shp):
            axis_of[(di, si)] = len(new_shape)
            new_shape.append(s)
    arr = mask.reshape(new_shape)
    perm = [axis_of[key] for key in level_axis]
    # any dims without levels were given a single implicit axis already in
    # split_shapes — formats from allocate() always carry a dense tail, so
    # every dim has at least one level after validate(); perm covers all axes.
    arr = np.transpose(arr, perm)

    n = len(fmt.levels)
    nonempty = [np.any(arr, axis=tuple(range(i + 1, n))) if i + 1 < n else arr
                for i in range(n)]
    # nonempty[i] has shape sizes[:i+1]; True where the unit holds any nnz.

    stored_parent = np.ones((), dtype=bool)   # level-0 root
    meta: list[float] = []
    decode = 0.0
    for i, level in enumerate(fmt.levels):
        s = int(level.size)  # type: ignore[arg-type]
        parents = float(np.sum(stored_parent))
        ne_mask = nonempty[i] & stored_parent[..., None]
        ne = float(np.sum(ne_mask))
        if level.prim is Prim.B:
            bits = parents * s
        elif level.prim is Prim.CP:
            bits = ne * clog2(s)
        elif level.prim is Prim.RLE:
            bits = ne * clog2(s + 1)
        elif level.prim is Prim.UOP:
            # field width: max non-zero count under any stored parent
            axes = tuple(range(i, arr.ndim))
            child_nnz = np.sum(arr, axis=axes) * stored_parent
            width = clog2(float(np.max(child_nnz)) + 1.0)
            bits = parents * (s + 1) * width
        else:  # NONE / CUSTOM-dense
            bits = 0.0
        meta.append(bits)
        decode += DECODE_COST[level.prim] * bits
        stored_parent = ne_mask if keeps_only_nonempty(level.prim) \
            else np.broadcast_to(stored_parent[..., None],
                                 stored_parent.shape + (s,)).copy()

    payload = float(np.sum(stored_parent)) * value_bits
    return SizeReport(payload_bits=payload,
                      metadata_bits=float(sum(meta)),
                      decode_ops=decode,
                      per_level=tuple(meta))


# ---------------------------------------------------------------------------
# Computation reduction (paper §II-B2): gating / skipping, uni/bidirectional
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ComputeReduction:
    """One of the five strategies: none, {gating,skipping} × {→, ←, ↔}.

    ``check_i``/``check_w`` state which operand's zeros are detected; the
    checked operands' densities multiply into the effective MAC fraction.
    ``skipping`` saves cycles as well as energy; gating saves energy only.
    """

    kind: str = "none"                 # "none" | "gating" | "skipping"
    check_i: bool = False
    check_w: bool = False

    def mac_fraction(self, rho_i: float, rho_w: float) -> float:
        if self.kind == "none":
            return 1.0
        f = 1.0
        if self.check_i:
            f *= rho_i
        if self.check_w:
            f *= rho_w
        return f

    def cycle_fraction(self, rho_i: float, rho_w: float) -> float:
        """Upfront estimate of temporal loop-bound shrinkage (§III-D1)."""
        if self.kind == "skipping":
            return self.mac_fraction(rho_i, rho_w)
        return 1.0

    def label(self) -> str:
        if self.kind == "none":
            return "none"
        arrow = {"10": "I→W", "01": "W→I", "11": "I↔W"}[
            f"{int(self.check_i)}{int(self.check_w)}"]
        return f"{self.kind} {arrow}"


NO_REDUCTION = ComputeReduction()


def reduction(kind: str, direction: str) -> ComputeReduction:
    """Factory: direction in {'I', 'W', 'IW'} = which operands are checked."""
    return ComputeReduction(kind=kind,
                            check_i="I" in direction,
                            check_w="W" in direction)
