"""Hardware configurations (paper §III-A input 2, Table II).

A :class:`HardwareConfig` is a MAC array + a memory hierarchy (outer→inner)
+ a computation-reduction strategy + the compression-format slot(s) the
hardware implements.  Energy constants are per-bit, in normalized units
following the Eyeriss/SCNN energy-per-access ratios (DRAM ≈ 200× RF per
16-bit word); all paper experiments report *normalized* energy, so the
ratios — not absolute joules — are what matters and what we validate.

Arch 1/2 model Eyeriss-style hierarchies, Arch 3/4 DSTC-style (Table II),
both scaled to 16× MACs and 4× on-chip memory per §IV-A1.  TPUV5E models the
execution-plane target for the codesign bridge (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.sparsity import ComputeReduction, reduction


@dataclasses.dataclass(frozen=True)
class MemLevel:
    """One memory level.  ``capacity_bits`` None = unbounded (off-chip)."""

    name: str
    capacity_bits: Optional[float]
    bw_bits_per_cycle: float
    pj_per_bit_read: float
    pj_per_bit_write: float

    @property
    def pj_per_bit(self) -> float:
        return (self.pj_per_bit_read + self.pj_per_bit_write) / 2.0


@dataclasses.dataclass(frozen=True)
class HardwareConfig:
    name: str
    macs: int
    levels: tuple[MemLevel, ...]        # outer→inner: [DRAM, GLB, RF]
    mac_pj: float                       # energy per MAC
    reduc: ComputeReduction
    clock_ghz: float = 1.0
    decode_pj_per_op: float = 0.05      # metadata decode energy (§IV-E:
    #                                     1.56–15.45% area overhead ⇒ small
    #                                     per-op cost relative to a MAC)
    rf_reuse: float = 16.0              # temporal reuse at the RF level —
    #                                     each GLB word feeds ~this many MACs
    #                                     (Eyeriss row-stationary ≈ 0.5KB RF)
    glb_resident_frac: float = 0.0      # fraction of GLB capacity the
    #                                     streaming pipeline may pin for
    #                                     compressed-payload residency; 0
    #                                     disables the reuse term (the seed
    #                                     cost model, bit-for-bit)

    @property
    def dram(self) -> MemLevel:
        return self.levels[0]

    @property
    def glb(self) -> MemLevel:
        return self.levels[1]

    @property
    def rf(self) -> MemLevel:
        return self.levels[-1]


# 16-bit-word energy ratios (Eyeriss ISCA'16): DRAM=200, GLB=6, RF=1, MAC=1.
_WORD = 16.0


def _eyeriss_like(name: str, reduc: ComputeReduction) -> HardwareConfig:
    # Eyeriss: 168 PEs × 16 = 2688 MACs; 108KB GLB × 4 = 432KB.
    return HardwareConfig(
        name=name,
        macs=2688,
        levels=(
            MemLevel("DRAM", None, 64.0, 200 / _WORD, 200 / _WORD),
            MemLevel("GLB", 432e3 * 8 * 1.0, 512.0, 6 / _WORD, 6 / _WORD),
            MemLevel("RF", 0.5e3 * 8 * 2688, 2 * 2688.0, 1 / _WORD, 1 / _WORD),
        ),
        mac_pj=1.0,
        reduc=reduc,
        clock_ghz=0.2,
    )


def _dstc_like(name: str, reduc: ComputeReduction) -> HardwareConfig:
    # DSTC-style tensor core: 2048 MACs, larger SRAM, wider DRAM bus.
    return HardwareConfig(
        name=name,
        macs=2048,
        levels=(
            MemLevel("DRAM", None, 256.0, 200 / _WORD, 200 / _WORD),
            MemLevel("GLB", 2e6 * 8 * 1.0, 2048.0, 5 / _WORD, 5 / _WORD),
            MemLevel("RF", 1e3 * 8 * 2048, 4 * 2048.0, 1 / _WORD, 1 / _WORD),
        ),
        mac_pj=1.0,
        reduc=reduc,
        clock_ghz=1.0,
    )


# Table II.  Default formats: Arch1/2 ship RLE, Arch3/4 ship Bitmap.
ARCH1 = _eyeriss_like("Arch 1", reduction("gating", "I"))
ARCH2 = _eyeriss_like("Arch 2", reduction("skipping", "I"))
ARCH3 = _dstc_like("Arch 3", reduction("skipping", "IW"))
ARCH4 = _dstc_like("Arch 4", reduction("gating", "IW"))

DEFAULT_FORMAT = {"Arch 1": "RLE", "Arch 2": "RLE",
                  "Arch 3": "Bitmap", "Arch 4": "Bitmap"}

ALL_ARCHS = (ARCH1, ARCH2, ARCH3, ARCH4)


# Execution-plane target: TPU v5e (197 TFLOP/s bf16, 819 GB/s HBM, ~128 MiB
# VMEM modeled).  Zero-skipping on the MXU only exists at tile granularity
# (DESIGN.md §4) — modeled as block-granular skipping I↔W.
TPUV5E = HardwareConfig(
    name="TPUv5e",
    macs=4 * 128 * 128,
    levels=(
        MemLevel("HBM", 16e9 * 8, 819e9 * 8 / 0.94e9, 200 / _WORD, 200 / _WORD),
        MemLevel("VMEM", 128e6 * 8, 5e12 * 8 / 0.94e9, 3 / _WORD, 3 / _WORD),
        MemLevel("VREG", 1e6 * 8, 4 * 65536.0, 1 / _WORD, 1 / _WORD),
    ),
    mac_pj=1.0,
    reduc=reduction("skipping", "IW"),
    clock_ghz=0.94,
)


def with_streaming_reuse(arch: HardwareConfig,
                         frac: float = 0.5) -> HardwareConfig:
    """``arch`` with a GLB residency budget for the streaming pipeline.

    ``frac`` of the GLB may hold compressed payload across outer-loop
    iterations, so re-fetches of the resident slice are served on-chip
    instead of from DRAM (the cost model's reuse term,
    ``costmodel._evaluate_terms``).  The name is tagged so memo keys and
    reports distinguish reuse-aware searches from the baseline."""
    if not 0.0 <= frac <= 1.0:
        raise ValueError(f"glb_resident_frac must be in [0,1], got {frac}")
    return dataclasses.replace(
        arch, name=f"{arch.name}+resident{frac:g}", glb_resident_frac=frac)


def arch_by_name(name: str) -> HardwareConfig:
    if "+resident" in name:               # with_streaming_reuse round trip
        base, _, frac = name.rpartition("+resident")
        return with_streaming_reuse(arch_by_name(base), float(frac))
    table = {a.name: a for a in ALL_ARCHS + (TPUV5E,)}
    # tolerate compact ids
    table.update({"arch1": ARCH1, "arch2": ARCH2, "arch3": ARCH3,
                  "arch4": ARCH4, "tpuv5e": TPUV5E})
    return table[name]
