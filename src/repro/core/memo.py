"""Central memoization registry for the search/evaluation hot path.

The co-search re-derives a lot of identical intermediate state: the same
(format, tensor) pair is compiled once per op per pattern pair, the same
mapping space is enumerated once per pattern pair, and identical layers are
re-searched across pairs and models.  Modules register their caches here so
they can be cleared (tests, benchmarks) or bypassed (cache-correctness
checks, seed-path timing) in one place.

Cache keys are **value-based** (frozen-dataclass fields, dict items tuples)
rather than object identities, so equal inputs hit regardless of where they
were constructed.  Keys used across the codebase:

  * ``compile_format`` / ``analyze``: (format levels+name, dims items,
    sparsity model, value_bits);
  * ``enumerate_mappings``:   ((M, N, K), value_bits, arch, ratio_i,
    ratio_w, spatial_top, orders);
  * ``_reference_cf``:        (pattern levels or named format, spec key);
  * ``_search_op``:           (op shape+sparsity+count, arch, candidate
    pair, CoSearchConfig);
  * ``generate_candidates``:  (spec key, EngineConfig, penalize).

Unhashable inputs (e.g. a custom ``Sparsity`` subclass) silently skip the
cache — correctness never depends on a hit.
"""

from __future__ import annotations

import contextlib
from typing import Any, Callable, Iterator

_REGISTRY: list[dict] = []
_enabled: bool = True
_MISS = object()                # distinguishes a cached None from a miss


def register(cache: dict) -> dict:
    """Register a module-level cache dict for global clear/disable."""
    _REGISTRY.append(cache)
    return cache


def enabled() -> bool:
    return _enabled


def set_enabled(on: bool) -> None:
    global _enabled
    _enabled = on


def clear() -> None:
    for c in _REGISTRY:
        c.clear()


@contextlib.contextmanager
def disabled() -> Iterator[None]:
    """Temporarily bypass every registered cache (they keep their entries)."""
    global _enabled
    prev = _enabled
    _enabled = False
    try:
        yield
    finally:
        _enabled = prev


def get_or(cache: dict, key: Any, compute: Callable[[], Any]) -> Any:
    """``cache[key]`` or ``compute()`` (stored), honoring the global switch.

    ``key`` may be None (caller found its inputs unhashable) — then this is
    a plain ``compute()``.
    """
    if key is None or not _enabled:
        return compute()
    try:
        hit = cache.get(key, _MISS)
    except TypeError:           # unhashable component slipped into the key
        return compute()
    if hit is _MISS:
        hit = compute()
        cache[key] = hit
    return hit
