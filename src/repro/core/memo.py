"""Central memoization registry for the search/evaluation hot path.

The co-search re-derives a lot of identical intermediate state: the same
(format, tensor) pair is compiled once per op per pattern pair, the same
mapping space is enumerated once per pattern pair, and identical layers are
re-searched across pairs and models.  Modules register their caches here so
they can be cleared (tests, benchmarks) or bypassed (cache-correctness
checks, seed-path timing) in one place.

Cache keys are **value-based** (frozen-dataclass fields, dict items tuples)
rather than object identities, so equal inputs hit regardless of where they
were constructed.  Keys used across the codebase:

  * ``compile_format`` / ``analyze``: (format levels+name, dims items,
    sparsity model, value_bits);
  * ``enumerate_mappings``:   ((M, N, K), value_bits, arch, ratio_i,
    ratio_w, spatial_top, orders);
  * ``factorizations``:       (extent, parts);
  * ``_reference_cf``:        (pattern levels or named format, spec key);
  * ``_search_op``:           (op shape+sparsity+count, arch, candidate
    pair, CoSearchConfig);
  * ``generate_candidates``:  (spec key, EngineConfig, penalize).

Unhashable inputs (e.g. a custom ``Sparsity`` subclass) silently skip the
cache — correctness never depends on a hit.

Every registered cache carries hit/miss counters (:func:`stats`,
:func:`stats_report`); lookups made while caching is disabled, or with a
``None`` key, are not counted.  Counters survive :func:`clear` (so a
cold-cache benchmark still reports its warm-up misses) and are zeroed with
:func:`reset_stats`.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Callable, Iterator, Optional

_REGISTRY: list[dict] = []
_enabled: bool = True
_MISS = object()                # distinguishes a cached None from a miss
_STATS_LOCK = threading.Lock()  # counters stay exact under cosearch_multi's
#                                 thread-sharded work-list


@dataclasses.dataclass
class CacheStats:
    """Hit/miss counters for one registered cache."""

    name: str
    hits: int = 0
    misses: int = 0

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0


_STATS: dict[int, CacheStats] = {}      # id(cache) -> counters


def register(cache: dict, name: Optional[str] = None) -> dict:
    """Register a module-level cache dict for global clear/disable."""
    _REGISTRY.append(cache)
    _STATS[id(cache)] = CacheStats(name or f"cache{len(_REGISTRY)}")
    return cache


def enabled() -> bool:
    return _enabled


def set_enabled(on: bool) -> None:
    global _enabled
    _enabled = on


def clear() -> None:
    for c in _REGISTRY:
        c.clear()


def note(cache: dict, hit: bool) -> None:
    """Record a hit/miss for a registered cache that is probed manually
    (without :func:`get_or`)."""
    st = _STATS.get(id(cache))
    if st is not None:
        with _STATS_LOCK:
            if hit:
                st.hits += 1
            else:
                st.misses += 1


def stats() -> dict[str, CacheStats]:
    """Per-cache counters, keyed by the name given at :func:`register`."""
    return {st.name: st for st in _STATS.values()}


def reset_stats() -> None:
    for st in _STATS.values():
        st.hits = 0
        st.misses = 0


def stats_report(only_active: bool = True) -> str:
    """One-line ``name=hits/lookups(rate)`` summary, for benchmark output."""
    parts = []
    for st in sorted(_STATS.values(), key=lambda s: s.name):
        if only_active and not st.lookups:
            continue
        parts.append(f"{st.name}={st.hits}/{st.lookups}"
                     f"({100.0 * st.hit_rate:.0f}%)")
    return " ".join(parts) if parts else "no-cache-activity"


@contextlib.contextmanager
def disabled() -> Iterator[None]:
    """Temporarily bypass every registered cache (they keep their entries)."""
    global _enabled
    prev = _enabled
    _enabled = False
    try:
        yield
    finally:
        _enabled = prev


def get_or(cache: dict, key: Any, compute: Callable[[], Any]) -> Any:
    """``cache[key]`` or ``compute()`` (stored), honoring the global switch.

    ``key`` may be None (caller found its inputs unhashable) — then this is
    a plain ``compute()``.
    """
    if key is None or not _enabled:
        return compute()
    try:
        hit = cache.get(key, _MISS)
    except TypeError:           # unhashable component slipped into the key
        return compute()
    if hit is _MISS:
        note(cache, False)
        hit = compute()
        cache[key] = hit
    else:
        note(cache, True)
    return hit
