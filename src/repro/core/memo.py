"""Central memoization registry for the search/evaluation hot path.

The co-search re-derives a lot of identical intermediate state: the same
(format, tensor) pair is compiled once per op per pattern pair, the same
mapping space is enumerated once per pattern pair, and identical layers are
re-searched across pairs and models.  Modules register their caches here so
they can be cleared (tests, benchmarks) or bypassed (cache-correctness
checks, seed-path timing) in one place.

Cache keys are **value-based** (frozen-dataclass fields, dict items tuples)
rather than object identities, so equal inputs hit regardless of where they
were constructed.  Keys used across the codebase:

  * ``compile_format`` / ``analyze``: (format levels+name, dims items,
    sparsity model, value_bits);
  * ``enumerate_mappings``:   ((M, N, K), value_bits, arch, ratio_i,
    ratio_w, spatial_top, orders);
  * ``factorizations``:       (extent, parts);
  * ``reference_allocation``: (bare pattern levels, spec key) — seeded by
    ``generate_candidates`` as a by-product of its batched scan;
  * ``_search_op``:           (op shape+sparsity+count, arch, candidate
    pair, CoSearchConfig);
  * ``generate_candidates``:  (spec key, EngineConfig, penalize);
  * ``mapping_ctx``:          tagged entries over (op shape, arch, exact
    (ratio_i, ratio_w) tuple, spatial_top): ``("table", base)`` holds the
    cf_o-independent packed mapping table, ``("ctx", base, cf_o value
    key)`` the mapping-only half of the evaluator formulas — shared
    across pattern pairs whose reference ratios coincide;
  * ``fetch_table``:          ("ft", side, mapping_ctx base, population
    cf_keys) — per-(mapping table, format population) fetch matrices,
    shared across pattern pairs whose side populations coincide.

Unhashable inputs (e.g. a custom ``Sparsity`` subclass) silently skip the
cache — correctness never depends on a hit.

Every registered cache carries hit/miss counters (:func:`stats`,
:func:`stats_report`); lookups made while caching is disabled, or with a
``None`` key, are not counted.  Counters survive :func:`clear` (so a
cold-cache benchmark still reports its warm-up misses) and are zeroed with
:func:`reset_stats`.

:func:`export_state` / :func:`import_state` snapshot the registry as a
plain ``{cache name: entries}`` dict for shipping to worker processes
(:func:`repro.core.cosearch.cosearch_multi` with ``executor="process"``):
keys and values are value-based, so a warmed child resolves the same
lookups the parent already paid for.  The reverse direction is
:func:`key_snapshot` + :func:`export_delta`: a worker records which keys
it started with and ships back only the entries IT computed, so the
parent's caches absorb every worker's work (later searches over shared op
shapes replay instead of recomputing).

:func:`save` / :func:`load` make snapshots DURABLE: pickled to disk with a
format version + :func:`code_fingerprint` key, so a later process warms up
from a previous run's work — and silently ignores snapshots written by
different code (``benchmarks/run.py --memo PATH`` wires this up).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Callable, Iterator, Optional, Sequence

_REGISTRY: list[dict] = []
_enabled: bool = True
_MISS = object()                # distinguishes a cached None from a miss
_STATS_LOCK = threading.Lock()  # counters stay exact under cosearch_multi's
#                                 thread-sharded work-list


@dataclasses.dataclass
class CacheStats:
    """Hit/miss counters for one registered cache."""

    name: str
    hits: int = 0
    misses: int = 0

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0


_STATS: dict[int, CacheStats] = {}      # id(cache) -> counters


def register(cache: dict, name: Optional[str] = None) -> dict:
    """Register a module-level cache dict for global clear/disable."""
    _REGISTRY.append(cache)
    _STATS[id(cache)] = CacheStats(name or f"cache{len(_REGISTRY)}")
    return cache


def enabled() -> bool:
    return _enabled


def set_enabled(on: bool) -> None:
    global _enabled
    _enabled = on


def clear(names: Optional[Sequence[str]] = None) -> None:
    """Empty registered caches (all of them, or just the named ones).

    Selective clearing lets benchmarks cool exactly the plane under test
    (e.g. ``clear(names=["search_op", "mapping_ctx"])``) while the shared
    compile/enumeration caches stay warm for both compared paths.  Unknown
    names raise — a typo'd name silently left warm would turn a cold-cache
    measurement into a warm-vs-warm one."""
    if names is not None:
        unknown = set(names) - {st.name for st in _STATS.values()}
        if unknown:
            raise KeyError(f"unregistered cache name(s): {sorted(unknown)}")
    for c in _REGISTRY:
        if names is not None and _STATS[id(c)].name not in names:
            continue
        c.clear()


def note(cache: dict, hit: bool) -> None:
    """Record a hit/miss for a registered cache that is probed manually
    (without :func:`get_or`)."""
    st = _STATS.get(id(cache))
    if st is not None:
        with _STATS_LOCK:
            if hit:
                st.hits += 1
            else:
                st.misses += 1


def stats() -> dict[str, CacheStats]:
    """Per-cache counters, keyed by the name given at :func:`register`."""
    return {st.name: st for st in _STATS.values()}


def reset_stats() -> None:
    for st in _STATS.values():
        st.hits = 0
        st.misses = 0


def stats_report(only_active: bool = True) -> str:
    """One-line ``name=hits/lookups(rate)`` summary, for benchmark output."""
    parts = []
    for st in sorted(_STATS.values(), key=lambda s: s.name):
        if only_active and not st.lookups:
            continue
        parts.append(f"{st.name}={st.hits}/{st.lookups}"
                     f"({100.0 * st.hit_rate:.0f}%)")
    return " ".join(parts) if parts else "no-cache-activity"


def export_state(names: Optional[Sequence[str]] = None,
                 picklable_only: bool = True) -> dict[str, dict]:
    """Snapshot registered caches as ``{name: {key: value}}``.

    ``names`` restricts the snapshot to specific caches; by default every
    registered cache is included.  With ``picklable_only`` (the default —
    required when the snapshot crosses a process boundary), entries whose
    (key, value) cannot be pickled are silently dropped: correctness never
    depends on a cache hit, so a dropped entry just recomputes in the
    importer."""
    out: dict[str, dict] = {}
    for cache in _REGISTRY:
        name = _STATS[id(cache)].name
        if names is not None and name not in names:
            continue
        entries = dict(cache)
        if picklable_only:
            entries = _picklable_entries(entries)
        out[name] = entries
    return out


def _picklable_entries(entries: dict) -> dict:
    import pickle
    try:
        pickle.dumps(entries)             # common case: one pass, all good
        return entries
    except Exception:
        kept = {}
        for k, v in entries.items():
            try:
                pickle.dumps((k, v))
            except Exception:
                continue
            kept[k] = v
        return kept


def key_snapshot(names: Optional[Sequence[str]] = None) -> dict[str, set]:
    """Current key sets of the (named) registered caches — the baseline a
    later :func:`export_delta` diffs against."""
    out: dict[str, set] = {}
    for cache in _REGISTRY:
        name = _STATS[id(cache)].name
        if names is not None and name not in names:
            continue
        out[name] = set(cache.keys())
    return out


def export_delta(baseline: dict[str, set],
                 names: Optional[Sequence[str]] = None,
                 picklable_only: bool = True) -> dict[str, dict]:
    """:func:`export_state` restricted to entries whose keys are NOT in
    ``baseline`` (a :func:`key_snapshot`) — what THIS process computed since
    the snapshot.  Process workers ship these back so the parent's
    :func:`import_state` absorbs their work; caches named in ``baseline``
    but absent from ``names`` (or vice versa) are simply skipped."""
    out: dict[str, dict] = {}
    for cache in _REGISTRY:
        name = _STATS[id(cache)].name
        if names is not None and name not in names:
            continue
        if name not in baseline:
            continue
        seen = baseline[name]
        entries = {k: v for k, v in cache.items() if k not in seen}
        if picklable_only:
            entries = _picklable_entries(entries)
        if entries:
            out[name] = entries
    return out


def import_state(state: dict[str, dict]) -> None:
    """Merge an :func:`export_state` snapshot into the registered caches.

    Matching is by cache name; snapshot entries win over nothing (existing
    entries are kept — equal keys map to equal values, both sides being
    pure functions of the key).  Unknown names are ignored, so a snapshot
    from a process with extra registrations imports cleanly."""
    by_name = {_STATS[id(c)].name: c for c in _REGISTRY}
    for name, entries in state.items():
        cache = by_name.get(name)
        if cache is not None:
            for k, v in entries.items():
                cache.setdefault(k, v)


# ---------------------------------------------------------------------------
# Durable snapshots (the persistent memo store)
# ---------------------------------------------------------------------------

_SNAPSHOT_VERSION = 1
_FINGERPRINT: Optional[str] = None


def code_fingerprint() -> str:
    """SHA-256 over the ``repro`` package's source files.

    Cache values are pure functions of their keys ONLY while the code that
    computes them is unchanged — a durable snapshot keyed on this hash can
    never replay entries produced by different formulas."""
    global _FINGERPRINT
    if _FINGERPRINT is None:
        import hashlib
        import os
        pkg = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        h = hashlib.sha256()
        for root, dirs, files in sorted(os.walk(pkg)):
            dirs.sort()
            for f in sorted(files):
                if not f.endswith(".py"):
                    continue
                path = os.path.join(root, f)
                h.update(os.path.relpath(path, pkg).encode())
                with open(path, "rb") as fh:
                    h.update(fh.read())
        _FINGERPRINT = h.hexdigest()
    return _FINGERPRINT


def save(path: str, names: Optional[Sequence[str]] = None) -> int:
    """Write a durable snapshot of the (named) registered caches to
    ``path``; returns the number of entries written.

    The snapshot is pickled with a format version and the current
    :func:`code_fingerprint`, so :func:`load` can reject snapshots from a
    different code state instead of replaying stale values."""
    import pickle
    state = export_state(names)
    with open(path, "wb") as f:
        pickle.dump({"version": _SNAPSHOT_VERSION,
                     "fingerprint": code_fingerprint(),
                     "state": state}, f)
    return sum(len(v) for v in state.values())


def load(path: str) -> bool:
    """Merge a :func:`save` snapshot from ``path`` into the registry.

    Returns True when the snapshot was imported.  A missing, unreadable,
    or STALE snapshot (version or code-fingerprint mismatch) returns False
    without touching the caches — persistence is an optimization, never a
    correctness dependency, so staleness is ignored, not crashed on."""
    import pickle
    try:
        with open(path, "rb") as f:
            snap = pickle.load(f)
        if (snap.get("version") != _SNAPSHOT_VERSION
                or snap.get("fingerprint") != code_fingerprint()):
            return False
        state = snap["state"]
    except Exception:
        return False
    import_state(state)
    return True


@contextlib.contextmanager
def disabled() -> Iterator[None]:
    """Temporarily bypass every registered cache (they keep their entries)."""
    global _enabled
    prev = _enabled
    _enabled = False
    try:
        yield
    finally:
        _enabled = prev


def get_or(cache: dict, key: Any, compute: Callable[[], Any]) -> Any:
    """``cache[key]`` or ``compute()`` (stored), honoring the global switch.

    ``key`` may be None (caller found its inputs unhashable) — then this is
    a plain ``compute()``.
    """
    if key is None or not _enabled:
        return compute()
    try:
        hit = cache.get(key, _MISS)
    except TypeError:           # unhashable component slipped into the key
        return compute()
    if hit is _MISS:
        note(cache, False)
        hit = compute()
        cache[key] = hit
    else:
        note(cache, True)
    return hit
