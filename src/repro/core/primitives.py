"""Compression primitives — the atoms of SnipSnap's hierarchical format encoding.

Paper §III-B, Fig. 4(a). A *primitive* is a basic compression operation applied
at one level of a fiber-tree view of a tensor:

  RLE  — run-length encoding: number of zeros between adjacent non-zeros.
  CP   — coordinate payload: coordinates of non-zero positions.
  B    — bitmap: one bit per position marking zero/non-zero.
  UOP  — uncompressed offset pairs: group-wise first-non-zero offsets ending
         with the total count (CSR-style row-pointer array).
  NONE — level kept uncompressed / flattened (dense positions).
  CUSTOM — user-supplied metadata-bit model.

Each primitive defines how many METADATA bits it stores at its level, given
(a) the number of *stored parents* (units whose children this level describes),
(b) the level's fan-out ``s`` (positions per parent), and
(c) occupancy statistics supplied by the Sparsity Analyzer.

Semantics shared by all compressed primitives: metadata is materialized only
under parents that are actually stored, and only non-empty children are
recursed into / stored below.  This is what makes hierarchical formats win
(Fig. 5): an all-zero group of 6 elements costs 1 top-level bit, not 6.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Callable, Optional


def clog2(x: float) -> int:
    """ceil(log2(x)) with a floor of 1 bit (a field narrower than 1 bit
    does not exist in hardware)."""
    return max(1, math.ceil(math.log2(max(2.0, float(x)))))


class Prim(enum.Enum):
    RLE = "RLE"
    CP = "CP"
    B = "B"
    UOP = "UOP"
    NONE = "None"
    CUSTOM = "Custom"

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return self.value


@dataclasses.dataclass(frozen=True)
class LevelStats:
    """Occupancy statistics for one format level, from the Sparsity Analyzer.

    stored_parents : expected number of parent units whose children this level
                     describes (>= number of *non-empty* parents; equal to it
                     unless an outer ``None`` level forced dense storage).
    fanout         : s — positions per parent at this level.
    nonempty_positions : expected number of non-empty positions at this level
                     (across all parents).
    child_nnz      : expected number of non-zero *elements* under ONE parent
                     (used to size UOP offset fields).
    """

    stored_parents: float
    fanout: int
    nonempty_positions: float
    child_nnz: float


# ---------------------------------------------------------------------------
# Metadata-bit models, one per primitive.
# ---------------------------------------------------------------------------

def _bits_b(st: LevelStats) -> float:
    # One bit per position, for every stored parent.
    return st.stored_parents * st.fanout


def _bits_cp(st: LevelStats) -> float:
    # One coordinate per non-empty position; field addresses the fan-out.
    return st.nonempty_positions * clog2(st.fanout)


def _bits_rle(st: LevelStats) -> float:
    # One run-length per non-empty position.  Field must be able to express a
    # run spanning the whole fan-out (escape codes ignored — expectation
    # model; same simplification as Sparseloop's RLE model).
    return st.nonempty_positions * clog2(st.fanout + 1)


def _bits_uop(st: LevelStats) -> float:
    # Per stored parent: s offsets + a terminating total count, each wide
    # enough to index the parent's non-zero payload (CSR row pointers).
    field = clog2(st.child_nnz + 1.0)
    return st.stored_parents * (st.fanout + 1) * field


def _bits_none(st: LevelStats) -> float:
    return 0.0


_BIT_MODELS: dict[Prim, Callable[[LevelStats], float]] = {
    Prim.B: _bits_b,
    Prim.CP: _bits_cp,
    Prim.RLE: _bits_rle,
    Prim.UOP: _bits_uop,
    Prim.NONE: _bits_none,
}


def metadata_bits(prim: Prim, stats: LevelStats,
                  custom_model: Optional[Callable[[LevelStats], float]] = None
                  ) -> float:
    """Expected metadata bits stored by ``prim`` at a level with ``stats``."""
    if prim is Prim.CUSTOM:
        if custom_model is None:
            raise ValueError("Custom primitive requires a custom bit model")
        return custom_model(stats)
    return _BIT_MODELS[prim](stats)


def keeps_only_nonempty(prim: Prim) -> bool:
    """Whether the primitive prunes empty children from storage below it.

    All compressed primitives do; ``None`` keeps every child (dense level).
    """
    return prim is not Prim.NONE


# Decompression/complexity weight per primitive, used by the cost model to
# charge metadata-processing energy.  Relative magnitudes follow the paper's
# qualitative ranking (B cheapest to decode; UOP/CSR-style pointer chasing and
# RLE prefix-sums cost more).  Units: decode ops per metadata bit.
DECODE_COST: dict[Prim, float] = {
    Prim.B: 1.0,
    Prim.CP: 1.5,
    Prim.RLE: 2.0,
    Prim.UOP: 1.5,
    Prim.NONE: 0.0,
    Prim.CUSTOM: 2.0,
}
