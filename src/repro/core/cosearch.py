"""Progressive co-search workflow (paper §III-D, Fig. 7 right).

Interleaves dataflow and compression-format exploration in a single forward
pass, with no post-hoc correction loops:

  1. the Sparsity Analyzer models the computation-reduction strategy UPFRONT
     (effective MAC/cycle fractions shrink temporal bounds before any
     dataflow is generated);
  2. compression patterns are generated (adaptive engine, penalty-pruned);
  3. per pattern, loop ordering/tiling candidates are enumerated with
     COMPRESSION-AWARE legality (compressed tile sizes → more tilings legal,
     none invalidated later);
  4. the dimension allocation is derived from each candidate mapping
     (efficiency-oriented allocating), and the evaluator scores the joint
     (format, mapping) point.

One compression pattern is selected per operand role for the whole workload
(hardware ships a single format decoder); dimension allocations follow each
operator's own tiling.

Hot-loop structure: per (op, pattern pair), the mapping space comes from the
memoized :func:`repro.core.dataflow.mappings_for`, mapping-derived
allocations are deduplicated per (tile, spatial) factor tuple (loop order
does not enter the allocation) and derived for all tuples in one
:func:`repro.core.engine.allocate_for_mappings` call, and the whole
candidate set scores through the gather evaluator
(:func:`repro.core.costmodel.evaluate_batch_gather`): the op's mapping set
packs once, each side's UNIQUE derived/reference formats build one
:func:`repro.core.costmodel.format_fetch_table`, candidate rows are
(mapping, format row) index triples, and the mapping-only formula half
(:func:`repro.core.costmodel.mapping_ctx`) is memoized by (op shape, arch,
exact ratio tuple, cf_o) so pattern pairs with coinciding reference ratios
share one context.  ``use_gather=False`` keeps the PR-3 per-row
:func:`repro.core.costmodel.evaluate_batch` repack as a benchmark
reference, ``use_batch=False`` the seed scalar loop — all three
bit-identical.  Whole `_search_op` results are memoized by (op
shape+sparsity+count, arch, candidate pair, config) so identical layers
are searched once across pairs and models; see :mod:`repro.core.memo` for
the cache registry and key conventions.  :func:`cosearch_multi` flattens
(pair, model) items into a work-list that can shard across threads or
processes (``workers=``, ``executor=``) with a deterministic merge;
process workers ship their `_search_op`/compile/`mapping_ctx` cache deltas
back for the parent to :func:`repro.core.memo.import_state`, so later
searches over shared op shapes replay instead of recomputing.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Optional, Sequence

import numpy as np

from repro.core import memo
from repro.core.arch import HardwareConfig
from repro.core.costmodel import (CompiledFormat, CostReport, cf_key,
                                  compile_format, dense_format, evaluate,
                                  evaluate_batch, evaluate_batch_gather,
                                  format_fetch_table, format_key, mapping_ctx,
                                  memory_energy, pack_mappings)
from repro.core.dataflow import Mapping, mappings_for
from repro.core.engine import (Candidate, EngineConfig, SearchStats,
                               allocate_for_mapping, allocate_for_mappings,
                               generate_candidates, reference_allocation)
from repro.core.formats import Format, Level, standard_formats
from repro.core.primitives import Prim
from repro.core.sparsity import TensorSpec
from repro.core.workload import MatMul, Workload


class SearchError(RuntimeError):
    """The search space contains no legal design.

    Raised instead of silently asserting: carries the operator name and the
    (pattern_i, pattern_w) pair that last failed to produce a legal
    (mapping, allocation), so callers can tell WHICH op/format combination
    exhausted the space (typically: no mapping fits the GLB under the
    compression ratios, or the pattern cannot be allocated on the op's
    dims)."""

    def __init__(self, message: str, op: Optional[str] = None,
                 pair: Optional[tuple] = None):
        super().__init__(message)
        self.op = op
        self.pair = pair


@dataclasses.dataclass(frozen=True)
class CoSearchConfig:
    objective: str = "edp"             # "energy" | "latency" | "edp"
    engine: EngineConfig = EngineConfig()
    spatial_top: int = 3
    max_pairs: int = 12                # (fmt_i, fmt_w) combos evaluated
    compress_threshold: float = 0.999  # only compress operands sparser than this
    use_batch: bool = True             # vectorized evaluator (False = the
    #                                    legacy scalar loop, for benchmarks)
    use_gather: bool = True            # score through evaluate_batch_gather
    #                                    over per-op fetch tables (False =
    #                                    the PR-3 per-row evaluate_batch
    #                                    repack, kept as a benchmark
    #                                    reference; bit-identical)
    eval_threads: Optional[int] = None  # _evaluate_terms tail chunking:
    #                                     None = auto, 1 = serial; any
    #                                     value is bit-identical (the tail
    #                                     is elementwise per row)
    op_workers: Optional[int] = None    # thread the per-op _search_op loop
    #                                     inside each pattern pair (ops are
    #                                     independent given the registry);
    #                                     None/1 = serial.  Results AND
    #                                     SearchStats are identical for any
    #                                     setting (deterministic replay
    #                                     merge in op order)


@dataclasses.dataclass
class OpDesign:
    op: MatMul
    mapping: Mapping
    fmt_i: Optional[Format]
    fmt_w: Optional[Format]
    cost: CostReport


@dataclasses.dataclass
class DesignPoint:
    ops: list[OpDesign]
    pattern_i: Optional[tuple]
    pattern_w: Optional[tuple]

    @property
    def energy(self) -> float:
        return sum(o.cost.energy for o in self.ops)

    @property
    def cycles(self) -> float:
        return sum(o.cost.cycles for o in self.ops)

    @property
    def edp(self) -> float:
        return self.energy * self.cycles

    @property
    def memory_energy(self) -> float:
        return sum(memory_energy(o.cost) for o in self.ops)

    def metric(self, objective: str) -> float:
        return {"energy": self.energy, "latency": self.cycles,
                "edp": self.edp}[objective]


@dataclasses.dataclass
class SearchResult:
    design: DesignPoint
    evaluations: int
    runtime_s: float
    stats: SearchStats


# ---------------------------------------------------------------------------

def _representative_spec(workload: Workload, role: str) -> TensorSpec:
    """The largest sparse tensor of the role drives pattern generation."""
    best, best_sz = None, -1.0
    for op in workload.ops:
        dims = op.i_dims() if role == "I" else op.w_dims()
        sp = op.sp_i if role == "I" else op.sp_w
        sz = float(op.M) * op.N if role == "I" else float(op.N) * op.K
        if sp.density < 1.0 and sz > best_sz:
            best, best_sz = TensorSpec(dims, sp, op.value_bits), sz
    if best is None:
        # dense role — no compression candidates
        op = workload.ops[0]
        dims = op.i_dims() if role == "I" else op.w_dims()
        sp = op.sp_i if role == "I" else op.sp_w
        best = TensorSpec(dims, sp, op.value_bits)
    return best


def _role_candidates(workload: Workload, role: str, cfg: CoSearchConfig,
                     stats: SearchStats) -> list[Optional[Candidate]]:
    spec = _representative_spec(workload, role)
    if spec.sparsity.density > cfg.compress_threshold:
        return [None]                   # dense operand: store uncompressed
    cands = generate_candidates(spec, cfg.engine, stats=stats,
                                use_batch=cfg.use_batch)
    side = max(2, int(math.isqrt(cfg.max_pairs)) + 1)
    return list(cands[:side]) + [None]


def _bare_and_leaf(cand: Candidate
                   ) -> tuple[tuple[Level, ...], dict[str, int]]:
    """Strip sizes & dense head from a candidate's reference format; keep
    dense-leaf block factors (relative block shape travels with the
    pattern)."""
    bare = tuple(Level(l.prim, l.dim, None) for l in cand.fmt.levels
                 if l.prim is not Prim.NONE)
    pattern_dims_set = {l.dim for l in bare}
    leaf = {l.dim: int(l.size) for l in cand.fmt.levels
            if l.prim is Prim.NONE and l.dim in pattern_dims_set
            and l.size is not None}
    return bare, leaf


def _op_format(cand: Optional[Candidate], pattern_dims: dict[str, int],
               mapping: Mapping, spec: TensorSpec) -> Optional[CompiledFormat]:
    """Instantiate the candidate pattern on one op via mapping-derived
    allocation (efficiency-oriented allocating); standard named formats are
    instantiated directly (their layout IS their identity)."""
    if cand is None:
        return None
    if cand.fmt.name in ("Bitmap", "RLE", "CSR", "CSC", "COO"):
        return compile_format(standard_formats(spec.dims)[cand.fmt.name], spec)
    bare, leaf = _bare_and_leaf(cand)
    fmt = allocate_for_mapping(bare, spec.dims, spec.dims, mapping, leaf=leaf)
    if fmt is None:
        return None
    return compile_format(fmt, spec)


def _reference_cf(cand: Optional[Candidate], spec: TensorSpec
                  ) -> Optional[CompiledFormat]:
    """Best SIZE-optimal allocation of the candidate's pattern on this op's
    dims (the engine's reference view, independent of the mapping).

    The allocation scan lives in :func:`repro.core.engine.
    reference_allocation`, whose cache :func:`~repro.core.engine.
    generate_candidates` seeds as a by-product of candidate generation — on
    the representative spec the reference is a dict hit, not a second scan;
    only ops whose dims/sparsity differ fall through to one vectorized
    pass.  The compile itself is memoized by (format, spec)."""
    if cand is None:
        return None
    if cand.fmt.name in ("Bitmap", "RLE", "CSR", "CSC", "COO"):
        return compile_format(standard_formats(spec.dims)[cand.fmt.name], spec)
    bare, _ = _bare_and_leaf(cand)
    fmt = reference_allocation(bare, spec)
    return compile_format(fmt, spec) if fmt is not None else None


def output_cf(cand_i: Optional[Candidate], op: MatMul
              ) -> Optional[CompiledFormat]:
    """Output-activation writeback format: the I-side (activation) decoder
    re-used on O's dims (positional rename N→K) — O is the next operator's
    sparse input and leaves the chip compressed (SCNN-style)."""
    if cand_i is None or op.sp_o.density >= 0.999:
        return None
    spec_o = TensorSpec(op.o_dims(), op.sp_o, op.value_bits)
    if cand_i.fmt.name in ("Bitmap", "RLE", "CSR", "CSC", "COO"):
        return compile_format(standard_formats(spec_o.dims)[cand_i.fmt.name],
                              spec_o)
    rename = {"N": "K"}
    bare = tuple(Level(l.prim, rename.get(l.dim, l.dim), None)
                 for l in cand_i.fmt.levels if l.prim is not Prim.NONE)
    renamed = Candidate(Format(bare), cand_i.report, cand_i.eq_data)
    return _reference_cf(renamed, spec_o)


_SEARCH_OP_CACHE: dict = memo.register({}, "search_op")


def _search_op_key(op: MatMul, arch: HardwareConfig,
                   cand_i: Optional[Candidate], cand_w: Optional[Candidate],
                   cfg: CoSearchConfig) -> Optional[tuple]:
    """Cache key for a whole per-op search: the op's SHAPE + sparsity +
    repeat count (its name does not enter any formula), the architecture,
    the exact candidate pair, and the search config.  ``eval_threads`` and
    ``op_workers`` are normalized out of the key — they are perf-only knobs
    whose every setting is bit-identical by contract, so thread settings
    share one cache."""
    key = ((op.M, op.N, op.K, op.sp_i, op.sp_w, op.sp_o, op.count,
            op.value_bits), arch, cand_i, cand_w,
           dataclasses.replace(cfg, eval_threads=None, op_workers=None))
    try:
        hash(key)
    except TypeError:           # unhashable sparsity model / custom config
        return None
    return key


def _search_op(op: MatMul, arch: HardwareConfig,
               cand_i: Optional[Candidate], cand_w: Optional[Candidate],
               cfg: CoSearchConfig) -> tuple[Optional[OpDesign], int, bool]:
    """Best (mapping, allocation) for one op under a fixed pattern pair.

    Two allocations compete per mapping: the mapping-DERIVED one
    (efficiency-oriented allocating — perfectly aligned, possibly larger)
    and the SIZE-optimal reference (smaller, alignment-penalized by the
    cost model).  The evaluator arbitrates, which is exactly the paper's
    co-design argument made operational.

    Returns ``(design, evaluations, cache_hit)`` — ``evaluations`` replays
    the recorded count on a hit (warm and cold runs stay bit-identical);
    the flag lets callers track how much work was FRESH
    (``SearchStats.fresh_evaluations``)."""
    key = _search_op_key(op, arch, cand_i, cand_w, cfg)
    if memo.enabled() and key is not None:
        hit = _SEARCH_OP_CACHE.get(key)
        memo.note(_SEARCH_OP_CACHE, hit is not None)
        if hit is not None:
            od, evals = hit
            # the cached design came from an identically-shaped op; rebind
            # the identity (name) of THIS op
            return (dataclasses.replace(od, op=op) if od is not None
                    else None, evals, True)
    od, evals = _search_op_impl(op, arch, cand_i, cand_w, cfg)
    if memo.enabled() and key is not None:
        _SEARCH_OP_CACHE[key] = (od, evals)
    return od, evals, False


def _search_ops(ops: Sequence[MatMul], arch: HardwareConfig,
                cand_i: Optional[Candidate], cand_w: Optional[Candidate],
                cfg: CoSearchConfig
                ) -> tuple[list[OpDesign], int, int, Optional[str]]:
    """Search every op of a workload under one fixed pattern pair.

    Returns ``(designs, evaluations, fresh evaluations, failed op name or
    None)`` — the shared inner loop of :func:`cosearch` and
    :func:`_multi_work_item`.

    With ``cfg.op_workers`` > 1 the per-op searches run on a thread pool.
    Ops are independent given the candidate pair, so only the MERGE order
    matters: one pool task is submitted per unique :func:`_search_op_key`
    (duplicate-shape ops would otherwise race to compute the same entry;
    unkeyable ops each get their own task), then results are replayed IN OP
    ORDER — the first op of each key takes its task's (design, evals, hit)
    verbatim, later same-key ops re-probe :func:`_search_op` (a guaranteed
    cache hit that also rebinds the design to that op's name), and counting
    stops at the first failed op exactly where the serial loop breaks.
    Designs, evaluation counts, AND memo hit/miss counters are therefore
    bit-identical to the serial path for any worker count."""
    workers = cfg.op_workers
    if not workers or workers <= 1 or len(ops) < 2:
        designs: list[OpDesign] = []
        evals = fresh = 0
        for op in ops:
            od, e, hit = _search_op(op, arch, cand_i, cand_w, cfg)
            evals += e
            if not hit:
                fresh += e
            if od is None:
                return designs, evals, fresh, op.name
            designs.append(od)
        return designs, evals, fresh, None

    from concurrent.futures import ThreadPoolExecutor
    tasks: list[MatMul] = []            # one representative op per task
    task_of_op: list[tuple[int, bool]] = []     # (task index, first-of-key)
    if memo.enabled():
        owner: dict = {}                # cache key -> task index
        for op in ops:
            key = _search_op_key(op, arch, cand_i, cand_w, cfg)
            if key is not None and key in owner:
                task_of_op.append((owner[key], False))
                continue
            idx = len(tasks)
            tasks.append(op)
            if key is not None:
                owner[key] = idx
            task_of_op.append((idx, True))
    else:
        # no cache to dedup through: every op computes independently
        for i, op in enumerate(ops):
            tasks.append(op)
            task_of_op.append((i, True))
    with ThreadPoolExecutor(max_workers=workers) as ex:
        futs = [ex.submit(_search_op, op, arch, cand_i, cand_w, cfg)
                for op in tasks]
        results = [f.result() for f in futs]
    designs = []
    evals = fresh = 0
    for op, (idx, first) in zip(ops, task_of_op):
        if first:
            od, e, hit = results[idx]
        else:
            od, e, hit = _search_op(op, arch, cand_i, cand_w, cfg)
        evals += e
        if not hit:
            fresh += e
        if od is None:
            return designs, evals, fresh, op.name
        designs.append(od)
    return designs, evals, fresh, None


def _derived_side(cand: Optional[Candidate], spec: TensorSpec,
                  rep_mappings: Sequence[Mapping], fixed: bool,
                  ref: CompiledFormat) -> list[CompiledFormat]:
    """Mapping-derived allocations for one operand side, one compile per
    representative mapping (falling back to the reference allocation where
    the derivation fails) — the batched equivalent of per-mapping
    :func:`_op_format` calls."""
    if fixed or cand is None:
        return [ref] * len(rep_mappings)
    bare, leaf = _bare_and_leaf(cand)
    fmts = allocate_for_mappings(bare, spec.dims, spec.dims, rep_mappings,
                                 leaf=leaf)
    return [compile_format(f, spec) if f is not None else ref for f in fmts]


def _factor_key(mapping: Mapping) -> tuple:
    """Dedup key of the mapping-derived allocation: the (tile, spatial)
    factor tuples — the loop order never enters the derivation.  Shared by
    every plane of :func:`_search_op_impl`, whose bit-identity contract
    depends on all of them deduplicating identically."""
    return (tuple(mapping.tile.items()), tuple(mapping.spatial.items()))


_MAPCTX_CACHE: dict = memo.register({}, "mapping_ctx")


def _mapping_ctx_for(op: MatMul, arch: HardwareConfig, ratio_i: float,
                     ratio_w: float, spatial_top: int,
                     cf_o: Optional[CompiledFormat],
                     mappings: Sequence[Mapping]):
    """Packed mapping table + mapping-only evaluator context for one op's
    mapping set, memoized by (op shape, arch, exact ratio tuple,
    spatial_top, cf_o value key).

    :func:`repro.core.dataflow.mappings_for` is deterministic in exactly
    those inputs (names, repeat counts and sparsity models beyond the
    densities/probabilities the context reads do not enter), so pattern
    pairs whose reference ratios coincide — e.g. every metadata-heavy side
    whose ratio clips to 1.0, and identically-shaped layers across models —
    share one context instead of re-deriving it per pair.  The packed
    table is cf_o-independent, so it caches under its own (tagged) key:
    pairs differing only in output format share the table and only
    re-derive the context half."""
    base = ((op.M, op.N, op.K, op.value_bits, op.sp_i, op.sp_w), arch,
            (ratio_i, ratio_w), spatial_top)
    try:
        hash((base, cf_key(cf_o)))
        t_key = ("table", base)
        c_key = ("ctx", base, cf_key(cf_o))
    except TypeError:           # unhashable sparsity model
        base = None
        t_key = c_key = None
    table = memo.get_or(_MAPCTX_CACHE, t_key,
                        lambda: pack_mappings(mappings))
    ctx = memo.get_or(_MAPCTX_CACHE, c_key,
                      lambda: mapping_ctx(op, arch, table, cf_o))
    return table, ctx, base


_FETCH_TABLE_CACHE: dict = memo.register({}, "fetch_table")


def _fetch_table_for(base: Optional[tuple], side: str,
                     cfs: Sequence[CompiledFormat], table) -> "FormatTable":
    """Per-(mapping table, format population) fetch table, memoized.

    Keyed like ``mapping_ctx`` (``base`` identifies the op's packed table
    exactly) plus the population's compiled-format value keys — pattern
    pairs of the same op whose derived/reference populations coincide on
    one side (very common: the W-side population repeats across every
    I-side pattern it is paired with) share one table instead of
    re-running the alignment broadcast per pair."""
    key = None
    if base is not None:
        try:
            key = ("ft", side, base, tuple(cf_key(cf) for cf in cfs))
            hash(key)
        except TypeError:
            key = None
    return memo.get_or(_FETCH_TABLE_CACHE, key,
                       lambda: format_fetch_table(cfs, table))


def _side_rows(ders: Sequence[CompiledFormat], ref: CompiledFormat
               ) -> tuple[list[CompiledFormat], np.ndarray, int]:
    """Deduplicate one side's derived formats into fetch-table rows.

    Returns (unique formats, per-rep row index, reference row index) —
    dedup keys on :func:`format_key`, which is exact on one spec: equal
    keys compile to value-identical :class:`CompiledFormat`\\ s."""
    uniq: list[CompiledFormat] = []
    pos: dict[tuple, int] = {}
    idx = np.empty(len(ders), np.int64)
    for r, cf in enumerate(ders):
        k = format_key(cf.fmt)
        p = pos.get(k)
        if p is None:
            p = pos[k] = len(uniq)
            uniq.append(cf)
        idx[r] = p
    rk = format_key(ref.fmt)
    rp = pos.get(rk)
    if rp is None:
        rp = len(uniq)
        uniq.append(ref)
    return uniq, idx, rp


def _search_op_gather(op: MatMul, arch: HardwareConfig,
                      cand_i: Optional[Candidate],
                      cand_w: Optional[Candidate], cfg: CoSearchConfig,
                      spec_i: TensorSpec, spec_w: TensorSpec,
                      ref_i: CompiledFormat, ref_w: CompiledFormat,
                      cf_o: Optional[CompiledFormat], ratio_i: float,
                      ratio_w: float, fixed_i: bool, fixed_w: bool,
                      mappings: Sequence[Mapping]
                      ) -> tuple[Optional[OpDesign], int]:
    """The gather evaluator plane of :func:`_search_op_impl`: candidate
    rows are (mapping, I-format row, W-format row) index triples over the
    op's packed mapping table and per-side fetch tables built from the
    UNIQUE derived/reference formats — no per-row format repacking.  Row
    order replays the repack path exactly (per mapping: derived pair, then
    the reference pair when it differs), so designs, tie-breaks and
    ``evaluations`` are bit-identical to ``use_gather=False``."""
    n_map = len(mappings)
    if n_map == 0:
        return None, 0
    # dedupe (tile, spatial) factor tuples; rep_of maps mapping -> rep row
    reps: dict[tuple, int] = {}
    rep_of = np.empty(n_map, np.int64)
    rep_mappings: list[Mapping] = []
    for j, mapping in enumerate(mappings):
        fkey = _factor_key(mapping)
        r = reps.get(fkey)
        if r is None:
            r = reps[fkey] = len(rep_mappings)
            rep_mappings.append(mapping)
        rep_of[j] = r
    der_i = _derived_side(cand_i, spec_i, rep_mappings, fixed_i, ref_i)
    der_w = _derived_side(cand_w, spec_w, rep_mappings, fixed_w, ref_w)
    uniq_i, i_rep, ref_i_pos = _side_rows(der_i, ref_i)
    uniq_w, w_rep, ref_w_pos = _side_rows(der_w, ref_w)

    # candidate rows: per mapping the derived pair, then the reference
    # pair when it differs by format value (the repack path's dup rule)
    i_map, w_map = i_rep[rep_of], w_rep[rep_of]
    dup = (i_map != ref_i_pos) | (w_map != ref_w_pos)
    counts = 1 + dup.astype(np.int64)
    map_idx = np.repeat(np.arange(n_map), counts)
    is_ref = np.zeros(len(map_idx), bool)
    is_ref[np.cumsum(counts)[dup] - 1] = True
    i_idx = i_map[map_idx]
    w_idx = w_map[map_idx]
    i_idx[is_ref] = ref_i_pos
    w_idx[is_ref] = ref_w_pos
    evals = len(map_idx)

    table, ctx, base = _mapping_ctx_for(op, arch, ratio_i, ratio_w,
                                        cfg.spatial_top, cf_o, mappings)
    ft_i = _fetch_table_for(base, "I", uniq_i, table)
    ft_w = _fetch_table_for(base, "W", uniq_w, table)
    bc = evaluate_batch_gather(op, arch, table, ft_i, i_idx, ft_w, w_idx,
                               map_idx, cf_o, ctx=ctx,
                               eval_threads=cfg.eval_threads)
    j = int(np.argmin(bc.metric(cfg.objective)))
    cf_i = ref_i if is_ref[j] else uniq_i[int(i_idx[j])]
    cf_w = ref_w if is_ref[j] else uniq_w[int(w_idx[j])]
    best = OpDesign(op, mappings[int(map_idx[j])], cf_i.fmt, cf_w.fmt,
                    bc.report(j))
    return best, evals


def _search_op_impl(op: MatMul, arch: HardwareConfig,
                    cand_i: Optional[Candidate], cand_w: Optional[Candidate],
                    cfg: CoSearchConfig) -> tuple[Optional[OpDesign], int]:
    spec_i = TensorSpec(op.i_dims(), op.sp_i, op.value_bits)
    spec_w = TensorSpec(op.w_dims(), op.sp_w, op.value_bits)

    dense_i = dense_format(spec_i)
    dense_w = dense_format(spec_w)
    ref_i = _reference_cf(cand_i, spec_i) or dense_i
    ref_w = _reference_cf(cand_w, spec_w) or dense_w
    cf_o = output_cf(cand_i, op)
    # compression-aware legality from THIS op's reference formats
    ratio_i = min(ref_i.ratio, 1.0)
    ratio_w = min(ref_w.ratio, 1.0)
    # standard named formats have a fixed layout — the reference IS the
    # only allocation, so mapping-derived variants would be duplicates
    named = ("Bitmap", "RLE", "CSR", "CSC", "COO")
    fixed_i = cand_i is not None and cand_i.fmt.name in named
    fixed_w = cand_w is not None and cand_w.fmt.name in named

    # The mapping-derived allocation depends only on the tile/spatial
    # factors, never the loop order — derive once per factor tuple (6
    # orders share each).
    mappings = mappings_for(op, arch, ratio_i, ratio_w,
                            spatial_top=cfg.spatial_top)
    if cfg.use_batch and cfg.use_gather:
        return _search_op_gather(op, arch, cand_i, cand_w, cfg, spec_i,
                                 spec_w, ref_i, ref_w, cf_o, ratio_i,
                                 ratio_w, fixed_i, fixed_w, mappings)
    derived: dict[tuple, tuple[CompiledFormat, CompiledFormat]] = {}
    if cfg.use_batch:
        # batched: all deduped factor tuples of the op derived at once
        reps: dict[tuple, Mapping] = {}
        for mapping in mappings:
            reps.setdefault(_factor_key(mapping), mapping)
        rep_mappings = list(reps.values())
        der_i = _derived_side(cand_i, spec_i, rep_mappings, fixed_i, ref_i)
        der_w = _derived_side(cand_w, spec_w, rep_mappings, fixed_w, ref_w)
        derived = {fkey: (mi, mw)
                   for fkey, mi, mw in zip(reps, der_i, der_w)}

    cand_mappings: list[Mapping] = []
    cand_pairs: list[tuple[CompiledFormat, CompiledFormat]] = []
    for mapping in mappings:
        fkey = _factor_key(mapping)
        pair = derived.get(fkey)
        if pair is None:            # legacy scalar path (use_batch=False)
            map_i = ref_i if fixed_i else \
                (_op_format(cand_i, op.i_dims(), mapping, spec_i) or ref_i)
            map_w = ref_w if fixed_w else \
                (_op_format(cand_w, op.w_dims(), mapping, spec_w) or ref_w)
            pair = derived[fkey] = (map_i, map_w)
        map_i, map_w = pair
        cand_mappings.append(mapping)
        cand_pairs.append((map_i, map_w))
        # the reference pair competes unless the derived allocation IS the
        # reference (compare by value — caching may or may not share objects)
        if (format_key(map_i.fmt), format_key(map_w.fmt)) != \
                (format_key(ref_i.fmt), format_key(ref_w.fmt)):
            cand_mappings.append(mapping)
            cand_pairs.append((ref_i, ref_w))

    evals = len(cand_mappings)
    if not cand_mappings:
        return None, 0

    if cfg.use_batch:
        bc = evaluate_batch(op, arch, cand_mappings, cand_pairs, cf_o)
        j = int(np.argmin(bc.metric(cfg.objective)))
        cf_i, cf_w = cand_pairs[j]
        best = OpDesign(op, cand_mappings[j], cf_i.fmt, cf_w.fmt,
                        bc.report(j))
        return best, evals

    # legacy scalar loop (benchmark reference for the batch path)
    best: Optional[OpDesign] = None
    for mapping, (cf_i, cf_w) in zip(cand_mappings, cand_pairs):
        cost = evaluate(op, arch, mapping, cf_i, cf_w, cf_o)
        if best is None or cost.metric(cfg.objective) < best.cost.metric(cfg.objective):
            best = OpDesign(op, mapping, cf_i.fmt, cf_w.fmt, cost)
    return best, evals


def _dense_sentinel(cands: Sequence[Optional[Candidate]]) -> float:
    """Finite EqData stand-in for the dense (no-format) option when ranking
    pattern pairs.  ``math.inf / 4`` is still ``inf``, so dense-containing
    pair sums all collapsed to ``inf`` and their relative order was
    arbitrary; a finite sentinel above every observed EqData keeps dense
    sides ranked last PER SIDE while part-dense pairs still order by their
    compressed side's EqData."""
    observed = [c.eq_data for c in cands if c is not None]
    return 4.0 * max(observed) if observed else 1.0


def _pair_rank(pair: tuple[Optional[Candidate], Optional[Candidate]],
               sentinel: float) -> float:
    ci, cw = pair
    return ((ci.eq_data if ci is not None else sentinel) +
            (cw.eq_data if cw is not None else sentinel))


def _fixed_candidate(fmt_name: str, spec: TensorSpec) -> Optional[Candidate]:
    if fmt_name in (None, "dense", "Dense"):
        return None
    fmt = standard_formats(spec.dims)[fmt_name]
    from repro.core.sparsity import analyze
    rep = analyze(fmt, spec)
    return Candidate(fmt, rep, rep.total_bits)


def cosearch(workload: Workload, arch: HardwareConfig,
             cfg: CoSearchConfig = CoSearchConfig(),
             fixed_formats: Optional[tuple[Optional[str], Optional[str]]] = None,
             ) -> SearchResult:
    """Joint dataflow + compression-format search for one workload.

    ``fixed_formats=(name_i, name_w)`` runs the Table-I "Fixed" mode: the
    format is preset (one of Bitmap/RLE/CSR/COO or None=dense) and only the
    dataflow is searched — still with the progressive workflow's upfront
    reduction + compression-aware allocation.
    """
    t0 = time.perf_counter()
    stats = SearchStats()

    if fixed_formats is not None:
        spec_i = _representative_spec(workload, "I")
        spec_w = _representative_spec(workload, "W")
        pairs: list[tuple[Optional[Candidate], Optional[Candidate]]] = [(
            _fixed_candidate(fixed_formats[0], spec_i),
            _fixed_candidate(fixed_formats[1], spec_w),
        )]
    else:
        cands_i = _role_candidates(workload, "I", cfg, stats)
        cands_w = _role_candidates(workload, "W", cfg, stats)
        pairs = [(ci, cw) for ci in cands_i for cw in cands_w]
        # rank pairs by combined reference EqData (finite dense sentinel) and cap
        sentinel = _dense_sentinel(cands_i + cands_w)
        pairs.sort(key=lambda p: _pair_rank(p, sentinel))
        # always keep the fully-dense pair as a fallback
        dense_pair = (None, None)
        pairs = pairs[: cfg.max_pairs]
        if dense_pair not in pairs:
            pairs.append(dense_pair)

    evals = 0
    best_design: Optional[DesignPoint] = None
    last_fail: tuple[Optional[str], Optional[tuple]] = (None, None)
    for ci, cw in pairs:
        pair_key = (ci.pattern if ci else None, cw.pattern if cw else None)
        ops, e, f, fail = _search_ops(workload.ops, arch, ci, cw, cfg)
        evals += e
        stats.evaluations += e
        stats.fresh_evaluations += f
        if fail is not None:
            last_fail = (fail, pair_key)
            continue
        dp = DesignPoint(ops, *pair_key)
        if best_design is None or dp.metric(cfg.objective) < best_design.metric(cfg.objective):
            best_design = dp
    if best_design is None:
        raise SearchError(
            f"co-search produced no legal design for {workload.name!r} "
            f"(last failure: op={last_fail[0]!r} pair={last_fail[1]!r})",
            op=last_fail[0], pair=last_fail[1])
    return SearchResult(best_design, evals, time.perf_counter() - t0, stats)


# ---------------------------------------------------------------------------
# Multi-model co-search with importance scoring (§III-C3)
# ---------------------------------------------------------------------------

# Caches whose per-item deltas process workers ship back to the parent:
# the whole-op search results plus the compile/context state they rest on.
_RETURN_CACHES = ("search_op", "compile_format", "mapping_ctx")

_WORKER_BASELINE: Optional[dict] = None


def _multi_init_worker(state: dict) -> None:
    """Process-pool initializer: warm the child's memo caches from the
    parent's :func:`repro.core.memo.export_state` snapshot, so each worker
    starts with the candidate/compile/mapping state phase 1 already paid
    for instead of recomputing it per process.  A key snapshot of the
    return caches is taken here so each work item can ship back exactly
    the entries THIS worker computed (:func:`repro.core.memo.
    export_delta`)."""
    memo.import_state(state)
    global _WORKER_BASELINE
    _WORKER_BASELINE = memo.key_snapshot(_RETURN_CACHES)


def _multi_work_item(item: tuple
                     ) -> tuple[list[OpDesign], int, int, float,
                                Optional[str]]:
    """One (pattern pair, model) unit of the co-search work-list.

    Top-level and fed a picklable tuple — (pair key, candidate pair,
    workload, arch, config) are all frozen value types — so the same
    function runs on the serial path, thread pool, and process pool.
    Returns (designs, evaluations, fresh evaluations, seconds, failed op
    name)."""
    key, pair, wl, arch, cfg = item
    ci, cw = pair
    t0 = time.perf_counter()
    ops, evals, fresh, fail = _search_ops(wl.ops, arch, ci, cw, cfg)
    return ops, evals, fresh, time.perf_counter() - t0, fail


def _multi_work_item_return_state(item: tuple) -> tuple:
    """:func:`_multi_work_item` plus the worker's new return-cache entries
    since its baseline snapshot — the process path's result payload.  The
    baseline advances past each shipped delta, so every entry crosses the
    process boundary once per worker; the parent merges the deltas via
    :func:`repro.core.memo.import_state` (idempotent ``setdefault``, safe
    under overlap between workers)."""
    out = _multi_work_item(item)
    delta: dict = {}
    if _WORKER_BASELINE is not None:
        delta = memo.export_delta(_WORKER_BASELINE, _RETURN_CACHES)
        for name, entries in delta.items():
            _WORKER_BASELINE[name].update(entries)
    return out + (delta,)


def cosearch_multi(workloads: Sequence[Workload], arch: HardwareConfig,
                   importance: dict[str, float],
                   cfg: CoSearchConfig = CoSearchConfig(),
                   workers: Optional[int] = None,
                   executor: str = "thread",
                   memo_autosave: Optional[str] = None,
                   autosave_every: int = 16,
                   ) -> tuple[dict[str, SearchResult], tuple, float]:
    """Pick ONE shared format pair across models minimizing the importance-
    weighted objective.  Returns (per-model results under the winning pair,
    winning pattern pair, weighted metric).

    Runs as three phases: (1) per-model candidate generation (serial —
    memoized and cheap — with per-model ``SearchStats`` snapshots, so each
    model's result reports ITS OWN pattern/allocation counters rather than
    aliasing one shared object); (2) a flat (pair, model) work-list whose
    items are independent — ``workers`` opts into a ``concurrent.futures``
    pool; (3) a deterministic merge in work-list order, so results are
    identical for any worker count and either executor.

    ``executor`` picks the phase-2 pool: ``"thread"`` shares the
    ``_search_op`` cache in-process (the items spend much of their time in
    vectorized NumPy, which releases the GIL, but the remaining Python
    share serializes); ``"process"`` shards past the GIL — work items are
    picklable value tuples, and each worker warms its own memo registry
    from a :func:`repro.core.memo.export_state` snapshot of phase 1's
    caches, so per-process state pays off immediately.  Process workers
    also ship their new ``_search_op``/``compile_format``/``mapping_ctx``
    entries back with each item result (:func:`repro.core.memo.
    export_delta`), which the parent imports — the parent registry ends
    the run as warm as a serial run's, so later models/searches sharing
    op shapes replay instead of recomputing (pinned by the
    ``fresh_evaluations`` regression test).  Item results (designs + eval
    counts) are pure functions of the item, so the merged output is
    identical across executors and worker counts — with one diagnostic
    exception: ``SearchStats.fresh_evaluations`` reflects which items
    found a warm cache, which under a pool depends on scheduling; it is
    deterministic only on the serial path.

    ``memo_autosave`` checkpoints the long phase-2 loop: the memo registry
    snapshots to that path (:func:`repro.core.memo.save`) after every
    ``autosave_every`` completed work items and again at the end.  After a
    crash/kill, a fresh process that :func:`repro.core.memo.load`\\ s the
    snapshot and re-runs the same call replays the completed items from
    cache and recomputes only the rest — results are bit-identical to an
    uninterrupted run (the memo replays recorded designs AND eval
    counters)."""
    # -- phase 1: candidate generation, union of pattern pairs over models --
    per_model_stats: dict[str, SearchStats] = {}
    pair_keys: dict[tuple, tuple[Optional[Candidate], Optional[Candidate]]] = {}
    for wl in workloads:
        st = SearchStats()
        cands_i = _role_candidates(wl, "I", cfg, st)
        cands_w = _role_candidates(wl, "W", cfg, st)
        per_model_stats[wl.name] = st
        for ci in cands_i:
            for cw in cands_w:
                key = (ci.pattern if ci else None, cw.pattern if cw else None)
                pair_keys.setdefault(key, (ci, cw))

    sentinel = _dense_sentinel([c for pair in pair_keys.values()
                                for c in pair])
    items = sorted(pair_keys.items(),
                   key=lambda kv: _pair_rank(kv[1], sentinel))[: cfg.max_pairs]

    # -- phase 2: flat (pair, model) work-list ------------------------------
    if executor not in ("thread", "process"):
        raise ValueError(f"executor must be 'thread' or 'process', "
                         f"got {executor!r}")
    work = [(key, pair, wl) for key, pair in items for wl in workloads]
    payload = [(key, pair, wl, arch, cfg) for key, pair, wl in work]

    def autosave(done: int) -> None:
        if memo_autosave and autosave_every > 0 \
                and done % autosave_every == 0:
            memo.save(memo_autosave)

    if workers is not None and workers > 1 and executor == "process":
        from concurrent.futures import ProcessPoolExecutor
        state = memo.export_state()
        results = []
        with ProcessPoolExecutor(max_workers=workers,
                                 initializer=_multi_init_worker,
                                 initargs=(state,)) as ex:
            for out in ex.map(_multi_work_item_return_state, payload):
                # absorb the worker's _search_op/compile/mapping_ctx work
                # into the parent registry: later models/searches sharing
                # op shapes replay it instead of recomputing
                memo.import_state(out[-1])
                results.append(out[:-1])
                autosave(len(results))
    elif workers is not None and workers > 1:
        from concurrent.futures import ThreadPoolExecutor
        results = []
        with ThreadPoolExecutor(max_workers=workers) as ex:
            for out in ex.map(_multi_work_item, payload):
                results.append(out)
                autosave(len(results))
    else:
        results = []
        for item in payload:
            results.append(_multi_work_item(item))
            autosave(len(results))
    if memo_autosave:
        memo.save(memo_autosave)

    # -- phase 3: deterministic merge in work-list order --------------------
    table: dict[str, dict[tuple, float]] = {wl.name: {} for wl in workloads}
    designs: dict[tuple, dict[str, SearchResult]] = {}
    last_fail: tuple[Optional[str], Optional[tuple]] = (None, None)
    for (key, (ci, cw), wl), (ops, evals, fresh, dt, fail) in zip(work,
                                                                  results):
        designs.setdefault(key, {})
        if fail is not None:
            last_fail = (fail, key)
            continue
        dp = DesignPoint(ops, *key)
        designs[key][wl.name] = SearchResult(
            dp, evals, dt,
            dataclasses.replace(per_model_stats[wl.name],
                                evaluations=evals,
                                fresh_evaluations=fresh))
        table[wl.name][key] = dp.metric(cfg.objective)

    complete = [k for k in designs if len(designs[k]) == len(workloads)]
    best_key, best_val = None, math.inf
    for k in complete:
        val = sum(importance.get(wl.name, 1.0) * table[wl.name][k]
                  for wl in workloads)
        if val < best_val:
            best_key, best_val = k, val
    if best_key is None:
        raise SearchError(
            "multi-model co-search found no pattern pair legal for every "
            f"model (last failure: op={last_fail[0]!r} pair={last_fail[1]!r})",
            op=last_fail[0], pair=last_fail[1])
    return designs[best_key], best_key, best_val
