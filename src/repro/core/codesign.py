"""Codesign bridge — compatibility shim.

The DSE → kernel translation grew into the execution-plane subsystem at
:mod:`repro.exec.plans` (whole-model :class:`~repro.exec.plans.ExecPlan`\\ s,
JSON round-trip, structured fallbacks); this module keeps the original
import surface (``KernelChoice`` / ``CompressionPlan`` / ``ffn_workload`` /
``plan_for_model``) alive for existing callers.
"""

from __future__ import annotations

from repro.exec.plans import (MXU_ALIGN, CompressionPlan, FallbackReason,
                              KernelChoice, ffn_workload, plan_for_model,
                              translate)

# the seed's private name, kept for callers that reached into it
_translate = translate

__all__ = ["MXU_ALIGN", "CompressionPlan", "FallbackReason", "KernelChoice",
           "ffn_workload", "plan_for_model", "translate"]
