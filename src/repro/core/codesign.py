"""Codesign bridge: SnipSnap DSE decisions → executable TPU kernel configs.

This closes the loop the paper opens: the DSE picks a compression format +
dimension allocation for each sparse operator; here those choices become
Pallas kernel selections and BlockSpec tile shapes for the execution plane
(DESIGN.md §4).  Formats whose structure matches the block-bitmap kernel
(`B(N₁)-B(K₁)` with dense leaves) map to ``bitmap_spmm`` with the leaf sizes
as the block shape (MXU-aligned); 2:4-sparse operands map to ``nm_spmm``.
Everything else stays dense (and the plan says why).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs.base import ModelConfig
from repro.core.arch import TPUV5E, HardwareConfig
from repro.core.cosearch import CoSearchConfig, SearchResult, cosearch
from repro.core.engine import EngineConfig
from repro.core.formats import Format
from repro.core.primitives import Prim
from repro.core.sparsity import NM, Bernoulli, Sparsity
from repro.core.workload import MatMul, Workload

MXU_ALIGN = 128


@dataclasses.dataclass(frozen=True)
class KernelChoice:
    op_name: str
    kind: str                  # "bitmap" | "nm" | "dense"
    block_n: int = 0           # bitmap_spmm block shape (bn, bk)
    block_k: int = 0
    predicted_ratio: float = 1.0
    format_str: str = "dense"


@dataclasses.dataclass
class CompressionPlan:
    choices: dict[str, KernelChoice]
    search: SearchResult

    def for_op(self, name: str) -> KernelChoice:
        return self.choices[name]


def _align(x: int, extent: int) -> int:
    """Snap a format level size to an MXU-friendly divisor of extent."""
    for cand in (x, MXU_ALIGN, 64, 32, 16, 8):
        if cand and extent % cand == 0 and cand <= extent:
            return cand
    return extent


def ffn_workload(cfg: ModelConfig, tokens: int, w_sparsity: Sparsity,
                 act_density: float = 1.0) -> Workload:
    """The FFN matmuls of one layer of ``cfg`` as a SnipSnap workload."""
    d = cfg.d_model
    f = cfg.moe.d_expert if cfg.moe else cfg.d_ff
    act = Bernoulli(act_density)
    ops = (
        MatMul("ffn.up", tokens, d, f, act, w_sparsity, cfg.n_layers),
        MatMul("ffn.down", tokens, f, d, act, w_sparsity, cfg.n_layers),
    )
    return Workload(f"{cfg.name}.ffn", ops)


def plan_for_model(cfg: ModelConfig, w_sparsity: Sparsity,
                   tokens: int = 4096, act_density: float = 1.0,
                   hardware: HardwareConfig = TPUV5E,
                   search_cfg: Optional[CoSearchConfig] = None,
                   ) -> CompressionPlan:
    """Run the co-search on the model's FFN ops against the TPU hardware
    model and translate the winning W-side format into kernel choices."""
    wl = ffn_workload(cfg, tokens, w_sparsity, act_density)
    # Hardware-constrained format space (paper §III-A: configurations are an
    # input): the TPU execution plane implements B-over-block-grid decoding
    # (bitmap_spmm) — so the searchable primitive set is {B} with dense
    # leaves, i.e. block-sparse formats the MXU can actually run.
    scfg = search_cfg or CoSearchConfig(
        objective="energy",
        engine=EngineConfig(max_levels=2, max_allocs_per_pattern=48,
                            prims=(Prim.B,)))
    if search_cfg is not None and hardware is TPUV5E:
        scfg = dataclasses.replace(
            search_cfg,
            engine=dataclasses.replace(search_cfg.engine, prims=(Prim.B,)))
    res = cosearch(wl, hardware, scfg)

    choices: dict[str, KernelChoice] = {}
    for od in res.design.ops:
        choices[od.op.name] = _translate(od.op, od.fmt_w, w_sparsity)
    return CompressionPlan(choices, res)


def _translate(op: MatMul, fmt_w: Optional[Format],
               w_sparsity: Sparsity) -> KernelChoice:
    if isinstance(w_sparsity, NM):
        return KernelChoice(op.name, "nm",
                            predicted_ratio=w_sparsity.n / w_sparsity.m * 1.125,
                            format_str="CP(2:4)")
    if fmt_w is None:
        return KernelChoice(op.name, "dense")

    # block-bitmap realizable: compressed levels are all B, with dense-leaf
    # (None) block factors determining the executable block shape.
    comp = [l for l in fmt_w.levels if l.prim is not Prim.NONE]
    leaves = {l.dim: int(l.size) for l in fmt_w.levels
              if l.prim is Prim.NONE and l.size is not None}
    if comp and all(l.prim is Prim.B for l in comp):
        bn = _align(leaves.get("N", MXU_ALIGN), op.N)
        bk = _align(leaves.get("K", MXU_ALIGN), op.K)
        from repro.core.sparsity import TensorSpec, analyze
        spec = TensorSpec(op.w_dims(), w_sparsity)
        ratio = analyze(fmt_w, spec).total_bits / spec.dense_bits
        return KernelChoice(op.name, "bitmap", bn, bk,
                            predicted_ratio=float(ratio),
                            format_str=str(fmt_w))
    # non-bitmap winner (CSR/RLE-style): no native TPU kernel — dense
    # execution with HBM-side compression only (documented limitation).
    return KernelChoice(op.name, "dense", format_str=str(fmt_w))
