"""Cost Model (paper §III-A Evaluator, right half).

Evaluates a design candidate = (MatMul op, hardware, Mapping, compression
formats for I and W) into energy / latency / EDP, "modeling MAC operations
and memory transfers".  The Sparsity Analyzer supplies compressed sizes and
computation-reduction fractions; this module turns them into per-level
access counts and cycles.

Alignment between format and dataflow (§III-C2, efficiency-oriented
allocating) is modeled physically:
  * a level whose block extent ``b`` exceeds the tile extent ``t`` along its
    dim forces over-fetch (whole compression groups move, factor b/t);
  * a tile that straddles group boundaries re-decodes the boundary groups
    (factor ceil(t/b)/(t/b));
  * RLE has no random access — a tile fetch decodes the whole run-chain
    spanned by the sequential region (granule rule).
When the dimension allocation copies the dataflow's tiling factors, every
factor collapses to 1.0 — exactly the paper's "aligns the compression format
with the dataflow, reducing runtime overhead".
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core.arch import HardwareConfig
from repro.core.dataflow import Mapping, irrelevant_refetch
from repro.core.formats import Format
from repro.core.primitives import DECODE_COST, Prim
from repro.core.sparsity import SizeReport, TensorSpec, analyze
from repro.core.workload import MatMul


@dataclasses.dataclass(frozen=True)
class _LevelInfo:
    dim: str
    block_below: int          # elements along `dim` under one position
    meta_bits: float
    decode_ops: float


@dataclasses.dataclass(frozen=True)
class CompiledFormat:
    """(format × tensor) analysis, pre-chewed for the mapping hot loop."""

    fmt: Optional[Format]               # None = stored dense
    dense_bits: float
    payload_bits: float
    levels: tuple[_LevelInfo, ...]
    payload_granule: dict[str, int]     # smallest fetchable payload block per
    #                                     dim (innermost compressed level's
    #                                     block, or RLE sequential span)

    @property
    def total_bits(self) -> float:
        return self.payload_bits + sum(l.meta_bits for l in self.levels)

    @property
    def ratio(self) -> float:
        return self.total_bits / self.dense_bits

    def _align(self, b: int, t: int) -> float:
        if b > t:
            return b / t
        whole = t / b
        return math.ceil(whole) / whole

    def fetched_bits(self, tile: dict[str, int]) -> float:
        """Bits moved per full pass over the tensor, tile-at-a-time."""
        if self.fmt is None:
            return self.dense_bits
        pay = self.payload_bits
        for d, g in self.payload_granule.items():
            if g > 1 and d in tile:
                pay *= self._align(g, tile[d])
        meta = sum(l.meta_bits * self._align(l.block_below, tile.get(l.dim, l.block_below))
                   for l in self.levels)
        return pay + meta

    def decode_ops(self, tile: dict[str, int]) -> float:
        if self.fmt is None:
            return 0.0
        return sum(l.decode_ops * self._align(l.block_below, tile.get(l.dim, l.block_below))
                   for l in self.levels)


def compile_format(fmt: Optional[Format], spec: TensorSpec) -> CompiledFormat:
    if fmt is None:
        return CompiledFormat(None, spec.dense_bits, spec.dense_bits, (), {})
    report: SizeReport = analyze(fmt, spec)
    infos: list[_LevelInfo] = []
    below: dict[str, int] = dict.fromkeys(spec.dims, 1)
    # block_below per level = product of sizes of INNER levels on the same dim
    sizes_per_dim: dict[str, list[int]] = {}
    for l in fmt.levels:
        sizes_per_dim.setdefault(l.dim, []).append(int(l.size))  # type: ignore[arg-type]
    seen: dict[str, int] = dict.fromkeys(spec.dims, 0)
    for i, l in enumerate(fmt.levels):
        seq = sizes_per_dim[l.dim]
        idx = seen[l.dim]
        blk = 1
        for s in seq[idx + 1:]:
            blk *= s
        seen[l.dim] += 1
        infos.append(_LevelInfo(l.dim, blk, report.per_level[i],
                                DECODE_COST[l.prim] * report.per_level[i]))
    # Payload granule per dim: payload is stored per position of the
    # innermost COMPRESSED level, so fetches move whole such blocks.  RLE has
    # no random access — its sequential span is the level extent times
    # everything below it.
    gran: dict[str, int] = {}
    rle_span: dict[str, int] = {}
    for i, l in enumerate(fmt.levels):
        if l.prim is Prim.NONE:
            continue
        # innermost compressed level wins: walking outer→inner, overwrite
        gran[l.dim] = infos[i].block_below
        if l.prim is Prim.RLE:
            span = int(l.size) * infos[i].block_below  # type: ignore[arg-type]
            rle_span[l.dim] = max(rle_span.get(l.dim, 1), span)
    for d, span in rle_span.items():
        gran[d] = max(gran.get(d, 1), span)
    return CompiledFormat(fmt, spec.dense_bits, report.payload_bits,
                          tuple(infos), gran)


def dense_format(spec: TensorSpec) -> CompiledFormat:
    return compile_format(None, spec)


# ---------------------------------------------------------------------------
# Evaluation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CostReport:
    energy: float               # normalized pJ
    cycles: float
    edp: float
    breakdown: dict[str, float]
    utilization: float
    dram_bits: float

    def metric(self, objective: str) -> float:
        return {"energy": self.energy, "latency": self.cycles,
                "edp": self.edp}[objective]


def evaluate(op: MatMul, arch: HardwareConfig, mapping: Mapping,
             cf_i: CompiledFormat, cf_w: CompiledFormat,
             cf_o: Optional[CompiledFormat] = None) -> CostReport:
    """Cost of running ``op`` with ``mapping`` and the given formats.

    ``cf_o``: format for the OUTPUT activation writeback (SCNN-style — the
    output is the next operator's sparse input and leaves the chip
    compressed).  Partial sums still move in wide precision."""
    vb = op.value_bits
    rho_i = op.sp_i.density
    rho_w = op.sp_w.density
    mac_frac = arch.reduc.mac_fraction(rho_i, rho_w)
    cyc_frac = arch.reduc.cycle_fraction(rho_i, rho_w)

    macs_dense = float(op.M) * op.N * op.K
    bounds = mapping.bounds(op)
    tile, sp, order = mapping.tile, mapping.spatial, mapping.order

    # --- DRAM traffic (tile-reuse rule + format fetch model) ---------------
    f_i = irrelevant_refetch(order, "I", bounds)
    f_w = irrelevant_refetch(order, "W", bounds)
    f_o = irrelevant_refetch(order, "O", bounds)
    o_elems = float(op.M) * op.K
    o_tile = {"M": tile["M"], "K": tile["K"]}
    o_final = (cf_o.fetched_bits(o_tile) if cf_o is not None
               else o_elems * vb)                 # compressed writeback
    # intermediate partial sums (when the reduction is split across tiles)
    # move in wide precision: (f_o − 1) write+read round trips
    o_bits = 2.0 * (f_o - 1.0) * o_elems * 2 * vb + o_final
    # Conditional fetch under skipping: a W stripe is fetched only if SOME
    # input element pairing it inside the tile is non-zero (decisive during
    # decode, M=1: zero activations skip whole weight rows — Deja-Vu-style);
    # symmetrically for I under weight checking.
    w_fetch = 1.0
    i_fetch = 1.0
    if arch.reduc.kind == "skipping":
        if arch.reduc.check_i:
            w_fetch = op.sp_i.prob_nonempty(tile["M"])
        if arch.reduc.check_w:
            i_fetch = op.sp_w.prob_nonempty(tile["K"])
    dram_bits = (cf_i.fetched_bits(tile) * f_i * i_fetch +
                 cf_w.fetched_bits(tile) * f_w * w_fetch +
                 o_bits)

    # --- GLB traffic: per-MAC operand streams with spatial + RF reuse ------
    # I is shared across the K-unrolled PEs, W across M-unrolled, O partial
    # sums reduce across N-unrolled; each fetched word is further reused
    # ~rf_reuse times from the register file.  Compressed operands stream
    # fewer bits (data stays compressed in GLB — SCNN-style).  Skipping
    # additionally suppresses the PARTNER operand's reads: a W word whose
    # paired I is zero is never fetched (and vice versa).
    rr = arch.rf_reuse
    skip = arch.reduc.kind == "skipping"
    i_partner = rho_w if (skip and arch.reduc.check_w) else 1.0
    w_partner = rho_i if (skip and arch.reduc.check_i) else 1.0
    glb_bits = (macs_dense * vb / (sp["K"] * rr) * min(cf_i.ratio, 1.0)
                * i_partner +
                macs_dense * vb / (sp["M"] * rr) * min(cf_w.ratio, 1.0)
                * w_partner +
                macs_dense * 2 * vb * mac_frac / (sp["N"] * rr *
                                                  max(tile["N"] // sp["N"], 1))
                + o_bits)

    # --- RF + MAC ----------------------------------------------------------
    rf_bits = macs_dense * mac_frac * 3 * vb
    mac_energy = macs_dense * mac_frac * arch.mac_pj

    # --- metadata decode (charged per DRAM stream) --------------------------
    decode = (cf_i.decode_ops(tile) * f_i + cf_w.decode_ops(tile) * f_w)
    decode_energy = decode * arch.decode_pj_per_op

    e_dram = dram_bits * arch.dram.pj_per_bit
    e_glb = glb_bits * arch.glb.pj_per_bit
    e_rf = rf_bits * arch.rf.pj_per_bit
    energy = e_dram + e_glb + e_rf + mac_energy + decode_energy

    # --- latency ------------------------------------------------------------
    n_tiles = bounds["M"] * bounds["N"] * bounds["K"]
    per_tile_cycles = (math.ceil(tile["M"] / sp["M"]) *
                       math.ceil(tile["N"] / sp["N"]) *
                       math.ceil(tile["K"] / sp["K"]))
    compute_cycles = n_tiles * per_tile_cycles * cyc_frac
    dram_cycles = dram_bits / arch.dram.bw_bits_per_cycle
    glb_cycles = glb_bits / arch.glb.bw_bits_per_cycle
    cycles = max(compute_cycles, dram_cycles, glb_cycles, 1.0)

    util = macs_dense * cyc_frac / (max(compute_cycles, 1.0) * arch.macs)
    cnt = op.count
    energy *= cnt
    cycles *= cnt
    return CostReport(
        energy=energy,
        cycles=cycles,
        edp=energy * cycles,
        breakdown={
            "dram": e_dram * cnt, "glb": e_glb * cnt, "rf": e_rf * cnt,
            "mac": mac_energy * cnt, "decode": decode_energy * cnt,
            "dram_cycles": dram_cycles * cnt,
            "compute_cycles": compute_cycles * cnt,
        },
        utilization=min(util, 1.0),
        dram_bits=dram_bits * cnt,
    )


def memory_energy(report: CostReport) -> float:
    """The paper's 'memory energy' metric: DRAM + on-chip buffer traffic —
    the data movement compression formats actually change.  RF accesses are
    part of the PE datapath (3/MAC regardless of format) and are accounted
    with compute, following Eyeriss/SCNN's energy taxonomy."""
    b = report.breakdown
    return b["dram"] + b["glb"]
