"""Cost Model (paper §III-A Evaluator, right half).

Evaluates a design candidate = (MatMul op, hardware, Mapping, compression
formats for I and W) into energy / latency / EDP, "modeling MAC operations
and memory transfers".  The Sparsity Analyzer supplies compressed sizes and
computation-reduction fractions; this module turns them into per-level
access counts and cycles.

Alignment between format and dataflow (§III-C2, efficiency-oriented
allocating) is modeled physically:
  * a level whose block extent ``b`` exceeds the tile extent ``t`` along its
    dim forces over-fetch (whole compression groups move, factor b/t);
  * a tile that straddles group boundaries re-decodes the boundary groups
    (factor ceil(t/b)/(t/b));
  * RLE has no random access — a tile fetch decodes the whole run-chain
    spanned by the sequential region (granule rule).
When the dimension allocation copies the dataflow's tiling factors, every
factor collapses to 1.0 — exactly the paper's "aligns the compression format
with the dataflow, reducing runtime overhead".

Batch evaluator architecture
----------------------------
The search hot loop scores thousands of (mapping, format-pair) candidates
per op.  :func:`evaluate_batch` materializes the whole candidate set into
structure-of-arrays form — tile/spatial extents and DRAM bounds as (n, 3)
arrays over (M, N, K), refetch multipliers gathered from a per-loop-order
lookup table, and each :class:`CompiledFormat` packed into a padded
per-level row (:func:`_format_row`, value-cached) — then computes
energy/cycles/EDP for every candidate in one vectorized NumPy pass.
Scalar :func:`evaluate` is a thin wrapper over a batch of one, so there is
a single source of truth for the cost formulas.  :func:`compile_format`
results are memoized by (format levels+name, dims, sparsity, value_bits)
via :mod:`repro.core.memo`.

:func:`evaluate_batch_gather` is the sweep entry point every search plane
now routes through: candidates are (mapping row, I-format row, W-format
row) index triples over a :func:`pack_mappings` table and per-population
:func:`format_fetch_table`\\ s, the mapping-only formula half is hoisted
into a reusable :func:`mapping_ctx`, and only the elementwise
:func:`_evaluate_terms` tail runs per candidate — optionally chunked
across a thread pool (``eval_threads``; the tail is elementwise per row,
so any chunking is bit-identical to the serial pass).
"""

from __future__ import annotations

import dataclasses
import math
import os
import threading
from typing import Optional, Sequence

import numpy as np

from repro.core import memo
from repro.core.arch import HardwareConfig
from repro.core.dataflow import DIMS, ORDERS, Mapping, irrelevant_refetch
from repro.core.formats import Format
from repro.core.primitives import DECODE_COST, Prim
from repro.core.sparsity import (SizeReport, TensorSpec, analyze,
                                 gather_scalar, spec_key)
from repro.core.workload import MatMul


@dataclasses.dataclass(frozen=True)
class _LevelInfo:
    dim: str
    block_below: int          # elements along `dim` under one position
    meta_bits: float
    decode_ops: float


@dataclasses.dataclass(frozen=True)
class CompiledFormat:
    """(format × tensor) analysis, pre-chewed for the mapping hot loop."""

    fmt: Optional[Format]               # None = stored dense
    dense_bits: float
    payload_bits: float
    levels: tuple[_LevelInfo, ...]
    payload_granule: dict[str, int]     # smallest fetchable payload block per
    #                                     dim (innermost compressed level's
    #                                     block, or RLE sequential span)

    @property
    def total_bits(self) -> float:
        return self.payload_bits + sum(l.meta_bits for l in self.levels)

    @property
    def ratio(self) -> float:
        return self.total_bits / self.dense_bits

    def _align(self, b: int, t: int) -> float:
        if b > t:
            return b / t
        whole = t / b
        return math.ceil(whole) / whole

    def fetched_bits(self, tile: dict[str, int]) -> float:
        """Bits moved per full pass over the tensor, tile-at-a-time."""
        if self.fmt is None:
            return self.dense_bits
        pay = self.payload_bits
        for d, g in self.payload_granule.items():
            if g > 1 and d in tile:
                pay *= self._align(g, tile[d])
        meta = sum(l.meta_bits * self._align(l.block_below, tile.get(l.dim, l.block_below))
                   for l in self.levels)
        return pay + meta

    def decode_ops(self, tile: dict[str, int]) -> float:
        if self.fmt is None:
            return 0.0
        return sum(l.decode_ops * self._align(l.block_below, tile.get(l.dim, l.block_below))
                   for l in self.levels)


def format_key(fmt: Optional[Format]) -> tuple:
    """Value-based hashable identity of a (possibly sized) format."""
    if fmt is None:
        return (None,)
    return (fmt.name, fmt.levels)


def cf_key(cf: Optional["CompiledFormat"]) -> tuple:
    """Value-based hashable identity of a compiled (format × tensor)
    analysis — everything the evaluator reads from it.  Two compiles with
    equal keys are interchangeable in any cost formula, which is what lets
    mapping contexts memoize by (op, arch, ratios, cf_o key)."""
    if cf is None:
        return (None,)
    return (format_key(cf.fmt), cf.dense_bits, cf.payload_bits, cf.levels,
            tuple(sorted(cf.payload_granule.items())))


_COMPILE_CACHE: dict = memo.register({}, "compile_format")


def compile_format(fmt: Optional[Format], spec: TensorSpec) -> CompiledFormat:
    sk = spec_key(spec)
    key = None if sk is None else (format_key(fmt), sk)
    return memo.get_or(_COMPILE_CACHE, key,
                       lambda: _compile_format_impl(fmt, spec))


def compile_format_from_report(fmt: Format, spec: TensorSpec,
                               report: SizeReport) -> CompiledFormat:
    """:func:`compile_format` fed a precomputed :class:`SizeReport` — the
    entry point for batch analyzers (``analyze_batch`` /
    ``analyze_plans``), which score whole format families in one pass and
    then compile each member without re-running the scalar ``analyze``.
    Shares the compile cache with :func:`compile_format`, so either entry
    point can satisfy the other's lookups."""
    sk = spec_key(spec)
    key = None if sk is None else (format_key(fmt), sk)
    return memo.get_or(_COMPILE_CACHE, key,
                       lambda: _compiled_with_report(fmt, spec, report))


def _compile_format_impl(fmt: Optional[Format], spec: TensorSpec
                         ) -> CompiledFormat:
    if fmt is None:
        return CompiledFormat(None, spec.dense_bits, spec.dense_bits, (), {})
    return _compiled_with_report(fmt, spec, analyze(fmt, spec))


def _compiled_with_report(fmt: Format, spec: TensorSpec, report: SizeReport
                          ) -> CompiledFormat:
    infos: list[_LevelInfo] = []
    below: dict[str, int] = dict.fromkeys(spec.dims, 1)
    # block_below per level = product of sizes of INNER levels on the same dim
    sizes_per_dim: dict[str, list[int]] = {}
    for l in fmt.levels:
        sizes_per_dim.setdefault(l.dim, []).append(int(l.size))  # type: ignore[arg-type]
    seen: dict[str, int] = dict.fromkeys(spec.dims, 0)
    for i, l in enumerate(fmt.levels):
        seq = sizes_per_dim[l.dim]
        idx = seen[l.dim]
        blk = 1
        for s in seq[idx + 1:]:
            blk *= s
        seen[l.dim] += 1
        infos.append(_LevelInfo(l.dim, blk, report.per_level[i],
                                DECODE_COST[l.prim] * report.per_level[i]))
    # Payload granule per dim: payload is stored per position of the
    # innermost COMPRESSED level, so fetches move whole such blocks.  RLE has
    # no random access — its sequential span is the level extent times
    # everything below it.
    gran: dict[str, int] = {}
    rle_span: dict[str, int] = {}
    for i, l in enumerate(fmt.levels):
        if l.prim is Prim.NONE:
            continue
        # innermost compressed level wins: walking outer→inner, overwrite
        gran[l.dim] = infos[i].block_below
        if l.prim is Prim.RLE:
            span = int(l.size) * infos[i].block_below  # type: ignore[arg-type]
            rle_span[l.dim] = max(rle_span.get(l.dim, 1), span)
    for d, span in rle_span.items():
        gran[d] = max(gran.get(d, 1), span)
    return CompiledFormat(fmt, spec.dense_bits, report.payload_bits,
                          tuple(infos), gran)


def dense_format(spec: TensorSpec) -> CompiledFormat:
    return compile_format(None, spec)


# ---------------------------------------------------------------------------
# Evaluation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CostReport:
    energy: float               # normalized pJ
    cycles: float
    edp: float
    breakdown: dict[str, float]
    utilization: float
    dram_bits: float

    def metric(self, objective: str) -> float:
        return {"energy": self.energy, "latency": self.cycles,
                "edp": self.edp}[objective]


# --- structure-of-arrays packing for the batch path ------------------------

_DIM_COL = {d: i for i, d in enumerate(DIMS)}            # M→0, N→1, K→2
_ORDER_IDX = {o: i for i, o in enumerate(ORDERS)}
# Per loop order: does the operand's (single) irrelevant dim sit outer to
# its innermost relevant loop?  Probed through irrelevant_refetch itself so
# the table can never drift from the scalar rule.
_PROBE = {d: 2 for d in DIMS}
_REFETCH_OUTER = {
    X: np.array([irrelevant_refetch(o, X, _PROBE) > 1.0 for o in ORDERS])
    for X in ("I", "W", "O")}
_IRR_COL = {"I": _DIM_COL["K"], "W": _DIM_COL["M"], "O": _DIM_COL["N"]}


@dataclasses.dataclass(frozen=True)
class _FormatRow:
    """One CompiledFormat flattened for vectorized fetch/decode math."""

    dense: bool
    dense_bits: float
    payload_bits: float
    ratio: float
    lvl_col: np.ndarray          # (L,) int — tile column per level
    lvl_block: np.ndarray        # (L,) float — block_below per level
    lvl_meta: np.ndarray         # (L,) float
    lvl_decode: np.ndarray       # (L,) float
    gran: np.ndarray             # (3,) float — payload granule per dim, 1=none


_ROW_CACHE: dict = memo.register({}, "format_row")


def _format_row(cf: CompiledFormat) -> _FormatRow:
    key = (cf.fmt is None, cf.dense_bits, cf.payload_bits, cf.levels,
           tuple(sorted(cf.payload_granule.items())))
    return memo.get_or(_ROW_CACHE, key, lambda: _build_row(cf))


def _build_row(cf: CompiledFormat) -> _FormatRow:
    gran = np.ones(len(DIMS))
    for d, g in cf.payload_granule.items():
        if g > 1:
            gran[_DIM_COL[d]] = float(g)
    # Zero-contribution levels (dense ``None`` heads/leaves: no metadata, no
    # decode work) drop out of the packed row — their align factors only
    # ever multiply 0.0, so the fetch/decode sums are exact without them
    # and every align matrix shrinks to the compressed levels only.
    lvls = [l for l in cf.levels if l.meta_bits != 0.0 or l.decode_ops != 0.0]
    return _FormatRow(
        dense=cf.fmt is None,
        dense_bits=cf.dense_bits,
        payload_bits=cf.payload_bits,
        ratio=cf.ratio,
        lvl_col=np.array([_DIM_COL[l.dim] for l in lvls], np.int64),
        lvl_block=np.array([float(l.block_below) for l in lvls]),
        lvl_meta=np.array([l.meta_bits for l in lvls]),
        lvl_decode=np.array([l.decode_ops for l in lvls]),
        gran=gran,
    )


@dataclasses.dataclass(frozen=True)
class _FormatSoA:
    """A stack of _FormatRows, level-padded (block=1, meta=decode=0)."""

    dense: np.ndarray            # (m,) bool
    dense_bits: np.ndarray       # (m,)
    payload_bits: np.ndarray     # (m,)
    ratio: np.ndarray            # (m,)
    lvl_col: np.ndarray          # (m, L) int
    lvl_block: np.ndarray        # (m, L)
    lvl_meta: np.ndarray         # (m, L)
    lvl_decode: np.ndarray       # (m, L)
    gran: np.ndarray             # (m, 3)


def _pack(cfs: Sequence[CompiledFormat]) -> _FormatSoA:
    rows = [_format_row(cf) for cf in cfs]
    m = len(rows)
    L = max((len(r.lvl_col) for r in rows), default=0) or 1
    col = np.zeros((m, L), np.int64)
    blk = np.ones((m, L))
    met = np.zeros((m, L))
    dec = np.zeros((m, L))
    for i, r in enumerate(rows):
        k = len(r.lvl_col)
        col[i, :k] = r.lvl_col
        blk[i, :k] = r.lvl_block
        met[i, :k] = r.lvl_meta
        dec[i, :k] = r.lvl_decode
    return _FormatSoA(
        dense=np.array([r.dense for r in rows], bool),
        dense_bits=np.array([r.dense_bits for r in rows]),
        payload_bits=np.array([r.payload_bits for r in rows]),
        ratio=np.array([r.ratio for r in rows]),
        lvl_col=col, lvl_block=blk, lvl_meta=met, lvl_decode=dec,
        gran=np.stack([r.gran for r in rows]),
    )


@dataclasses.dataclass(frozen=True)
class MappingSoA:
    """A mapping set packed once into structure-of-arrays form, so sweeps
    that score many (mapping subset, format pair) combinations of the same
    set (the stepwise baseline) pay the per-mapping Python exactly once."""

    tiles: np.ndarray            # (n, 3) int64 over DIMS
    sps: np.ndarray              # (n, 3) int64
    ords: np.ndarray             # (n,) int64 — index into ORDERS

    def __len__(self) -> int:
        return len(self.ords)


def pack_mappings(mappings: Sequence[Mapping]) -> MappingSoA:
    n = len(mappings)
    tiles = np.array([[m.tile[d] for d in DIMS] for m in mappings],
                     np.int64).reshape(n, len(DIMS))
    sps = np.array([[m.spatial[d] for d in DIMS] for m in mappings],
                   np.int64).reshape(n, len(DIMS))
    ords = np.array([_ORDER_IDX[m.order] for m in mappings], np.int64)
    return MappingSoA(tiles, sps, ords)


def _align_vec(b: np.ndarray, t: np.ndarray) -> np.ndarray:
    """Vectorized CompiledFormat._align: b/t when b>t, else ceil(t/b)/(t/b)."""
    whole = t / b
    return np.where(b > t, b / t, np.ceil(whole) / whole)


def _tiles_at_levels(soa: _FormatSoA, tiles: np.ndarray) -> np.ndarray:
    """Gather per-level tile extents: (n, L).  A one-row SoA broadcasts
    against an n-candidate tile array."""
    if soa.lvl_col.shape[0] == 1:
        return tiles[:, soa.lvl_col[0]]
    return np.take_along_axis(tiles, soa.lvl_col, axis=1)


def _fetched_bits_vec(soa: _FormatSoA, tiles: np.ndarray) -> np.ndarray:
    a = _align_vec(soa.lvl_block, _tiles_at_levels(soa, tiles))
    meta = (soa.lvl_meta * a).sum(axis=1)
    pay = soa.payload_bits * _align_vec(soa.gran, tiles).prod(axis=1)
    return np.where(soa.dense, soa.dense_bits, pay + meta)


def _prob_nonempty_vec(sp, vals: np.ndarray) -> np.ndarray:
    # Distribution models are arbitrary Python; tile extents come from a
    # small divisor set, so evaluate once per unique value and gather
    # (shared with sparsity.analyze_batch).
    return gather_scalar(sp.prob_nonempty, vals)


@dataclasses.dataclass
class BatchCost:
    """Vectorized cost of n (mapping, format-pair) candidates of one op.

    All arrays are length n and already scaled by ``op.count``;
    :meth:`report` reconstitutes the full scalar :class:`CostReport` for one
    candidate (identical to what :func:`evaluate` returns for it)."""

    energy: np.ndarray
    cycles: np.ndarray
    edp: np.ndarray
    utilization: np.ndarray
    dram_bits: np.ndarray
    e_dram: np.ndarray
    e_glb: np.ndarray
    e_decode: np.ndarray
    dram_cycles: np.ndarray
    compute_cycles: np.ndarray
    e_rf: float                     # format-independent, scalar
    e_mac: float

    def __len__(self) -> int:
        return len(self.energy)

    def metric(self, objective: str) -> np.ndarray:
        return {"energy": self.energy, "latency": self.cycles,
                "edp": self.edp}[objective]

    def report(self, i: int) -> CostReport:
        return CostReport(
            energy=float(self.energy[i]),
            cycles=float(self.cycles[i]),
            edp=float(self.edp[i]),
            breakdown={
                "dram": float(self.e_dram[i]), "glb": float(self.e_glb[i]),
                "rf": self.e_rf, "mac": self.e_mac,
                "decode": float(self.e_decode[i]),
                "dram_cycles": float(self.dram_cycles[i]),
                "compute_cycles": float(self.compute_cycles[i]),
            },
            utilization=float(self.utilization[i]),
            dram_bits=float(self.dram_bits[i]),
        )


def _empty_batch() -> BatchCost:
    z = np.zeros(0)
    return BatchCost(energy=z, cycles=z, edp=z, utilization=z,
                     dram_bits=z, e_dram=z, e_glb=z, e_decode=z,
                     dram_cycles=z, compute_cycles=z, e_rf=0.0, e_mac=0.0)


def evaluate_batch(op: MatMul, arch: HardwareConfig,
                   mappings: Sequence[Mapping],
                   cf_pairs: Sequence[tuple[CompiledFormat, CompiledFormat]],
                   cf_o: Optional[CompiledFormat] = None) -> BatchCost:
    """Vectorized :func:`evaluate` over aligned ``mappings``/``cf_pairs``.

    ``cf_pairs[j]`` is the (cf_i, cf_w) pair scored with ``mappings[j]``; a
    single pair broadcasts across all mappings.  ``cf_o`` (output writeback
    format) is shared by the whole batch, mirroring the search structure —
    it depends on the candidate pattern, not the mapping.
    """
    n = len(mappings)
    if len(cf_pairs) not in (1, n):
        raise ValueError(f"cf_pairs length {len(cf_pairs)} != 1 or {n}")
    if n == 0:
        return _empty_batch()
    soa_i = _pack([p[0] for p in cf_pairs])
    soa_w = _pack([p[1] for p in cf_pairs])
    ctx = mapping_ctx(op, arch, pack_mappings(mappings), cf_o)
    return _evaluate_core(op, arch, ctx, slice(None), soa_i, soa_w)


@dataclasses.dataclass(frozen=True)
class FormatTable:
    """Per-(format, tile) fetch terms of a format population over a packed
    mapping set, precomputed as (F, S) matrices.

    A sweep scoring many (format pair, mapping) combinations of the same
    populations (the stepwise baseline: up to 600×600 pairs over one
    shortlist) gathers rows from these tables instead of re-running the
    alignment math per candidate row — the only per-candidate work left is
    the elementwise tail of the cost formulas."""

    fet: np.ndarray              # (F, S) fetched bits per DRAM pass
    dec: np.ndarray              # (F, S) metadata decode ops
    ratio: np.ndarray            # (F,) compressed/dense ratio


def format_fetch_table(cfs: Sequence[CompiledFormat],
                       table: MappingSoA) -> FormatTable:
    """Build the (format population × mapping table) fetch matrices in one
    broadcast pass — element (f, s) carries exactly what the row-wise
    evaluator computes for (``cfs[f]``, ``table`` row ``s``): the same
    align/meta/payload expressions, summed over levels in the same order."""
    soa = _pack(cfs)
    tiles_f = table.tiles.astype(float)             # (S, 3)
    tl = tiles_f[:, soa.lvl_col]                    # (S, F, L)
    a = _align_vec(soa.lvl_block, tl)               # (S, F, L)
    meta = (soa.lvl_meta * a).sum(axis=2)           # (S, F)
    pay = soa.payload_bits * \
        _align_vec(soa.gran, tiles_f[:, None, :]).prod(axis=2)
    fet = np.where(soa.dense, soa.dense_bits, pay + meta)
    dec = np.where(soa.dense, 0.0, (soa.lvl_decode * a).sum(axis=2))
    return FormatTable(fet=np.ascontiguousarray(fet.T),
                       dec=np.ascontiguousarray(dec.T),
                       ratio=soa.ratio)


def evaluate_batch_gather(op: MatMul, arch: HardwareConfig,
                          table: MappingSoA, ft_i: FormatTable,
                          i_idx: np.ndarray, ft_w: FormatTable,
                          w_idx: np.ndarray, map_idx: np.ndarray,
                          cf_o: Optional[CompiledFormat] = None,
                          ctx: Optional["_MapCtx"] = None,
                          eval_threads: Optional[int] = None) -> BatchCost:
    """:func:`evaluate_batch` over gathered rows: candidate ``r`` pairs
    ``table`` row ``map_idx[r]`` with I-side format ``i_idx[r]`` and W-side
    format ``w_idx[r]`` of the precomputed :func:`format_fetch_table`\\ s.

    The mapping-only half of the formulas computes once per TABLE row
    (:func:`mapping_ctx` — pass ``ctx`` to reuse one across calls sharing
    (op, arch, table, cf_o), e.g. every chunk of a sweep), the
    per-(format, tile) fetch terms come from the tables, and only the
    elementwise tail runs per candidate — no per-row Python, no per-row
    alignment math.  Results are bit-identical to :func:`evaluate_batch`
    on the materialized rows (same expressions, same operation order).

    ``eval_threads`` splits the :func:`_evaluate_terms` tail into
    contiguous row chunks across a shared thread pool (NumPy releases the
    GIL inside the array kernels).  Every tail expression is elementwise
    over candidate rows, so the chunked result is bit-identical to the
    serial one for ANY thread count; ``None`` (the default) picks a count
    automatically — 1 below :data:`_EVAL_CHUNK_ROWS` rows, so small
    batches never pay pool overhead."""
    if len(map_idx) == 0:
        return _empty_batch()
    if ctx is None:
        ctx = mapping_ctx(op, arch, table, cf_o)
    args = (ft_i.fet[i_idx, map_idx], ft_i.dec[i_idx, map_idx],
            ft_i.ratio[i_idx],
            ft_w.fet[w_idx, map_idx], ft_w.dec[w_idx, map_idx],
            ft_w.ratio[w_idx])
    threads = resolve_eval_threads(eval_threads, len(map_idx))
    if threads <= 1:
        return _evaluate_terms(op, arch, ctx, map_idx, *args)
    bounds = np.linspace(0, len(map_idx), threads + 1).astype(np.int64)
    futures = [
        _eval_pool().submit(_evaluate_terms, op, arch, ctx,
                            map_idx[lo:hi], *(a[lo:hi] for a in args))
        for lo, hi in zip(bounds[:-1], bounds[1:]) if hi > lo]
    return _concat_batch([f.result() for f in futures])


# --- threaded tail: pool + sizing ------------------------------------------

_EVAL_CHUNK_ROWS = 32768        # min rows per thread chunk (auto mode)
_EVAL_POOL = None
_EVAL_POOL_LOCK = threading.Lock()


def _eval_pool():
    """Shared thread pool for the evaluator tail, created on first use
    (sized to the machine; per-call chunk counts are what bound fan-out)."""
    global _EVAL_POOL
    if _EVAL_POOL is None:
        with _EVAL_POOL_LOCK:
            if _EVAL_POOL is None:
                from concurrent.futures import ThreadPoolExecutor
                _EVAL_POOL = ThreadPoolExecutor(
                    max_workers=max(1, os.cpu_count() or 1),
                    thread_name_prefix="eval-tail")
    return _EVAL_POOL


def _reset_eval_pool() -> None:
    # A forked child (cosearch_multi's process executor on Linux) inherits
    # the pool OBJECT but not its worker threads — submitting to it would
    # block forever.  Drop the reference so the child lazily builds its
    # own pool on first threaded tail.
    global _EVAL_POOL
    _EVAL_POOL = None


if hasattr(os, "register_at_fork"):
    os.register_at_fork(after_in_child=_reset_eval_pool)


def resolve_eval_threads(eval_threads: Optional[int], n_rows: int) -> int:
    """The thread count a gather evaluation of ``n_rows`` rows will use:
    an explicit ``eval_threads`` wins (floored at 1); ``None`` = auto —
    one thread per :data:`_EVAL_CHUNK_ROWS` rows, capped at the CPU count,
    so the pool only engages when the tail is large enough to amortize
    thread handoff."""
    if eval_threads is not None:
        return max(1, int(eval_threads))
    return max(1, min(os.cpu_count() or 1, n_rows // _EVAL_CHUNK_ROWS))


def _concat_batch(parts: Sequence[BatchCost]) -> BatchCost:
    """Concatenate per-chunk tail results back into one :class:`BatchCost`.
    Every array is elementwise per candidate row, so concatenation of
    contiguous chunks reproduces the serial arrays exactly."""
    if len(parts) == 1:
        return parts[0]
    cat = {f.name: np.concatenate([getattr(p, f.name) for p in parts])
           for f in dataclasses.fields(BatchCost)
           if f.name not in ("e_rf", "e_mac")}
    return BatchCost(e_rf=parts[0].e_rf, e_mac=parts[0].e_mac, **cat)


@dataclasses.dataclass(frozen=True)
class _MapCtx:
    """The mapping-only half of the cost formulas, one row per mapping of a
    packed set: refetch multipliers, output traffic, conditional-fetch
    probabilities, GLB stream bases, compute cycles, utilization.  Rows are
    format-independent, so a sweep re-scoring the same mappings under many
    format pairs gathers instead of recomputing."""

    tiles_f: np.ndarray          # (S, 3)
    f_i: np.ndarray              # (S,) refetch multipliers
    f_w: np.ndarray
    i_fetch: np.ndarray          # (S,) conditional-fetch probabilities
    w_fetch: np.ndarray
    o_bits: np.ndarray           # (S,) output DRAM traffic (cf_o applied)
    glb_i_base: np.ndarray       # (S,) GLB stream bases (pre-ratio)
    glb_w_base: np.ndarray
    glb_o: np.ndarray            # (S,) partial-sum GLB traffic
    compute_cycles: np.ndarray   # (S,)
    util: np.ndarray             # (S,) pre-clip utilization


def mapping_ctx(op: MatMul, arch: HardwareConfig, msoa: MappingSoA,
                cf_o: Optional[CompiledFormat] = None) -> _MapCtx:
    """Precompute the mapping-only formula half for a packed mapping set;
    the result is reusable across every evaluation sharing
    (op, arch, mapping set, cf_o)."""
    n = len(msoa)
    vb = op.value_bits
    rho_i = op.sp_i.density
    rho_w = op.sp_w.density
    mac_frac = arch.reduc.mac_fraction(rho_i, rho_w)
    cyc_frac = arch.reduc.cycle_fraction(rho_i, rho_w)
    macs_dense = float(op.M) * op.N * op.K

    tiles, sps, ords = msoa.tiles, msoa.sps, msoa.ords
    tiles_f = tiles.astype(float)
    sps_f = sps.astype(float)
    ext = np.array([op.M, op.N, op.K], float)
    bounds = np.ceil(ext / tiles_f)

    # --- DRAM refetch (tile-reuse rule) + output traffic -------------------
    f_i = np.where(_REFETCH_OUTER["I"][ords], bounds[:, _IRR_COL["I"]], 1.0)
    f_w = np.where(_REFETCH_OUTER["W"][ords], bounds[:, _IRR_COL["W"]], 1.0)
    f_o = np.where(_REFETCH_OUTER["O"][ords], bounds[:, _IRR_COL["O"]], 1.0)
    o_elems = float(op.M) * op.K
    if cf_o is not None:
        # cf_o's dims are (M, K); the N column of ``tiles`` is never indexed
        o_final = _fetched_bits_vec(_pack([cf_o]), tiles_f)
    else:
        o_final = np.full(n, o_elems * vb)        # compressed writeback
    # intermediate partial sums (when the reduction is split across tiles)
    # move in wide precision: (f_o − 1) write+read round trips
    o_bits = 2.0 * (f_o - 1.0) * o_elems * 2 * vb + o_final
    # Conditional fetch under skipping: a W stripe is fetched only if SOME
    # input element pairing it inside the tile is non-zero (decisive during
    # decode, M=1: zero activations skip whole weight rows — Deja-Vu-style);
    # symmetrically for I under weight checking.
    w_fetch = np.ones(n)
    i_fetch = np.ones(n)
    if arch.reduc.kind == "skipping":
        if arch.reduc.check_i:
            w_fetch = _prob_nonempty_vec(op.sp_i, tiles[:, _DIM_COL["M"]])
        if arch.reduc.check_w:
            i_fetch = _prob_nonempty_vec(op.sp_w, tiles[:, _DIM_COL["K"]])

    # --- GLB stream bases: per-MAC operand streams with spatial + RF reuse
    # (the operand ratio multiplies in per candidate).  I is shared across
    # the K-unrolled PEs, W across M-unrolled, O partial sums reduce across
    # N-unrolled; each fetched word is further reused ~rf_reuse times from
    # the register file.
    rr = arch.rf_reuse
    n_stat = np.maximum(tiles[:, 1] // sps[:, 1], 1)
    glb_i_base = macs_dense * vb / (sps_f[:, 2] * rr)
    glb_w_base = macs_dense * vb / (sps_f[:, 0] * rr)
    glb_o = macs_dense * 2 * vb * mac_frac / (sps_f[:, 1] * rr * n_stat)

    # --- compute latency + utilization -------------------------------------
    n_tiles = bounds.prod(axis=1)
    per_tile_cycles = np.ceil(tiles_f / sps_f).prod(axis=1)
    compute_cycles = n_tiles * per_tile_cycles * cyc_frac
    util = macs_dense * cyc_frac / (np.maximum(compute_cycles, 1.0)
                                    * arch.macs)
    return _MapCtx(tiles_f=tiles_f, f_i=f_i, f_w=f_w,
                   i_fetch=i_fetch, w_fetch=w_fetch, o_bits=o_bits,
                   glb_i_base=glb_i_base, glb_w_base=glb_w_base, glb_o=glb_o,
                   compute_cycles=compute_cycles, util=util)


def _evaluate_core(op: MatMul, arch: HardwareConfig, ctx: _MapCtx, idx,
                   soa_i: _FormatSoA, soa_w: _FormatSoA) -> BatchCost:
    """Row-wise entry of the cost formulas: compute each candidate's fetch
    terms from its format SoA row (one align matrix per operand, shared
    between the fetch and decode terms), then run the shared elementwise
    tail.  ``idx`` selects the candidates' mapping rows from ``ctx``
    (``slice(None)`` = identity); ``soa_*`` broadcast one format row across
    the batch or carry one per candidate."""
    tiles_f = ctx.tiles_f[idx]
    a_i = _align_vec(soa_i.lvl_block, _tiles_at_levels(soa_i, tiles_f))
    a_w = _align_vec(soa_w.lvl_block, _tiles_at_levels(soa_w, tiles_f))
    fet_i = np.where(soa_i.dense, soa_i.dense_bits,
                     soa_i.payload_bits
                     * _align_vec(soa_i.gran, tiles_f).prod(axis=1)
                     + (soa_i.lvl_meta * a_i).sum(axis=1))
    fet_w = np.where(soa_w.dense, soa_w.dense_bits,
                     soa_w.payload_bits
                     * _align_vec(soa_w.gran, tiles_f).prod(axis=1)
                     + (soa_w.lvl_meta * a_w).sum(axis=1))
    dec_i = np.where(soa_i.dense, 0.0, (soa_i.lvl_decode * a_i).sum(axis=1))
    dec_w = np.where(soa_w.dense, 0.0, (soa_w.lvl_decode * a_w).sum(axis=1))
    return _evaluate_terms(op, arch, ctx, idx, fet_i, dec_i, soa_i.ratio,
                           fet_w, dec_w, soa_w.ratio)


def _evaluate_terms(op: MatMul, arch: HardwareConfig, ctx: _MapCtx, idx,
                    fet_i: np.ndarray, dec_i: np.ndarray, ratio_i: np.ndarray,
                    fet_w: np.ndarray, dec_w: np.ndarray, ratio_w: np.ndarray
                    ) -> BatchCost:
    """The elementwise tail of the cost formulas, shared by every entry
    point: combine per-candidate fetch terms with the gathered mapping-only
    ctx rows into energy / cycles / EDP."""
    rho_i = op.sp_i.density
    rho_w = op.sp_w.density
    mac_frac = arch.reduc.mac_fraction(rho_i, rho_w)
    macs_dense = float(op.M) * op.N * op.K
    vb = op.value_bits
    f_i, f_w = ctx.f_i[idx], ctx.f_w[idx]
    o_bits = ctx.o_bits[idx]

    # --- DRAM traffic (tile-reuse rule + format fetch model) ---------------
    dram_i = fet_i * f_i * ctx.i_fetch[idx]
    dram_w = fet_w * f_w * ctx.w_fetch[idx]

    # --- GLB traffic: compressed operands stream fewer bits (data stays
    # compressed in GLB — SCNN-style); skipping suppresses the PARTNER
    # operand's reads (a W word whose paired I is zero is never fetched,
    # and vice versa) -------------------------------------------------------
    skip = arch.reduc.kind == "skipping"
    i_partner = rho_w if (skip and arch.reduc.check_w) else 1.0
    w_partner = rho_i if (skip and arch.reduc.check_i) else 1.0
    glb_extra = 0.0

    # --- streaming-reuse term: a `glb_resident_frac` slice of the GLB may
    # pin compressed payload across outer-loop iterations, so that fraction
    # of each operand's RE-fetches (the `f − 1` refetch passes of the
    # tile-reuse rule) is served on-chip instead of from DRAM.  Distinct
    # fetches (the first pass) always come from DRAM; avoided refetches are
    # re-charged as GLB reads.  Decode stays charged per total stream — the
    # decoder sits at the compute side of the hierarchy either way.  The
    # term is formulated so that formats which STREAM well (small distinct
    # payload ⇒ high residency r) separate from formats that merely PACK
    # well.  Disabled (frac = 0, the default) this branch is skipped
    # entirely, keeping every seed cost bit-for-bit. -------------------------
    resident = arch.glb_resident_frac
    if resident:
        cap = resident * arch.glb.capacity_bits
        r_i = np.minimum(cap / np.maximum(fet_i, 1e-30), 1.0)
        r_w = np.minimum(cap / np.maximum(fet_w, 1e-30), 1.0)
        refet_i = fet_i * (f_i - 1.0) * ctx.i_fetch[idx]
        refet_w = fet_w * (f_w - 1.0) * ctx.w_fetch[idx]
        dram_i = dram_i - refet_i * r_i
        dram_w = dram_w - refet_w * r_w
        glb_extra = refet_i * r_i + refet_w * r_w

    dram_bits = dram_i + dram_w + o_bits
    glb_bits = (ctx.glb_i_base[idx] * np.minimum(ratio_i, 1.0) * i_partner
                + ctx.glb_w_base[idx]
                * np.minimum(ratio_w, 1.0) * w_partner
                + ctx.glb_o[idx]
                + o_bits
                + glb_extra)

    # --- RF + MAC ----------------------------------------------------------
    rf_bits = macs_dense * mac_frac * 3 * vb
    mac_energy = macs_dense * mac_frac * arch.mac_pj

    # --- metadata decode (charged per DRAM stream) --------------------------
    decode = dec_i * f_i + dec_w * f_w
    decode_energy = decode * arch.decode_pj_per_op

    e_dram = dram_bits * arch.dram.pj_per_bit
    e_glb = glb_bits * arch.glb.pj_per_bit
    e_rf = rf_bits * arch.rf.pj_per_bit
    energy = e_dram + e_glb + e_rf + mac_energy + decode_energy

    # --- latency ------------------------------------------------------------
    compute_cycles = ctx.compute_cycles[idx]
    dram_cycles = dram_bits / arch.dram.bw_bits_per_cycle
    glb_cycles = glb_bits / arch.glb.bw_bits_per_cycle
    cycles = np.maximum(np.maximum(compute_cycles, dram_cycles),
                        np.maximum(glb_cycles, 1.0))

    util = ctx.util[idx]
    cnt = op.count
    energy = energy * cnt
    cycles = cycles * cnt
    return BatchCost(
        energy=energy,
        cycles=cycles,
        edp=energy * cycles,
        utilization=np.minimum(util, 1.0),
        dram_bits=dram_bits * cnt,
        e_dram=e_dram * cnt,
        e_glb=e_glb * cnt,
        e_decode=decode_energy * cnt,
        dram_cycles=dram_cycles * cnt,
        compute_cycles=compute_cycles * cnt,
        e_rf=e_rf * cnt,
        e_mac=mac_energy * cnt,
    )


def evaluate(op: MatMul, arch: HardwareConfig, mapping: Mapping,
             cf_i: CompiledFormat, cf_w: CompiledFormat,
             cf_o: Optional[CompiledFormat] = None) -> CostReport:
    """Cost of running ``op`` with ``mapping`` and the given formats.

    ``cf_o``: format for the OUTPUT activation writeback (SCNN-style — the
    output is the next operator's sparse input and leaves the chip
    compressed).  Partial sums still move in wide precision.

    Thin wrapper over :func:`evaluate_batch` with a batch of one — the
    vectorized path is the single source of truth for the formulas."""
    return evaluate_batch(op, arch, (mapping,), ((cf_i, cf_w),),
                          cf_o).report(0)


def memory_energy(report: CostReport) -> float:
    """The paper's 'memory energy' metric: DRAM + on-chip buffer traffic —
    the data movement compression formats actually change.  RF accesses are
    part of the PE datapath (3/MAC regardless of format) and are accounted
    with compute, following Eyeriss/SCNN's energy taxonomy."""
    b = report.breakdown
    return b["dram"] + b["glb"]
