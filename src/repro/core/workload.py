"""Sparse workloads: operator-level MatMul specs + LLM graph builders.

Paper §III-A: SnipSnap's first input is "sparse workloads, possibly including
one or multiple LLMs, with operator-level computation and sparsity
specifications".  The core operation is MatMul in the paper's naming
convention:

    O[M][K] = sum_N  I[M][N] * W[N][K]        (N is the contracted dim)

so operand dimensions are  I:{M,N},  W:{N,K},  O:{M,K}.

LLM builders emit one MatMul per projection (Q,K,V,O,FC1,FC2) per phase
(prefill / per-token decode), annotated with activation/weight sparsity in
the ranges quoted by the paper from [4],[5] (e.g. FC2 activation sparsity up
to 97%, FC1 35–70%).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.sparsity import DENSE, Bernoulli, NM, Sparsity


@dataclasses.dataclass(frozen=True)
class MatMul:
    """One sparse matmul operator: O[M,K] = Σ_N I[M,N]·W[N,K]."""

    name: str
    M: int
    N: int
    K: int
    sp_i: Sparsity = DENSE          # input/activation sparsity
    sp_w: Sparsity = DENSE          # weight sparsity
    sp_o: Sparsity = DENSE          # OUTPUT activation sparsity (post-
    #                                 nonlinearity — compressed on writeback,
    #                                 SCNN-style, with the activation format)
    count: float = 1.0              # repetitions (layers × phases)
    value_bits: int = 16

    @property
    def macs(self) -> float:
        return float(self.M) * self.N * self.K * self.count

    def i_dims(self) -> dict[str, int]:
        return {"M": self.M, "N": self.N}

    def w_dims(self) -> dict[str, int]:
        return {"N": self.N, "K": self.K}

    def o_dims(self) -> dict[str, int]:
        return {"M": self.M, "K": self.K}


@dataclasses.dataclass(frozen=True)
class Workload:
    """A named bag of MatMul operators (one LLM, or one LLM phase)."""

    name: str
    ops: tuple[MatMul, ...]

    @property
    def macs(self) -> float:
        return sum(op.macs for op in self.ops)


# ---------------------------------------------------------------------------
# LLM graph builders (§IV-A2 benchmarks)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LLMSpec:
    name: str
    layers: int
    d_model: int
    d_ff: int
    heads: int
    # activation density (non-zero fraction) per op family; weight density.
    act_density: float = 1.0
    w_density: float = 1.0
    fc2_act_density: Optional[float] = None   # FC2 input often much sparser
    nm_weights: Optional[tuple[int, int]] = None   # e.g. (2, 4)


# Public configs.  Densities follow Fig. 10's annotated activation/weight
# density pairs and the §II-A ranges (FC1 act 35–70% sparse, FC2 up to 97%).
LLAMA2_7B = LLMSpec("LLaMA2-7B", 32, 4096, 11008, 32)
LLAMA2_13B = LLMSpec("LLaMA2-13B", 40, 5120, 13824, 40)
OPT_125M = LLMSpec("OPT-125M", 12, 768, 3072, 12)
OPT_6_7B = LLMSpec("OPT-6.7B", 32, 4096, 16384, 32)
OPT_13B = LLMSpec("OPT-13B", 40, 5120, 20480, 40)
OPT_30B = LLMSpec("OPT-30B", 48, 7168, 28672, 56)
BERT_BASE = LLMSpec("BERT-Base", 12, 768, 3072, 12)


def _sp(density: float) -> Sparsity:
    return DENSE if density >= 1.0 else Bernoulli(density)


def build_llm(spec: LLMSpec, seq: int, decode_tokens: int = 0,
              act_density: Optional[float] = None,
              w_density: Optional[float] = None,
              fc2_act_density: Optional[float] = None,
              batch: int = 1) -> Workload:
    """Emit the projection MatMuls for prefill (M=seq) and decode (M=1 per
    token, ``count`` scaled by decode_tokens).  2048-token prefill +
    128-token decode is the paper's evaluation setting (§IV-C, via [21]).
    FC2's input (the FFN activation) is usually far sparser than the rest
    (up to 97% zero in ReLU-fied OPT — §II-A)."""
    ad = spec.act_density if act_density is None else act_density
    wd = spec.w_density if w_density is None else w_density
    fc2_ad = fc2_act_density if fc2_act_density is not None else (
        spec.fc2_act_density if spec.fc2_act_density is not None else ad)
    sp_w: Sparsity = NM(*spec.nm_weights) if spec.nm_weights else _sp(wd)

    d, f, L = spec.d_model, spec.d_ff, spec.layers
    ops: list[MatMul] = []

    def phase(tag: str, m: int, count: float) -> None:
        ops.extend([
            MatMul(f"{tag}.qkv", m, d, 3 * d, _sp(ad), sp_w, _sp(ad), count),
            MatMul(f"{tag}.o", m, d, d, _sp(ad), sp_w, _sp(ad), count),
            # FC1's output IS FC2's (very sparse) input activation
            MatMul(f"{tag}.fc1", m, d, f, _sp(ad), sp_w, _sp(fc2_ad), count),
            MatMul(f"{tag}.fc2", m, f, d, _sp(fc2_ad), sp_w, _sp(ad), count),
        ])

    phase("prefill", seq * batch, float(L))
    if decode_tokens:
        phase("decode", batch, float(L) * decode_tokens)
    return Workload(spec.name, tuple(ops))


# ---------------------------------------------------------------------------
# CNN workloads for the DiMO-Sparse comparison (§IV-D) — conv as im2col GEMM.
# ---------------------------------------------------------------------------

def _conv_gemm(name: str, out_hw: int, cin: int, k: int, cout: int,
               act_density: float, w_density: float) -> MatMul:
    return MatMul(name, out_hw * out_hw, cin * k * k, cout,
                  _sp(act_density), _sp(w_density))


def alexnet(act_density: float = 0.6, w_density: float = 0.35) -> Workload:
    layers = [
        _conv_gemm("conv1", 55, 3, 11, 96, 1.0, w_density),
        _conv_gemm("conv2", 27, 96, 5, 256, act_density, w_density),
        _conv_gemm("conv3", 13, 256, 3, 384, act_density, w_density),
        _conv_gemm("conv4", 13, 384, 3, 384, act_density, w_density),
        _conv_gemm("conv5", 13, 384, 3, 256, act_density, w_density),
    ]
    return Workload("AlexNet", tuple(layers))


def vgg16(act_density: float = 0.5, w_density: float = 0.3) -> Workload:
    cfg = [(224, 3, 64), (224, 64, 64), (112, 64, 128), (112, 128, 128),
           (56, 128, 256), (56, 256, 256), (56, 256, 256),
           (28, 256, 512), (28, 512, 512), (28, 512, 512),
           (14, 512, 512), (14, 512, 512), (14, 512, 512)]
    ops = [_conv_gemm(f"conv{i}", hw, cin, 3, cout,
                      1.0 if i == 0 else act_density, w_density)
           for i, (hw, cin, cout) in enumerate(cfg)]
    return Workload("VGG-16", tuple(ops))


def resnet18(act_density: float = 0.55, w_density: float = 0.4) -> Workload:
    cfg = [(56, 64, 64)] * 4 + [(28, 128, 128)] * 4 + \
          [(14, 256, 256)] * 4 + [(7, 512, 512)] * 4
    ops = [_conv_gemm(f"conv{i}", hw, cin, 3, cout, act_density, w_density)
           for i, (hw, cin, cout) in enumerate(cfg)]
    return Workload("ResNet-18", tuple(ops))
