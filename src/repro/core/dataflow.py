"""Dataflow space: spatial unrolling, loop tiling, loop ordering, allocation.

Paper §II-B1: "Dataflow involves for-loop permutation combined with spatial
and temporal mapping... loop unrolling for parallelism, loop order
optimization, and loop allocation to memory hierarchy".  SnipSnap reuses
established methodology here ([20] ZigZag, [25] Sparseloop) — this module is
a compact ZigZag-lite mapper for the paper's MatMul convention
O[M,K] = Σ_N I[M,N]·W[N,K].

A :class:`Mapping` is:
  spatial — per-dim unroll factors on the MAC array (Π ≤ #MACs);
  tile    — per-dim GLB-resident tile extents (loop allocation: loops inside
            the tile run at the GLB/RF levels, loops over tiles at DRAM);
  order   — the DRAM-level loop permutation, outer→inner.

Access counting (costmodel.py) uses the classic tile-reuse rule: an operand's
DRAM traffic multiplies by the bounds of every loop that is irrelevant to it
and positioned OUTER to its innermost relevant loop.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Iterator, Optional, Sequence

import numpy as np

from repro.core import memo
from repro.core.arch import HardwareConfig
from repro.core.workload import MatMul

DIMS = ("M", "N", "K")
RELEVANT = {"I": ("M", "N"), "W": ("N", "K"), "O": ("M", "K")}
ORDERS: tuple[tuple[str, str, str], ...] = tuple(itertools.permutations(DIMS))  # type: ignore[assignment]


@dataclasses.dataclass(frozen=True)
class Mapping:
    spatial: dict[str, int]
    tile: dict[str, int]
    order: tuple[str, str, str]

    def bounds(self, op: MatMul) -> dict[str, int]:
        """DRAM-level loop bounds (tiles per dim, ceil)."""
        ext = {"M": op.M, "N": op.N, "K": op.K}
        return {d: math.ceil(ext[d] / self.tile[d]) for d in DIMS}

    def __str__(self) -> str:
        sp = "x".join(f"{d}{self.spatial[d]}" for d in DIMS)
        tl = "x".join(f"{d}{self.tile[d]}" for d in DIMS)
        return f"sp[{sp}] tile[{tl}] order[{''.join(self.order)}]"


# ---------------------------------------------------------------------------
# Candidate enumeration, bounded for search speed.
# ---------------------------------------------------------------------------

def _capped_divisors(x: int, cap: int = 12) -> list[int]:
    """A representative divisor subset: powers of two times the odd part's
    divisors, thinned to ``cap`` values spread across magnitudes."""
    divs = []
    i = 1
    while i * i <= x:
        if x % i == 0:
            divs.append(i)
            if i != x // i:
                divs.append(x // i)
        i += 1
    divs.sort()
    if len(divs) <= cap:
        return divs
    # keep extremes + geometrically spread interior
    idx = {0, len(divs) - 1}
    for k in range(1, cap - 1):
        idx.add(round(k * (len(divs) - 1) / (cap - 1)))
    return [divs[i] for i in sorted(idx)]


def spatial_candidates(op: MatMul, arch: HardwareConfig,
                       top: int = 6) -> list[dict[str, int]]:
    """Unroll-factor triples maximizing PE utilization.

    The array is modeled as a flat MAC budget (geometry waste shows up as
    ceil-division cycles in the cost model); dims may not unroll past their
    extent."""
    ext = {"M": op.M, "N": op.N, "K": op.K}
    cands: list[tuple[float, dict[str, int]]] = []
    dm = _capped_divisors(ext["M"], 8)
    dn = _capped_divisors(ext["N"], 8)
    dk = _capped_divisors(ext["K"], 8)
    for um in dm:
        if um > arch.macs:
            continue
        for un in dn:
            if um * un > arch.macs:
                continue
            for uk in dk:
                if um * un * uk > arch.macs:
                    continue
                util = um * un * uk / arch.macs
                cands.append((util, {"M": um, "N": un, "K": uk}))
    cands.sort(key=lambda t: -t[0])
    out, seen = [], set()
    for util, sp in cands:
        key = tuple(sp.values())
        if key in seen:
            continue
        seen.add(key)
        out.append(sp)
        if len(out) >= top:
            break
    return out


def tile_candidates(op: MatMul, spatial: dict[str, int],
                    per_dim_cap: int = 8) -> Iterator[dict[str, int]]:
    """GLB tile extents: multiples of the spatial factors, divisor-aligned,
    spread across magnitudes (the smallest legal tile — the spatial factor
    itself — is always included so capacity-constrained ops stay mappable)."""
    ext = {"M": op.M, "N": op.N, "K": op.K}
    opts: dict[str, list[int]] = {}
    for d in DIMS:
        cands = sorted({t for t in _capped_divisors(ext[d], per_dim_cap + 8)
                        if t % spatial[d] == 0}
                       | {spatial[d], ext[d]})
        if len(cands) > per_dim_cap:
            idx = {0, len(cands) - 1}
            for k in range(1, per_dim_cap - 1):
                idx.add(round(k * (len(cands) - 1) / (per_dim_cap - 1)))
            cands = [cands[i] for i in sorted(idx)]
        opts[d] = cands
    for tm in opts["M"]:
        for tn in opts["N"]:
            for tk in opts["K"]:
                yield {"M": tm, "N": tn, "K": tk}


def tile_fits(op: MatMul, tile: dict[str, int], arch: HardwareConfig,
              ratio_i: float = 1.0, ratio_w: float = 1.0,
              double_buffer: bool = True) -> bool:
    """Loop-allocation legality: the three live tiles must fit in GLB.

    ``ratio_*`` are compressed/dense size ratios — this is the paper's
    *compression-aware loop allocation* (§III-D2): compressed tiles are
    smaller, so more aggressive tilings become legal with no post-hoc
    correction pass."""
    vb = op.value_bits
    bits_i = tile["M"] * tile["N"] * vb * ratio_i
    bits_w = tile["N"] * tile["K"] * vb * ratio_w
    bits_o = tile["M"] * tile["K"] * 2 * vb     # fp32-ish accumulators
    need = bits_i + bits_w + bits_o
    if double_buffer:
        need += bits_i + bits_w                 # ping-pong input buffers
    cap = arch.glb.capacity_bits
    return cap is None or need <= cap


def tile_fits_batch(op: MatMul, tiles: np.ndarray, arch: HardwareConfig,
                    ratio_i: np.ndarray, ratio_w: np.ndarray,
                    double_buffer: bool = True) -> np.ndarray:
    """:func:`tile_fits` for many (format-pair, tile) points at once.

    ``tiles`` is an (n, 3) integer array over ``DIMS``; ``ratio_i`` /
    ``ratio_w`` are length-``p`` compressed/dense ratio vectors (one entry
    per format pair).  Returns a (p, n) boolean legality matrix.  The
    arithmetic replays :func:`tile_fits` element-wise in the same operation
    order (exact-int tile×tile×bits products, then one float multiply per
    ratio), so a row is bit-identical to ``[tile_fits(op, t, arch, ri, rw)
    for t in tiles]`` — the stepwise baseline's sweep relies on that to
    replay the scalar path's legality decisions."""
    vb = op.value_bits
    elems_i = (tiles[:, 0] * tiles[:, 1] * vb)[None, :]     # exact int64
    elems_w = (tiles[:, 1] * tiles[:, 2] * vb)[None, :]
    bits_o = tiles[:, 0] * tiles[:, 2] * (2 * vb)
    bits_i = elems_i * np.asarray(ratio_i, float)[:, None]
    bits_w = elems_w * np.asarray(ratio_w, float)[:, None]
    need = bits_i + bits_w + bits_o
    if double_buffer:
        need = need + (bits_i + bits_w)
    cap = arch.glb.capacity_bits
    if cap is None:
        return np.ones(need.shape, bool)
    return need <= cap


def irrelevant_refetch(order: Sequence[str], operand: str,
                       bounds: dict[str, int]) -> float:
    """Π of bounds of loops irrelevant to ``operand`` that sit outer to its
    innermost relevant loop — the refetch multiplier for DRAM traffic."""
    rel = RELEVANT[operand]
    innermost_rel = max(order.index(d) for d in rel)
    f = 1.0
    for pos, d in enumerate(order):
        if d not in rel and pos < innermost_rel:
            f *= bounds[d]
    return f


def enumerate_mappings(op: MatMul, arch: HardwareConfig,
                       ratio_i: float = 1.0, ratio_w: float = 1.0,
                       spatial_top: int = 4,
                       orders: Optional[Sequence[tuple[str, str, str]]] = None,
                       ) -> Iterator[Mapping]:
    """Full (bounded) mapping space for one MatMul on one architecture."""
    orders = tuple(orders) if orders is not None else ORDERS
    for sp in spatial_candidates(op, arch, top=spatial_top):
        for tile in tile_candidates(op, sp):
            if not tile_fits(op, tile, arch, ratio_i, ratio_w):
                continue
            for order in orders:
                yield Mapping(spatial=sp, tile=tile, order=order)


_MAPPINGS_CACHE: dict = memo.register({}, "mappings_for")


def mappings_for(op: MatMul, arch: HardwareConfig,
                 ratio_i: float = 1.0, ratio_w: float = 1.0,
                 spatial_top: int = 4,
                 orders: Optional[Sequence[tuple[str, str, str]]] = None,
                 ) -> tuple[Mapping, ...]:
    """Memoized :func:`enumerate_mappings` (same candidate set, same order).

    The space depends only on the op SHAPE (extents + value_bits — names,
    sparsity models and repeat counts do not enter legality), the
    architecture, the exact compression ratios, and the enumeration knobs —
    that tuple is the cache key, so identical layers across pattern pairs
    and models enumerate once.
    """
    orders = tuple(orders) if orders is not None else ORDERS
    key = ((op.M, op.N, op.K, op.value_bits), arch, ratio_i, ratio_w,
           spatial_top, orders)
    return memo.get_or(
        _MAPPINGS_CACHE, key,
        lambda: tuple(enumerate_mappings(op, arch, ratio_i, ratio_w,
                                         spatial_top=spatial_top,
                                         orders=orders)))
