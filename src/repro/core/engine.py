"""Adaptive Compression Engine (paper §III-C).

Generates candidate compression formats for tensors with varying sparsity via
three techniques:

  1. *Complexity-based penalizing* — ``EqData = γ^level × ActualData`` with
     γ = 1.05 by default; during pattern search, a pattern is pruned when its
     (lower-bounded or realized) EqData cannot beat the best strictly-simpler
     pattern.  This collapses the >4×10⁵-point space of Fig. 6 to a handful
     of 2–3-level candidates within a fraction of a percent of the optimum.

  2. *Efficiency-oriented allocating* — subdimension sizes are copied from
     the dataflow's loop-tiling hierarchy so compression groups coincide with
     tiles (zero alignment overhead in the cost model).

  3. *Importance-based scoring* — multi-LLM deployments select one shared
     format by ``argmin_fmt Σ ImpScore_i × OptMetric_i``.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Iterable, Optional, Sequence

import numpy as np

from repro.core import formats as F
from repro.core import memo
from repro.core.dataflow import Mapping
from repro.core.formats import Format, Level
from repro.core.primitives import Prim
from repro.core.sparsity import (SizeReport, Sparsity, TensorSpec, analyze,
                                 analyze_plans, spec_key)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    gamma: float = 1.05            # complexity penalty base (configurable)
    max_levels: int = 3            # pattern depth cap (paper finds 2–3 wins)
    top_k: int = 8                 # candidates handed to the co-search
    max_allocs_per_pattern: int = 64
    prims: tuple[Prim, ...] = (Prim.B, Prim.CP, Prim.RLE, Prim.UOP)


@dataclasses.dataclass(frozen=True)
class Candidate:
    fmt: Format                    # pattern with a reference allocation
    report: SizeReport
    eq_data: float                 # γ^levels × total bits

    @property
    def pattern(self) -> tuple:
        return self.fmt.pattern_key()


@dataclasses.dataclass
class SearchStats:
    patterns_seen: int = 0
    allocations_seen: int = 0
    pruned_patterns: int = 0
    # Evaluator-plane counters, filled by the co-search drivers:
    # ``evaluations`` counts every candidate the search SCORED (cache hits
    # replay the recorded count, keeping warm and cold runs bit-identical);
    # ``fresh_evaluations`` counts only candidates actually computed this
    # run — the share a warm ``_search_op`` cache (including entries
    # shipped back from process workers) did NOT have to redo.  It is a
    # DIAGNOSTIC: under thread/process executors, which work item finds a
    # warm cache depends on scheduling, so it is deterministic only on the
    # serial path (designs and ``evaluations`` are deterministic always).
    evaluations: int = 0
    fresh_evaluations: int = 0


def eq_data(total_bits: float, levels: int, gamma: float) -> float:
    """Equivalent data size (§III-C1): penalize deep patterns."""
    return (gamma ** levels) * total_bits


# Early-exit pruning knobs of the in-pattern allocation scan (§III-C1 applied
# per allocation): give the scan a warm-up before the simpler-format bar can
# cut it, and stop once the landscape has flattened.
_ALLOC_MIN_SCAN = 15
_ALLOC_PATIENCE = 24


def _alloc_scan_len(e: np.ndarray, bar: float) -> tuple[int, bool]:
    """Replay of the scalar allocation scan's early exits on an EqData
    vector: (how many allocations the per-candidate loop examines, whether
    it breaks inside this prefix).  The stop condition at index i depends
    only on e[:i+1], so the replay is exact on any prefix.  Keeps
    ``SearchStats.allocations_seen`` and the returned best allocation
    identical between the batched and scalar paths."""
    n = len(e)
    if not math.isfinite(bar):
        return n, False
    runmin = np.minimum.accumulate(e)
    improve = np.empty(n, bool)
    improve[0] = True
    improve[1:] = e[1:] < runmin[:-1]
    idx = np.arange(n)
    since = idx - np.maximum.accumulate(np.where(improve, idx, -1))
    stop = ((idx >= _ALLOC_MIN_SCAN) & (runmin >= bar)) | \
        (since >= _ALLOC_PATIENCE)
    if not stop.any():
        return n, False
    return int(np.argmax(stop)) + 1, True


_CANDIDATES_CACHE: dict = memo.register({}, "generate_candidates")

# Depth of the SIZE-optimal reference-allocation scan (the engine's
# "reference view" of a pattern on a tensor, mapping-independent): the first
# _REF_ALLOC_CAP dimension allocations, best total bits.
_REF_ALLOC_CAP = 24
_REF_ALLOC_CACHE: dict = memo.register({}, "reference_alloc")


def reference_allocation(pattern: Sequence[Level], spec: TensorSpec
                         ) -> Optional[Format]:
    """Best size-optimal allocation of a bare ``pattern`` on ``spec``'s dims
    (argmin total bits over the first ``_REF_ALLOC_CAP`` allocations).

    This is the reference format the co-search pits against mapping-derived
    allocations (:func:`repro.core.cosearch._reference_cf`).  Memoized by
    (pattern, spec); :func:`generate_candidates` seeds the cache for every
    candidate it returns as a by-product of its batched allocation scan, so
    on the engine's own generation spec the reference never costs a second
    scan — only ops whose dims/sparsity differ from the representative
    tensor fall through to the one-pass recompute here."""
    pattern = tuple(pattern)
    sk = spec_key(spec)
    return memo.get_or(_REF_ALLOC_CACHE,
                       None if sk is None else (pattern, sk),
                       lambda: _reference_allocation_impl(pattern, spec))


def _reference_allocation_impl(pattern: tuple[Level, ...], spec: TensorSpec
                               ) -> Optional[Format]:
    plans = list(F.allocation_plans(pattern, spec.dims,
                                    max_allocs=_REF_ALLOC_CAP))
    if not plans:
        return None
    # one vectorized pass; argmin's first-occurrence ties match the scalar
    # strict-less scan this replaced
    j = int(np.argmin(analyze_plans(plans, spec).total_bits))
    return plans[j].build()


def generate_candidates(spec: TensorSpec, cfg: EngineConfig = EngineConfig(),
                        penalize: bool = True,
                        stats: Optional[SearchStats] = None,
                        use_batch: bool = True,
                        ) -> list[Candidate]:
    """Enumerate patterns by iterative deepening with complexity pruning.

    Level-(n+1) patterns are built by extending level-n patterns; with
    ``penalize=True`` only patterns whose EqData beats the best strictly
    simpler pattern survive — excluded patterns are neither kept nor
    extended, which is what collapses the Fig. 6 search space.  With
    ``penalize=False`` every prefix is extended (the "w/o penalizing"
    series).  Returns the top-k candidates by EqData, each carrying its best
    reference allocation.

    Memoized by (spec dims+sparsity+value_bits, cfg, penalize): the search
    is deterministic, so repeat calls (per role × per pattern pair × per
    model in :func:`repro.core.cosearch.cosearch_multi`) replay the cached
    candidate list plus the counter deltas into ``stats``.

    ``use_batch=True`` scores every allocation of a pattern in one
    :func:`repro.core.sparsity.analyze_batch` pass and replays the scalar
    loop's early-exit pruning on the EqData vector post hoc — results and
    ``SearchStats`` counters are bit-identical to the legacy per-allocation
    loop (``use_batch=False``, kept as the benchmark reference), so the two
    paths share one cache.
    """
    outer_stats = stats
    try:
        key = ((tuple(spec.dims.items()), spec.sparsity, spec.value_bits),
               cfg, penalize)
        hash(key)
    except TypeError:
        key = None
    if key is not None and memo.enabled():
        hit = _CANDIDATES_CACHE.get(key)
        memo.note(_CANDIDATES_CACHE, hit is not None)
        if hit is not None:
            cands, delta = hit
            if outer_stats is not None:
                outer_stats.patterns_seen += delta.patterns_seen
                outer_stats.allocations_seen += delta.allocations_seen
                outer_stats.pruned_patterns += delta.pruned_patterns
            return list(cands)
    stats = SearchStats()
    dims = list(spec.dims)
    sk = spec_key(spec)
    # collect the size-optimal reference allocation per pattern while the
    # batched scan has the scored rows in hand (seeded into
    # _REF_ALLOC_CACHE for the winners below)
    collect_ref = use_batch and memo.enabled() and sk is not None
    ref_plans: dict[tuple[Level, ...], F.AllocPlan] = {}

    def score_scalar(pattern: tuple[Level, ...], bar: float
                     ) -> Optional[Candidate]:
        """Legacy per-allocation loop (the seed path, benchmark reference).
        When penalizing, stop early once the pattern evidently cannot beat
        the simpler-format bar (the same exclusion rule, applied
        in-pattern)."""
        best_alloc: Optional[Candidate] = None
        since_improve = 0
        for i, fmt in enumerate(F.allocate(pattern, spec.dims,
                                           max_allocs=cfg.max_allocs_per_pattern)):
            stats.allocations_seen += 1
            rep = analyze(fmt, spec)
            e = eq_data(rep.total_bits, len(pattern), cfg.gamma)
            if best_alloc is None or e < best_alloc.eq_data:
                best_alloc = Candidate(fmt, rep, e)
                since_improve = 0
            else:
                since_improve += 1
            if math.isfinite(bar):
                if i >= _ALLOC_MIN_SCAN and best_alloc.eq_data >= bar:
                    break              # evidently dominated by simpler formats
                if since_improve >= _ALLOC_PATIENCE:
                    break              # allocation landscape has flattened
        return best_alloc

    def score_batched(pattern: tuple[Level, ...], bar: float
                      ) -> Optional[Candidate]:
        """Allocations scored in vectorized chunks over raw size rows
        (:func:`repro.core.formats.allocation_plans` +
        :func:`repro.core.sparsity.analyze_plans` — no Format objects for
        losing allocations); the early-exit semantics of the scalar loop
        are applied as a post-hoc cut of the EqData vector, so chunks stop
        being consumed as soon as the replayed scan breaks (overshoot
        < one chunk).  With ``collect_ref``, the same pass also records the
        pattern's size-optimal reference allocation (first _REF_ALLOC_CAP
        rows, best total bits) — the scan window of the per-candidate
        replay stays the scalar loop's own cap, so the extra rows never
        enter the counters or the returned candidate."""
        cap = cfg.max_allocs_per_pattern
        gen = F.allocation_plans(pattern, spec.dims,
                                 max_allocs=max(cap, _REF_ALLOC_CAP)
                                 if collect_ref else cap)
        g = cfg.gamma ** len(pattern)
        # first chunk reaches exactly the earliest possible bar-stop
        # (index _ALLOC_MIN_SCAN); later chunks cover one patience window
        chunk = cap if not math.isfinite(bar) else _ALLOC_MIN_SCAN + 1
        if collect_ref:
            chunk = max(chunk, _REF_ALLOC_CAP)
        plans: list[F.AllocPlan] = []
        brs: list[tuple[int, object]] = []      # (row offset, BatchSizeReport)
        e = np.zeros(0)
        tb = np.zeros(0)
        k = 0
        while True:
            part = list(itertools.islice(gen, chunk))
            if not part:
                break
            br = analyze_plans(part, spec)
            brs.append((len(plans), br))
            plans.extend(part)
            e = np.concatenate((e, g * br.total_bits))
            tb = np.concatenate((tb, br.total_bits))
            k, stopped = _alloc_scan_len(e[:cap], bar)
            if stopped:
                break
            chunk = _ALLOC_PATIENCE
        if not plans:
            return None
        if collect_ref:
            # first chunk already covers >= _REF_ALLOC_CAP rows, so the
            # reference argmin sees the same prefix reference_allocation()
            # would enumerate
            ref_plans[pattern] = plans[int(np.argmin(tb[:_REF_ALLOC_CAP]))]
        stats.allocations_seen += k
        j = int(np.argmin(e[:k]))
        off, br = next(t for t in reversed(brs) if t[0] <= j)
        return Candidate(plans[j].build(), br.report(j - off), float(e[j]))

    score = score_batched if use_batch else score_scalar

    out: list[Candidate] = []
    frontier: list[tuple[Level, ...]] = [()]
    best_simpler = math.inf            # best EqData among shallower levels
    for n in range(1, cfg.max_levels + 1):
        level_best = math.inf
        next_frontier: list[tuple[Level, ...]] = []
        for base in frontier:
            for d in dims:
                for prim in cfg.prims:
                    pattern = base + (Level(prim, d),)
                    stats.patterns_seen += 1
                    if prim is Prim.UOP:
                        # UOP at the leaf is unscoreable (nothing to offset
                        # into) but extending it can win (CSR/CSC prefixes):
                        # inherit survival from the base pattern.
                        next_frontier.append(pattern)
                        continue
                    cand = score(pattern, best_simpler if penalize else math.inf)
                    if cand is None:
                        stats.pruned_patterns += 1
                        continue
                    if penalize and cand.eq_data >= best_simpler:
                        stats.pruned_patterns += 1
                        continue
                    level_best = min(level_best, cand.eq_data)
                    out.append(cand)
                    next_frontier.append(pattern)
        frontier = next_frontier
        best_simpler = min(best_simpler, level_best)

    out.sort(key=lambda c: c.eq_data)
    out = out[: cfg.top_k]
    if collect_ref:
        # seed the reference-allocation cache for the winners: the
        # co-search's per-op _reference_cf on the generation spec becomes a
        # cache hit instead of a second allocation scan
        for c in out:
            bare = tuple(Level(l.prim, l.dim, None) for l in c.fmt.levels
                         if l.prim is not Prim.NONE)
            plan = ref_plans.get(bare)
            if plan is not None:
                _REF_ALLOC_CACHE.setdefault((bare, sk), plan.build())
    if key is not None and memo.enabled():
        _CANDIDATES_CACHE[key] = (tuple(out), stats)
    if outer_stats is not None:
        outer_stats.patterns_seen += stats.patterns_seen
        outer_stats.allocations_seen += stats.allocations_seen
        outer_stats.pruned_patterns += stats.pruned_patterns
    return list(out)


# ---------------------------------------------------------------------------
# Efficiency-oriented allocating (§III-C2)
# ---------------------------------------------------------------------------

def _split_chain(extent: int, mapping_chain: Sequence[int], parts: int
                 ) -> Optional[tuple[int, ...]]:
    """Split ``extent`` into ``parts`` factors following the dataflow's
    tiling hierarchy ``mapping_chain`` (outer→inner extents, product ==
    extent).  If the chain has more stages than parts, inner stages merge;
    if fewer, fall back to balanced factor splits."""
    chain = [c for c in mapping_chain if c > 1]
    if len(chain) >= parts:
        merged = list(chain[: parts - 1])
        tail = 1
        for c in chain[parts - 1:]:
            tail *= c
        merged.append(tail)
        if math.prod(merged) == extent and all(c > 1 for c in merged):
            return tuple(merged)
    # fallback: balanced split (prefer near-equal factors > 1)
    best: Optional[tuple[int, ...]] = None
    for fac in F.factorizations_cached(extent, parts):
        if any(f <= 1 for f in fac):
            continue
        spread = max(fac) / min(fac)
        if best is None or spread < max(best) / min(best):
            best = fac
    return best


def _divide_out(chain: Sequence[int], leaf: int) -> Optional[list[int]]:
    """Remove a factor ``leaf`` from the inner end of a tiling chain."""
    out = list(chain)
    rem = leaf
    for i in range(len(out) - 1, -1, -1):
        g = math.gcd(out[i], rem)
        out[i] //= g
        rem //= g
        if rem == 1:
            break
    if rem != 1:
        return None
    return [c for c in out if c > 1]


_NO_FMT = object()              # fmt-cache sentinel (None is a legal value)


def allocate_for_mappings(pattern: Sequence[Level], dims: dict[str, int],
                          op_extents: dict[str, int],
                          mappings: Sequence[Mapping],
                          leaf: Optional[dict[str, int]] = None,
                          ) -> list[Optional[Format]]:
    """Derive the dimension allocation from the dataflow (§III-C2), for many
    mappings of one op at once.

    For each dim the loop hierarchy is (#DRAM tiles, tile/spatial, spatial);
    format levels take sizes outer→inner from that chain — e.g. with M=8
    outer and M=32 inner loops, ``B(M1)-B(M2)`` becomes ``B(M1,8)-B(M2,32)``.
    ``leaf`` optionally reserves an innermost dense-block factor per dim
    (block-sparse formats); it is divided out of the chain's inner stages.

    The allocation depends only on the pattern dims' (tile, spatial) extents
    — never the loop order — so the chain split runs once per unique per-dim
    extent pair and the format assembly once per unique factor tuple; the
    dim-only feasibility gates (leaf divisibility, enough >1 factors) are
    checked once for the whole batch.  Per-mapping results are identical to
    the original scalar derivation (:func:`allocate_for_mapping` is now a
    batch of one)."""
    leaf = leaf or {}
    per_dim_slots: dict[str, int] = {}
    for l in pattern:
        per_dim_slots[l.dim] = per_dim_slots.get(l.dim, 0) + 1

    # mapping-independent feasibility + targets, once per dim
    base: dict[str, tuple[int, int, int]] = {}   # d -> (extent, lf, target)
    for d, parts in per_dim_slots.items():
        extent = dims[d]
        lf = leaf.get(d, 1)
        if lf > 1 and extent % lf:
            return [None] * len(mappings)
        target = extent // lf
        if target == 1 or (parts > 1 and target < 2 ** parts):
            return [None] * len(mappings)
        base[d] = (extent, lf, target)

    head = tuple(Level(Prim.NONE, d, dims[d]) for d in dims
                 if d not in per_dim_slots)
    leaves = tuple(Level(Prim.NONE, d, lf) for d, lf in leaf.items()
                   if lf > 1 and d in per_dim_slots)

    split_cache: dict[tuple, Optional[tuple[int, ...]]] = {}
    fmt_cache: dict[tuple, Optional[Format]] = {}

    def dim_split(d: str, t: int, u: int) -> Optional[tuple[int, ...]]:
        skey = (d, t, u)
        if skey in split_cache:
            return split_cache[skey]
        extent, lf, target = base[d]
        chain: list[int] = []
        if t and extent % t == 0:
            chain = [extent // t, max(t // u, 1), u]
            if lf > 1:
                chain = _divide_out(chain, lf) or []
        split = _split_chain(target, chain, per_dim_slots[d])
        split_cache[skey] = split
        return split

    out: list[Optional[Format]] = []
    for mapping in mappings:
        fkey = tuple((d, mapping.tile.get(d, base[d][0]),
                      mapping.spatial.get(d, 1)) for d in per_dim_slots)
        fmt = fmt_cache.get(fkey, _NO_FMT)
        if fmt is not _NO_FMT:
            out.append(fmt)             # type: ignore[arg-type]
            continue
        chains: dict[str, tuple[int, ...]] = {}
        for d, t, u in fkey:
            split = dim_split(d, t, u)
            if split is None:
                break
            chains[d] = split
        if len(chains) != len(per_dim_slots):
            fmt_cache[fkey] = None
            out.append(None)
            continue
        used = dict.fromkeys(per_dim_slots, 0)
        levels: list[Level] = []
        for l in pattern:
            idx = used[l.dim]
            levels.append(l.with_size(chains[l.dim][idx]))
            used[l.dim] += 1
        fmt = Format(head + tuple(levels) + leaves)
        try:
            fmt.validate(dims)
        except ValueError:
            fmt = None
        fmt_cache[fkey] = fmt
        out.append(fmt)
    return out


def allocate_for_mapping(pattern: Sequence[Level], dims: dict[str, int],
                         op_extents: dict[str, int], mapping: Mapping,
                         leaf: Optional[dict[str, int]] = None,
                         ) -> Optional[Format]:
    """Scalar :func:`allocate_for_mappings` — a batch of one (single source
    of truth for the derivation rules)."""
    return allocate_for_mappings(pattern, dims, op_extents, (mapping,),
                                 leaf=leaf)[0]


# ---------------------------------------------------------------------------
# Importance-based scoring (§III-C3)
# ---------------------------------------------------------------------------

def select_shared(metric_by_model_by_format: dict[str, dict[str, float]],
                  importance: dict[str, float]) -> tuple[str, float]:
    """argmin_fmt Σ_i ImpScore(LLM_i) × OptMetric(LLM_i, fmt).

    ``metric_by_model_by_format[model][format_key]`` must be complete over a
    shared format-key set.  Returns (format_key, weighted metric)."""
    fmt_keys = None
    for model, table in metric_by_model_by_format.items():
        keys = set(table)
        fmt_keys = keys if fmt_keys is None else (fmt_keys & keys)
    if not fmt_keys:
        raise ValueError("no common format across models")
    best_key, best_val = None, math.inf
    for k in sorted(fmt_keys):
        val = sum(importance.get(m, 1.0) * table[k]
                  for m, table in metric_by_model_by_format.items())
        if val < best_val:
            best_key, best_val = k, val
    assert best_key is not None
    return best_key, best_val
