"""Baseline DSE workflows for the Table-I / §IV-D speed comparisons.

:func:`stepwise_search` re-implements the Sparseloop-style workflow of
Fig. 7 (left) **against the same cost model** as the progressive co-search,
so the measured speedup isolates workflow structure (the paper's claim)
rather than implementation differences:

  1. dataflow search on the DENSE workload (no upfront computation-reduction
     estimate, no compression-aware legality);
  2. sparse-feature modeling pass: every surviving mapping is RE-modeled per
     sparse configuration (computation reduction + compression applied
     post-hoc);
  3. legality check: compressed tiles can exceed dense estimates (metadata
     overhead) → illegal candidates are discarded and the search falls back,
     re-modeling further candidates (the iterative correction loop).

In "Search" mode the baseline additionally sweeps formats × dimension
allocations exhaustively (no complexity penalty, no mapping-derived
allocation), under a wall-clock budget per MatMul — mirroring the paper's
20-minute-per-MatMul Sparseloop budget.

:func:`dimo_like_search` models DiMO-Sparse's gradient-free iterative tuning
on a preset format (CNN workloads): random-restart coordinate descent over
the mapping space, many evaluations per op.
"""

from __future__ import annotations

import dataclasses
import math
import random
import time
from typing import Optional, Sequence

import numpy as np

from repro.core.arch import HardwareConfig
from repro.core.cosearch import (CoSearchConfig, DesignPoint, OpDesign,
                                 SearchResult, _fixed_candidate, output_cf)
from repro.core.costmodel import (compile_format, dense_format, evaluate,
                                  evaluate_batch)
from repro.core.dataflow import Mapping, enumerate_mappings, tile_fits
from repro.core.engine import SearchStats
from repro.core.formats import Format, allocate, enumerate_patterns, standard_formats
from repro.core.sparsity import TensorSpec, analyze
from repro.core.workload import MatMul, Workload


def _dense_view(op: MatMul) -> MatMul:
    from repro.core.sparsity import Bernoulli
    return dataclasses.replace(op, sp_i=Bernoulli(1.0), sp_w=Bernoulli(1.0))


def _fmt_or_none(name: Optional[str], dims: dict[str, int]) -> Optional[Format]:
    if name in (None, "dense", "Dense"):
        return None
    return standard_formats(dims)[name]


def stepwise_search(workload: Workload, arch: HardwareConfig,
                    cfg: CoSearchConfig = CoSearchConfig(),
                    fixed_formats: Optional[tuple[Optional[str], Optional[str]]] = ("Bitmap", "Bitmap"),
                    search_formats: bool = False,
                    budget_s_per_op: float = 10.0) -> SearchResult:
    """Sparseloop-style stepwise DSE (see module docstring).

    Structural costs faithfully reproduced: (1) the dense-first pass cannot
    use compression-aware pruning, so it covers a WIDER mapping space
    (nothing tells it which tilings only matter compressed); (2) every
    dense-legal mapping is RE-MODELED under the sparse configuration
    (stepwise modeling — no incremental reuse); (3) sparse-illegal
    candidates are discovered only at the final legality check."""
    t0 = time.perf_counter()
    evals = 0
    ops_out: list[OpDesign] = []

    for op in workload.ops:
        op_t0 = time.perf_counter()
        dense_op = _dense_view(op)
        spec_i = TensorSpec(op.i_dims(), op.sp_i, op.value_bits)
        spec_w = TensorSpec(op.w_dims(), op.sp_w, op.value_bits)
        d_i, d_w = dense_format(spec_i), dense_format(spec_w)

        # -- step 1: dense dataflow search (wider sweep, dense legality) ----
        # scored through the shared batch evaluator: the baseline keeps its
        # workflow-structure costs (wide sweep, re-modeling) but not a
        # slower per-candidate evaluator, so Table-I ratios stay structural
        dense_mappings = list(enumerate_mappings(dense_op, arch, 1.0, 1.0,
                                                 spatial_top=cfg.spatial_top * 2))
        metrics = evaluate_batch(dense_op, arch, dense_mappings,
                                 [(d_i, d_w)]).metric(cfg.objective)
        evals += len(dense_mappings)
        scored = list(zip(metrics.tolist(), dense_mappings))
        scored.sort(key=lambda t: t[0])
        # -- step 2 input: EVERY dense-legal mapping is re-modeled sparse --
        shortlist = [m for _, m in scored]

        # -- step 2: sparse feature modeling + legality corrections ---------
        if search_formats:
            format_pairs = _exhaustive_format_pairs(op, spec_i, spec_w)
        else:
            format_pairs = [(
                _fmt_or_none(fixed_formats[0], op.i_dims()) if op.sp_i.density < 1 else None,
                _fmt_or_none(fixed_formats[1], op.w_dims()) if op.sp_w.density < 1 else None,
            )]

        best: Optional[OpDesign] = None
        best_metric = math.inf
        for fmt_i, fmt_w in format_pairs:
            cf_i = compile_format(fmt_i, spec_i) if fmt_i else d_i
            cf_w = compile_format(fmt_w, spec_w) if fmt_w else d_w
            cf_o = None
            if fmt_i is not None and fmt_i.name:
                cf_o = output_cf(_fixed_candidate(fmt_i.name, spec_i), op)
            ratio_i = min(cf_i.ratio, 1.0) if fmt_i else 1.0
            ratio_w = min(cf_w.ratio, 1.0) if fmt_w else 1.0
            # post-hoc legality: metadata may not fit where dense did —
            # every rejected candidate is a wasted correction-loop model call
            legal = [m for m in shortlist
                     if tile_fits(op, m.tile, arch, ratio_i, ratio_w)]
            evals += len(shortlist)
            if legal:
                bc = evaluate_batch(op, arch, legal, [(cf_i, cf_w)], cf_o)
                metrics = bc.metric(cfg.objective)
                j = int(np.argmin(metrics))
                if metrics[j] < best_metric:
                    best_metric = float(metrics[j])
                    best = OpDesign(op, legal[j], cf_i.fmt, cf_w.fmt,
                                    bc.report(j))
            if search_formats and time.perf_counter() - op_t0 > budget_s_per_op:
                break
        assert best is not None, f"stepwise search found no design for {op.name}"
        ops_out.append(best)

    dp = DesignPoint(ops_out, None, None)
    return SearchResult(dp, evals, time.perf_counter() - t0, SearchStats())


def _exhaustive_format_pairs(op: MatMul, spec_i: TensorSpec, spec_w: TensorSpec,
                             max_levels: int = 3, alloc_cap: int = 24,
                             side_cap: int = 600):
    """Unpruned format × allocation sweep (what a format-naive stepwise
    framework would have to do).  Generates I-side × W-side combinations
    lazily in a shuffled order so budget cuts don't bias toward level-1
    formats; sides are capped to keep the cross product enumerable."""
    def side(spec: TensorSpec) -> list[Optional[Format]]:
        if spec.sparsity.density >= 1.0:
            return [None]
        fmts: list[Optional[Format]] = [None]
        for pat in enumerate_patterns(list(spec.dims), max_levels=max_levels):
            for fmt in allocate(pat, spec.dims, max_allocs=alloc_cap):
                fmts.append(fmt)
                if len(fmts) > side_cap * 4:
                    break
        rng = random.Random(1)
        if len(fmts) > side_cap:
            fmts = [None] + rng.sample(fmts[1:], side_cap - 1)
        return fmts

    lhs, rhs = side(spec_i), side(spec_w)
    rng = random.Random(0)
    order = [(i, j) for i in range(len(lhs)) for j in range(len(rhs))]
    rng.shuffle(order)
    for i, j in order:
        yield lhs[i], rhs[j]


# ---------------------------------------------------------------------------
# DiMO-Sparse-like iterative mapping optimizer (preset format, CNNs)
# ---------------------------------------------------------------------------

def dimo_like_search(workload: Workload, arch: HardwareConfig,
                     cfg: CoSearchConfig = CoSearchConfig(),
                     fixed_formats: tuple[Optional[str], Optional[str]] = ("Bitmap", "Bitmap"),
                     restarts: int = 12, iters: int = 200,
                     seed: int = 0, use_batch: bool = True) -> SearchResult:
    """Random-restart coordinate descent over mappings with a preset format —
    a stand-in for DiMO-Sparse's differentiable-relaxation loop, which needs
    many model evaluations per op to converge.

    ``use_batch=True`` precomputes the metric of EVERY mapping with one
    :func:`evaluate_batch` call per op and replays the seeded random walk as
    pure array indexing: the walk only ever accepts a strictly better
    candidate, so each restart segment resolves to the FIRST draw attaining
    the segment's running minimum (``argmin`` with first-occurrence ties),
    and the cross-restart winner to the first strict minimum over segment
    bests.  Same RNG stream (one ``_randbelow`` per draw, as ``rng.choice``
    consumed), bit-identical designs, and ``evaluations`` still counts the
    walk's model queries (the algorithmic cost of a DiMO-style tuner — what
    Table I compares), not the internal batching.  ``use_batch=False`` keeps
    the legacy per-draw scalar loop as the benchmark reference."""
    t0 = time.perf_counter()
    rng = random.Random(seed)
    evals = 0
    ops_out: list[OpDesign] = []
    steps = iters // restarts
    for op in workload.ops:
        spec_i = TensorSpec(op.i_dims(), op.sp_i, op.value_bits)
        spec_w = TensorSpec(op.w_dims(), op.sp_w, op.value_bits)
        fmt_i = _fmt_or_none(fixed_formats[0], op.i_dims()) if op.sp_i.density < 1 else None
        fmt_w = _fmt_or_none(fixed_formats[1], op.w_dims()) if op.sp_w.density < 1 else None
        cf_i = compile_format(fmt_i, spec_i) if fmt_i else dense_format(spec_i)
        cf_w = compile_format(fmt_w, spec_w) if fmt_w else dense_format(spec_w)
        cf_o = None
        if fmt_i is not None and fmt_i.name:
            cf_o = output_cf(_fixed_candidate(fmt_i.name, spec_i), op)

        all_mappings = list(enumerate_mappings(op, arch, 1.0, 1.0,
                                               spatial_top=cfg.spatial_top))

        if use_batch:
            bc = evaluate_batch(op, arch, all_mappings, [(cf_i, cf_w)], cf_o)
            metrics = bc.metric(cfg.objective)
            n = len(all_mappings)
            # identical RNG stream: rng.choice(seq) is seq[_randbelow(len)]
            draws = np.array([rng.randrange(n)
                              for _ in range(restarts * (1 + steps))],
                             np.int64).reshape(restarts, 1 + steps)
            evals += restarts * (1 + steps)
            seg = metrics[draws]                      # (restarts, 1+steps)
            pos = seg.argmin(axis=1)                  # first draw at seg min
            per_restart = seg[np.arange(restarts), pos]
            r = int(np.argmin(per_restart))           # first strict winner
            j = int(draws[r, pos[r]])
            ops_out.append(OpDesign(op, all_mappings[j], cf_i.fmt, cf_w.fmt,
                                    bc.report(j)))
            continue

        best: Optional[OpDesign] = None
        for _ in range(restarts):
            cur = rng.choice(all_mappings)
            cur_cost = evaluate(op, arch, cur, cf_i, cf_w, cf_o)
            evals += 1
            for _ in range(steps):
                nxt = rng.choice(all_mappings)
                c = evaluate(op, arch, nxt, cf_i, cf_w, cf_o)
                evals += 1
                if c.metric(cfg.objective) < cur_cost.metric(cfg.objective):
                    cur, cur_cost = nxt, c
            if best is None or cur_cost.metric(cfg.objective) < best.cost.metric(cfg.objective):
                best = OpDesign(op, cur, cf_i.fmt, cf_w.fmt, cur_cost)
        assert best is not None
        ops_out.append(best)
    dp = DesignPoint(ops_out, None, None)
    return SearchResult(dp, evals, time.perf_counter() - t0, SearchStats())
