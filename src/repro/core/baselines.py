"""Baseline DSE workflows for the Table-I / §IV-D speed comparisons.

:func:`stepwise_search` re-implements the Sparseloop-style workflow of
Fig. 7 (left) **against the same cost model** as the progressive co-search,
so the measured speedup isolates workflow structure (the paper's claim)
rather than implementation differences:

  1. dataflow search on the DENSE workload (no upfront computation-reduction
     estimate, no compression-aware legality);
  2. sparse-feature modeling pass: every surviving mapping is RE-modeled per
     sparse configuration (computation reduction + compression applied
     post-hoc);
  3. legality check: compressed tiles can exceed dense estimates (metadata
     overhead) → illegal candidates are discarded and the search falls back,
     re-modeling further candidates (the iterative correction loop).

In "Search" mode the baseline additionally sweeps formats × dimension
allocations exhaustively (no complexity penalty, no mapping-derived
allocation), under a per-MatMul budget — either wall-clock
(``budget_s_per_op``, mirroring the paper's 20-minute-per-MatMul Sparseloop
budget) or deterministic pair-count (``budget_pairs_per_op``, what the
benchmarks and tests use so runs reproduce exactly).

``use_batch=True`` (default) runs the whole sweep vectorized: the side
format populations are enumerated as :class:`~repro.core.formats.AllocPlan`
rows and compiled in one :func:`~repro.core.sparsity.analyze_plans` pass
per pattern group, the post-hoc legality check runs as a
:func:`~repro.core.dataflow.tile_fits_batch` ratio-vector predicate over
(pair, tile) matrices, and (mapping, format-pair) chunks score through
single :func:`~repro.core.costmodel.evaluate_batch_gather` calls (whose
elementwise tail chunks across threads per ``CoSearchConfig.eval_threads``
— bit-identical for any thread count).  The per-op
budget cutoff replays deterministically post hoc, so under the count-based
budget the batch path visits the same pairs, picks the same designs, and
reports the same ``evaluations`` as the seed scalar loop
(``use_batch=False``, kept as the benchmark reference) — the baseline keeps
its workflow-structure costs (wide sweep, re-modeling, correction loops)
but not our Python overhead, so Table-I ratios stay structural.

:func:`dimo_like_search` models DiMO-Sparse's gradient-free iterative tuning
on a preset format (CNN workloads): random-restart coordinate descent over
the mapping space, many evaluations per op.
"""

from __future__ import annotations

import dataclasses
import math
import random
import time
from typing import Optional, Sequence

import numpy as np

from repro.core.arch import HardwareConfig
from repro.core.cosearch import (CoSearchConfig, DesignPoint, OpDesign,
                                 SearchError, SearchResult, _fixed_candidate,
                                 output_cf)
from repro.core.costmodel import (CompiledFormat, compile_format,
                                  compile_format_from_report, dense_format,
                                  evaluate, evaluate_batch,
                                  evaluate_batch_gather, format_fetch_table,
                                  mapping_ctx, pack_mappings)
from repro.core.dataflow import (Mapping, enumerate_mappings, tile_fits,
                                 tile_fits_batch)
from repro.core.engine import SearchStats
from repro.core.formats import (AllocPlan, Format, allocation_plans,
                                enumerate_patterns, standard_formats)
from repro.core.sparsity import TensorSpec, analyze_plans
from repro.core.workload import MatMul, Workload


def _dense_view(op: MatMul) -> MatMul:
    from repro.core.sparsity import Bernoulli
    return dataclasses.replace(op, sp_i=Bernoulli(1.0), sp_w=Bernoulli(1.0))


def _fmt_or_none(name: Optional[str], dims: dict[str, int]) -> Optional[Format]:
    if name in (None, "dense", "Dense"):
        return None
    return standard_formats(dims)[name]


_PAIR_CHUNK = 128               # format pairs per vectorized sweep chunk


def stepwise_search(workload: Workload, arch: HardwareConfig,
                    cfg: CoSearchConfig = CoSearchConfig(),
                    fixed_formats: Optional[tuple[Optional[str], Optional[str]]] = ("Bitmap", "Bitmap"),
                    search_formats: bool = False,
                    budget_s_per_op: float = 10.0,
                    budget_pairs_per_op: Optional[int] = None,
                    use_batch: bool = True,
                    pair_log: Optional[list] = None) -> SearchResult:
    """Sparseloop-style stepwise DSE (see module docstring).

    Structural costs faithfully reproduced: (1) the dense-first pass cannot
    use compression-aware pruning, so it covers a WIDER mapping space
    (nothing tells it which tilings only matter compressed); (2) every
    dense-legal mapping is RE-MODELED under the sparse configuration
    (stepwise modeling — no incremental reuse); (3) sparse-illegal
    candidates are discovered only at the final legality check.

    ``budget_pairs_per_op`` (count-based, deterministic) takes precedence
    over the wall-clock ``budget_s_per_op`` when set; both only apply in
    Search mode.  Under the count budget the batch path replays the seed
    loop's cutoff pair-for-pair (same pairs visited, same designs, same
    ``evaluations``); under the wall-clock budget the scalar loop checks
    the clock after every pair while the batch path can only check between
    chunks, so the two paths may visit different pair counts — use the
    count budget wherever reproducibility matters (benchmarks and tests
    do).  ``pair_log``, if given, collects ``(op name, i, j)`` per visited
    Search-mode pair (the equivalence tests pin identical visit order
    across paths).  ``use_batch=False`` keeps the seed per-pair loop as
    the benchmark reference."""
    t0 = time.perf_counter()
    evals = 0
    ops_out: list[OpDesign] = []

    for op in workload.ops:
        op_t0 = time.perf_counter()
        dense_op = _dense_view(op)
        spec_i = TensorSpec(op.i_dims(), op.sp_i, op.value_bits)
        spec_w = TensorSpec(op.w_dims(), op.sp_w, op.value_bits)
        d_i, d_w = dense_format(spec_i), dense_format(spec_w)

        # -- step 1: dense dataflow search (wider sweep, dense legality) ----
        # scored through the shared batch evaluator: the baseline keeps its
        # workflow-structure costs (wide sweep, re-modeling) but not a
        # slower per-candidate evaluator, so Table-I ratios stay structural
        dense_mappings = list(enumerate_mappings(dense_op, arch, 1.0, 1.0,
                                                 spatial_top=cfg.spatial_top * 2))
        metrics = evaluate_batch(dense_op, arch, dense_mappings,
                                 [(d_i, d_w)]).metric(cfg.objective)
        evals += len(dense_mappings)
        scored = list(zip(metrics.tolist(), dense_mappings))
        scored.sort(key=lambda t: t[0])
        # -- step 2 input: EVERY dense-legal mapping is re-modeled sparse --
        shortlist = [m for _, m in scored]

        # -- step 2: sparse feature modeling + legality corrections ---------
        # the side populations and the shuffled pair order are shared by
        # both paths (pure enumeration — the per-pair work is what differs)
        if search_formats:
            lhs, lhs_plans = _format_side(spec_i)
            rhs, rhs_plans = _format_side(spec_w)
        else:
            lhs = [_fmt_or_none(fixed_formats[0], op.i_dims())
                   if op.sp_i.density < 1 else None]
            rhs = [_fmt_or_none(fixed_formats[1], op.w_dims())
                   if op.sp_w.density < 1 else None]
            lhs_plans, rhs_plans = [None], [None]
        order = _pair_order(len(lhs), len(rhs))

        if use_batch:
            best, e = _sweep_batched(
                op, arch, cfg, shortlist, spec_i, spec_w, d_i, d_w,
                lhs, lhs_plans, rhs, rhs_plans, order, search_formats,
                budget_s_per_op, budget_pairs_per_op, op_t0, pair_log)
        else:
            best, e = _sweep_scalar(
                op, arch, cfg, shortlist, spec_i, spec_w, d_i, d_w,
                lhs, rhs, order, search_formats,
                budget_s_per_op, budget_pairs_per_op, op_t0, pair_log)
        evals += e
        if best is None:
            raise SearchError(
                f"stepwise search found no design for {op.name!r} "
                f"({len(shortlist)} dense-legal mappings, "
                f"{len(order)} format pairs)",
                op=op.name,
                pair=None if search_formats else tuple(fixed_formats or ()))
        ops_out.append(best)

    dp = DesignPoint(ops_out, None, None)
    return SearchResult(dp, evals, time.perf_counter() - t0, SearchStats())


def _format_side(spec: TensorSpec, max_levels: int = 3, alloc_cap: int = 24,
                 side_cap: int = 600
                 ) -> tuple[list[Optional[Format]], list[Optional[AllocPlan]]]:
    """One side of the unpruned format × allocation sweep (what a
    format-naive stepwise framework would have to do): every pattern ×
    allocation up to the caps, thinned to ``side_cap`` by seeded sampling
    so budget cuts don't bias toward level-1 formats.

    Enumerates :class:`~repro.core.formats.AllocPlan` rows and only builds
    :class:`Format` objects for the sampled survivors; the RNG stream (and
    hence the sampled population) is identical to the seed's Format-level
    enumeration, since sampling consumes randomness by population LENGTH
    only.  Returns (formats, plans) aligned, index 0 = dense ``None``."""
    if spec.sparsity.density >= 1.0:
        return [None], [None]
    plans: list[Optional[AllocPlan]] = [None]
    for pat in enumerate_patterns(list(spec.dims), max_levels=max_levels):
        for plan in allocation_plans(pat, spec.dims, max_allocs=alloc_cap):
            plans.append(plan)
            if len(plans) > side_cap * 4:
                break
    rng = random.Random(1)
    if len(plans) > side_cap:
        plans = [None] + rng.sample(plans[1:], side_cap - 1)
    return [None] + [p.build() for p in plans[1:]], plans


def _pair_order(n_lhs: int, n_rhs: int) -> np.ndarray:
    """The sweep's shuffled visit order over the (i, j) cross product, as a
    flat-index permutation (entry k decodes as ``divmod(k, n_rhs)``).

    Seeded and deterministic, so budget cuts hit a stable, unbiased prefix
    of the cross product; generated with numpy's PCG64 permutation rather
    than the seed's Python Fisher–Yates — the full 600×600 product shuffles
    in milliseconds instead of dominating both sweep paths' wall-clock.
    Both paths share the order, so batch-vs-scalar equivalence holds
    pair-for-pair."""
    rng = np.random.Generator(np.random.PCG64(0))
    return rng.permutation(n_lhs * n_rhs)


def _sweep_scalar(op: MatMul, arch: HardwareConfig, cfg: CoSearchConfig,
                  shortlist: list[Mapping], spec_i: TensorSpec,
                  spec_w: TensorSpec, d_i: CompiledFormat, d_w: CompiledFormat,
                  lhs: list[Optional[Format]], rhs: list[Optional[Format]],
                  order: np.ndarray, search_formats: bool,
                  budget_s_per_op: float, budget_pairs_per_op: Optional[int],
                  op_t0: float, pair_log: Optional[list]
                  ) -> tuple[Optional[OpDesign], int]:
    """The seed per-pair loop (benchmark reference): one compile + one
    Python legality scan + one evaluator call per visited pair."""
    best: Optional[OpDesign] = None
    best_metric = math.inf
    evals = 0
    visited = 0
    n_rhs = len(rhs)
    for flat in order.tolist():
        i, j = divmod(flat, n_rhs)
        fmt_i, fmt_w = lhs[i], rhs[j]
        cf_i = compile_format(fmt_i, spec_i) if fmt_i else d_i
        cf_w = compile_format(fmt_w, spec_w) if fmt_w else d_w
        cf_o = None
        if fmt_i is not None and fmt_i.name:
            cf_o = output_cf(_fixed_candidate(fmt_i.name, spec_i), op)
        ratio_i = min(cf_i.ratio, 1.0) if fmt_i else 1.0
        ratio_w = min(cf_w.ratio, 1.0) if fmt_w else 1.0
        # post-hoc legality: metadata may not fit where dense did —
        # every rejected candidate is a wasted correction-loop model call
        legal = [m for m in shortlist
                 if tile_fits(op, m.tile, arch, ratio_i, ratio_w)]
        evals += len(shortlist)
        if legal:
            bc = evaluate_batch(op, arch, legal, [(cf_i, cf_w)], cf_o)
            metrics = bc.metric(cfg.objective)
            k = int(np.argmin(metrics))
            if metrics[k] < best_metric:
                best_metric = float(metrics[k])
                best = OpDesign(op, legal[k], cf_i.fmt, cf_w.fmt,
                                bc.report(k))
        visited += 1
        if search_formats and pair_log is not None:
            pair_log.append((op.name, i, j))
        if search_formats:
            if budget_pairs_per_op is not None:
                if visited >= budget_pairs_per_op:
                    break
            elif time.perf_counter() - op_t0 > budget_s_per_op:
                break
    return best, evals


def _compile_side(fmts: Sequence[Optional[Format]],
                  plans: Sequence[Optional[AllocPlan]], spec: TensorSpec,
                  dense: CompiledFormat, used: np.ndarray
                  ) -> tuple[list[Optional[CompiledFormat]], np.ndarray]:
    """Compile one side's format population in one pass: plans group by
    pattern and score through :func:`~repro.core.sparsity.analyze_plans`
    (one vectorized walk per pattern family), each member compiling from
    its precomputed report — no per-pair ``compile_format``/``analyze``
    round trips.  Only indices in ``used`` (those reachable within the
    budgeted pair prefix) are compiled; the rest stay ``None`` with a
    placeholder ratio of 1.0, and are never gathered.  Returns (compiled
    formats, legality ratio vector)."""
    used_set = set(used.tolist())
    cfs: list[Optional[CompiledFormat]] = [None] * len(fmts)
    groups: dict[tuple, list[int]] = {}
    for idx in used_set:
        fmt, plan = fmts[idx], plans[idx]
        if fmt is None:
            cfs[idx] = dense
        elif plan is None:          # named standard format (Fixed mode)
            cfs[idx] = compile_format(fmt, spec)
        else:
            groups.setdefault(plan.pattern, []).append(idx)
    for idxs in groups.values():
        idxs.sort()
        br = analyze_plans([plans[i] for i in idxs], spec)
        for row, idx in enumerate(idxs):
            cfs[idx] = compile_format_from_report(fmts[idx], spec,
                                                  br.report(row))
    ratios = np.array([1.0 if (cf is None or fmt is None)
                       else min(cf.ratio, 1.0)
                       for fmt, cf in zip(fmts, cfs)])
    return cfs, ratios


def _sweep_batched(op: MatMul, arch: HardwareConfig, cfg: CoSearchConfig,
                   shortlist: list[Mapping], spec_i: TensorSpec,
                   spec_w: TensorSpec, d_i: CompiledFormat,
                   d_w: CompiledFormat, lhs: list[Optional[Format]],
                   lhs_plans: list[Optional[AllocPlan]],
                   rhs: list[Optional[Format]],
                   rhs_plans: list[Optional[AllocPlan]],
                   order: np.ndarray, search_formats: bool,
                   budget_s_per_op: float, budget_pairs_per_op: Optional[int],
                   op_t0: float, pair_log: Optional[list]
                   ) -> tuple[Optional[OpDesign], int]:
    """Vectorized sweep: per chunk of visited pairs, ONE ratio-vector
    legality matrix (:func:`~repro.core.dataflow.tile_fits_batch`) and ONE
    :func:`~repro.core.costmodel.evaluate_batch_gather` call over the legal
    (mapping, pair) rows — the shortlist packs once per op and rows gather
    by numpy indexing, so the per-pair Python of the seed loop disappears;
    the per-pair argmin + strict-less best update and the budget cutoff
    replay the scalar loop in visit order, so designs, pair logs and
    ``evaluations`` are bit-identical under the count-based budget."""
    n_short = len(shortlist)
    table = pack_mappings(shortlist)
    n_pairs = len(order)
    if search_formats and budget_pairs_per_op is not None:
        n_pairs = min(n_pairs, budget_pairs_per_op)
    n_rhs = len(rhs)
    # output writeback format per I-side entry (named formats only — the
    # sweep's unnamed allocations write back dense, as in the seed loop)
    cf_os = [output_cf(_fixed_candidate(f.name, spec_i), op)
             if (f is not None and f.name) else None for f in lhs]

    # Only formats reachable within the pair-visit horizon compile and
    # enter the fetch tables: the count budget fixes the horizon exactly;
    # under the wall-clock budget the horizon starts small and DOUBLES as
    # the clock allows, so a tight budget never pays full-population setup
    # for pairs it will never visit (recompiles on extension hit the memo
    # compile cache).
    lhs_cfs: list = []
    rhs_cfs: list = []
    lhs_ratio = rhs_ratio = None
    ft_i = ft_w = None
    pos_i = np.zeros(len(lhs), np.int64)
    pos_w = np.zeros(len(rhs), np.int64)

    def build_to(h: int) -> None:
        nonlocal lhs_cfs, lhs_ratio, rhs_cfs, rhs_ratio, ft_i, ft_w
        used_i = np.unique(order[:h] // n_rhs)
        used_w = np.unique(order[:h] % n_rhs)
        lhs_cfs, lhs_ratio = _compile_side(lhs, lhs_plans, spec_i, d_i,
                                           used_i)
        rhs_cfs, rhs_ratio = _compile_side(rhs, rhs_plans, spec_w, d_w,
                                           used_w)
        # per-(format, tile) fetch terms for the reachable populations,
        # one broadcast pass each — the chunk loop below only gathers;
        # pos_* maps a side index to its table row
        ft_i = format_fetch_table([lhs_cfs[k] for k in used_i.tolist()],
                                  table)
        ft_w = format_fetch_table([rhs_cfs[k] for k in used_w.tolist()],
                                  table)
        pos_i[used_i] = np.arange(len(used_i))
        pos_w[used_w] = np.arange(len(used_w))

    wall_clock = search_formats and budget_pairs_per_op is None
    horizon = min(n_pairs, 4 * _PAIR_CHUNK) if wall_clock else n_pairs
    build_to(horizon)
    # one mapping-only ctx per distinct cf_o (Search mode: just None),
    # shared by every chunk instead of rebuilt per evaluator call
    ctx_by_cfo: dict[int, object] = {}
    best: Optional[OpDesign] = None
    best_metric = math.inf
    evals = 0
    pos = 0
    while pos < n_pairs:
        if pos >= horizon:              # clock still running: extend
            horizon = min(n_pairs, horizon * 2)
            build_to(horizon)
        chunk = order[pos:min(pos + _PAIR_CHUNK, n_pairs, horizon)]
        ii = chunk // n_rhs
        jj = chunk % n_rhs
        ii_l, jj_l = ii.tolist(), jj.tolist()
        legal = tile_fits_batch(op, table.tiles, arch,
                                lhs_ratio[ii], rhs_ratio[jj])
        evals += len(chunk) * n_short
        # one evaluator call per run of equal cf_o (Search mode: one run —
        # cf_o is None for every unnamed side format)
        runs: list[tuple[Optional[CompiledFormat], int, int]] = []
        for c, i in enumerate(ii_l):
            if not runs or runs[-1][0] is not cf_os[i]:
                runs.append((cf_os[i], c, c + 1))
            else:
                runs[-1] = (runs[-1][0], runs[-1][1], c + 1)
        pair_best: list[Optional[tuple]] = [None] * len(chunk)
        for cf_o, c0, c1 in runs:
            # row r of the gather = (pair c0+pair_rows[r], map_idx[r]);
            # np.nonzero walks row-major, i.e. pairs in visit order with
            # each pair's legal mappings in shortlist order — exactly the
            # scalar loop's scan
            pair_rows, map_idx = np.nonzero(legal[c0:c1])
            if len(map_idx) == 0:
                continue
            ctx = ctx_by_cfo.get(id(cf_o))
            if ctx is None:
                ctx = ctx_by_cfo[id(cf_o)] = mapping_ctx(op, arch, table,
                                                         cf_o)
            bc = evaluate_batch_gather(op, arch, table,
                                       ft_i, pos_i[ii[c0 + pair_rows]],
                                       ft_w, pos_w[jj[c0 + pair_rows]],
                                       map_idx, cf_o, ctx=ctx,
                                       eval_threads=cfg.eval_threads)
            metrics = bc.metric(cfg.objective)
            counts = np.bincount(pair_rows, minlength=c1 - c0)
            offs = np.concatenate(([0], np.cumsum(counts)))
            for c in range(c0, c1):
                lo, hi = int(offs[c - c0]), int(offs[c - c0 + 1])
                if hi > lo:
                    k = lo + int(np.argmin(metrics[lo:hi]))
                    pair_best[c] = (float(metrics[k]), bc, k,
                                    shortlist[int(map_idx[k])])
        # strict-less replay of the scalar loop's best update, visit order
        for c, (i, j) in enumerate(zip(ii_l, jj_l)):
            if search_formats and pair_log is not None:
                pair_log.append((op.name, i, j))
            pb = pair_best[c]
            if pb is not None and pb[0] < best_metric:
                metric, bc, k, mapping = pb
                best_metric = metric
                best = OpDesign(op, mapping, lhs_cfs[i].fmt, rhs_cfs[j].fmt,
                                bc.report(k))
        pos += len(chunk)
        if search_formats and budget_pairs_per_op is None and \
                time.perf_counter() - op_t0 > budget_s_per_op:
            break
    return best, evals


# ---------------------------------------------------------------------------
# DiMO-Sparse-like iterative mapping optimizer (preset format, CNNs)
# ---------------------------------------------------------------------------

def dimo_like_search(workload: Workload, arch: HardwareConfig,
                     cfg: CoSearchConfig = CoSearchConfig(),
                     fixed_formats: tuple[Optional[str], Optional[str]] = ("Bitmap", "Bitmap"),
                     restarts: int = 12, iters: int = 200,
                     seed: int = 0, use_batch: bool = True) -> SearchResult:
    """Random-restart coordinate descent over mappings with a preset format —
    a stand-in for DiMO-Sparse's differentiable-relaxation loop, which needs
    many model evaluations per op to converge.

    ``use_batch=True`` precomputes the metric of EVERY mapping with one
    :func:`evaluate_batch` call per op and replays the seeded random walk as
    pure array indexing: the walk only ever accepts a strictly better
    candidate, so each restart segment resolves to the FIRST draw attaining
    the segment's running minimum (``argmin`` with first-occurrence ties),
    and the cross-restart winner to the first strict minimum over segment
    bests.  Same RNG stream (one ``_randbelow`` per draw, as ``rng.choice``
    consumed), bit-identical designs, and ``evaluations`` still counts the
    walk's model queries (the algorithmic cost of a DiMO-style tuner — what
    Table I compares), not the internal batching.  ``use_batch=False`` keeps
    the legacy per-draw scalar loop as the benchmark reference."""
    t0 = time.perf_counter()
    rng = random.Random(seed)
    evals = 0
    ops_out: list[OpDesign] = []
    steps = iters // restarts
    for op in workload.ops:
        spec_i = TensorSpec(op.i_dims(), op.sp_i, op.value_bits)
        spec_w = TensorSpec(op.w_dims(), op.sp_w, op.value_bits)
        fmt_i = _fmt_or_none(fixed_formats[0], op.i_dims()) if op.sp_i.density < 1 else None
        fmt_w = _fmt_or_none(fixed_formats[1], op.w_dims()) if op.sp_w.density < 1 else None
        cf_i = compile_format(fmt_i, spec_i) if fmt_i else dense_format(spec_i)
        cf_w = compile_format(fmt_w, spec_w) if fmt_w else dense_format(spec_w)
        cf_o = None
        if fmt_i is not None and fmt_i.name:
            cf_o = output_cf(_fixed_candidate(fmt_i.name, spec_i), op)

        all_mappings = list(enumerate_mappings(op, arch, 1.0, 1.0,
                                               spatial_top=cfg.spatial_top))

        if use_batch:
            bc = evaluate_batch(op, arch, all_mappings, [(cf_i, cf_w)], cf_o)
            metrics = bc.metric(cfg.objective)
            n = len(all_mappings)
            # identical RNG stream: rng.choice(seq) is seq[_randbelow(len)]
            draws = np.array([rng.randrange(n)
                              for _ in range(restarts * (1 + steps))],
                             np.int64).reshape(restarts, 1 + steps)
            evals += restarts * (1 + steps)
            seg = metrics[draws]                      # (restarts, 1+steps)
            pos = seg.argmin(axis=1)                  # first draw at seg min
            per_restart = seg[np.arange(restarts), pos]
            r = int(np.argmin(per_restart))           # first strict winner
            j = int(draws[r, pos[r]])
            ops_out.append(OpDesign(op, all_mappings[j], cf_i.fmt, cf_w.fmt,
                                    bc.report(j)))
            continue

        best: Optional[OpDesign] = None
        for _ in range(restarts):
            cur = rng.choice(all_mappings)
            cur_cost = evaluate(op, arch, cur, cf_i, cf_w, cf_o)
            evals += 1
            for _ in range(steps):
                nxt = rng.choice(all_mappings)
                c = evaluate(op, arch, nxt, cf_i, cf_w, cf_o)
                evals += 1
                if c.metric(cfg.objective) < cur_cost.metric(cfg.objective):
                    cur, cur_cost = nxt, c
            if best is None or cur_cost.metric(cfg.objective) < best.cost.metric(cfg.objective):
                best = OpDesign(op, cur, cf_i.fmt, cf_w.fmt, cur_cost)
        assert best is not None
        ops_out.append(best)
    dp = DesignPoint(ops_out, None, None)
    return SearchResult(dp, evals, time.perf_counter() - t0, SearchStats())
