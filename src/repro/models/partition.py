"""Parameter / cache PartitionSpecs — Megatron-style TP on the ``model``
axis, DP over ``data`` (+``pod``), EP for MoE experts.

Rules are applied by leaf path + array rank, with leading stack axes
(scan-over-layers) padded with ``None``.  A dim is only sharded when its
extent divides the mesh axis size — otherwise the spec falls back to
replication for that dim (e.g. 8 KV heads on a 16-way model axis shard the
cache's SEQUENCE axis instead: flash-decode-style sequence parallelism).
"""

from __future__ import annotations

from typing import Any, Optional  # noqa: F401

import jax
from jax.sharding import PartitionSpec as P

from repro.models import optflags

PyTree = Any

# trailing-dims rules: leaf-name → (spec for last N dims)
_COL = ("wq", "wk", "wv", "w_gate", "w_up", "w_in", "w_x", "w_y",
        "w_a", "w_i")                       # (d_model, wide): shard wide
_ROW = ("wo", "w_down", "w_out")            # (wide, d_model): shard wide
_REPL = ("ln1", "ln2", "ln3", "final_norm", "enc_norm", "norm", "conv",
         "lam", "A_log", "D", "dt_bias", "router")


def _last_name(path) -> str:
    for e in reversed(path):
        if isinstance(e, jax.tree_util.DictKey):
            return str(e.key)
    return ""


def _divisible(extent: int, axis_size: Optional[int]) -> bool:
    return axis_size is not None and axis_size > 0 and extent % axis_size == 0


def param_specs(params: PyTree, mesh_axes: dict[str, int],
                data_axes: tuple[str, ...] = ("data",),
                model_axis: str = "model",
                kv_heads: Optional[int] = None) -> PyTree:
    """PartitionSpec pytree matching ``params``.

    ``mesh_axes`` maps axis name → size (for divisibility checks).
    Optimizer-state sharding reuses these specs (ZeRO-style: states shard
    exactly like their parameters).  With optflag 'replkv', K/V projections
    whose head count does not divide the TP degree are REPLICATED (they are
    small under GQA) — sharding their flat output forces XLA into
    replicate-and-repartition copies at the (B,S,H,D) reshape.
    """
    msize = mesh_axes.get(model_axis, 1)
    repl_kv = (optflags.enabled("replkv") and
               (kv_heads is None or kv_heads % msize != 0))

    def rule(path, leaf) -> P:
        name = _last_name(path)
        nd = leaf.ndim
        path_s = jax.tree_util.keystr(path)
        if name == "embed":
            if _divisible(leaf.shape[0], msize):
                return P(model_axis, None)
            return P(None, None)
        if name in ("payload_gate", "payload_up") and nd >= 4:
            # block-sparse FFN payload (…, gk, T, bn, bk): block-column EP
            if _divisible(leaf.shape[-4], msize):
                return P(*([None] * (nd - 4)), model_axis, None, None, None)
            return P(*([None] * nd))
        if name in ("rows_gate", "rows_up") and nd >= 2:
            if _divisible(leaf.shape[-2], msize):
                return P(*([None] * (nd - 2)), model_axis, None)
            return P(*([None] * nd))
        if name in ("wk", "wv") and repl_kv:
            return P(*([None] * nd))
        if name in _REPL or name == "_meta":
            return P(*([None] * nd))
        if name in ("w_gate", "w_up", "w_down") and "ffn" in path_s and nd >= 3:
            # MoE expert stacks (…, E, d, f): shard the expert axis (EP)
            if _divisible(leaf.shape[-3], msize):
                return P(*([None] * (nd - 3)), model_axis, None, None)
            return P(*([None] * nd))
        if name in _COL and nd >= 2:
            if _divisible(leaf.shape[-1], msize):
                return P(*([None] * (nd - 1)), model_axis)
            return P(*([None] * nd))
        if name in _ROW and nd >= 2:
            if _divisible(leaf.shape[-2], msize):
                return P(*([None] * (nd - 2)), model_axis, None)
            return P(*([None] * nd))
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(rule, params)


def cache_specs(cache: PyTree, mesh_axes: dict[str, int],
                data_axes: tuple[str, ...] = ("data",),
                model_axis: str = "model") -> PyTree:
    """Decode-cache sharding.

    KV caches are (L, B, S, H, D): batch → data; heads → model when
    divisible, else the SEQUENCE axis → model (KV sequence parallelism —
    each model shard holds a slice of the context, softmax combines via
    XLA-inserted collectives).  States (L, B, …) shard batch + the widest
    divisible feature axis.
    """
    msize = mesh_axes.get(model_axis, 1)
    dsize = 1
    for a in data_axes:
        dsize *= mesh_axes.get(a, 1)
    batch_spec = data_axes if len(data_axes) > 1 else data_axes[0]

    def rule(path, leaf) -> P:
        nd = leaf.ndim
        if nd == 0:
            return P()
        name = _last_name(path)
        if name in ("k", "v") and nd == 5:
            l, b, s, h, d = leaf.shape
            bspec = batch_spec if b % dsize == 0 else None
            if h % msize == 0:
                return P(None, bspec, None, model_axis, None)
            if s % msize == 0:
                return P(None, bspec, model_axis, None, None)
            return P(None, bspec, None, None, None)
        # generic states: (L, B, …) — shard batch; widest divisible tail axis
        spec: list = [None] * nd
        if nd >= 2 and leaf.shape[1] % dsize == 0:
            spec[1] = batch_spec
        for ax in range(nd - 1, 1, -1):
            if leaf.shape[ax] % msize == 0 and leaf.shape[ax] >= msize:
                spec[ax] = model_axis
                break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, cache)


def batch_specs(batch: PyTree, mesh_axes: dict[str, int],
                data_axes: tuple[str, ...] = ("data",)) -> PyTree:
    """Input batches shard the leading (batch) axis over the data axes —
    replicated when the batch doesn't divide (e.g. long_500k's batch=1)."""
    bspec = data_axes if len(data_axes) > 1 else data_axes[0]
    dsize = 1
    for a in data_axes:
        dsize *= mesh_axes.get(a, 1)

    def rule(path, leaf) -> P:
        nd = getattr(leaf, "ndim", 0)
        if nd == 0:
            return P()
        if leaf.shape[0] % dsize != 0:
            return P(*([None] * nd))
        return P(*([bspec] + [None] * (nd - 1)))

    return jax.tree_util.tree_map_with_path(rule, batch)
