"""Mamba-2 SSD (state-space duality) layer — chunked parallel scan.

Implements the SSD algorithm of arXiv:2405.21060: the sequence is split into
chunks; within a chunk the recurrence is computed as masked matmuls
("attention-like" duality), and chunk states are propagated by a short
``lax.scan`` over chunks.  Per-head scalar decay A (Mamba-2 restriction),
B/C projections shared across heads in a group (we use one group).

Decode is the O(1) recurrent step on the carried state
(B, heads, head_dim, d_state).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import COMPUTE_DTYPE, _init
from repro.models.sharding import shard


def ssm_params(key, cfg: ModelConfig) -> dict:
    s = cfg.ssm
    assert s is not None
    d = cfg.d_model
    d_in = d * s.expand
    nh = d_in // s.head_dim
    ks = jax.random.split(key, 7)
    return {
        # in_proj emits [x, z(gate), B, C, dt]
        "w_in": _init(ks[0], (d, 2 * d_in + 2 * s.d_state + nh)),
        "w_out": _init(ks[1], (d_in, d)),
        "conv": _init(ks[2], (s.d_conv, d_in + 2 * s.d_state), scale_axis=0),
        "A_log": jnp.zeros((nh,), jnp.float32) + jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.zeros((d_in,), jnp.float32),
    }


def _split_proj(xz: jax.Array, cfg: ModelConfig):
    s = cfg.ssm
    d_in = cfg.d_model * s.expand
    nh = d_in // s.head_dim
    x, z, Bm, Cm, dt = jnp.split(
        xz, [d_in, 2 * d_in, 2 * d_in + s.d_state,
             2 * d_in + 2 * s.d_state], axis=-1)
    return x, z, Bm, Cm, dt, nh, d_in


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv along S. x: (B, S, C), w: (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):                      # K is tiny (4): unrolled
        out = out + xp[:, i: i + x.shape[1], :] * w[i]
    return out


def ssd_chunked(xh, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD scan.

    xh: (B, S, H, P) values; dt: (B, S, H) softplus'd step; A: (H,) decay
    rate (negative); Bm/Cm: (B, S, N) input/output projections.
    Returns y: (B, S, H, P) and final state (B, H, P, N).
    """
    b, s, h, p = xh.shape
    n = Bm.shape[-1]
    nc = s // chunk
    # (nc, B, L, ...) layout: one lax.scan over chunks does BOTH the
    # intra-chunk masked matmul and the inter-chunk state recurrence, so the
    # O(L²) score tensor is live for a single chunk only.
    xc = jnp.moveaxis(xh.reshape(b, nc, chunk, h, p), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(b, nc, chunk, h), 1, 0)
    Bc = jnp.moveaxis(Bm.reshape(b, nc, chunk, n), 1, 0)
    Cc = jnp.moveaxis(Cm.reshape(b, nc, chunk, n), 1, 0)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(state, inp):
        xi, dti, Bi, Ci = inp                           # (B,L,H,P) (B,L,H) ...
        dA = dti * A[None, None, :]                     # (B,L,H) ≤ 0
        cum = jnp.cumsum(dA, axis=1)
        seg = cum[:, -1, :]                             # (B,H) chunk decay
        # L_mat[i,j] = exp(cum_i - cum_j), i ≥ j.  Mask BEFORE exp: the
        # upper triangle has diff > 0 and exp would overflow to inf, which
        # poisons gradients through the where (NaN via inf·0).
        diff = cum[:, :, None, :] - cum[:, None, :, :]  # (B,L,L,H)
        diff = jnp.where(mask[None, :, :, None], diff, -jnp.inf)
        l_mat = jnp.exp(diff)
        scores = jnp.einsum("bin,bjn->bij", Ci, Bi)     # (B,L,L)
        gated = (scores[..., None] * l_mat *
                 dti[:, None, :, :]).astype(COMPUTE_DTYPE)
        y_diag = jnp.einsum("bijh,bjhp->bihp", gated, xi)
        # carried-state contribution to each position
        w_in = jnp.exp(cum).astype(COMPUTE_DTYPE)
        y_off = jnp.einsum("bln,blh,bhpn->blhp",
                           Ci.astype(COMPUTE_DTYPE), w_in, state)
        # state update: decay whole chunk + decay-to-end-weighted inputs
        w_end = jnp.exp(seg[:, None, :] - cum)          # (B,L,H)
        st_in = jnp.einsum("bln,blh,blhp->bhpn", Bi.astype(COMPUTE_DTYPE),
                           (w_end * dti).astype(COMPUTE_DTYPE), xi)
        new_state = state * jnp.exp(seg)[..., None, None].astype(state.dtype) \
            + st_in
        return new_state, y_diag + y_off

    init = jnp.zeros((b, h, p, n), COMPUTE_DTYPE)
    final, ys = jax.lax.scan(step, init, (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)
    return y, final


def ssm_block(x: jax.Array, p: dict, cfg: ModelConfig
              ) -> tuple[jax.Array, jax.Array]:
    """Full Mamba-2 mixer over a sequence.  x: (B, S, d) → (y, final_state)."""
    s_cfg = cfg.ssm
    b, s, d = x.shape
    xz = jnp.einsum("btd,de->bte", x, p["w_in"].astype(COMPUTE_DTYPE))
    xi, z, Bm, Cm, dt, nh, d_in = _split_proj(xz, cfg)
    conv_in = jnp.concatenate([xi, Bm, Cm], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv"].astype(COMPUTE_DTYPE)))
    xi, Bm, Cm = jnp.split(conv_out, [d_in, d_in + s_cfg.d_state], axis=-1)
    xh = xi.reshape(b, s, nh, s_cfg.head_dim)
    xh = shard(xh, "batch", None, "model", None)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    chunk = min(s_cfg.chunk, s)
    y, state = ssd_chunked(xh, dt, A, Bm, Cm, chunk)
    y = y + xh * p["D"].astype(COMPUTE_DTYPE)[None, None, :, None]
    y = y.reshape(b, s, d_in)
    y = y * jax.nn.silu(z)                               # gated output
    out = jnp.einsum("bte,ed->btd", y, p["w_out"].astype(COMPUTE_DTYPE))
    return out, state


def ssm_decode(x: jax.Array, p: dict, cfg: ModelConfig,
               state: jax.Array, conv_state: jax.Array
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """O(1) recurrent step.  x: (B, d); state: (B, H, P, N);
    conv_state: (B, K-1, conv_channels) rolling window."""
    s_cfg = cfg.ssm
    b, d = x.shape
    xz = jnp.einsum("bd,de->be", x, p["w_in"].astype(COMPUTE_DTYPE))
    xi, z, Bm, Cm, dt, nh, d_in = _split_proj(xz, cfg)
    conv_in = jnp.concatenate([xi, Bm, Cm], axis=-1)     # (B, C)
    window = jnp.concatenate([conv_state, conv_in[:, None, :]], axis=1)
    w = p["conv"].astype(COMPUTE_DTYPE)
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, w))
    new_conv_state = window[:, 1:, :]
    xi, Bm, Cm = jnp.split(conv_out, [d_in, d_in + s_cfg.d_state], axis=-1)
    xh = xi.reshape(b, nh, s_cfg.head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B, H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A[None, :])                     # (B, H)
    upd = jnp.einsum("bhp,bn,bh->bhpn", xh, Bm.astype(COMPUTE_DTYPE),
                     dt.astype(COMPUTE_DTYPE))
    state = state * decay[..., None, None].astype(state.dtype) + upd
    y = jnp.einsum("bhpn,bn->bhp", state, Cm.astype(COMPUTE_DTYPE))
    y = y + xh * p["D"].astype(COMPUTE_DTYPE)[None, :, None]
    y = y.reshape(b, d_in) * jax.nn.silu(z)
    out = jnp.einsum("be,ed->bd", y, p["w_out"].astype(COMPUTE_DTYPE))
    return out, state, new_conv_state
