"""Model assembly for all assigned families.

Design rules:
  * layers are STACKED and consumed by ``jax.lax.scan`` — HLO is O(1) in
    depth (62-layer models compile in seconds, not minutes);
  * heterogeneous stacks (Gemma3 5:1 local:global, RecurrentGemma 1:2
    attn:recurrent) scan over SUPER-BLOCKS whose bodies apply the exact
    interleave, with a small tail stack for the remainder;
  * every train-mode layer body is wrapped in ``jax.checkpoint`` (remat) so
    activation memory is O(layers · boundary), not O(layers · internals);
  * decode carries stacked caches (KV rings for local attention, full KV for
    global, SSM/LRU states) and updates them functionally via scan outputs.

The public surface is :class:`Model` (init / loss / prefill / decode_step /
init_cache) + :func:`input_specs`.

Execution-plane integration: every FFN/attention projection einsum routes
through :func:`repro.models.layers.proj` (a per-role dispatch point).  The
dense model runs it hook-free; :class:`repro.exec.dispatch.CompressedModel`
installs a hook and drives the SAME scanned stack with an ``extras`` pytree
(layer-stacked compressed operands, leading axis = layer): the scan body
installs each layer's slice via :func:`repro.models.layers.layer_ctx`, so
planned projections resolve their per-layer payloads from inside ONE
compiled scanned block instead of a per-layer Python re-drive.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ModelConfig, ShapeCfg
from repro.models import attention as attn
from repro.models import optflags
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import rglru as rg
from repro.models import ssm as ssm_mod
from repro.models.sharding import shard

PyTree = Any


def _ckpt(fn):
    """Remat wrapper.  With 'saveremat', tensors named 'ar_out' (the
    post-all-reduce block outputs) are SAVED, so the backward pass never
    replays TP collectives — Megatron-style selective recompute."""
    if optflags.enabled("saveremat"):
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.save_only_these_names("ar_out"))
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Per-kind layer params
# ---------------------------------------------------------------------------

def _layer_params(key, cfg: ModelConfig, kind: str) -> dict:
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    p: dict = {"ln1": jnp.zeros((d,), jnp.float32),
               "ln2": jnp.zeros((d,), jnp.float32)}
    if kind in ("attn", "local", "global", "cross"):
        p["attn"] = L.attn_params(ks[0], cfg)
        if kind == "cross":
            p["cross"] = L.attn_params(ks[2], cfg)
            p["ln3"] = jnp.zeros((d,), jnp.float32)
        if cfg.moe:
            p["ffn"] = moe_mod.moe_params(ks[1], cfg)
        elif optflags.enabled("sparseffn") and cfg.sparse_ffn:
            p["ffn"] = L.sparse_mlp_params(ks[1], cfg)
        else:
            p["ffn"] = L.mlp_params(ks[1], cfg)
    elif kind == "rec":
        p["rec"] = rg.rglru_params(ks[0], cfg)
        p["ffn"] = L.mlp_params(ks[1], cfg)
    elif kind == "ssm":
        p["ssm"] = ssm_mod.ssm_params(ks[0], cfg)
        del p["ln2"]
    else:
        raise ValueError(kind)
    return p


def _stack(key, n: int, make) -> PyTree:
    """Stack n independently-initialized param pytrees along axis 0."""
    keys = jax.random.split(key, n)
    trees = [make(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


#: Families the cache/decode machinery knows how to serve.  Anything else
#: must fail LOUDLY: the decode-path switches below all end in a default
#: branch, so an unknown family would otherwise silently get the uniform
#: dense cache and mis-serve instead of raising.
KNOWN_FAMILIES = ("dense", "moe", "vlm", "ssm", "hybrid", "encdec")


def _check_family(cfg: ModelConfig) -> None:
    if cfg.family not in KNOWN_FAMILIES:
        raise ValueError(
            f"unknown model family {cfg.family!r} for {cfg.name}: cannot "
            f"build a decode cache (known: {', '.join(KNOWN_FAMILIES)})")


def _uniform_stack(cfg: ModelConfig) -> bool:
    """True when the model is one homogeneous scanned attention stack (the
    families prefill / extras-threading support)."""
    return cfg.family in ("dense", "moe", "vlm") and cfg.hybrid is None


# ---------------------------------------------------------------------------
# Forward bodies (train/prefill mode)
# ---------------------------------------------------------------------------

def _ffn_apply(x, p, cfg: ModelConfig):
    if cfg.moe:
        return moe_mod.moe_block(x, p, cfg)
    return L.mlp(x, p)


def _seqpar(x):
    """Sequence-parallel residual stream (optflag 'seqpar'): shard S on the
    model axis between blocks — XLA then lowers the TP psum as
    reduce-scatter and re-gathers at the next projection."""
    if optflags.enabled("seqpar") and x.ndim == 3 and x.shape[1] % 16 == 0:
        return shard(x, "batch", "model", None)
    return x


def _attn_layer(x, p, cfg, freqs, positions, *, causal=True, window=0,
                kv_override=None, return_kv=False):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    a = attn.attention_block(h, p["attn"], cfg, freqs, positions,
                             causal=causal, window=window,
                             return_kv=return_kv)
    if return_kv:
        a, kv_k, kv_v = a
    x = x + checkpoint_name(a, "ar_out")
    x = _seqpar(x)
    if kv_override is not None:
        h = L.rms_norm(x, p["ln3"], cfg.norm_eps)
        x = x + attn.attention_block(h, p["cross"], cfg, None, positions,
                                     causal=False, kv_override=kv_override)
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    out = _seqpar(x + checkpoint_name(_ffn_apply(h, p["ffn"], cfg),
                                      "ar_out"))
    if return_kv:
        return out, kv_k, kv_v
    return out


def _rec_layer(x, p, cfg):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    y, h_last, conv_tail = rg.rglru_block(h, p["rec"], cfg)
    x = x + y
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + L.mlp(h, p["ffn"]), (h_last, conv_tail)


def _ssm_layer(x, p, cfg):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    y, state = ssm_mod.ssm_block(h, p["ssm"], cfg)
    return x + y, state


# ---------------------------------------------------------------------------
# Stack runners (scan over stacked layer params)
# ---------------------------------------------------------------------------

def _run_uniform(x, stacked, cfg: ModelConfig, freqs, positions, kind: str,
                 remat: bool, window: int = 0, extras=None):
    """Scan the uniform layer stack.

    ``extras`` is an optional pytree with a leading layer axis (e.g. a
    :class:`repro.exec.compress.StackedStore`'s payloads).  It rides the
    scan's xs; the body publishes each layer's slice through
    ``L.layer_ctx`` so the proj hook can resolve layer-varying operands
    while the compiled graph stays one scanned block."""
    causal = cfg.family != "encdec" or kind != "enc"

    def body(h, sl):
        p, e = sl
        with L.layer_ctx(e):
            if kind == "ssm":
                out, _ = _ssm_layer(h, p, cfg)
            else:
                out = _attn_layer(h, p, cfg, freqs, positions,
                                  causal=causal, window=window)
        return out, None

    fn = _ckpt(body) if remat else body
    x, _ = jax.lax.scan(fn, x, (stacked, extras))
    return x


def _run_gemma3(x, params, cfg: ModelConfig, freqs_l, freqs_g, positions,
                remat: bool):
    """10×(5 local + 1 global) + 2 local."""
    def super_block(h, p):
        def local_body(hh, pp):
            return _attn_layer(hh, pp, cfg, freqs_l, positions, causal=True,
                               window=cfg.window), None

        def global_body(hh, pp):
            return _attn_layer(hh, pp, cfg, freqs_g, positions, causal=True)

        lb = _ckpt(local_body) if remat else local_body
        h, _ = jax.lax.scan(lb, h, p["local"])
        gb = _ckpt(global_body) if remat else global_body
        h = gb(h, p["global"])
        return h, None

    x, _ = jax.lax.scan(super_block, x, params["super"])
    def tail_body(hh, pp):
        return _attn_layer(hh, pp, cfg, freqs_l, positions, causal=True,
                           window=cfg.window), None
    tb = _ckpt(tail_body) if remat else tail_body
    x, _ = jax.lax.scan(tb, x, params["tail"])
    return x


def _run_recurrentgemma(x, params, cfg: ModelConfig, freqs, positions,
                        remat: bool):
    """8×(rec, rec, attn) + 2 rec."""
    def super_block(h, p):
        h, _ = _rec_layer(h, p["rec1"], cfg)
        h, _ = _rec_layer(h, p["rec2"], cfg)
        h = _attn_layer(h, p["attn"], cfg, freqs, positions, causal=True,
                        window=cfg.window)
        return h, None

    sb = _ckpt(super_block) if remat else super_block
    x, _ = jax.lax.scan(sb, x, params["super"])

    def tail(h, p):
        h, _ = _rec_layer(h, p, cfg)
        return h, None
    tl = _ckpt(tail) if remat else tail
    x, _ = jax.lax.scan(tl, x, params["tail"])
    return x


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---------------- params ----------------
    def init(self, rng) -> PyTree:
        cfg = self.cfg
        k_emb, k_layers, k_enc, k_tail = jax.random.split(rng, 4)
        params: dict = {
            "embed": L._init(k_emb, (cfg.vocab, cfg.d_model), scale_axis=1),
            "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        }
        if cfg.family in ("dense", "moe", "vlm") and cfg.hybrid is None:
            params["blocks"] = _stack(
                k_layers, cfg.n_layers,
                lambda k: _layer_params(k, cfg, "attn"))
        elif cfg.name.startswith("gemma3"):
            n_super = (cfg.n_layers - len(cfg.hybrid.tail)) // 6
            params["super"] = _stack(k_layers, n_super, lambda k: {
                "local": _stack(jax.random.fold_in(k, 0), 5,
                                lambda kk: _layer_params(kk, cfg, "local")),
                "global": _layer_params(jax.random.fold_in(k, 1), cfg, "global"),
            })
            params["tail"] = _stack(k_tail, len(cfg.hybrid.tail),
                                    lambda k: _layer_params(k, cfg, "local"))
        elif cfg.family == "hybrid":
            n_super = (cfg.n_layers - len(cfg.hybrid.tail)) // 3
            params["super"] = _stack(k_layers, n_super, lambda k: {
                "rec1": _layer_params(jax.random.fold_in(k, 0), cfg, "rec"),
                "rec2": _layer_params(jax.random.fold_in(k, 1), cfg, "rec"),
                "attn": _layer_params(jax.random.fold_in(k, 2), cfg, "attn"),
            })
            params["tail"] = _stack(k_tail, len(cfg.hybrid.tail),
                                    lambda k: _layer_params(k, cfg, "rec"))
        elif cfg.family == "ssm":
            params["blocks"] = _stack(k_layers, cfg.n_layers,
                                      lambda k: _layer_params(k, cfg, "ssm"))
        elif cfg.family == "encdec":
            params["enc_blocks"] = _stack(
                k_enc, cfg.enc_layers, lambda k: _layer_params(k, cfg, "attn"))
            params["blocks"] = _stack(
                k_layers, cfg.n_layers,
                lambda k: _layer_params(k, cfg, "cross"))
            params["enc_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
        else:
            raise ValueError(cfg.family)
        return params

    # ---------------- forward (train / prefill hidden states) ----------------
    def hidden_states(self, params: PyTree, tokens: jax.Array,
                      enc_frames: Optional[jax.Array] = None,
                      remat: bool = True, extras: PyTree = None) -> jax.Array:
        cfg = self.cfg
        b, s = tokens.shape
        if extras is not None and not _uniform_stack(cfg):
            raise NotImplementedError(
                "extras (layer-stacked operands) need a uniform layer stack")
        x = L.embed(tokens, params["embed"])
        positions = jnp.arange(s)
        freqs = L.rope_freqs(cfg)
        if cfg.family == "encdec":
            assert enc_frames is not None, "encdec needs encoder frames"
            enc = enc_frames.astype(L.COMPUTE_DTYPE) + _sinusoid(
                cfg.enc_seq, cfg.d_model)
            enc = _run_uniform(enc, params["enc_blocks"], cfg, None,
                               jnp.arange(cfg.enc_seq), "enc", remat)
            enc = L.rms_norm(enc, params["enc_norm"], cfg.norm_eps)
            x = x + _sinusoid(s, cfg.d_model)

            def body(h, p):
                return _attn_layer(h, p, cfg, None, positions, causal=True,
                                   kv_override=(enc, enc)), None
            fn = _ckpt(body) if remat else body
            x, _ = jax.lax.scan(fn, x, params["blocks"])
        elif cfg.name.startswith("gemma3"):
            x = _run_gemma3(x, params, cfg, freqs, freqs, positions, remat)
        elif cfg.family == "hybrid":
            x = _run_recurrentgemma(x, params, cfg, freqs, positions, remat)
        elif cfg.family == "ssm":
            x = _run_uniform(x, params["blocks"], cfg, None, positions,
                             "ssm", remat)
        else:
            x = _run_uniform(x, params["blocks"], cfg, freqs, positions,
                             "attn", remat, window=cfg.window, extras=extras)
        return L.rms_norm(x, params["final_norm"], cfg.norm_eps)

    def loss(self, params: PyTree, batch: dict) -> jax.Array:
        x = self.hidden_states(params, batch["tokens"],
                               batch.get("enc_frames"))
        return L.unembed_loss(x, params["embed"], batch["labels"])

    # ---------------- decode ----------------
    def prefill(self, params: PyTree, tokens: jax.Array, max_len: int,
                extras: PyTree = None) -> tuple[jax.Array, PyTree]:
        """Full-sequence forward that ALSO fills a fresh decode cache.

        One batched pass replaces the token-by-token decode_step ingest:
        the layer scan's ys carry each layer's post-RoPE, pre-GQA-repeat
        (K, V) — exactly what :func:`attention_decode_block` would have
        written — so ``decode_step(pos=s)`` continues seamlessly.  Returns
        (logits (B, S, V) float32, cache).

        Uniform full-attention stacks only (``window`` rings and
        hybrid/ssm/encdec states keep the token-by-token path — see
        ``launch.serve.generate``'s fallback).
        """
        cfg = self.cfg
        if not _uniform_stack(cfg) or cfg.window:
            raise NotImplementedError(
                "prefill: uniform full-attention stacks only")
        b, s = tokens.shape
        if s > max_len:
            raise ValueError(f"prompt ({s}) exceeds max_len ({max_len})")
        x = L.embed(tokens, params["embed"])
        positions = jnp.arange(s)
        freqs = L.rope_freqs(cfg)

        def body(h, sl):
            p, e = sl
            with L.layer_ctx(e):
                out, k, v = _attn_layer(h, p, cfg, freqs, positions,
                                        causal=True, return_kv=True)
            return out, (k, v)

        x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], extras))
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("btd,vd->btv", x,
                            params["embed"].astype(L.COMPUTE_DTYPE))
        logits = shard(logits.astype(jnp.float32), "batch", None, "vocab")
        cache = self.init_cache(b, max_len)
        dt = cache["self"]["k"].dtype
        cache["self"]["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["self"]["k"], ks.astype(dt), 0, axis=2)
        cache["self"]["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["self"]["v"], vs.astype(dt), 0, axis=2)
        return logits, cache

    def init_cache(self, batch: int, max_len: int) -> PyTree:
        """Zeroed decode caches sized for ``max_len`` context."""
        cfg = self.cfg
        _check_family(cfg)
        hd, nk = cfg.head_dim, max(cfg.n_kv_heads, 1)
        dt = L.COMPUTE_DTYPE

        def kv(n_layers, length):
            shape = (n_layers, batch, length, nk, hd)
            return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}

        if cfg.family == "ssm":
            s = cfg.ssm
            d_in = cfg.d_model * s.expand
            nh = d_in // s.head_dim
            conv_c = d_in + 2 * s.d_state
            return {
                "state": jnp.zeros((cfg.n_layers, batch, nh, s.head_dim,
                                    s.d_state), dt),
                "conv": jnp.zeros((cfg.n_layers, batch, s.d_conv - 1,
                                   conv_c), dt),
            }
        if cfg.name.startswith("gemma3"):
            n_super = (cfg.n_layers - 2) // 6
            win = min(cfg.window, max_len)
            return {
                "local": kv(n_super * 5 + 2, win),
                "global": kv(n_super, max_len),
            }
        if cfg.family == "hybrid":
            n_super = (cfg.n_layers - 2) // 3
            dr = cfg.d_model
            win = min(cfg.window, max_len) if cfg.window else max_len
            return {
                "attn": kv(n_super, win),
                "h": jnp.zeros((n_super * 2 + 2, batch, dr), dt),
                "conv": jnp.zeros((n_super * 2 + 2, batch, 3, dr), dt),
            }
        if cfg.family == "encdec":
            return {
                "self": kv(cfg.n_layers, max_len),
                "cross": kv(cfg.n_layers, cfg.enc_seq),
                "cross_ready": jnp.zeros((), jnp.int32),
            }
        return {"self": kv(cfg.n_layers, max_len)}

    def decode_step(self, params: PyTree, cache: PyTree, tokens: jax.Array,
                    pos: jax.Array, extras: PyTree = None
                    ) -> tuple[jax.Array, PyTree]:
        """One token for the whole batch.  tokens: (B,); pos: scalar for a
        lockstep batch, or a (B,) per-slot position vector (continuous
        batching: each batch row is an independent KV slot decoding at its
        own position — RoPE, cache writes, and the causal mask all follow
        the row's own position; see :mod:`repro.launch.mixer`).  Returns
        (logits (B, V), new cache).  ``extras``: optional layer-stacked
        operand pytree riding the scan (see _run_uniform)."""
        cfg = self.cfg
        _check_family(cfg)
        pos = jnp.asarray(pos)
        if pos.ndim not in (0, 1) or \
                (pos.ndim == 1 and pos.shape[0] != tokens.shape[0]):
            raise ValueError(
                f"decode_step: pos must be a scalar or a per-slot vector "
                f"matching the batch ({tokens.shape[0]},); got {pos.shape}")
        if extras is not None and not _uniform_stack(cfg):
            raise NotImplementedError(
                "extras (layer-stacked operands) need a uniform layer stack")
        x = jnp.take(params["embed"], tokens, axis=0).astype(L.COMPUTE_DTYPE)
        freqs = L.rope_freqs(cfg)

        def attn_step(h, p, kc, vc, cache_pos):
            hn = L.rms_norm(h[:, None], p["ln1"], cfg.norm_eps)[:, 0]
            y, kc, vc = attn.attention_decode_block(
                hn, p["attn"], cfg, freqs, pos, kc, vc, cache_pos)
            h = h + y
            hn = L.rms_norm(h[:, None], p["ln2"], cfg.norm_eps)[:, 0]
            if cfg.moe:
                h = h + moe_mod.moe_decode(hn, p["ffn"], cfg)
            elif "payload_gate" in p["ffn"]:
                h = h + L.sparse_mlp_decode(hn, p["ffn"])
            else:
                h = h + L.mlp(hn[:, None], p["ffn"])[:, 0]
            return h, kc, vc

        if cfg.family == "ssm":
            def body(h, sl):
                p, st, cv = sl
                hn = L.rms_norm(h[:, None], p["ln1"], cfg.norm_eps)[:, 0]
                y, st, cv = ssm_mod.ssm_decode(hn, p["ssm"], cfg, st, cv)
                return h + y, (st, cv)
            x, (st, cv) = jax.lax.scan(
                body, x, (params["blocks"], cache["state"], cache["conv"]))
            cache = {"state": st, "conv": cv}
        elif cfg.name.startswith("gemma3"):
            win = cache["local"]["k"].shape[2]
            lpos = jnp.where(win > 0, pos % win, 0)
            n_super = cache["global"]["k"].shape[0]

            def super_body(h, sl):
                p, lk, lv, gk, gv = sl

                def local_body(hh, inner):
                    pp, kk, vv = inner
                    hh, kk, vv = attn_step(hh, pp, kk, vv, lpos)
                    return hh, (kk, vv)
                h, (lk, lv) = jax.lax.scan(
                    local_body, h, (p["local"], lk, lv))
                h, gk, gv = attn_step(h, p["global"], gk, gv, pos)
                return h, (lk, lv, gk, gv)

            lk5 = cache["local"]["k"][: n_super * 5].reshape(
                (n_super, 5) + cache["local"]["k"].shape[1:])
            lv5 = cache["local"]["v"][: n_super * 5].reshape(
                (n_super, 5) + cache["local"]["v"].shape[1:])
            x, (lk5, lv5, gk, gv) = jax.lax.scan(
                super_body, x,
                (params["super"], lk5, lv5,
                 cache["global"]["k"], cache["global"]["v"]))

            def tail_body(h, sl):
                p, kk, vv = sl
                h, kk, vv = attn_step(h, p, kk, vv, lpos)
                return h, (kk, vv)
            tk = cache["local"]["k"][n_super * 5:]
            tv = cache["local"]["v"][n_super * 5:]
            x, (tk, tv) = jax.lax.scan(tail_body, x, (params["tail"], tk, tv))
            cache = {
                "local": {
                    "k": jnp.concatenate(
                        [lk5.reshape((-1,) + lk5.shape[2:]), tk]),
                    "v": jnp.concatenate(
                        [lv5.reshape((-1,) + lv5.shape[2:]), tv])},
                "global": {"k": gk, "v": gv},
            }
        elif cfg.family == "hybrid":
            win = cache["attn"]["k"].shape[2]
            apos = pos % win
            n_super = cache["attn"]["k"].shape[0]

            def rec_step(h, p, hs, cv):
                hn = L.rms_norm(h[:, None], p["ln1"], cfg.norm_eps)[:, 0]
                y, hs, cv = rg.rglru_decode(hn, p["rec"], cfg, hs, cv)
                h = h + y
                hn = L.rms_norm(h[:, None], p["ln2"], cfg.norm_eps)[:, 0]
                return h + L.mlp(hn[:, None], p["ffn"])[:, 0], hs, cv

            def super_body(h, sl):
                p, kk, vv, h1, c1, h2, c2 = sl
                h, h1, c1 = rec_step(h, p["rec1"], h1, c1)
                h, h2, c2 = rec_step(h, p["rec2"], h2, c2)
                h, kk, vv = attn_step(h, p["attn"], kk, vv, apos)
                return h, (kk, vv, h1, c1, h2, c2)

            hs = cache["h"][: 2 * n_super].reshape(
                (n_super, 2) + cache["h"].shape[1:])
            cv = cache["conv"][: 2 * n_super].reshape(
                (n_super, 2) + cache["conv"].shape[1:])
            x, (kk, vv, h1, c1, h2, c2) = jax.lax.scan(
                super_body, x,
                (params["super"], cache["attn"]["k"], cache["attn"]["v"],
                 hs[:, 0], cv[:, 0], hs[:, 1], cv[:, 1]))

            def tail_body(h, sl):
                p, hh, cc = sl
                h, hh, cc = rec_step(h, p, hh, cc)
                return h, (hh, cc)
            x, (th, tc) = jax.lax.scan(
                tail_body, x, (params["tail"], cache["h"][2 * n_super:],
                               cache["conv"][2 * n_super:]))
            new_h = jnp.concatenate(
                [jnp.stack([h1, h2], 1).reshape((-1,) + h1.shape[1:]), th])
            new_c = jnp.concatenate(
                [jnp.stack([c1, c2], 1).reshape((-1,) + c1.shape[1:]), tc])
            cache = {"attn": {"k": kk, "v": vv}, "h": new_h, "conv": new_c}
        elif cfg.family == "encdec":
            def body(h, sl):
                p, kk, vv, ck, cv = sl
                hn = L.rms_norm(h[:, None], p["ln1"], cfg.norm_eps)[:, 0]
                y, kk, vv = attn.attention_decode_block(
                    hn, p["attn"], cfg, freqs, pos, kk, vv, pos)
                h = h + y
                hn = L.rms_norm(h[:, None], p["ln3"], cfg.norm_eps)[:, 0]
                rep = cfg.n_heads // max(cfg.n_kv_heads, 1)
                q = jnp.einsum("bd,de->be", hn,
                               p["cross"]["wq"].astype(L.COMPUTE_DTYPE))
                q = q.reshape(-1, cfg.n_heads, cfg.head_dim)
                y = attn.decode_attention(
                    q, attn._repeat_kv(ck, rep), attn._repeat_kv(cv, rep),
                    ck.shape[1])
                h = h + jnp.einsum(
                    "be,ed->bd", y.reshape(y.shape[0], -1),
                    p["cross"]["wo"].astype(L.COMPUTE_DTYPE))
                hn = L.rms_norm(h[:, None], p["ln2"], cfg.norm_eps)[:, 0]
                h = h + L.mlp(hn[:, None], p["ffn"])[:, 0]
                return h, (kk, vv)
            x, (kk, vv) = jax.lax.scan(
                body, x, (params["blocks"], cache["self"]["k"],
                          cache["self"]["v"], cache["cross"]["k"],
                          cache["cross"]["v"]))
            cache = dict(cache)
            cache["self"] = {"k": kk, "v": vv}
        else:
            def body(h, sl):
                p, kk, vv, e = sl
                cache_pos = pos % kk.shape[1] if cfg.window else pos
                with L.layer_ctx(e):
                    h, kk, vv = attn_step(h, p, kk, vv, cache_pos)
                return h, (kk, vv)
            x, (kk, vv) = jax.lax.scan(
                body, x, (params["blocks"], cache["self"]["k"],
                          cache["self"]["v"], extras))
            cache = {"self": {"k": kk, "v": vv}}

        x = L.rms_norm(x[:, None], params["final_norm"], cfg.norm_eps)[:, 0]
        return L.logits_head(x, params["embed"]), cache


def _sinusoid(s: int, d: int) -> jax.Array:
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, dim / d)
    pe = jnp.zeros((s, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang))
    return pe[None].astype(L.COMPUTE_DTYPE)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, no allocation) — dry-run fodder
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeCfg) -> dict:
    """Abstract inputs for one (arch × shape) cell.

    train/prefill: token + label batches (+ stub frontend embeddings);
    decode: one-token batch + position.  The KV cache itself is produced by
    ``Model.init_cache`` shapes via eval_shape (no allocation).
    """
    b, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if shape.kind == "train":
        out = {"tokens": tok, "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.family == "encdec":
            out["enc_frames"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_seq, cfg.d_model), jnp.float32)
        return out
    if shape.kind == "prefill":
        out = {"tokens": tok}
        if cfg.family == "encdec":
            out["enc_frames"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_seq, cfg.d_model), jnp.float32)
        return out
    # decode: one new token against a seq_len-deep cache
    return {"tokens": jax.ShapeDtypeStruct((b,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}
