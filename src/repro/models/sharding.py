"""Sharding helpers shared by the model zoo.

Models annotate activations with logical axis names; the launch layer binds
them to mesh axes via a context.  Outside a mesh (CPU smoke tests) the
annotations are no-ops, so model code is mesh-agnostic.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def _axis_map() -> Optional[dict]:
    return getattr(_state, "axis_map", None)


@contextlib.contextmanager
def logical_axis_rules(axis_map: dict):
    """Bind logical axis names → mesh axis names (or None) for the scope.

    ``axis_map`` example: {"batch": ("pod", "data"), "model": "model",
    "seq": None, "vocab": "model", "expert": "model"}.
    """
    prev = _axis_map()
    _state.axis_map = axis_map
    try:
        yield
    finally:
        _state.axis_map = prev


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Constrain ``x``'s sharding by logical axis names (no-op untethered)."""
    amap = _axis_map()
    if amap is None:
        return x
    spec = P(*[amap.get(a) if a is not None else None for a in logical_axes])
    return jax.lax.with_sharding_constraint(x, spec)


def spec(*logical_axes: Optional[str]) -> P:
    """PartitionSpec from logical names under the current rules (for pjit
    in/out shardings).  Without rules, fully replicated."""
    amap = _axis_map() or {}
    return P(*[amap.get(a) if a is not None else None for a in logical_axes])


def named_sharding(mesh, *logical_axes: Optional[str]):
    """A NamedSharding on ``mesh`` from logical names under the current
    rules — for placing INPUTS (e.g. a serving batch on the data axis)
    rather than constraining intermediates."""
    return jax.sharding.NamedSharding(mesh, spec(*logical_axes))
