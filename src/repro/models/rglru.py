"""RG-LRU recurrent block (RecurrentGemma, arXiv:2402.19427).

The recurrence  h_t = a_t ⊙ h_{t-1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)  with
a_t = exp(−c · softplus(Λ) · r_t)  is a first-order linear recurrence —
computed with ``jax.lax.associative_scan`` over time (log-depth, parallel),
the TPU-idiomatic replacement for a sequential RNN loop.

The full recurrent block is: x → {linear branch (GeLU), recurrent branch
(causal conv1d → RG-LRU)} → elementwise product → out-projection.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import COMPUTE_DTYPE, _init
from repro.models.sharding import shard

_C = 8.0  # RG-LRU gate sharpness constant (paper value)


def rglru_params(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    dr = d  # recurrence width
    ks = jax.random.split(key, 6)
    return {
        "w_x": _init(ks[0], (d, dr)),          # recurrent branch in-proj
        "w_y": _init(ks[1], (d, dr)),          # gate (linear) branch
        "w_out": _init(ks[2], (dr, d)),
        "conv": _init(ks[3], (4, dr), scale_axis=0),
        "w_a": _init(ks[4], (dr, dr)),         # recurrence gate
        "w_i": _init(ks[5], (dr, dr)),         # input gate
        "lam": jnp.full((dr,), 3.0, jnp.float32),   # Λ: a ≈ 0.95 at r=1
    }


def _gates(x, p):
    r = jax.nn.sigmoid(jnp.einsum("...d,de->...e", x,
                                  p["w_a"].astype(COMPUTE_DTYPE)))
    i = jax.nn.sigmoid(jnp.einsum("...d,de->...e", x,
                                  p["w_i"].astype(COMPUTE_DTYPE)))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    return a, beta, i


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i: i + x.shape[1], :] * w[i]
    return out


def rglru_scan(x: jax.Array, a: jax.Array, beta: jax.Array,
               h0: jax.Array) -> tuple[jax.Array, jax.Array]:
    """h_t = a_t h_{t-1} + beta_t x_t via associative scan.  x/a/beta:
    (B, S, D); h0: (B, D).  Returns (h (B,S,D), h_final)."""
    bx = beta.astype(jnp.float32) * x.astype(jnp.float32)
    # fold h0 into the first element
    bx = bx.at[:, 0, :].add(a[:, 0, :].astype(jnp.float32) * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(
        combine, (a.astype(jnp.float32), bx), axis=1)
    return hh.astype(x.dtype), hh[:, -1, :]


def rglru_block(x: jax.Array, p: dict, cfg: ModelConfig
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Full recurrent block over a sequence.  x: (B, S, d).
    Returns (y, h_final, conv_tail) — the latter two seed decode caches."""
    xr = jnp.einsum("btd,de->bte", x, p["w_x"].astype(COMPUTE_DTYPE))
    gate = jax.nn.gelu(jnp.einsum("btd,de->bte", x,
                                  p["w_y"].astype(COMPUTE_DTYPE)))
    conv = _causal_conv(xr, p["conv"].astype(COMPUTE_DTYPE))
    a, beta, i_gate = _gates(conv, p)
    h, h_last = rglru_scan(conv * i_gate, a, beta,
                           jnp.zeros(conv.shape[::2], conv.dtype))
    h = shard(h, "batch", None, "model")
    y = jnp.einsum("bte,ed->btd", h * gate, p["w_out"].astype(COMPUTE_DTYPE))
    conv_tail = xr[:, -(p["conv"].shape[0] - 1):, :]
    return y, h_last, conv_tail


def rglru_decode(x: jax.Array, p: dict, cfg: ModelConfig,
                 h: jax.Array, conv_state: jax.Array
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token step.  x: (B, d); h: (B, dr); conv_state: (B, K-1, dr)."""
    xr = jnp.einsum("bd,de->be", x, p["w_x"].astype(COMPUTE_DTYPE))
    gate = jax.nn.gelu(jnp.einsum("bd,de->be", x,
                                  p["w_y"].astype(COMPUTE_DTYPE)))
    window = jnp.concatenate([conv_state, xr[:, None, :]], axis=1)
    conv = jnp.einsum("bkc,kc->bc", window, p["conv"].astype(COMPUTE_DTYPE))
    new_conv_state = window[:, 1:, :]
    a, beta, i_gate = _gates(conv, p)
    h_new = (a.astype(jnp.float32) * h.astype(jnp.float32) +
             beta.astype(jnp.float32) *
             (conv * i_gate).astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("be,ed->bd", h_new * gate,
                   p["w_out"].astype(COMPUTE_DTYPE))
    return y, h_new, new_conv_state
