"""Mixture-of-Experts: token-choice top-k routing, GShard-style capacity
dispatch, expert-parallel over the ``model`` mesh axis.

Dispatch is expressed as einsums over a (tokens, experts, capacity) one-hot —
fully SPMD-friendly (no data-dependent scatter), with tokens grouped into
small routing groups (``router_group``) so the dispatch tensor stays
O(group · E · C) instead of O(global_tokens · E · C).  Expert weights are
sharded on the expert axis (EP); XLA inserts the all-to-all between the
data-sharded token groups and model-sharded experts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import COMPUTE_DTYPE, _init
from repro.models.sharding import shard


def moe_params(key, cfg: ModelConfig) -> dict:
    m = cfg.moe
    assert m is not None
    kr, k1, k2, k3 = jax.random.split(key, 4)
    d, f, e = cfg.d_model, m.d_expert, m.n_experts
    return {
        "router": _init(kr, (d, e)),
        "w_gate": _init(k1, (e, d, f), scale_axis=1),
        "w_up": _init(k2, (e, d, f), scale_axis=1),
        "w_down": _init(k3, (e, f, d), scale_axis=1),
    }


def _capacity(group: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(group * m.top_k * m.capacity_factor / m.n_experts) + 1
    return max(4, min(c, group))


def moe_block(x: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, d) → (B, S, d).  Top-k routing with capacity dropping."""
    m = cfg.moe
    b, s, d = x.shape
    e, f, k = m.n_experts, m.d_expert, m.top_k
    grp = min(m.router_group, s)
    ng = (b * s) // grp
    xg = x.reshape(ng, grp, d)
    cap = _capacity(grp, cfg)

    logits = jnp.einsum("gtd,de->gte", xg, p["router"].astype(COMPUTE_DTYPE))
    logits = logits.astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)                  # (g, t, E)
    topw, tope = jax.lax.top_k(gates, k)                     # (g, t, k)
    topw = topw / jnp.maximum(jnp.sum(topw, -1, keepdims=True), 1e-9)

    # position of each (token, slot) in its expert's buffer, via cumsum over
    # the flattened (slot-major) one-hot — tokens beyond capacity are dropped.
    onehot = jax.nn.one_hot(tope, e, dtype=jnp.float32)      # (g, t, k, E)
    slot_major = jnp.moveaxis(onehot, 2, 1).reshape(ng, k * grp, e)
    pos = jnp.cumsum(slot_major, axis=1) - slot_major        # (g, k·t, E)
    pos = jnp.moveaxis(pos.reshape(ng, k, grp, e), 1, 2)     # (g, t, k, E)
    pos = jnp.sum(pos * onehot, axis=-1)                     # (g, t, k)
    keep = (pos < cap) & (topw > 0.0)
    pos = jnp.where(keep, pos, 0).astype(jnp.int32)

    cap_oh = jax.nn.one_hot(pos, cap, dtype=COMPUTE_DTYPE)   # (g, t, k, C)
    disp = jnp.einsum("gtke,gtkc->gtec", onehot.astype(COMPUTE_DTYPE),
                      cap_oh * keep[..., None].astype(COMPUTE_DTYPE))
    comb = jnp.einsum("gtke,gtkc,gtk->gtec", onehot.astype(COMPUTE_DTYPE),
                      cap_oh, (topw * keep).astype(COMPUTE_DTYPE))

    xe = jnp.einsum("gtd,gtec->gecd", xg, disp)              # (g, E, C, d)
    xe = shard(xe, "batch", "expert", None, None)
    h = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(COMPUTE_DTYPE))
    u = jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(COMPUTE_DTYPE))
    h = jax.nn.silu(h) * u
    h = shard(h, "batch", "expert", None, None)
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(COMPUTE_DTYPE))
    y = jnp.einsum("gecd,gtec->gtd", ye, comb)
    return y.reshape(b, s, d)


def moe_decode(x: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    """Single-token MoE: x (B, d).  s=1 makes each token its own routing
    group, so capacity dropping degenerates to pure top-k (no drops)."""
    return moe_block(x[:, None, :], p, cfg)[:, 0, :]
