"""Beyond-baseline optimization flags (§Perf hillclimbing).

The paper-faithful baseline keeps all flags OFF; each hillclimb iteration
enables one and re-lowers, so EXPERIMENTS.md §Perf can attribute every
delta.  Flags:

  padheads  — pad attention head counts up to a multiple of the TP degree
              (56→64 heads on a 16-way axis): kills XLA's "involuntary full
              rematerialization" resharding all-gathers on the (B,S,H,D)
              reshapes, at the price of ~H_pad/H extra attention FLOPs.
  replkv    — replicate the (small) K/V projections when n_kv_heads doesn't
              divide the TP degree, instead of sharding their flat output
              dim (which forces replicate-and-repartition copies).
  saveremat — remat policy keeps each block's OUTPUT (post-all-reduce), so
              the backward recompute does not replay TP collectives.
  maskedkv  — decode caches update via a one-hot masked blend instead of
              dynamic_update_slice: fully shardable along the cache's S
              axis (no all-gather for S-sharded caches), costs one extra
              cache-sized elementwise pass.
  sparseffn — serve-time FFN weights stored in the SnipSnap-chosen
              block-bitmap format: payload-only weight streams (gather-BMM
              over non-zero blocks + segment-sum), cutting decode weight
              traffic by the block density.
  seqpar    — Megatron-style sequence parallelism: the residual stream is
              sharded along S on the model axis between blocks, so XLA
              lowers the TP output-projection psum as reduce-scatter and
              re-gathers at the next projection — ~2× fewer link-bytes than
              all-reduce (which is internally RS+AG).
"""

from __future__ import annotations

import contextlib
import threading

_state = threading.local()

ALL_FLAGS = ("padheads", "replkv", "saveremat", "maskedkv", "sparseffn",
             "seqpar", "gqagroup", "bf16params")
# bf16params — serve with bf16 parameters (cast once at load): decode is a
#              weight-stream problem; fp32 master copies belong to training.
# gqagroup — decode attention computes per KV-head GROUP (no materialized
#            _repeat_kv broadcast of the cache): the S-sharded cache is
#            consumed in place; softmax/contraction collectives shrink to
#            (B, Hkv, rep)-sized scalars instead of cache-sized gathers.


def active() -> frozenset:
    return getattr(_state, "flags", frozenset())


def enabled(flag: str) -> bool:
    return flag in active()


@contextlib.contextmanager
def optimizations(flags):
    flags = frozenset(flags)
    unknown = flags - set(ALL_FLAGS)
    if unknown:
        raise ValueError(f"unknown optimization flags: {sorted(unknown)}")
    prev = active()
    _state.flags = flags
    try:
        yield
    finally:
        _state.flags = prev
