"""Core layers: norms, embeddings, RoPE, MLP, parameter init.

Pure-functional JAX: params are nested dicts of arrays; every layer is a
plain function.  Layer stacks are STACKED along a leading axis and consumed
by ``jax.lax.scan`` (transformer.py) so that HLO size stays O(1) in depth —
essential for compiling 62-layer models on 512 host devices.
"""

from __future__ import annotations

import contextlib
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import optflags
from repro.models.sharding import shard

TP_DEGREE = 16   # production model-axis size (padheads rounds up to this)


def eff_heads(n: int) -> int:
    """Head count after optional pad-to-TP-multiple (optflags 'padheads')."""
    if optflags.enabled("padheads") and n % TP_DEGREE:
        return ((n // TP_DEGREE) + 1) * TP_DEGREE
    return n


Dtype = jnp.dtype
COMPUTE_DTYPE = jnp.bfloat16
PARAM_DTYPE = jnp.float32


# ---------------------------------------------------------------------------
# Projection dispatch hook (execution plane)
# ---------------------------------------------------------------------------
# Every FFN/attention projection matmul routes through :func:`proj`.  With no
# hook installed this is exactly the dense einsum the layers always ran; the
# exec plane (repro.exec.dispatch) installs a hook that swaps individual
# (layer, role) projections for compressed Pallas kernels per its ExecPlan.

_PROJ_HOOK = None


def set_proj_hook(fn) -> None:
    """Install (or clear, with ``None``) the projection override.

    ``fn(x, w, role) -> Optional[jax.Array]``: return the projection output
    (same leading dims as ``x``, trailing dim from ``w``) to take over the
    matmul, or ``None`` to fall through to the dense einsum."""
    global _PROJ_HOOK
    _PROJ_HOOK = fn


def proj(x: jax.Array, w: jax.Array, role: str) -> jax.Array:
    """``x @ w`` over the last axis of ``x`` (the layers' projection shape:
    w is (d_in, d_out)), dispatchable per ``role``."""
    if _PROJ_HOOK is not None:
        y = _PROJ_HOOK(x, w, role)
        if y is not None:
            return y
    return jnp.einsum("...d,df->...f", x, w.astype(COMPUTE_DTYPE))


# The hook's per-layer operand channel.  The transformer's scan runners
# thread an optional ``extras`` pytree (leading layer axis) through
# ``lax.scan`` and install each layer's SLICE here around the layer body,
# so a hook can resolve layer-varying operands (compressed weights) while
# the compiled graph stays one scanned block.  Trace-time state only.

_LAYER_CTX: Any = None


@contextlib.contextmanager
def layer_ctx(value: Any):
    """Install the current layer's extras slice for the proj hook."""
    global _LAYER_CTX
    prev = _LAYER_CTX
    _LAYER_CTX = value
    try:
        yield
    finally:
        _LAYER_CTX = prev


def current_layer_ctx() -> Any:
    """The per-layer extras slice the enclosing scan body installed."""
    return _LAYER_CTX


def _init(key, shape, scale_axis: int = 0, dtype=PARAM_DTYPE):
    fan_in = shape[scale_axis]
    return jax.random.normal(key, shape, dtype) / math.sqrt(max(fan_in, 1))


def rms_norm(x: jax.Array, weight: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    """Token embedding with vocab-sharded table (gather lowers to a sharded
    take; XLA inserts the all-gather on the vocab axis)."""
    out = jnp.take(table, tokens, axis=0).astype(COMPUTE_DTYPE)
    return shard(out, "batch", None, None)


def unembed_loss(x: jax.Array, table: jax.Array, labels: jax.Array,
                 chunk: int = 512) -> jax.Array:
    """Next-token cross-entropy with sequence-chunked logits.

    Never materializes (B, S, V); scans over S in ``chunk`` slices so the
    live logits buffer is (B, chunk, V) — sharded over batch(data) and
    vocab(model).  Returns mean loss over all positions.
    """
    b, s, d = x.shape
    v = table.shape[0]
    n_chunks = max(s // chunk, 1)
    chunk = s // n_chunks
    xc = x[:, : n_chunks * chunk].reshape(b, n_chunks, chunk, d)
    yc = labels[:, : n_chunks * chunk].reshape(b, n_chunks, chunk)
    xc = jnp.moveaxis(xc, 1, 0)          # (n_chunks, B, chunk, d)
    yc = jnp.moveaxis(yc, 1, 0)

    tbl = table.astype(COMPUTE_DTYPE)

    def body(carry, inp):
        xi, yi = inp
        logits = jnp.einsum("btd,vd->btv", xi, tbl).astype(jnp.float32)
        logits = shard(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yi[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, yc))
    return total / (b * n_chunks * chunk)


def logits_head(x: jax.Array, table: jax.Array) -> jax.Array:
    """Decode-time logits for the last position only: (B, V)."""
    logits = jnp.einsum("bd,vd->bv", x, table.astype(COMPUTE_DTYPE))
    return shard(logits.astype(jnp.float32), "batch", "vocab")


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(cfg: ModelConfig) -> Optional[jax.Array]:
    if cfg.rope_fraction <= 0.0:
        return None
    rot = int(cfg.head_dim * cfg.rope_fraction)
    rot -= rot % 2
    return cfg.rope_base ** (-jnp.arange(0, rot, 2, dtype=jnp.float32) / rot)


def apply_rope(x: jax.Array, positions: jax.Array, freqs: Optional[jax.Array]
               ) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S).

    Applies rotary embedding to the first ``2·len(freqs)`` features of D
    (``rope_fraction`` < 1 leaves the tail untouched — ChatGLM-style)."""
    if freqs is None:
        return x
    rot = 2 * freqs.shape[0]
    ang = positions[..., None].astype(jnp.float32) * freqs       # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    out = out.reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([out, x[..., rot:]], axis=-1) if rot < x.shape[-1] else out


# ---------------------------------------------------------------------------
# MLP (SwiGLU) + params
# ---------------------------------------------------------------------------

def mlp_params(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": _init(k1, (d, f)),
        "w_up": _init(k2, (d, f)),
        "w_down": _init(k3, (f, d)),
    }


def mlp(x: jax.Array, p: dict) -> jax.Array:
    g = proj(x, p["w_gate"], "ffn.w_gate")
    u = proj(x, p["w_up"], "ffn.w_up")
    h = jax.nn.silu(g) * u
    h = shard(h, "batch", None, "model")
    return proj(h, p["w_down"], "ffn.w_down")


def attn_params(key, cfg: ModelConfig) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, h, nk = cfg.d_model, cfg.head_dim, cfg.n_kv_heads
    nh = eff_heads(cfg.n_heads)
    return {
        "wq": _init(kq, (d, nh * h)),
        "wk": _init(kk, (d, nk * h)),
        "wv": _init(kv, (d, nk * h)),
        "wo": _init(ko, (nh * h, d)),
    }


# ---------------------------------------------------------------------------
# Block-sparse FFN (serve path, optflags 'sparseffn')
# ---------------------------------------------------------------------------

def sparse_mlp_params(key, cfg: ModelConfig, density: float = 0.25,
                      bn: int = 128, bk: int = 128) -> dict:
    """FFN up/gate weights in the SnipSnap-chosen block-bitmap format:
    per-block-column padded payload (gk, T, bn, bk) + block-row ids.
    w_down stays dense (its contraction dim is model-sharded; gathering
    across shards would trade memory for collectives)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, f = cfg.d_model, cfg.d_ff
    gn, gk = d // bn, f // bk
    t = max(1, int(gn * density))
    return {
        "payload_gate": _init(k1, (gk, t, bn, bk), scale_axis=2),
        "rows_gate": jnp.zeros((gk, t), jnp.int32),
        "payload_up": _init(k2, (gk, t, bn, bk), scale_axis=2),
        "rows_up": jnp.zeros((gk, t), jnp.int32),
        "w_down": _init(k3, (f, d)),
        "_meta": jnp.array([bn, bk], jnp.int32),
    }


def _bsp_matmul(x: jax.Array, payload: jax.Array, rows: jax.Array
                ) -> jax.Array:
    """x: (B, N); payload: (gk, T, bn, bk); rows: (gk, T) block-row ids.
    Streams ONLY the non-zero payload blocks (the compressed format's win:
    weight traffic × block density)."""
    b, n = x.shape
    gk, t, bn, bk = payload.shape
    xb = x.reshape(b, n // bn, bn)
    xsel = jnp.take(xb, rows.reshape(-1), axis=1)       # (B, gk·T, bn)
    xsel = xsel.reshape(b, gk, t, bn)
    y = jnp.einsum("bgtn,gtnk->bgk", xsel,
                   payload.astype(COMPUTE_DTYPE))
    return y.reshape(b, gk * bk)


def sparse_mlp_decode(x: jax.Array, p: dict) -> jax.Array:
    """Single-token SwiGLU FFN over block-compressed up/gate weights."""
    g = _bsp_matmul(x, p["payload_gate"], p["rows_gate"])
    u = _bsp_matmul(x, p["payload_up"], p["rows_up"])
    h = jax.nn.silu(g) * u
    h = shard(h, "batch", "model")
    return jnp.einsum("bf,fd->bd", h, p["w_down"].astype(COMPUTE_DTYPE))
