"""Attention: chunked causal (flash-style online softmax via lax.scan),
sliding-window local attention, and single-token decode against a KV cache.

Memory discipline: full (S, S) score matrices are never materialized — the
KV axis is scanned in chunks with a running (max, denominator, numerator)
accumulator, so peak live memory is O(B · H · Sq_chunk · Skv_chunk).  This is
what keeps prefill_32k compilable; on TPU the same schedule is what a Pallas
flash kernel would pin into VMEM.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import optflags
from repro.models.layers import COMPUTE_DTYPE, apply_rope
from repro.models.sharding import shard

NEG_INF = -1e30


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(B, S, Hkv, D) → (B, S, Hkv·n_rep, D) for GQA."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)) \
        .reshape(b, s, h * n_rep, d)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      causal: bool = True, window: int = 0,
                      q_chunk: int = 512, kv_chunk: int = 512,
                      q_offset: int = 0) -> jax.Array:
    """Online-softmax attention.

    q: (B, Sq, H, D); k/v: (B, Skv, H, D) (same H after GQA repeat).
    ``window`` > 0 restricts attention to the last ``window`` keys (local).
    ``q_offset``: absolute position of q[0] relative to k[0] (prefill = 0
    with Sq == Skv; decode uses decode_attention instead).
    """
    b, sq, h, d = q.shape
    skv = k.shape[1]
    scale = 1.0 / math.sqrt(d)
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    nq = (sq + q_chunk - 1) // q_chunk
    nk = (skv + kv_chunk - 1) // kv_chunk
    # pad to whole chunks
    sq_p, skv_p = nq * q_chunk, nk * kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))

    qs = jnp.moveaxis(qp.reshape(b, nq, q_chunk, h, d), 1, 0)
    ks = jnp.moveaxis(kp.reshape(b, nk, kv_chunk, h, d), 1, 0)
    vs = jnp.moveaxis(vp.reshape(b, nk, kv_chunk, h, d), 1, 0)
    q_pos = q_offset + jnp.arange(sq_p).reshape(nq, q_chunk)
    k_pos = jnp.arange(skv_p).reshape(nk, kv_chunk)
    k_valid = (jnp.arange(skv_p) < skv).reshape(nk, kv_chunk)

    def q_block(args):
        qi, qpos = args                     # (B, qc, H, D), (qc,)

        def kv_step(carry, inp):
            m, l, acc = carry
            ki, vi, kpos, kval = inp
            s = jnp.einsum("bqhd,bkhd->bhqk", qi, ki) * scale
            mask = kval[None, None, None, :]
            if causal:
                mask = mask & (kpos[None, None, None, :] <=
                               qpos[None, None, :, None])
            if window > 0:
                mask = mask & (kpos[None, None, None, :] >
                               qpos[None, None, :, None] - window)
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(COMPUTE_DTYPE), vi)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (ks, vs, k_pos, k_valid))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 1, 2)      # (B, qc, H, D)

    outs = jax.lax.map(q_block, (qs, q_pos))            # (nq, B, qc, H, D)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq_p, h, d)[:, :sq]
    return out.astype(q.dtype)


def _valid_mask(s: int, length: jax.Array) -> jax.Array:
    """(1 | B, S) validity mask from a scalar or per-row (B,) ``length``.

    Per-row lengths are the mixer's per-slot causal mask: every batch row
    (= KV slot) attends to its OWN prefix only, so slots at different
    positions — or stale KV left by an evicted request — never leak."""
    return jnp.arange(s)[None, :] < jnp.reshape(length, (-1, 1))


def decode_attention_gqa(q: jax.Array, k_cache: jax.Array,
                         v_cache: jax.Array, length: jax.Array) -> jax.Array:
    """Grouped-query decode attention WITHOUT materializing repeated KV.

    q: (B, H, D); caches: (B, S, Hkv, D) with H = r·Hkv.  ``length`` is the
    number of valid cache positions — a scalar, or (B,) per-slot lengths.
    The cache is consumed in its stored layout (S may be model-sharded: the
    only cross-shard values are the (B, Hkv, r)-sized softmax stats and the
    (B, Hkv, r, D) output partials — never the cache itself)."""
    b, s, hk, d = k_cache.shape
    h = q.shape[1]
    r = h // hk
    qg = q.reshape(b, hk, r, d)
    scale = 1.0 / math.sqrt(d)
    scores = jnp.einsum("bgrd,bsgd->bgrs", qg, k_cache) * scale
    valid = _valid_mask(s, length)[:, None, None, :]
    scores = jnp.where(valid, scores, NEG_INF)
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bgrs,bsgd->bgrd", w.astype(COMPUTE_DTYPE), v_cache)
    return out.reshape(b, h, d).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     length: jax.Array) -> jax.Array:
    """One-token attention against a cache.

    q: (B, H, D); caches: (B, S, H, D); ``length``: number of valid cache
    positions — a scalar, or (B,) per-slot lengths for mixed-position
    batches.  Cost is linear in S — this is the decode_32k / long_500k
    step.
    """
    b, s, h, d = k_cache.shape
    scale = 1.0 / math.sqrt(d)
    valid = _valid_mask(s, length)                       # (1 | B, S)
    scores = jnp.einsum("bhd,bshd->bhs", q, k_cache) * scale
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", w.astype(COMPUTE_DTYPE), v_cache)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention block (projections + rope + attention + out-proj)
# ---------------------------------------------------------------------------

def attention_block(x: jax.Array, p: dict, cfg: ModelConfig,
                    freqs: Optional[jax.Array], positions: jax.Array,
                    causal: bool = True, window: int = 0,
                    kv_override: Optional[tuple[jax.Array, jax.Array]] = None,
                    return_kv: bool = False):
    """Training/prefill attention over a full sequence.

    ``kv_override`` supplies external K/V inputs (cross-attention).
    ``return_kv=True`` additionally returns the pre-GQA-repeat (K, V) —
    post-RoPE K, exactly what :func:`attention_decode_block` writes into
    the decode cache — so prefill can fill the cache in one batched pass."""
    b, s, _ = x.shape
    nh, nk, hd = L.eff_heads(cfg.n_heads), cfg.n_kv_heads, cfg.head_dim
    q = L.proj(x, p["wq"], "attn.wq")
    q = q.reshape(b, s, nh, hd)
    if kv_override is None:
        k = L.proj(x, p["wk"], "attn.wk")
        v = L.proj(x, p["wv"], "attn.wv")
        k = k.reshape(b, s, nk, hd)
        v = v.reshape(b, s, nk, hd)
        k = apply_rope(k, positions, freqs)
    else:
        assert not return_kv, "return_kv only applies to self-attention"
        xkv = kv_override[0]
        skv = xkv.shape[1]
        k = L.proj(xkv, p["wk"], "attn.wk")
        v = L.proj(xkv, p["wv"], "attn.wv")
        k = k.reshape(b, skv, nk, hd)
        v = v.reshape(b, skv, nk, hd)
    q = apply_rope(q, positions, freqs)
    q = shard(q, "batch", None, "model", None)
    k = shard(k, "batch", None, "model", None)
    rep = nh // max(nk, 1)
    kr, vr = _repeat_kv(k, rep), _repeat_kv(v, rep)
    o = chunked_attention(q, kr, vr, causal=causal, window=window)
    o = o.reshape(b, s, nh * hd)
    out = L.proj(o, p["wo"], "attn.wo")
    if return_kv:
        return out, k, v
    return out


def attention_decode_block(x: jax.Array, p: dict, cfg: ModelConfig,
                           freqs: Optional[jax.Array], pos: jax.Array,
                           k_cache: jax.Array, v_cache: jax.Array,
                           cache_pos: jax.Array,
                           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token attention step.

    x: (B, d).  Caches (B, S, Hkv, D) are updated at ``cache_pos`` (ring
    position for sliding windows; == pos for full caches).  ``pos`` /
    ``cache_pos`` are scalars (lockstep batch) or (B,) per-slot vectors
    (the mixer's mixed-position batch: every row rotates, writes, and
    masks at its OWN position).  Returns (out (B, d), new_k_cache,
    new_v_cache).
    """
    b, _ = x.shape
    nh, nk, hd = L.eff_heads(cfg.n_heads), cfg.n_kv_heads, cfg.head_dim
    pos = jnp.asarray(pos)
    cache_pos = jnp.asarray(cache_pos)
    q = L.proj(x, p["wq"], "attn.wq")
    k = L.proj(x, p["wk"], "attn.wk")
    v = L.proj(x, p["wv"], "attn.wv")
    pos1 = jnp.reshape(pos, (b, 1)) if pos.ndim else jnp.reshape(pos, (1,))
    q = apply_rope(q.reshape(b, 1, nh, hd), pos1, freqs).reshape(b, nh, hd)
    k = apply_rope(k.reshape(b, 1, nk, hd), pos1, freqs).reshape(b, nk, hd)
    v = v.reshape(b, nk, hd)
    if optflags.enabled("maskedkv") or cache_pos.ndim:
        # one-hot masked blend: elementwise along the (possibly model-
        # sharded) S axis — no replicate-and-repartition, unlike a dynamic
        # update at a traced index.  Costs one cache-sized RMW pass.  A
        # per-slot (B,) cache_pos always takes this path (each row writes
        # at its own position — dynamic_update_slice cannot).
        hot = (jnp.arange(k_cache.shape[1])[None, :] ==
               jnp.reshape(cache_pos, (-1, 1)))[:, :, None, None]
        k_cache = jnp.where(hot, k[:, None].astype(k_cache.dtype), k_cache)
        v_cache = jnp.where(hot, v[:, None].astype(v_cache.dtype), v_cache)
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k[:, None].astype(k_cache.dtype), cache_pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v[:, None].astype(v_cache.dtype), cache_pos, axis=1)
    s_max = k_cache.shape[1]
    length = jnp.minimum(pos + 1, s_max)
    if optflags.enabled("gqagroup"):
        o = decode_attention_gqa(q, k_cache, v_cache, length)
    else:
        rep = nh // max(nk, 1)
        o = decode_attention(q, _repeat_kv(k_cache, rep),
                             _repeat_kv(v_cache, rep), length)
    o = o.reshape(b, nh * hd)
    out = L.proj(o, p["wo"], "attn.wo")
    return out, k_cache, v_cache
