"""AdamW + cosine schedule + global-norm clipping, pure JAX pytrees.

Optimizer states carry the SAME PartitionSpecs as their parameters
(ZeRO-style sharded optimizer for free under pjit).  Includes optional int8
gradient compression with error feedback for the DP all-reduce — a
distributed-optimization trick for DCN-crossing pod-level data parallelism:
gradients are quantized per-leaf before the (pjit-implicit) all-reduce and
the quantization residual is fed back into the next step.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    grad_compress: bool = False      # int8 + error feedback


class OptState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree
    err: Optional[PyTree]            # error-feedback residual (compression)


def init(params: PyTree, cfg: AdamWConfig) -> OptState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    err = jax.tree.map(jnp.zeros_like, params) if cfg.grad_compress else None
    return OptState(jnp.zeros((), jnp.int32), zeros,
                    jax.tree.map(jnp.zeros_like, params), err)


def schedule(step: jax.Array, cfg: AdamWConfig) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def _global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def _quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-8) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads(grads: PyTree, err: PyTree) -> tuple[PyTree, PyTree]:
    """int8 quantization with error feedback: g' = Q(g + e); e' = g + e − g'.

    Under pjit the all-reduce happens on the QUANTIZED values (4× fewer DCN
    bytes across pods); the residual keeps long-run convergence unbiased.
    """
    def one(g, e):
        t = g.astype(jnp.float32) + e
        q, scale = _quantize_int8(t)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), (t - deq)

    flat = jax.tree.map(one, grads, err)
    deq = jax.tree.map(lambda t: t[0], flat,
                       is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], flat,
                           is_leaf=lambda t: isinstance(t, tuple))
    return deq, new_err


def apply(params: PyTree, grads: PyTree, state: OptState, cfg: AdamWConfig
          ) -> tuple[PyTree, OptState]:
    if cfg.grad_compress and state.err is not None:
        grads, new_err = compress_grads(grads, state.err)
    else:
        new_err = state.err

    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-8))
    step = state.step + 1
    lr = schedule(step, cfg)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        new_p = p.astype(jnp.float32) - lr * (
            mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p)
        return new_p.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, OptState(step, new_mu, new_nu, new_err)
