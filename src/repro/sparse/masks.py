"""Weight pruning + activation-sparsity measurement.

Produces the sparse tensors that SnipSnap's formats compress: unstructured
magnitude pruning, N:M structured pruning, and block pruning (MXU-aligned —
the TPU-executable granularity).  ``activation_density`` measures realized
activation sparsity (ReLU-style zeros) to feed the Sparsity Analyzer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def magnitude_prune(w: jax.Array, density: float) -> jax.Array:
    """Keep the top-|density| fraction by magnitude (unstructured)."""
    flat = jnp.abs(w).ravel()
    k = max(int(flat.size * density), 1)
    thresh = jnp.sort(flat)[-k]
    return jnp.where(jnp.abs(w) >= thresh, w, 0)


def nm_prune(w: jax.Array, n_sel: int = 2, m_group: int = 4) -> jax.Array:
    """N:M structured pruning along axis 0 (the contraction dim)."""
    n, k = w.shape
    assert n % m_group == 0
    wg = w.reshape(n // m_group, m_group, k)
    order = jnp.argsort(-jnp.abs(wg), axis=1)
    ranks = jnp.argsort(order, axis=1)
    return jnp.where(ranks < n_sel, wg, 0).reshape(n, k)


def block_prune(w: jax.Array, bn: int, bk: int, density: float) -> jax.Array:
    """Keep the top-|density| fraction of (bn × bk) blocks by Frobenius
    norm — MXU-aligned block sparsity, directly executable by
    ``kernels.bitmap_spmm``."""
    n, k = w.shape
    assert n % bn == 0 and k % bk == 0
    gn, gk = n // bn, k // bk
    wb = w.reshape(gn, bn, gk, bk)
    norms = jnp.sqrt(jnp.sum(jnp.square(wb), axis=(1, 3)))   # (gn, gk)
    nkeep = max(int(gn * gk * density), 1)
    thresh = jnp.sort(norms.ravel())[-nkeep]
    mask = (norms >= thresh)[:, None, :, None]
    return (wb * mask).reshape(n, k)


def activation_density(x: jax.Array, atol: float = 0.0) -> float:
    """Fraction of non-zeros (|x| > atol) — feeds TensorSpec densities."""
    return float(jnp.mean(jnp.abs(x) > atol))


def density(w) -> float:
    return float(np.mean(np.asarray(w) != 0))
