"""Sharded numpy checkpointing: atomic, step-tagged, resumable, elastic.

Layout:  <dir>/step_<N>/
            meta.json            — step, pytree structure, shard map
            shard_<host>.npz     — this host's param/opt leaves
         <dir>/LATEST            — atomic pointer (tmp + rename)

No tensorstore dependency; each host writes only its own leaves (here: one
host).  Restore works onto a DIFFERENT mesh shape — arrays are saved
unsharded per-leaf and re-sharded by the caller's pjit in_shardings, which
is what makes elastic restart (§runtime) possible.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree) -> tuple[list[np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return [np.asarray(l) for l in leaves], treedef


def save(ckpt_dir: str, step: int, tree: PyTree, host_id: int = 0,
         extra: Optional[dict] = None) -> str:
    """Write a checkpoint; returns its directory.  Atomic via tmp+rename."""
    leaves, treedef = _flatten(tree)
    os.makedirs(ckpt_dir, exist_ok=True)
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_ckpt_")
    try:
        np.savez(os.path.join(tmp, f"shard_{host_id}.npz"),
                 **{f"leaf_{i}": l for i, l in enumerate(leaves)})
        meta = {"step": step, "n_leaves": len(leaves),
                "treedef": str(treedef), "extra": extra or {}}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(step_dir):
            shutil.rmtree(step_dir)
        os.rename(tmp, step_dir)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # atomic LATEST pointer
    ptr_tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(os.path.basename(step_dir))
    os.replace(ptr_tmp, os.path.join(ckpt_dir, "LATEST"))
    return step_dir


def latest_step(ckpt_dir: str) -> Optional[int]:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, like: PyTree, step: Optional[int] = None,
            host_id: int = 0) -> tuple[PyTree, dict]:
    """Restore into the structure of ``like`` (shapes must match).
    Returns (tree, extra).  ``step=None`` → latest."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(step_dir, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(step_dir, f"shard_{host_id}.npz"))
    leaves, treedef = jax.tree.flatten(like)
    assert meta["n_leaves"] == len(leaves), \
        f"checkpoint has {meta['n_leaves']} leaves, expected {len(leaves)}"
    restored = []
    for i, ref_leaf in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        assert arr.shape == tuple(ref_leaf.shape), \
            f"leaf {i}: ckpt {arr.shape} != model {ref_leaf.shape}"
        restored.append(arr.astype(ref_leaf.dtype))
    return jax.tree.unflatten(treedef, restored), meta.get("extra", {})


def prune_old(ckpt_dir: str, keep: int = 3) -> None:
    """Garbage-collect old checkpoints, keeping the newest ``keep``."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
