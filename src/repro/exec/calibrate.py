"""Measured-vs-predicted calibration loop.

Closes the loop the analytical co-search leaves open: run the plan's
compressed model under :func:`repro.exec.dispatch.instrument`, compare the
EXACT per-role fetched bits against the cost model's expected fetch terms
(:class:`~repro.exec.plans.OpPlan` ``predicted_w_fetch_bits``), fit a
per-:class:`~repro.core.arch.HardwareConfig` energy-coefficient scalar by
least squares, and re-run the search with the calibrated hardware to report
prediction drift.

Why predictions drift: the search's statistical sparsity model may not
match the realized weights — e.g. i.i.d. ``Bernoulli`` predicts near-dense
bitmap payloads (any large block is almost surely non-empty) while block
pruning clusters zeros into whole blocks, so measured traffic comes in at
~the block density.  Calibration absorbs the aggregate mismatch into the
DRAM energy coefficient (the per-bit cost the search actually ranks
designs by); a model-aware spec (``BlockBernoulli``) makes the fit scale
≈ 1 and the residuals collapse — both paths are exercised in
``benchmarks/bench_exec.py``.

The fit is PER LEVEL: distinct fetches (first pass over the payload) fit
the DRAM coefficient as before, while the refetch residual — total
streamed bits minus distinct bits, i.e. the passes the streaming pipeline
re-issues per output stripe — fits the GLB coefficient
(:func:`fit_glb_scale`).  A systematic gap between the searched tile's
refetch factor and the kernel's realized ``M / tile_M`` passes shows up
as exactly this residual, which is what the drift report surfaces.

Counter provenance: :func:`~repro.exec.dispatch.instrument` records at
TRACE time.  The scan-compiled serving path dispatches each role once per
trace with layer-summed totals (``calls += n_layers``), so the per-call
means compared here (``w_fetch_bits_per_call`` vs per-layer
``predicted_w_fetch_bits``) are identical between the scanned and the
unrolled forwards — the fit is path-independent by construction.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.configs.base import ModelConfig
from repro.core.arch import HardwareConfig
from repro.core.cosearch import CoSearchConfig
from repro.exec.dispatch import OpCounters
from repro.exec.plans import ExecPlan, build_exec_plan
from repro.obs import trace as otr


@dataclasses.dataclass(frozen=True)
class CalibRow:
    """One role's measured-vs-predicted W-side fetch comparison (bits per
    full pass over the weight).

    The ``*_stream_bits`` pair covers the memory pipeline's second level:
    TOTAL payload bits streamed across all output-stripe passes (measured
    by ``OpCounters.w_stream_bits``; predicted as distinct fetch × the
    mapping's tile-reuse refetch factor).  ``stream − distinct`` is the
    refetch residual the GLB coefficient is fitted on."""

    role: str
    kind: str
    measured_bits: float
    predicted_bits: float
    measured_stream_bits: float = 0.0
    predicted_stream_bits: float = 0.0

    @property
    def rel_err(self) -> float:
        if self.predicted_bits == 0.0:
            return 0.0
        return self.measured_bits / self.predicted_bits - 1.0

    def residual(self, scale: float) -> float:
        """Relative error after scaling predictions by ``scale``."""
        p = self.predicted_bits * scale
        return self.measured_bits / p - 1.0 if p else 0.0

    @property
    def measured_refetch_bits(self) -> float:
        """Measured bits re-streamed BEYOND the first (distinct) pass."""
        return max(self.measured_stream_bits - self.measured_bits, 0.0)

    @property
    def predicted_refetch_bits(self) -> float:
        return max(self.predicted_stream_bits - self.predicted_bits, 0.0)

    @property
    def stream_rel_err(self) -> float:
        if self.predicted_stream_bits == 0.0:
            return 0.0
        return self.measured_stream_bits / self.predicted_stream_bits - 1.0

    def refetch_residual(self, glb_scale: float) -> float:
        """Relative refetch-bits error after the GLB fit."""
        p = self.predicted_refetch_bits * glb_scale
        return self.measured_refetch_bits / p - 1.0 if p else 0.0


def compare(plan: ExecPlan, counters: dict[str, OpCounters]
            ) -> list[CalibRow]:
    """Join measured per-call counters with the plan's predicted terms."""
    rows = []
    for op in plan.ops:
        c = counters.get(op.role)
        if c is None or not c.calls:
            continue
        rows.append(CalibRow(
            role=op.role, kind=op.choice.kind,
            measured_bits=c.w_fetch_bits_per_call,
            predicted_bits=op.predicted_w_fetch_bits,
            measured_stream_bits=c.w_stream_bits_per_call,
            predicted_stream_bits=op.predicted_w_stream_bits))
    return rows


def fit_scale(rows: Sequence[CalibRow]) -> float:
    """Least-squares scalar s minimizing Σ (s·predicted − measured)²."""
    num = sum(r.predicted_bits * r.measured_bits for r in rows)
    den = sum(r.predicted_bits ** 2 for r in rows)
    return num / den if den else 1.0


def fit_glb_scale(rows: Sequence[CalibRow]) -> float:
    """Least-squares scalar on the REFETCH residual (stream − distinct).

    Re-fetched passes are what the on-chip level absorbs under the
    streaming pipeline (the cost model's reuse term), so the measured/
    predicted refetch ratio folds into the GLB coefficient — separately
    from :func:`fit_scale`'s distinct-fetch DRAM fit.  With no refetch on
    either side (single-pass mappings) the fit is the identity."""
    num = sum(r.predicted_refetch_bits * r.measured_refetch_bits
              for r in rows)
    den = sum(r.predicted_refetch_bits ** 2 for r in rows)
    return num / den if den else 1.0


def calibrated_hardware(arch: HardwareConfig, scale: float,
                        glb_scale: float = 1.0) -> HardwareConfig:
    """``arch`` with its DRAM (and optionally GLB) energy coefficients
    scaled by the fits.

    ``scale`` folds the measured/predicted DISTINCT-fetch traffic ratio
    into the per-bit DRAM cost; ``glb_scale`` folds the refetch-residual
    ratio into the per-bit GLB cost — so the search's energy objective
    ranks candidates by what the execution plane will actually move at
    each level."""
    dram = arch.levels[0]
    dram = dataclasses.replace(
        dram,
        pj_per_bit_read=dram.pj_per_bit_read * scale,
        pj_per_bit_write=dram.pj_per_bit_write * scale)
    levels = (dram,) + arch.levels[1:]
    name = f"{arch.name}+cal{scale:.3g}"
    if glb_scale != 1.0:
        glb = dataclasses.replace(
            levels[1],
            pj_per_bit_read=levels[1].pj_per_bit_read * glb_scale,
            pj_per_bit_write=levels[1].pj_per_bit_write * glb_scale)
        levels = (levels[0], glb) + levels[2:]
        name += f"+glb{glb_scale:.3g}"
    return dataclasses.replace(arch, name=name, levels=levels)


@dataclasses.dataclass
class CalibrationReport:
    """The full loop's outcome: fit quality + re-search drift."""

    rows: list[CalibRow]
    scale: float                    # fitted energy-coefficient scalar
    max_rel_err: float              # worst |measured/predicted − 1| pre-fit
    max_residual: float             # worst residual AFTER applying the fit
    baseline_energy: float          # Σ predicted op energy, original arch
    calibrated_energy: float        # same under the calibrated arch re-search
    calibrated_plan: ExecPlan
    kinds_changed: dict[str, tuple[str, str]]   # role → (before, after)
    glb_scale: float = 1.0          # fitted GLB scalar (refetch residual)
    max_stream_rel_err: float = 0.0   # worst stream-bits error pre-fit
    max_refetch_residual: float = 0.0   # worst refetch residual AFTER fit

    @property
    def energy_drift(self) -> float:
        """Relative predicted-energy change after calibration."""
        if self.baseline_energy == 0.0:
            return 0.0
        return self.calibrated_energy / self.baseline_energy - 1.0


def calibrate(cfg: ModelConfig, plan: ExecPlan,
              counters: dict[str, OpCounters],
              search_cfg: Optional[CoSearchConfig] = None
              ) -> CalibrationReport:
    """Fit the energy coefficient and re-run the search calibrated.

    ``plan`` must have been built for ``cfg``; ``counters`` come from a
    :func:`repro.exec.dispatch.instrument` run of its compressed model.
    The re-search reuses the plan's own workload knobs (tokens,
    activation density, value width)."""
    with otr.span("calibrate", arch=plan.arch, roles=len(plan.ops)):
        with otr.span("calibrate.compare"):
            rows = compare(plan, counters)
        if not rows:
            raise ValueError("no measured counters overlap the plan's roles")
        with otr.span("calibrate.fit", rows=len(rows)):
            scale = fit_scale(rows)
            glb_scale = fit_glb_scale(rows)
        otr.event("calibrate.fitted", scale=round(scale, 6),
                  glb_scale=round(glb_scale, 6))
        # plan.hardware() already carries the plan's own scales, so repeated
        # calibration rounds compose multiplicatively at both levels
        arch_cal = calibrated_hardware(plan.hardware(), scale,
                                       glb_scale=glb_scale)
        with otr.span("calibrate.research"):
            plan_cal = build_exec_plan(cfg, plan.sparsity, tokens=plan.tokens,
                                       act_density=plan.act_density,
                                       hardware=arch_cal,
                                       search_cfg=search_cfg,
                                       value_bits=plan.value_bits)
        # keep the BASE arch name (resolvable through arch_by_name after a
        # JSON round trip) + the composed scales on the plan itself
        plan_cal = dataclasses.replace(
            plan_cal, arch=plan.arch, energy_scale=plan.energy_scale * scale,
            glb_energy_scale=plan.glb_energy_scale * glb_scale)
    changed = {}
    for op in plan.ops:
        after = plan_cal.for_role(op.role)
        if after.choice.kind != op.choice.kind:
            changed[op.role] = (op.choice.kind, after.choice.kind)
    return CalibrationReport(
        rows=rows, scale=scale,
        max_rel_err=max(abs(r.rel_err) for r in rows),
        max_residual=max(abs(r.residual(scale)) for r in rows),
        baseline_energy=sum(op.predicted_energy for op in plan.ops),
        calibrated_energy=sum(op.predicted_energy for op in plan_cal.ops),
        calibrated_plan=plan_cal,
        kinds_changed=changed,
        glb_scale=glb_scale,
        max_stream_rel_err=max(abs(r.stream_rel_err) for r in rows),
        max_refetch_residual=max(abs(r.refetch_residual(glb_scale))
                                 for r in rows))
