"""Plan-driven matmul dispatch: compressed kernels inside the real model.

The transformer's FFN/attention projections all route through
:func:`repro.models.layers.proj`.  :class:`CompressedModel` installs a hook
there and drives the model's OWN scanned layer stack with an ``extras``
pytree — the :class:`~repro.exec.compress.StackedStore`'s layer-stacked
compressed payloads ride ``lax.scan``'s xs, the scan body publishes each
layer's slice through :func:`repro.models.layers.layer_ctx`, and the hook
resolves it into the matching Pallas kernel call (``bitmap_spmm`` /
``nm_spmm``, interpret mode on CPU, native on TPU).  Dense-kind roles fall
through to the exact einsum the dense model runs.  Because the compiled
graph is the dense model's one scanned block (HLO O(1) in depth) and padded
payload blocks sit beyond every column's ``counts``, compressed and dense
forwards differ only by kernel accumulation order — and the scanned and
unrolled compressed forwards are bit-identical.

The per-layer Python re-drive from the previous revision survives as
:meth:`CompressedModel.hidden_states_unrolled` (equivalence tests, the
scan-vs-unrolled benchmark).  Kernel wrappers are jit-cached per static
configuration (:func:`repro.kernels.ops` ``_jitted``); the stacked path
keys that cache on the SHARED across-layers configuration per role, so a
whole serving trace costs ``len(plan.ops)`` kernel builds, not
``n_layers ×`` that.

Counter semantics under jit/scan (:func:`instrument`): the hook runs at
TRACE time, once per (role) per traced scan body — so one scanned forward
records each role ONCE, with totals covering all ``n_layers`` layers
(``calls += n_layers``, bits/MACs/decode-ops summed over the layer axis
from host-side stacked accounting).  Per-layer means (``calls``,
``w_fetch_bits_per_call``) therefore match the unrolled per-layer loop
exactly, and :mod:`repro.exec.calibrate` fits the same coefficients on
either path.  Re-running a jitted function does NOT re-record (no retrace);
wrap the traced call in a fresh ``instrument()`` block per measurement.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.exec.compress import (CompressedStore, CompressedTensor,
                                 StackedStore, stack_store)
from repro.kernels import ops as kops
from repro.models import layers as L
from repro.models import transformer as T


# ---------------------------------------------------------------------------
# Measured traffic counters
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class OpCounters:
    """Accumulated measured traffic of one dispatch role."""

    calls: int = 0
    w_fetch_bits: float = 0.0     # payload + metadata, realized encoding
    x_bits: float = 0.0
    y_bits: float = 0.0
    macs: float = 0.0             # useful MACs (compressed operand elems × M)
    decode_ops: float = 0.0       # metadata units decoded (blocks / indices)
    # distinct-vs-total streaming split (the memory pipeline's two levels):
    # DISTINCT bits cross DRAM→chip once per call; STREAM bits count every
    # HBM→VMEM payload transfer the kernel grid actually issues — one full
    # pass per output-row stripe (M / tile_M), so stream/distinct is the
    # realized refetch factor the cost model's reuse term prices.
    w_distinct_bits: float = 0.0
    w_stream_bits: float = 0.0

    @property
    def w_fetch_bits_per_call(self) -> float:
        return self.w_fetch_bits / self.calls if self.calls else 0.0

    @property
    def w_stream_bits_per_call(self) -> float:
        return self.w_stream_bits / self.calls if self.calls else 0.0

    @property
    def refetch_factor(self) -> float:
        """Measured total-stream / distinct-fetch ratio (≥ 1)."""
        if not self.w_distinct_bits:
            return 1.0
        return self.w_stream_bits / self.w_distinct_bits


_ACTIVE_COUNTERS: Optional[dict[str, OpCounters]] = None


@contextlib.contextmanager
def instrument() -> Iterator[dict[str, OpCounters]]:
    """Collect per-role :class:`OpCounters` for every dispatched projection
    TRACED inside the context (nested dispatchers share the dict).

    Tracing is the recording event: the unrolled path records once per
    (layer, role); the scanned path records once per role with
    ``calls += n_layers`` and layer-summed totals, so per-call means agree.
    A jit cache hit replays without recording — time a jitted forward by
    tracing it inside the block (or clearing jax's cache first)."""
    global _ACTIVE_COUNTERS
    prev = _ACTIVE_COUNTERS
    counters: dict[str, OpCounters] = {}
    _ACTIVE_COUNTERS = counters
    try:
        yield counters
    finally:
        _ACTIVE_COUNTERS = prev


def _record(role: str, x2: jax.Array, y_k: int,
            w_bits: float, macs: float, decode_ops: float,
            layers: int = 1, stream_passes: int = 1) -> None:
    """Record one dispatch covering ``layers`` realized layer matmuls.

    ``w_bits``/``macs``/``decode_ops`` are totals over those layers; x/y
    activation traffic is per-layer and scaled here.  ``stream_passes`` is
    how many times the kernel grid re-streams the full weight payload
    (one pass per output-row stripe, M / tile_M)."""
    if _ACTIVE_COUNTERS is None:
        return
    c = _ACTIVE_COUNTERS.setdefault(role, OpCounters())
    c.calls += layers
    c.w_fetch_bits += w_bits
    c.x_bits += float(layers * x2.size * x2.dtype.itemsize * 8)
    c.y_bits += float(layers * x2.shape[0] * y_k * 32)   # kernels emit f32
    c.macs += macs
    c.decode_ops += decode_ops
    c.w_distinct_bits += w_bits
    c.w_stream_bits += w_bits * stream_passes


def measured_w_bits(entry: CompressedTensor) -> float:
    """Realized W-side bits one full pass over ``entry`` fetches."""
    return entry.stored_bits


# ---------------------------------------------------------------------------
# Trace-time kernel-failure guard
# ---------------------------------------------------------------------------

_KERNEL_GUARD = None


@contextlib.contextmanager
def kernel_guard(sink) -> Iterator[None]:
    """Per-role dense fallback for kernel dispatch failures.

    While active, an exception raised by a compressed kernel call inside a
    dispatcher (a lowering/launch failure — or an injected one, see
    :func:`repro.kernels.ops.kernel_fault_hook`) is reported to
    ``sink(role, exc)`` and the projection returns ``None``, falling
    through to the dense einsum over the params pytree, instead of failing
    the whole forward.  The failure surfaces at TRACE time, so the demotion
    is baked into that trace's compiled graph.  Without the guard (the
    default) kernel exceptions propagate unchanged."""
    global _KERNEL_GUARD
    prev = _KERNEL_GUARD
    _KERNEL_GUARD = sink
    try:
        yield
    finally:
        _KERNEL_GUARD = prev


def _guarded_kernel(role: str, fn):
    """Run one kernel dispatch under the active guard (if any)."""
    if _KERNEL_GUARD is None:
        return fn()
    try:
        return fn()
    except Exception as e:                     # noqa: BLE001 — reported, not hidden
        _KERNEL_GUARD(role, e)
        return None


# ---------------------------------------------------------------------------
# The dispatchers (repro.models.layers.proj hooks)
# ---------------------------------------------------------------------------

def _tile(extent: int, cap: int = 128, multiple: int = 1) -> int:
    """Largest divisor of ``extent`` that is ≤ cap (and a multiple of
    ``multiple`` when possible) — kernel grid tiles must divide extents."""
    t = min(extent, cap)
    while t > 1 and (extent % t or t % multiple):
        t -= 1
    return max(t, 1)


class _Dispatcher:
    """Per-(layer, role) hook for the UNROLLED reference forward.

    Bitmap kernels use one per-role ``t_max`` (max over layers), so every
    layer of a role shares a single jitted kernel configuration — same
    cache-sharing property the stacked path gets by construction."""

    def __init__(self, store: CompressedStore):
        self.store = store
        self.layer = 0
        self._t_max: dict[str, int] = {}
        for e in store:
            if e.kind == "bitmap" and e.expert < 0:
                self._t_max[e.role] = max(self._t_max.get(e.role, 1),
                                          e.data.max_per_col)

    def __call__(self, x: jax.Array, w: jax.Array, role: str
                 ) -> Optional[jax.Array]:
        entry = self.store.get(self.layer, role)
        if entry is None:
            return None                       # unplanned role: dense einsum
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        m = x2.shape[0]
        if entry.kind == "bitmap":
            d = entry.data
            y = _guarded_kernel(role, lambda: kops.bitmap_spmm(
                x2, d, bm=_tile(m), t_max=self._t_max[role]))
            if y is None:                     # guarded kernel failure: dense
                return None
            nnzb = int(np.asarray(d.counts).sum())
            _record(role, x2, d.k, w_bits=entry.stored_bits,
                    macs=float(m) * nnzb * d.bn * d.bk,
                    decode_ops=float(nnzb),
                    stream_passes=m // _tile(m))
        elif entry.kind == "nm":
            d = entry.data
            y = _guarded_kernel(role, lambda: kops.nm_spmm(
                x2, d, bm=_tile(m), bn=_tile(d.n, multiple=d.m_group),
                bk=_tile(d.k)))
            if y is None:                     # guarded kernel failure: dense
                return None
            _record(role, x2, d.k, w_bits=entry.stored_bits,
                    macs=float(m) * d.values.size,
                    decode_ops=float(d.indices.size),
                    stream_passes=m // _tile(m))
        else:
            # dense-kind: record the dense traffic, run the standard einsum
            _record(role, x2, w.shape[-1],
                    w_bits=entry.stored_bits,
                    macs=float(m) * w.size, decode_ops=0.0)
            return None
        return y.astype(x.dtype).reshape(*lead, y.shape[-1])


class _StackedDispatcher:
    """Hook for the SCANNED forward: static kernel configuration from the
    :class:`StackedStore`, per-layer operands from the scan body's
    ``layer_ctx`` slice.  Runs once per role per trace; the compiled scan
    replays it for every layer with that layer's payload slice."""

    def __init__(self, stacked: StackedStore):
        self.stacked = stacked

    def __call__(self, x: jax.Array, w: jax.Array, role: str
                 ) -> Optional[jax.Array]:
        sr = self.stacked.roles.get(role)
        if sr is None:
            return None                       # unplanned role: dense einsum
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        m = x2.shape[0]
        nl = self.stacked.n_layers
        if sr.kind == "dense":
            _record(role, x2, w.shape[-1], w_bits=sr.stored_bits,
                    macs=float(m) * sr.payload_elems, decode_ops=0.0,
                    layers=nl)
            return None
        e = L.current_layer_ctx()
        if e is None or role not in e:
            return None       # hook active outside a carrying scan: dense
        d = e[role]
        if sr.kind == "bitmap":
            bc = kops.BitmapCompressed(
                blocks=d["blocks"], counts=d["counts"],
                row_ids=d["row_ids"], offsets=d["offsets"],
                n=sr.n, k=sr.k, bn=sr.bn, bk=sr.bk, max_per_col=sr.t_max)
            y = _guarded_kernel(role, lambda: kops.bitmap_spmm(
                x2, bc, bm=_tile(m), t_max=sr.t_max))
            if y is None:                     # guarded kernel failure: dense
                return None
            _record(role, x2, sr.k, w_bits=sr.stored_bits,
                    macs=float(m) * sr.payload_elems,
                    decode_ops=sr.decode_units, layers=nl,
                    stream_passes=m // _tile(m))
        else:                                 # nm
            nc = kops.NMCompressed(
                values=d["values"], indices=d["indices"],
                n=sr.n, k=sr.k, n_sel=sr.n_sel, m_group=sr.m_group)
            y = _guarded_kernel(role, lambda: kops.nm_spmm(
                x2, nc, bm=_tile(m), bn=_tile(sr.n, multiple=sr.m_group),
                bk=_tile(sr.k)))
            if y is None:                     # guarded kernel failure: dense
                return None
            _record(role, x2, sr.k, w_bits=sr.stored_bits,
                    macs=float(m) * sr.payload_elems,
                    decode_ops=sr.decode_units, layers=nl,
                    stream_passes=m // _tile(m))
        return y.astype(x.dtype).reshape(*lead, y.shape[-1])


@contextlib.contextmanager
def active(store: CompressedStore) -> Iterator[_Dispatcher]:
    """Install the per-layer dispatch hook for ``store`` (unrolled path)."""
    disp = _Dispatcher(store)
    L.set_proj_hook(disp)
    try:
        yield disp
    finally:
        L.set_proj_hook(None)


@contextlib.contextmanager
def active_stacked(stacked: StackedStore) -> Iterator[_StackedDispatcher]:
    """Install the scan-carried dispatch hook for ``stacked``."""
    disp = _StackedDispatcher(stacked)
    L.set_proj_hook(disp)
    try:
        yield disp
    finally:
        L.set_proj_hook(None)


# ---------------------------------------------------------------------------
# Compressed forward / serving surface
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CompressedModel:
    """A served model: dense params for the un-planned pieces + a
    :class:`CompressedStore` for every planned projection.

    Mirrors :class:`repro.models.transformer.Model`'s serving surface
    (``prefill`` / ``init_cache`` / ``decode_step`` / ``hidden_states``)
    for uniform attention stacks, driving the model's OWN scanned bodies
    with the layer-stacked store as scan extras — MoE expert matmuls
    currently execute dense (their plan entries are accounting-only),
    matching the store's ``kind="dense"`` fall-through."""

    model: T.Model
    store: CompressedStore
    stacked: Optional[StackedStore] = None

    def __post_init__(self):
        if self.stacked is None:
            self.stacked = stack_store(self.store)

    @property
    def cfg(self):
        return self.model.cfg

    # -- integrity ----------------------------------------------------------
    def verify(self) -> dict[str, str]:
        """Verify BOTH representations this model serves from: the
        per-layer store (checksums + structure) and the layer-stacked
        serving payloads.  Raises the first
        :class:`repro.runtime.integrity.IntegrityError`; returns the merged
        ``{role: "ok"}`` map otherwise."""
        out = self.store.verify()
        out.update(self.stacked.verify())
        return out

    def demoted(self, roles) -> "CompressedModel":
        """A new model with the given roles served DENSE (entries dropped
        from the store; the stacked representation is rebuilt).  The guarded
        serving path calls this after an integrity violation so one corrupt
        role costs its compression ratio, not the whole batch."""
        return CompressedModel(self.model, self.store.without_roles(roles))

    # -- full-sequence forward ---------------------------------------------
    def hidden_states(self, params, tokens: jax.Array) -> jax.Array:
        with active_stacked(self.stacked):
            return self.model.hidden_states(params, tokens, remat=False,
                                            extras=self.stacked.extras())

    def hidden_states_unrolled(self, params, tokens: jax.Array) -> jax.Array:
        """Previous-revision reference: per-layer Python loop re-driving the
        layer body (O(layers) HLO).  Kept for scanned-vs-unrolled
        equivalence tests and the bench_serve comparison row."""
        cfg = self.model.cfg
        b, s = tokens.shape
        x = L.embed(tokens, params["embed"])
        positions = jnp.arange(s)
        freqs = L.rope_freqs(cfg)
        with active(self.store) as disp:
            for layer in range(cfg.n_layers):
                disp.layer = layer
                p = jax.tree.map(lambda a: a[layer], params["blocks"])
                x = T._attn_layer(x, p, cfg, freqs, positions, causal=True,
                                  window=cfg.window)
        return L.rms_norm(x, params["final_norm"], cfg.norm_eps)

    def logits(self, params, tokens: jax.Array) -> jax.Array:
        x = self.hidden_states(params, tokens)
        return jnp.einsum("btd,vd->btv", x,
                          params["embed"].astype(L.COMPUTE_DTYPE))

    # -- serving (prefill + KV-cache decode) --------------------------------
    def prefill(self, params, tokens: jax.Array, max_len: int):
        """Compressed full-sequence forward that fills a decode cache —
        same contract as :meth:`repro.models.transformer.Model.prefill`."""
        with active_stacked(self.stacked):
            return self.model.prefill(params, tokens, max_len,
                                      extras=self.stacked.extras())

    def init_cache(self, batch: int, max_len: int):
        return self.model.init_cache(batch, max_len)

    def decode_step(self, params, cache, tokens: jax.Array, pos: jax.Array):
        """One compressed decode token for the whole batch — same contract
        as :meth:`repro.models.transformer.Model.decode_step`.  ``pos`` may
        be a scalar (lockstep batch) or a per-slot ``(B,)`` vector (the
        continuous-batching mixer / ragged-prompt serving)."""
        with active_stacked(self.stacked):
            return self.model.decode_step(params, cache, tokens, pos,
                                          extras=self.stacked.extras())

    def generate(self, params, prompts: jax.Array, gen: int,
                 max_len: Optional[int] = None, **kwargs):
        """Greedy batched generation (shared driver with the dense model:
        :func:`repro.launch.serve.generate`).  Returns
        (tokens (B, gen), t_prefill_s, t_gen_s); with ``guarded=True`` a
        :class:`repro.runtime.guard.HealthReport` is appended."""
        from repro.launch import serve
        if max_len is None:
            max_len = prompts.shape[1] + gen
        return serve.generate(self, params, prompts, gen, max_len, **kwargs)

    def serve_mixed(self, params, requests, *, slots: int,
                    max_len: int, **kwargs):
        """Continuous-batching serve of a request STREAM over the
        compressed plane (delegates to :class:`repro.launch.mixer.Mixer`,
        same as :meth:`generate` delegates to the static driver).  Returns
        ``(results, mixer)`` — per-request :class:`RequestResult`\\ s in
        request order plus the drained mixer (events / stats)."""
        from repro.launch.mixer import Mixer
        mx = Mixer(self, params, slots=slots, max_len=max_len, **kwargs)
        return mx.run(requests), mx
