"""Plan-driven matmul dispatch: compressed kernels inside the real model.

The transformer's FFN/attention projections all route through
:func:`repro.models.layers.proj`.  :class:`CompressedModel` installs a hook
there and walks the layer stack in a per-layer Python loop (compressed
operands differ per layer, so the stacked ``lax.scan`` cannot carry them),
swapping each planned (layer, role) projection for the matching Pallas
kernel — ``bitmap_spmm`` / ``nm_spmm``, interpret mode on CPU, native on
TPU — while dense-kind roles fall through to the exact einsum the dense
model runs.  Because the surrounding forward IS the dense model's code
path (:func:`repro.models.transformer._attn_layer` per layer), compressed
and dense forwards differ only by kernel accumulation order.

Kernel wrappers are jit-cached per static configuration
(:func:`repro.kernels.ops` ``_jitted``), so repeated layers that share a
block shape reuse one compiled kernel.

:func:`instrument` turns on per-role traffic counters: every dispatched
matmul records the EXACT bits its operands move (realized payload +
metadata of the compressed store, not the statistical expectation) plus
MACs and decode operations — the measured half of the calibration loop
(:mod:`repro.exec.calibrate`).
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.exec.compress import CompressedStore, CompressedTensor
from repro.kernels import ops as kops
from repro.models import layers as L
from repro.models import transformer as T


# ---------------------------------------------------------------------------
# Measured traffic counters
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class OpCounters:
    """Accumulated measured traffic of one dispatch role."""

    calls: int = 0
    w_fetch_bits: float = 0.0     # payload + metadata, realized encoding
    x_bits: float = 0.0
    y_bits: float = 0.0
    macs: float = 0.0             # useful MACs (compressed operand elems × M)
    decode_ops: float = 0.0       # metadata units decoded (blocks / indices)

    @property
    def w_fetch_bits_per_call(self) -> float:
        return self.w_fetch_bits / self.calls if self.calls else 0.0


_ACTIVE_COUNTERS: Optional[dict[str, OpCounters]] = None


@contextlib.contextmanager
def instrument() -> Iterator[dict[str, OpCounters]]:
    """Collect per-role :class:`OpCounters` for every dispatched projection
    executed inside the context (nested dispatchers share the dict)."""
    global _ACTIVE_COUNTERS
    prev = _ACTIVE_COUNTERS
    counters: dict[str, OpCounters] = {}
    _ACTIVE_COUNTERS = counters
    try:
        yield counters
    finally:
        _ACTIVE_COUNTERS = prev


def _record(role: str, x2: jax.Array, y_k: int,
            w_bits: float, macs: float, decode_ops: float) -> None:
    if _ACTIVE_COUNTERS is None:
        return
    c = _ACTIVE_COUNTERS.setdefault(role, OpCounters())
    c.calls += 1
    c.w_fetch_bits += w_bits
    c.x_bits += float(x2.size * x2.dtype.itemsize * 8)
    c.y_bits += float(x2.shape[0] * y_k * 32)        # kernels emit float32
    c.macs += macs
    c.decode_ops += decode_ops


def measured_w_bits(entry: CompressedTensor) -> float:
    """Realized W-side bits one full pass over ``entry`` fetches."""
    return entry.stored_bits


# ---------------------------------------------------------------------------
# The dispatcher (repro.models.layers.proj hook)
# ---------------------------------------------------------------------------

def _tile(extent: int, cap: int = 128, multiple: int = 1) -> int:
    """Largest divisor of ``extent`` that is ≤ cap (and a multiple of
    ``multiple`` when possible) — kernel grid tiles must divide extents."""
    t = min(extent, cap)
    while t > 1 and (extent % t or t % multiple):
        t -= 1
    return max(t, 1)


class _Dispatcher:
    """The installed ``proj`` hook: per-(layer, role) kernel dispatch."""

    def __init__(self, store: CompressedStore):
        self.store = store
        self.layer = 0

    def __call__(self, x: jax.Array, w: jax.Array, role: str
                 ) -> Optional[jax.Array]:
        entry = self.store.get(self.layer, role)
        if entry is None:
            return None                       # unplanned role: dense einsum
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        m = x2.shape[0]
        if entry.kind == "bitmap":
            d = entry.data
            nnzb = int(np.asarray(d.counts).sum())
            _record(role, x2, d.k, w_bits=entry.stored_bits,
                    macs=float(m) * nnzb * d.bn * d.bk,
                    decode_ops=float(nnzb))
            y = kops.bitmap_spmm(x2, d, bm=_tile(m))
        elif entry.kind == "nm":
            d = entry.data
            _record(role, x2, d.k, w_bits=entry.stored_bits,
                    macs=float(m) * d.values.size,
                    decode_ops=float(d.indices.size))
            y = kops.nm_spmm(x2, d, bm=_tile(m),
                             bn=_tile(d.n, multiple=d.m_group),
                             bk=_tile(d.k))
        else:
            # dense-kind: record the dense traffic, run the standard einsum
            _record(role, x2, w.shape[-1],
                    w_bits=entry.stored_bits,
                    macs=float(m) * w.size, decode_ops=0.0)
            return None
        return y.astype(x.dtype).reshape(*lead, y.shape[-1])


@contextlib.contextmanager
def active(store: CompressedStore) -> Iterator[_Dispatcher]:
    """Install the dispatch hook for ``store`` on the model layers."""
    disp = _Dispatcher(store)
    L.set_proj_hook(disp)
    try:
        yield disp
    finally:
        L.set_proj_hook(None)


# ---------------------------------------------------------------------------
# Compressed forward
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CompressedModel:
    """A served model: dense params for the un-planned pieces + a
    :class:`CompressedStore` for every planned projection.

    Mirrors :meth:`repro.models.transformer.Model.hidden_states` for
    uniform attention stacks, reusing the model's own layer body per layer
    (the hook swaps the projections) — MoE FFNs currently execute dense
    (their plan entries are accounting-only), matching the store's
    ``kind="dense"`` fall-through."""

    model: T.Model
    store: CompressedStore

    def hidden_states(self, params, tokens: jax.Array) -> jax.Array:
        cfg = self.model.cfg
        b, s = tokens.shape
        x = L.embed(tokens, params["embed"])
        positions = jnp.arange(s)
        freqs = L.rope_freqs(cfg)
        with active(self.store) as disp:
            for layer in range(cfg.n_layers):
                disp.layer = layer
                p = jax.tree.map(lambda a: a[layer], params["blocks"])
                x = T._attn_layer(x, p, cfg, freqs, positions, causal=True,
                                  window=cfg.window)
        return L.rms_norm(x, params["final_norm"], cfg.norm_eps)

    def logits(self, params, tokens: jax.Array) -> jax.Array:
        x = self.hidden_states(params, tokens)
        return jnp.einsum("btd,vd->btv", x,
                          params["embed"].astype(L.COMPUTE_DTYPE))
