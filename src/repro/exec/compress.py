"""Apply an :class:`~repro.exec.plans.ExecPlan` to a real weight pytree.

Walks the model's stacked layer parameters, slices each (layer, role)
projection weight out, and stores it in the plan's chosen representation:
:class:`~repro.kernels.ops.BitmapCompressed`,
:class:`~repro.kernels.ops.NMCompressed`, or the dense array.  Every entry
carries EXACT achieved-size accounting (payload + metadata bits of the
realized weights, not the statistical expectation), which is what the
calibration loop compares the cost model's predictions against.

Compression is lossless for weights that already carry the plan's sparsity
structure (block-sparse for bitmap entries, N:M for nm entries);
:func:`prune_params` produces such weights from a dense pytree.  MoE roles
fan out per expert (one entry per (layer, role, expert)).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.sparsity import NM
from repro.exec.plans import ExecPlan, OpPlan
from repro.kernels import ops as kops
from repro.sparse import masks


def _role_path(role: str) -> tuple[str, str]:
    """Dispatch role → (sub-tree, leaf) inside one layer's param dict."""
    group, leaf = role.split(".", 1)
    if group == "attn":
        return "attn", leaf
    if group in ("ffn", "moe"):
        return "ffn", leaf
    raise KeyError(f"unknown role {role!r}")


@dataclasses.dataclass
class CompressedTensor:
    """One (layer, role[, expert]) weight in its executable representation."""

    layer: int
    role: str
    expert: int                # -1 for non-MoE roles
    kind: str                  # "bitmap" | "nm" | "dense"
    data: Any                  # BitmapCompressed | NMCompressed | jax.Array
    dense_bits: float
    stored_bits: float

    @property
    def achieved_ratio(self) -> float:
        return self.stored_bits / self.dense_bits


@dataclasses.dataclass
class CompressedStore:
    """The compressed parameter store an :class:`ExecPlan` serves from."""

    plan: ExecPlan
    entries: dict[tuple[int, str, int], CompressedTensor]

    def get(self, layer: int, role: str, expert: int = -1
            ) -> Optional[CompressedTensor]:
        return self.entries.get((layer, role, expert))

    def __iter__(self) -> Iterator[CompressedTensor]:
        return iter(self.entries.values())

    def __len__(self) -> int:
        return len(self.entries)

    # -- achieved-ratio accounting -----------------------------------------
    def achieved_ratio(self, role: Optional[str] = None) -> float:
        """stored/dense bits over the whole store (or one role), exact."""
        es = [e for e in self if role is None or e.role == role]
        dense = sum(e.dense_bits for e in es)
        return sum(e.stored_bits for e in es) / dense if dense else 1.0

    def ratio_report(self) -> dict[str, float]:
        roles = sorted({e.role for e in self})
        out = {r: self.achieved_ratio(r) for r in roles}
        out["total"] = self.achieved_ratio()
        return out


def _stored_bits(kind: str, data: Any, vb: int) -> float:
    """Exact stored size: payload + metadata of the realized encoding."""
    if kind == "bitmap":
        nnzb = int(np.asarray(data.counts).sum())   # true non-zero blocks
        gn, gk = data.n // data.bn, data.k // data.bk
        return float(nnzb * data.bn * data.bk * vb + gn * gk)
    if kind == "nm":
        idx_bits = max(1, math.ceil(math.log2(data.m_group)))
        return float(data.values.size * vb + data.indices.size * idx_bits)
    return float(data.size * vb)


def _layer_weight(params: dict, layer: int, role: str, expert: int
                  ) -> jax.Array:
    group, leaf = _role_path(role)
    w = params["blocks"][group][leaf]
    w = w[layer]
    if expert >= 0:
        w = w[expert]
    return w


def _check_uniform(cfg: ModelConfig) -> None:
    if cfg.hybrid is not None or cfg.family not in ("dense", "moe", "vlm"):
        raise NotImplementedError(
            f"exec plane serves uniform attention stacks; {cfg.name} is "
            f"family={cfg.family!r} hybrid={cfg.hybrid!r}")


def _fanout(plan_op: OpPlan, cfg: ModelConfig) -> range:
    if plan_op.role.startswith("moe."):
        assert cfg.moe is not None
        return range(cfg.moe.n_experts)
    return range(-1, 0)          # single entry, expert = -1


def compress_params(params: dict, plan: ExecPlan, cfg: ModelConfig
                    ) -> CompressedStore:
    """Compress every planned (layer, role[, expert]) weight of ``params``.

    ``params`` is a :meth:`repro.models.transformer.Model.init` pytree whose
    weights already carry the plan's sparsity structure (see
    :func:`prune_params`).  Dense-kind entries keep the raw array (the
    dispatcher falls through to the dense einsum)."""
    _check_uniform(cfg)
    sp = plan.sparsity
    n_sel, m_group = (sp.n, sp.m) if isinstance(sp, NM) else (2, 4)
    entries: dict[tuple[int, str, int], CompressedTensor] = {}
    for op in plan.ops:
        ch = op.choice
        for layer in range(cfg.n_layers):
            for expert in _fanout(op, cfg):
                w = _layer_weight(params, layer, op.role, expert)
                vb = w.dtype.itemsize * 8
                dense_bits = float(w.size * vb)
                if ch.kind == "bitmap":
                    data: Any = kops.compress_bitmap(
                        np.asarray(w), ch.block_n, ch.block_k)
                elif ch.kind == "nm":
                    data = kops.compress_nm(np.asarray(w), n_sel, m_group)
                else:
                    data = jnp.asarray(w)
                entries[(layer, op.role, expert)] = CompressedTensor(
                    layer=layer, role=op.role, expert=expert, kind=ch.kind,
                    data=data, dense_bits=dense_bits,
                    stored_bits=_stored_bits(ch.kind, data, vb))
    return CompressedStore(plan, entries)


def prune_params(params: dict, plan: ExecPlan, cfg: ModelConfig) -> dict:
    """Prune ``params`` to the plan's servable sparsity structure.

    Bitmap roles get block pruning at the plan's block shape and target
    density; nm roles get 2:4 pruning; dense roles pass through.  Returns a
    new pytree (the input is not mutated) — the dense REFERENCE forward
    should run on this same pruned tree so compressed-vs-dense comparisons
    isolate kernel numerics, not pruning error."""
    _check_uniform(cfg)
    sp = plan.sparsity
    density = sp.density
    n_sel, m_group = (sp.n, sp.m) if isinstance(sp, NM) else (2, 4)
    blocks = dict(params["blocks"])          # group dicts copied on write
    out = dict(params)
    out["blocks"] = blocks
    for op in plan.ops:
        ch = op.choice
        if ch.kind == "dense":
            continue
        group, leaf = _role_path(op.role)
        w = blocks[group][leaf]

        def prune_one(w2d):
            if ch.kind == "bitmap":
                return masks.block_prune(w2d, ch.block_n, ch.block_k, density)
            return masks.nm_prune(w2d, n_sel, m_group)

        if w.ndim == 3:                               # (L, n, k)
            pruned = jnp.stack([prune_one(w[l]) for l in range(w.shape[0])])
        else:                                         # (L, E, n, k) — MoE
            pruned = jnp.stack([
                jnp.stack([prune_one(w[l, e]) for e in range(w.shape[1])])
                for l in range(w.shape[0])])
        blocks[group] = dict(blocks[group])
        blocks[group][leaf] = pruned
    return out
