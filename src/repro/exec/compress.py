"""Apply an :class:`~repro.exec.plans.ExecPlan` to a real weight pytree.

Walks the model's stacked layer parameters, slices each (layer, role)
projection weight out, and stores it in the plan's chosen representation:
:class:`~repro.kernels.ops.BitmapCompressed`,
:class:`~repro.kernels.ops.NMCompressed`, or the dense array.  Every entry
carries EXACT achieved-size accounting (payload + metadata bits of the
realized weights, not the statistical expectation), which is what the
calibration loop compares the cost model's predictions against.

Compression is lossless for weights that already carry the plan's sparsity
structure (block-sparse for bitmap entries, N:M for nm entries);
:func:`prune_params` produces such weights from a dense pytree.  MoE roles
fan out per expert (one entry per (layer, role, expert)).

:func:`stack_store` re-lays a per-layer store as a **layer-stacked**
:class:`StackedStore`: one pytree per role with a leading layer axis,
padded so every scanned layer shares ONE kernel configuration per role —
the representation ``jax.lax.scan`` carries through the compiled serving
block (:class:`repro.exec.dispatch.CompressedModel` prefill/decode).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.sparsity import NM
from repro.exec.plans import ExecPlan, OpPlan
from repro.kernels import ops as kops
from repro.sparse import masks


def _role_path(role: str) -> tuple[str, str]:
    """Dispatch role → (sub-tree, leaf) inside one layer's param dict."""
    group, leaf = role.split(".", 1)
    if group == "attn":
        return "attn", leaf
    if group in ("ffn", "moe"):
        return "ffn", leaf
    raise KeyError(f"unknown role {role!r}")


@dataclasses.dataclass
class CompressedTensor:
    """One (layer, role[, expert]) weight in its executable representation."""

    layer: int
    role: str
    expert: int                # -1 for non-MoE roles
    kind: str                  # "bitmap" | "nm" | "dense"
    data: Any                  # BitmapCompressed | NMCompressed | jax.Array
    dense_bits: float
    stored_bits: float

    @property
    def achieved_ratio(self) -> float:
        return self.stored_bits / self.dense_bits


@dataclasses.dataclass
class CompressedStore:
    """The compressed parameter store an :class:`ExecPlan` serves from."""

    plan: ExecPlan
    entries: dict[tuple[int, str, int], CompressedTensor]

    def get(self, layer: int, role: str, expert: int = -1
            ) -> Optional[CompressedTensor]:
        return self.entries.get((layer, role, expert))

    def __iter__(self) -> Iterator[CompressedTensor]:
        return iter(self.entries.values())

    def __len__(self) -> int:
        return len(self.entries)

    # -- achieved-ratio accounting -----------------------------------------
    def achieved_ratio(self, role: Optional[str] = None) -> float:
        """stored/dense bits over the whole store (or one role), exact."""
        es = [e for e in self if role is None or e.role == role]
        dense = sum(e.dense_bits for e in es)
        return sum(e.stored_bits for e in es) / dense if dense else 1.0

    def ratio_report(self) -> dict[str, float]:
        roles = sorted({e.role for e in self})
        out = {r: self.achieved_ratio(r) for r in roles}
        out["total"] = self.achieved_ratio()
        return out

    # -- integrity ----------------------------------------------------------
    def verify(self) -> dict[str, str]:
        """Structural invariants + content checksums for every role.

        Raises :class:`repro.runtime.integrity.IntegrityError` on the first
        violation; returns ``{role: "ok"}`` otherwise.  Checksums compare
        against ``plan.checksums`` (recorded by :func:`compress_params`);
        plans without recorded digests get structure-only verification."""
        from repro.runtime import integrity
        return integrity.verify(self)

    def without_roles(self, roles) -> "CompressedStore":
        """A new store with the given roles' entries removed.

        Dropping a role makes the dispatcher fall through to the dense
        einsum over the (pruned) params pytree — the guarded serving path's
        per-role demotion after an integrity violation."""
        drop = set(roles)
        return CompressedStore(self.plan, {
            k: e for k, e in self.entries.items() if e.role not in drop})


def _stored_bits(kind: str, data: Any, vb: int) -> float:
    """Exact stored size: payload + metadata of the realized encoding."""
    if kind == "bitmap":
        nnzb = int(np.asarray(data.counts).sum())   # true non-zero blocks
        gn, gk = data.n // data.bn, data.k // data.bk
        return float(nnzb * data.bn * data.bk * vb + gn * gk)
    if kind == "nm":
        idx_bits = max(1, math.ceil(math.log2(data.m_group)))
        return float(data.values.size * vb + data.indices.size * idx_bits)
    return float(data.size * vb)


def _layer_weight(params: dict, layer: int, role: str, expert: int
                  ) -> jax.Array:
    group, leaf = _role_path(role)
    w = params["blocks"][group][leaf]
    w = w[layer]
    if expert >= 0:
        w = w[expert]
    return w


def _check_uniform(cfg: ModelConfig) -> None:
    if cfg.hybrid is not None or cfg.family not in ("dense", "moe", "vlm"):
        raise NotImplementedError(
            f"exec plane serves uniform attention stacks; {cfg.name} is "
            f"family={cfg.family!r} hybrid={cfg.hybrid!r}")


def _fanout(plan_op: OpPlan, cfg: ModelConfig) -> range:
    if plan_op.role.startswith("moe."):
        assert cfg.moe is not None
        return range(cfg.moe.n_experts)
    return range(-1, 0)          # single entry, expert = -1


def compress_params(params: dict, plan: ExecPlan, cfg: ModelConfig
                    ) -> CompressedStore:
    """Compress every planned (layer, role[, expert]) weight of ``params``.

    ``params`` is a :meth:`repro.models.transformer.Model.init` pytree whose
    weights already carry the plan's sparsity structure (see
    :func:`prune_params`).  Dense-kind entries keep the raw array (the
    dispatcher falls through to the dense einsum)."""
    _check_uniform(cfg)
    sp = plan.sparsity
    n_sel, m_group = (sp.n, sp.m) if isinstance(sp, NM) else (2, 4)
    entries: dict[tuple[int, str, int], CompressedTensor] = {}
    for op in plan.ops:
        ch = op.choice
        for layer in range(cfg.n_layers):
            for expert in _fanout(op, cfg):
                w = _layer_weight(params, layer, op.role, expert)
                vb = w.dtype.itemsize * 8
                dense_bits = float(w.size * vb)
                if ch.kind == "bitmap":
                    data: Any = kops.compress_bitmap(
                        np.asarray(w), ch.block_n, ch.block_k)
                elif ch.kind == "nm":
                    data = kops.compress_nm(np.asarray(w), n_sel, m_group)
                else:
                    data = jnp.asarray(w)
                entries[(layer, op.role, expert)] = CompressedTensor(
                    layer=layer, role=op.role, expert=expert, kind=ch.kind,
                    data=data, dense_bits=dense_bits,
                    stored_bits=_stored_bits(ch.kind, data, vb))
    store = CompressedStore(plan, entries)
    # record per-role content digests IN the plan: the plan is the durable
    # artifact (JSON round-tripped), so a store rebuilt or reloaded later
    # verifies against what compression actually produced
    from repro.runtime import integrity
    store.plan = dataclasses.replace(
        plan, checksums=integrity.checksum_store(store))
    return store


# ---------------------------------------------------------------------------
# Layer-stacked store (the scan-compiled serving representation)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StackedRole:
    """One dispatch role's compressed weights for ALL layers, stacked.

    ``data`` is a dict of arrays with a leading layer axis (``None`` for
    dense-kind roles, which fall through to the dense einsum carried by the
    params pytree itself).  Bitmap payloads are padded to the max non-zero
    block count across layers, so every scanned layer slice has the same
    shape and runs the same kernel grid (``t_max`` is the shared static
    bound).  Accounting fields are totals over the layer axis: the EXACT
    realized encoding (``stored_bits``) stays what the calibration loop
    compares against; ``padded_bits`` is what the padded stacked payload
    actually occupies (the price of shape uniformity)."""

    role: str
    kind: str                  # "bitmap" | "nm" | "dense"
    n: int
    k: int
    data: Optional[dict]       # stacked arrays, leading axis = layer
    # static kernel configuration (shared by every scanned layer)
    bn: int = 0
    bk: int = 0
    t_max: int = 1
    n_sel: int = 0
    m_group: int = 0
    # accounting — totals over all layers
    dense_bits: float = 0.0
    stored_bits: float = 0.0
    padded_bits: float = 0.0
    payload_elems: float = 0.0   # compressed operand elements, one full pass
    decode_units: float = 0.0    # metadata units decoded, one full pass


@dataclasses.dataclass
class StackedStore:
    """A :class:`CompressedStore` re-laid for ``jax.lax.scan``: per-role
    pytrees with a leading layer axis + one static kernel config per role."""

    plan: ExecPlan
    n_layers: int
    roles: dict[str, StackedRole]

    def extras(self) -> dict[str, dict]:
        """The scan-carried xs pytree: role → stacked arrays (kernel-backed
        roles only; dense roles ride in the params pytree)."""
        return {r: sr.data for r, sr in self.roles.items()
                if sr.data is not None}

    def padding_overhead(self) -> float:
        """padded/stored bits over the kernel-backed roles (≥ 1)."""
        stored = sum(sr.stored_bits for sr in self.roles.values()
                     if sr.data is not None)
        padded = sum(sr.padded_bits for sr in self.roles.values()
                     if sr.data is not None)
        return padded / stored if stored else 1.0

    # -- integrity ----------------------------------------------------------
    def verify(self) -> dict[str, str]:
        """Verify the SERVING representation: per-layer structural checks on
        the stacked slices plus content digests re-derived from the logical
        (un-padded) encoding, compared against ``plan.checksums``.  Raises
        :class:`repro.runtime.integrity.IntegrityError` on violation;
        dense-kind roles carry no stacked payload and are skipped."""
        from repro.runtime import integrity
        return integrity.verify(self)


def _stack_bitmap(role: str, entries: list[CompressedTensor]) -> StackedRole:
    ds = [e.data for e in entries]
    bn, bk = ds[0].bn, ds[0].bk
    n, k = ds[0].n, ds[0].k
    vb = ds[0].blocks.dtype.itemsize * 8
    pad_to = max(max(int(d.blocks.shape[0]) for d in ds), 1)
    blocks, rows = [], []
    for d in ds:
        nnzb = int(d.blocks.shape[0])
        b = np.zeros((pad_to, bn, bk), np.asarray(d.blocks).dtype)
        r = np.zeros((pad_to,), np.int32)
        if nnzb:
            b[:nnzb] = np.asarray(d.blocks)
            r[:nnzb] = np.asarray(d.row_ids)
        blocks.append(b)
        rows.append(r)
    total_nnzb = sum(int(np.asarray(d.counts).sum()) for d in ds)
    stored = sum(e.stored_bits for e in entries)
    return StackedRole(
        role=role, kind="bitmap", n=n, k=k,
        data={"blocks": jnp.asarray(np.stack(blocks)),
              "row_ids": jnp.asarray(np.stack(rows)),
              "counts": jnp.stack([d.counts for d in ds]),
              "offsets": jnp.stack([d.offsets for d in ds])},
        bn=bn, bk=bk,
        t_max=max(max(d.max_per_col for d in ds), 1),
        dense_bits=sum(e.dense_bits for e in entries),
        stored_bits=stored,
        padded_bits=stored + (len(ds) * pad_to - total_nnzb) * bn * bk * vb,
        payload_elems=float(total_nnzb * bn * bk),
        decode_units=float(total_nnzb))


def _stack_nm(role: str, entries: list[CompressedTensor]) -> StackedRole:
    ds = [e.data for e in entries]
    stored = sum(e.stored_bits for e in entries)
    return StackedRole(
        role=role, kind="nm", n=ds[0].n, k=ds[0].k,
        data={"values": jnp.stack([d.values for d in ds]),
              "indices": jnp.stack([d.indices for d in ds])},
        n_sel=ds[0].n_sel, m_group=ds[0].m_group,
        dense_bits=sum(e.dense_bits for e in entries),
        stored_bits=stored, padded_bits=stored,
        payload_elems=float(sum(d.values.size for d in ds)),
        decode_units=float(sum(d.indices.size for d in ds)))


def _stack_dense(role: str, entries: list[CompressedTensor]) -> StackedRole:
    d0 = entries[0].data
    stored = sum(e.stored_bits for e in entries)
    return StackedRole(
        role=role, kind="dense", n=d0.shape[0], k=d0.shape[1], data=None,
        dense_bits=sum(e.dense_bits for e in entries),
        stored_bits=stored, padded_bits=stored,
        payload_elems=float(sum(e.data.size for e in entries)),
        decode_units=0.0)


def stack_store(store: CompressedStore) -> StackedStore:
    """Re-lay ``store`` with a leading layer axis per role.

    Only non-expert entries stack (MoE expert matmuls execute dense inside
    the MoE block and their plan entries are accounting-only, exactly as in
    the per-layer store).  Bitmap payloads pad to the across-layers max
    non-zero block count; padded blocks are zeros with row id 0 and sit
    beyond every column's ``counts``, so the kernel never accumulates them
    — results are bit-identical to the per-layer dispatch."""
    n_layers = store.plan.n_layers
    by_role: dict[str, list[CompressedTensor]] = {}
    for e in store:
        if e.expert >= 0:
            continue
        by_role.setdefault(e.role, []).append(e)
    roles: dict[str, StackedRole] = {}
    for role, entries in by_role.items():
        entries.sort(key=lambda e: e.layer)
        if len(entries) != n_layers:
            raise ValueError(f"role {role!r} has {len(entries)} entries for "
                             f"{n_layers} layers")
        kind = entries[0].kind
        if any(e.kind != kind for e in entries):
            raise ValueError(f"role {role!r} mixes kinds across layers")
        stack = {"bitmap": _stack_bitmap, "nm": _stack_nm,
                 "dense": _stack_dense}[kind]
        roles[role] = stack(role, entries)
    return StackedStore(plan=store.plan, n_layers=n_layers, roles=roles)


def prune_params(params: dict, plan: ExecPlan, cfg: ModelConfig) -> dict:
    """Prune ``params`` to the plan's servable sparsity structure.

    Bitmap roles get block pruning at the plan's block shape and target
    density; nm roles get 2:4 pruning; dense roles pass through.  Returns a
    new pytree (the input is not mutated) — the dense REFERENCE forward
    should run on this same pruned tree so compressed-vs-dense comparisons
    isolate kernel numerics, not pruning error."""
    _check_uniform(cfg)
    sp = plan.sparsity
    density = sp.density
    n_sel, m_group = (sp.n, sp.m) if isinstance(sp, NM) else (2, 4)
    blocks = dict(params["blocks"])          # group dicts copied on write
    out = dict(params)
    out["blocks"] = blocks
    for op in plan.ops:
        ch = op.choice
        if ch.kind == "dense":
            continue
        group, leaf = _role_path(op.role)
        w = blocks[group][leaf]

        def prune_one(w2d):
            if ch.kind == "bitmap":
                return masks.block_prune(w2d, ch.block_n, ch.block_k, density)
            return masks.nm_prune(w2d, n_sel, m_group)

        if w.ndim == 3:                               # (L, n, k)
            pruned = jnp.stack([prune_one(w[l]) for l in range(w.shape[0])])
        else:                                         # (L, E, n, k) — MoE
            pruned = jnp.stack([
                jnp.stack([prune_one(w[l, e]) for e in range(w.shape[1])])
                for l in range(w.shape[0])])
        blocks[group] = dict(blocks[group])
        blocks[group][leaf] = pruned
    return out
