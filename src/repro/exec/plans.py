"""Execution plans: SnipSnap DSE decisions → whole-model kernel configs.

This generalizes the original ``core/codesign.py`` bridge (one layer's FFN
pair) into :class:`ExecPlan`\\ s covering every per-layer projection of a
:class:`~repro.configs.base.ModelConfig` — attention QKV/O, the FFN triple,
and MoE expert fan-out — so a co-search result can drive a *running*
compressed model (``repro.exec.compress`` / ``repro.exec.dispatch``).

Plans are plain data and JSON round-trippable (:meth:`ExecPlan.to_json` /
:meth:`ExecPlan.from_json`, bit-identical): search once, serve many times.
Each :class:`OpPlan` also carries the cost model's predicted fetch/energy
terms for its winning (format, mapping), which is what the calibration loop
(:mod:`repro.exec.calibrate`) compares measured counters against.

Formats whose structure matches the block-bitmap kernel (``B(N₁)-B(K₁)``
with dense leaves) map to ``bitmap_spmm`` with the leaf sizes as the block
shape (MXU-aligned); 2:4-sparse operands map to ``nm_spmm``.  Everything
else stays dense — and the plan now says WHY, as a structured
:class:`FallbackReason` instead of a silent drop.
"""

from __future__ import annotations

import dataclasses
import json
import math
from typing import Optional

from repro.configs.base import ModelConfig
from repro.core.arch import TPUV5E, HardwareConfig, arch_by_name
from repro.core.cosearch import CoSearchConfig, SearchResult, cosearch
from repro.core.dataflow import irrelevant_refetch
from repro.core.engine import EngineConfig
from repro.core.costmodel import compile_format
from repro.core.formats import Format
from repro.core.primitives import Prim
from repro.core.sparsity import (NM, Bernoulli, BlockBernoulli, Sparsity,
                                 TensorSpec, analyze)
from repro.core.workload import MatMul, Workload

MXU_ALIGN = 128

#: Current :class:`ExecPlan` JSON schema version.  v1 plans (no ``version``
#: key, no ``checksums``) predate PR 8 and still load; bumping this requires
#: teaching :meth:`ExecPlan.from_dict` the new layout.
PLAN_VERSION = 2


class PlanVersionError(ValueError):
    """A serialized plan declares a schema version this code cannot read.

    Raised by :meth:`ExecPlan.from_dict` BEFORE any field access, so a
    future-format plan fails with a structured error naming both versions
    instead of a ``KeyError`` deep in ``hardware()`` resolution."""

    def __init__(self, found: int, supported: int = PLAN_VERSION):
        self.found = found
        self.supported = supported
        super().__init__(
            f"ExecPlan schema version {found} is newer than the supported "
            f"version {supported}; refusing to guess at the layout")


# ---------------------------------------------------------------------------
# Kernel choices + structured fallbacks
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FallbackReason:
    """Why a planned role is (or went) dense instead of a native kernel.

    ``code`` is machine-checkable; ``detail`` carries the human context
    (typically the format string).  Plan-time reasons are recorded on the
    :class:`KernelChoice` so unservable winners are visible instead of
    quietly dropped; the guarded serving path (:mod:`repro.runtime.guard`)
    reuses the same type for RUNTIME demotions, with codes
    ``integrity_violation`` / ``kernel_failure`` / ``nonfinite_logits`` /
    ``deadline_exceeded`` / ``step_failure``."""

    code: str        # plan: "no_tpu_kernel" | "unallocated_leaf"
    #                # runtime: "integrity_violation" | "kernel_failure" |
    #                #   "nonfinite_logits" | "deadline_exceeded" |
    #                #   "step_failure"
    detail: str = ""


@dataclasses.dataclass(frozen=True)
class KernelChoice:
    op_name: str
    kind: str                  # "bitmap" | "nm" | "dense"
    block_n: int = 0           # bitmap_spmm block shape (bn, bk)
    block_k: int = 0
    predicted_ratio: float = 1.0
    format_str: str = "dense"
    fallback: Optional[FallbackReason] = None


@dataclasses.dataclass
class CompressionPlan:
    """Legacy single-layer FFN plan (the original codesign bridge API)."""

    choices: dict[str, KernelChoice]
    search: SearchResult

    def for_op(self, name: str) -> KernelChoice:
        return self.choices[name]


def _align(x: int, extent: int) -> int:
    """Snap a format level size to an MXU-friendly divisor of extent."""
    for cand in (x, MXU_ALIGN, 64, 32, 16, 8):
        if cand and extent % cand == 0 and cand <= extent:
            return cand
    return extent


def translate(op: MatMul, fmt_w: Optional[Format],
              w_sparsity: Sparsity) -> KernelChoice:
    """One searched W-side format → the kernel that can execute it."""
    if isinstance(w_sparsity, NM):
        # n/m values survive + ceil(log2(m))-bit positions per kept value
        idx_bits = max(1, (w_sparsity.m - 1).bit_length())
        ratio = w_sparsity.n / w_sparsity.m * (1 + idx_bits / op.value_bits)
        return KernelChoice(op.name, "nm", predicted_ratio=ratio,
                            format_str=f"CP({w_sparsity.n}:{w_sparsity.m})")
    if fmt_w is None:
        # the search itself chose dense — not a fallback
        return KernelChoice(op.name, "dense")

    # block-bitmap realizable: compressed levels are all B, with dense-leaf
    # (None) block factors determining the executable block shape.
    comp = [l for l in fmt_w.levels if l.prim is not Prim.NONE]
    leaves = {l.dim: int(l.size) for l in fmt_w.levels
              if l.prim is Prim.NONE and l.size is not None}
    if comp and all(l.prim is Prim.B for l in comp):
        bn = _align(leaves.get("N", MXU_ALIGN), op.N)
        bk = _align(leaves.get("K", MXU_ALIGN), op.K)
        spec = TensorSpec(op.w_dims(), w_sparsity, op.value_bits)
        ratio = analyze(fmt_w, spec).total_bits / spec.dense_bits
        return KernelChoice(op.name, "bitmap", bn, bk,
                            predicted_ratio=float(ratio),
                            format_str=str(fmt_w))
    # non-bitmap winner (CSR/RLE-style): no native TPU kernel — dense
    # execution with HBM-side compression only (documented limitation).
    return KernelChoice(op.name, "dense", format_str=str(fmt_w),
                        fallback=FallbackReason("no_tpu_kernel", str(fmt_w)))


# ---------------------------------------------------------------------------
# Workload builders
# ---------------------------------------------------------------------------

def ffn_workload(cfg: ModelConfig, tokens: int, w_sparsity: Sparsity,
                 act_density: float = 1.0) -> Workload:
    """The FFN matmuls of one layer of ``cfg`` as a SnipSnap workload."""
    d = cfg.d_model
    f = cfg.moe.d_expert if cfg.moe else cfg.d_ff
    act = Bernoulli(act_density)
    ops = (
        MatMul("ffn.up", tokens, d, f, act, w_sparsity,
               count=float(cfg.n_layers)),
        MatMul("ffn.down", tokens, f, d, act, w_sparsity,
               count=float(cfg.n_layers)),
    )
    return Workload(f"{cfg.name}.ffn", ops)


def model_workload(cfg: ModelConfig, tokens: int, w_sparsity: Sparsity,
                   act_density: float = 1.0,
                   value_bits: int = 16) -> Workload:
    """EVERY per-layer projection of ``cfg`` as one workload.

    Op names are the dispatch roles (:meth:`ModelConfig.matmul_roles`);
    MoE roles route ``tokens · top_k / n_experts`` tokens to each expert
    and repeat ``n_layers · n_experts`` times (the expert fan-out), dense
    roles repeat ``n_layers`` times.  ``value_bits`` is the serving value
    width — pass the parameter store's real width (32 for fp32 params) so
    predicted fetch terms compare against measured counters 1:1."""
    act = Bernoulli(act_density)
    ops = []
    for r in cfg.matmul_roles():
        m = tokens
        if r.fanout > 1 and cfg.moe:
            m = max(1, int(tokens * cfg.moe.top_k / cfg.moe.n_experts))
        ops.append(MatMul(r.role, m, r.n, r.k, act, w_sparsity, act,
                          count=float(cfg.n_layers * r.fanout),
                          value_bits=value_bits))
    return Workload(f"{cfg.name}.model", tuple(ops))


# ---------------------------------------------------------------------------
# Whole-model execution plans
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OpPlan:
    """One projection role's execution decision + predicted cost terms.

    ``predicted_*_fetch_bits`` are the cost model's expected bits moved in
    ONE full DRAM pass over the operand under the winning (format, tile) —
    the terms the calibration loop compares measured counters against.
    ``predicted_w_stream_bits`` multiplies in the mapping's tile-reuse
    refetch factor (``irrelevant_refetch``): the TOTAL W-side payload the
    memory pipeline streams across all output-stripe passes, compared
    against the measured ``OpCounters.w_stream_bits``.
    ``predicted_dram_bits`` / ``predicted_energy`` are the op's full
    count-scaled :class:`~repro.core.costmodel.CostReport` values."""

    role: str
    m: int
    n: int
    k: int
    count: float
    choice: KernelChoice
    tile: dict[str, int]
    predicted_w_fetch_bits: float
    predicted_i_fetch_bits: float
    predicted_dram_bits: float
    predicted_energy: float
    predicted_w_stream_bits: float = 0.0


def _sparsity_to_dict(sp: Sparsity) -> dict:
    if isinstance(sp, NM):
        return {"kind": "nm", "n": sp.n, "m": sp.m}
    if isinstance(sp, BlockBernoulli):
        return {"kind": "block_bernoulli", "density": sp.density,
                "block_elems": sp.block_elems}
    if isinstance(sp, Bernoulli):
        return {"kind": "bernoulli", "density": sp.density}
    raise TypeError(f"unserializable sparsity model {sp!r}")


def _sparsity_from_dict(d: dict) -> Sparsity:
    kind = d["kind"]
    if kind == "nm":
        return NM(d["n"], d["m"])
    if kind == "block_bernoulli":
        return BlockBernoulli(d["density"], d["block_elems"])
    if kind == "bernoulli":
        return Bernoulli(d["density"])
    raise ValueError(f"unknown sparsity kind {kind!r}")


@dataclasses.dataclass(frozen=True)
class ExecPlan:
    """A whole model's kernel configuration: search once, serve many times.

    Pure data — everything serializes to JSON and round-trips bit-identically
    (floats go through ``repr`` shortest-round-trip).  ``search`` optionally
    carries the live :class:`SearchResult` in-process; it is NOT serialized
    and does not enter equality."""

    model: str
    arch: str                   # BASE hardware name (arch_by_name-resolvable)
    objective: str
    tokens: int
    n_layers: int
    w_sparsity: dict
    ops: tuple[OpPlan, ...]
    act_density: float = 1.0
    value_bits: int = 16
    energy_scale: float = 1.0   # calibration fit applied to the DRAM pj/bit
    glb_energy_scale: float = 1.0   # calibration fit applied to the GLB
    #                                 pj/bit (refetch-residual fit)
    version: int = PLAN_VERSION
    # per-role sha256 content digests of the compressed payloads, recorded
    # by compress.compress_params and re-checked by CompressedStore.verify /
    # StackedStore.verify (empty for plans that never met real weights)
    checksums: dict = dataclasses.field(default_factory=dict)
    search: Optional[SearchResult] = dataclasses.field(
        default=None, compare=False, repr=False)

    def for_role(self, role: str) -> OpPlan:
        for op in self.ops:
            if op.role == role:
                return op
        raise KeyError(role)

    @property
    def sparsity(self) -> Sparsity:
        return _sparsity_from_dict(self.w_sparsity)

    def hardware(self) -> HardwareConfig:
        """The plan's hardware model: the named base arch with the plan's
        calibration scale (if any) re-applied — calibrated plans stay
        resolvable after a JSON round trip."""
        base = arch_by_name(self.arch)
        if self.energy_scale == 1.0 and self.glb_energy_scale == 1.0:
            return base
        from repro.exec.calibrate import calibrated_hardware
        return calibrated_hardware(base, self.energy_scale,
                                   glb_scale=self.glb_energy_scale)

    def fallbacks(self) -> dict[str, FallbackReason]:
        """Roles whose format winner could not be served natively."""
        return {op.role: op.choice.fallback for op in self.ops
                if op.choice.fallback is not None}

    def fallback_counts(self) -> dict[str, int]:
        """Fallback occurrences by reason code (for bench/serve reporting:
        how many planned roles run dense, and why)."""
        counts: dict[str, int] = {}
        for fb in self.fallbacks().values():
            counts[fb.code] = counts.get(fb.code, 0) + 1
        return counts

    # -- JSON ---------------------------------------------------------------
    def to_dict(self) -> dict:
        # drop `search` BEFORE asdict: it is the largest object in the
        # subsystem and asdict would deep-convert it just to be discarded
        out = dataclasses.asdict(dataclasses.replace(self, search=None))
        del out["search"]
        return out

    def to_json(self, indent: Optional[int] = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @staticmethod
    def from_dict(d: dict) -> "ExecPlan":
        version = int(d.get("version", 1))   # v1 predates the version key
        if version > PLAN_VERSION:
            raise PlanVersionError(version)
        ops = []
        for o in d["ops"]:
            fb = o["choice"].get("fallback")
            choice = KernelChoice(
                **{**o["choice"],
                   "fallback": FallbackReason(**fb) if fb else None})
            ops.append(OpPlan(**{**o, "choice": choice,
                                 "tile": dict(o["tile"])}))
        return ExecPlan(model=d["model"], arch=d["arch"],
                        objective=d["objective"], tokens=d["tokens"],
                        n_layers=d["n_layers"], w_sparsity=dict(d["w_sparsity"]),
                        ops=tuple(ops), act_density=d["act_density"],
                        value_bits=d["value_bits"],
                        energy_scale=d.get("energy_scale", 1.0),
                        glb_energy_scale=d.get("glb_energy_scale", 1.0),
                        version=version,
                        checksums=dict(d.get("checksums", {})))

    @staticmethod
    def from_json(s: str) -> "ExecPlan":
        return ExecPlan.from_dict(json.loads(s))


def _tpu_search_cfg(hardware: HardwareConfig,
                    search_cfg: Optional[CoSearchConfig]) -> CoSearchConfig:
    """Hardware-constrained format space (paper §III-A: configurations are
    an input): the TPU execution plane implements B-over-block-grid decoding
    (bitmap_spmm) — so the searchable primitive set is {B} with dense
    leaves, i.e. block-sparse formats the MXU can actually run."""
    if search_cfg is None:
        return CoSearchConfig(
            objective="energy",
            engine=EngineConfig(max_levels=2, max_allocs_per_pattern=48,
                                prims=(Prim.B,)))
    # calibrated variants keep the base arch's name as a prefix
    if hardware is TPUV5E or hardware.name.startswith(TPUV5E.name):
        return dataclasses.replace(
            search_cfg,
            engine=dataclasses.replace(search_cfg.engine, prims=(Prim.B,)))
    return search_cfg


def plan_for_model(cfg: ModelConfig, w_sparsity: Sparsity,
                   tokens: int = 4096, act_density: float = 1.0,
                   hardware: HardwareConfig = TPUV5E,
                   search_cfg: Optional[CoSearchConfig] = None,
                   ) -> CompressionPlan:
    """Run the co-search on the model's FFN ops against the TPU hardware
    model and translate the winning W-side format into kernel choices.

    The original single-layer bridge, kept for the legacy API; new code
    should use :func:`build_exec_plan`."""
    wl = ffn_workload(cfg, tokens, w_sparsity, act_density)
    res = cosearch(wl, hardware, _tpu_search_cfg(hardware, search_cfg))
    choices: dict[str, KernelChoice] = {}
    for od in res.design.ops:
        choices[od.op.name] = translate(od.op, od.fmt_w, w_sparsity)
    return CompressionPlan(choices, res)


def build_exec_plan(cfg: ModelConfig, w_sparsity: Sparsity,
                    tokens: int = 4096, act_density: float = 1.0,
                    hardware: HardwareConfig = TPUV5E,
                    search_cfg: Optional[CoSearchConfig] = None,
                    value_bits: int = 16) -> ExecPlan:
    """Co-search the WHOLE model's projections and emit an :class:`ExecPlan`.

    One op per :meth:`ModelConfig.matmul_roles` role (identically-shaped
    layers share the memoized per-op search), each translated into a
    :class:`KernelChoice` and annotated with the cost model's predicted
    fetch/energy terms for the calibration loop."""
    wl = model_workload(cfg, tokens, w_sparsity, act_density, value_bits)
    scfg = _tpu_search_cfg(hardware, search_cfg)
    res = cosearch(wl, hardware, scfg)

    ops: list[OpPlan] = []
    for od in res.design.ops:
        op = od.op
        choice = translate(op, od.fmt_w, w_sparsity)
        spec_w = TensorSpec(op.w_dims(), op.sp_w, op.value_bits)
        spec_i = TensorSpec(op.i_dims(), op.sp_i, op.value_bits)
        cf_w = compile_format(od.fmt_w, spec_w)
        cf_i = compile_format(od.fmt_i, spec_i)
        w_fetch = float(cf_w.fetched_bits(od.mapping.tile))
        # tile-reuse refetch factor of the winning loop order: how many
        # times the full W payload streams DRAM→chip across output tiles
        ext = {"M": op.M, "N": op.N, "K": op.K}
        bounds = {d: math.ceil(ext[d] / od.mapping.tile[d]) for d in ext}
        f_w = irrelevant_refetch(od.mapping.order, "W", bounds)
        ops.append(OpPlan(
            role=op.name, m=op.M, n=op.N, k=op.K, count=op.count,
            choice=choice, tile=dict(od.mapping.tile),
            predicted_w_fetch_bits=w_fetch,
            predicted_i_fetch_bits=float(cf_i.fetched_bits(od.mapping.tile)),
            predicted_dram_bits=float(od.cost.dram_bits),
            predicted_energy=float(od.cost.energy),
            predicted_w_stream_bits=w_fetch * f_w))
    return ExecPlan(model=cfg.name, arch=hardware.name,
                    objective=scfg.objective, tokens=tokens,
                    n_layers=cfg.n_layers,
                    w_sparsity=_sparsity_to_dict(w_sparsity),
                    ops=tuple(ops), act_density=act_density,
                    value_bits=value_bits, search=res)
