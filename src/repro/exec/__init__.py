"""Execution plane: searched designs become running compressed models.

  * :mod:`repro.exec.plans`     — whole-model :class:`ExecPlan`s (per-layer
    attention QKV/O + FFN ops, MoE expert fan-out), JSON round-trippable;
  * :mod:`repro.exec.compress`  — apply a plan to a real weight pytree
    (bitmap / N:M / dense stores with exact achieved-ratio accounting);
  * :mod:`repro.exec.dispatch`  — swap the models' dense projection einsums
    for the compressed Pallas kernels per plan entry;
  * :mod:`repro.exec.calibrate` — measured-vs-predicted traffic counters,
    least-squares energy-coefficient fitting, search re-run drift report.
"""

from repro.exec.plans import (PLAN_VERSION, ExecPlan, FallbackReason,
                              KernelChoice, OpPlan, PlanVersionError,
                              build_exec_plan, model_workload)
from repro.exec.compress import (CompressedStore, StackedStore,
                                 compress_params, prune_params, stack_store)
from repro.exec.dispatch import (CompressedModel, OpCounters, instrument,
                                 kernel_guard)
from repro.exec.calibrate import CalibrationReport, calibrate

__all__ = [
    "PLAN_VERSION", "ExecPlan", "FallbackReason", "KernelChoice", "OpPlan",
    "PlanVersionError", "build_exec_plan", "model_workload",
    "CompressedStore", "StackedStore", "compress_params", "prune_params",
    "stack_store",
    "CompressedModel", "OpCounters", "instrument", "kernel_guard",
    "CalibrationReport", "calibrate",
]
