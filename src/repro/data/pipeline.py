"""Deterministic, shardable synthetic-token data pipeline.

Production shape without external deps: fixed-seed counter-based generation
(stateless — batch ``i`` is a pure function of (seed, i)), so any host can
produce its own shard, restarts resume exactly, and elastic re-sharding is a
matter of re-slicing the batch index space.  State is a single integer →
trivially checkpointable.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class PipelineState:
    step: int = 0

    def to_dict(self) -> dict:
        return {"step": self.step}

    @staticmethod
    def from_dict(d: dict) -> "PipelineState":
        return PipelineState(step=int(d["step"]))


@dataclasses.dataclass
class TokenPipeline:
    """Markov-ish synthetic LM stream: structured enough that loss decreases
    under training (next token correlates with current), deterministic per
    (seed, step, shard)."""

    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    enc_seq: int = 0             # >0: also emit encoder frame embeddings
    d_model: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts

    def batch_at(self, step: int) -> dict:
        """The (deterministic) host-local batch for a global step."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        b, s, v = self.host_batch, self.seq_len, self.vocab
        # token_t+1 = (a·token_t + drift + noise) mod v → learnable structure
        a = 31
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, v, b)
        noise = (rng.random((b, s)) < 0.15)
        jumps = rng.integers(0, v, (b, s))
        for t in range(s):
            nxt = (toks[:, t] * a + 7) % v
            toks[:, t + 1] = np.where(noise[:, t], jumps[:, t], nxt)
        out = {"tokens": jnp.asarray(toks[:, :-1]),
               "labels": jnp.asarray(toks[:, 1:])}
        if self.enc_seq:
            out["enc_frames"] = jnp.asarray(
                rng.normal(size=(b, self.enc_seq, self.d_model)).astype(
                    np.float32))
        return out

    def iterate(self, state: Optional[PipelineState] = None
                ) -> Iterator[tuple[PipelineState, dict]]:
        state = state or PipelineState()
        while True:
            batch = self.batch_at(state.step)
            state = PipelineState(state.step + 1)
            yield state, batch

    def reshard(self, n_hosts: int, host_id: int) -> "TokenPipeline":
        """Elastic re-shard: same stream, new host split (fault recovery)."""
        return dataclasses.replace(self, n_hosts=n_hosts, host_id=host_id)
