"""Payload integrity for the compressed serving plane.

A compressed store is a single point of silent corruption: one flipped
bitmap count or truncated N:M index buffer produces garbage tokens, not a
crash.  This module makes corruption LOUD, in two layers:

  * **Content checksums** — :func:`checksum_store` digests every role's
    compressed payload (sha256 over the *logical* encoding: counts,
    offsets, the first ``nnzb`` row ids and blocks — so the per-layer
    store and the padded layer-stacked store hash identically).
    ``compress.compress_params`` records the digests in the plan
    (``ExecPlan.checksums``, JSON round-tripped); ``CompressedStore.verify``
    / ``StackedStore.verify`` recompute and compare.
  * **Structural invariants** — cheap shape/range checks that need no
    reference digest: per-column counts non-negative and ≤ the block-grid
    rows, offsets exactly the exclusive cumsum of counts (hence monotone),
    row ids inside the grid, payload within capacity, N:M indices inside
    ``[0, m_group)``.  These run even for plans that predate checksums.

Violations raise a structured :class:`IntegrityError` carrying
``(layer, role, reason)`` so the guarded serving path
(:mod:`repro.runtime.guard`) can demote exactly the failing role to dense
weights instead of serving garbage — or crashing the whole batch.

Everything here is duck-typed over the store/stacked dataclasses (no
import of :mod:`repro.exec` — the exec plane imports *us* lazily).
"""

from __future__ import annotations

import hashlib
from typing import Iterator, Optional

import numpy as np


class IntegrityError(RuntimeError):
    """A compressed payload failed verification.

    Structured: ``role`` and ``reason`` are always set; ``layer`` is the
    first offending layer when known (``None`` for role-wide digest
    mismatches where the layer cannot be localized)."""

    def __init__(self, role: str, reason: str,
                 layer: Optional[int] = None, detail: str = ""):
        self.role = role
        self.reason = reason
        self.layer = layer
        self.detail = detail
        where = f"layer {layer} " if layer is not None else ""
        msg = f"integrity violation at {where}role {role!r}: {reason}"
        if detail:
            msg += f" ({detail})"
        super().__init__(msg)


# ---------------------------------------------------------------------------
# Structural invariants
# ---------------------------------------------------------------------------

def check_bitmap_structure(role: str, layer: int, counts, offsets, row_ids,
                           blocks, n: int, k: int, bn: int, bk: int) -> None:
    """Invariants of one layer's bitmap CSC encoding (cheap, O(grid))."""
    gn, gk = n // bn, k // bk
    counts = np.asarray(counts)
    offsets = np.asarray(offsets)
    if counts.shape != (gk,) or offsets.shape != (gk,):
        raise IntegrityError(role, "metadata_shape_mismatch", layer,
                             f"counts {counts.shape} offsets {offsets.shape} "
                             f"for grid ({gn},{gk})")
    if counts.size and int(counts.min()) < 0:
        raise IntegrityError(role, "negative_count", layer)
    if counts.size and int(counts.max()) > gn:
        raise IntegrityError(role, "count_exceeds_blocks", layer,
                             f"max count {int(counts.max())} > {gn} "
                             f"block rows per column")
    nnzb = int(counts.sum())
    capacity = int(np.asarray(blocks).shape[0])
    if nnzb > capacity:
        raise IntegrityError(role, "payload_overflow", layer,
                             f"counts sum to {nnzb} blocks but payload "
                             f"holds {capacity}")
    expect = np.concatenate([[0], np.cumsum(counts[:-1])]).astype(np.int64) \
        if counts.size else np.zeros(0, np.int64)
    if not np.array_equal(offsets.astype(np.int64), expect):
        raise IntegrityError(role, "offsets_not_cumsum", layer,
                             "offsets are not the exclusive cumsum of "
                             "counts (truncated or non-monotone)")
    rid = np.asarray(row_ids)[:nnzb]
    if rid.size and (int(rid.min()) < 0 or int(rid.max()) >= gn):
        raise IntegrityError(role, "row_id_out_of_range", layer,
                             f"row ids must lie in [0, {gn})")


def check_nm_structure(role: str, layer: int, values, indices,
                       n: int, k: int, n_sel: int, m_group: int) -> None:
    """Invariants of one layer's N:M encoding."""
    values = np.asarray(values)
    indices = np.asarray(indices)
    expect = (n * n_sel // m_group, k)
    if values.shape != expect or indices.shape != expect:
        raise IntegrityError(role, "payload_shape_mismatch", layer,
                             f"values {values.shape} indices {indices.shape} "
                             f"expected {expect}")
    if indices.size and (int(indices.min()) < 0
                         or int(indices.max()) >= m_group):
        raise IntegrityError(role, "nm_index_out_of_range", layer,
                             f"indices must lie in [0, {m_group})")


# ---------------------------------------------------------------------------
# Content checksums
# ---------------------------------------------------------------------------

def _digest_bitmap(h, layer: int, expert: int, counts, offsets, row_ids,
                   blocks, n: int, k: int, bn: int, bk: int) -> None:
    counts = np.asarray(counts)
    nnzb = int(counts.sum())
    blocks = np.asarray(blocks)
    h.update(f"bitmap:{layer}:{expert}:{n}x{k}/{bn}x{bk}:"
             f"{blocks.dtype.str}".encode())
    h.update(np.ascontiguousarray(counts, np.int64).tobytes())
    h.update(np.ascontiguousarray(np.asarray(offsets), np.int64).tobytes())
    h.update(np.ascontiguousarray(np.asarray(row_ids)[:nnzb],
                                  np.int64).tobytes())
    h.update(np.ascontiguousarray(blocks[:nnzb]).tobytes())


def _digest_nm(h, layer: int, expert: int, values, indices,
               n: int, k: int, n_sel: int, m_group: int) -> None:
    values = np.asarray(values)
    h.update(f"nm:{layer}:{expert}:{n}x{k}:{n_sel}:{m_group}:"
             f"{values.dtype.str}".encode())
    h.update(np.ascontiguousarray(values).tobytes())
    h.update(np.ascontiguousarray(np.asarray(indices),
                                  np.int64).tobytes())


def _digest_dense(h, layer: int, expert: int, w) -> None:
    w = np.asarray(w)
    h.update(f"dense:{layer}:{expert}:{w.shape}:{w.dtype.str}".encode())
    h.update(np.ascontiguousarray(w).tobytes())


def _digest_entry(h, e) -> None:
    d = e.data
    if e.kind == "bitmap":
        _digest_bitmap(h, e.layer, e.expert, d.counts, d.offsets, d.row_ids,
                       d.blocks, d.n, d.k, d.bn, d.bk)
    elif e.kind == "nm":
        _digest_nm(h, e.layer, e.expert, d.values, d.indices,
                   d.n, d.k, d.n_sel, d.m_group)
    else:
        _digest_dense(h, e.layer, e.expert, d)


def checksum_store(store) -> dict[str, str]:
    """Per-role sha256 hexdigests of a :class:`CompressedStore`'s payloads.

    Entries of a role digest in (layer, expert) order.  The digest covers
    only the logical encoding (``[:nnzb]`` slices for bitmap), so the
    padded :class:`StackedStore` representation reproduces it exactly."""
    by_role: dict[str, list] = {}
    for e in store:
        by_role.setdefault(e.role, []).append(e)
    out: dict[str, str] = {}
    for role in sorted(by_role):
        h = hashlib.sha256()
        for e in sorted(by_role[role], key=lambda e: (e.layer, e.expert)):
            _digest_entry(h, e)
        out[role] = h.hexdigest()
    return out


# ---------------------------------------------------------------------------
# Verification drivers
# ---------------------------------------------------------------------------

def _store_role_errors(store) -> Iterator[tuple[str, Optional[IntegrityError]]]:
    """(role, first violation or None) for every role of a per-layer store.

    Structure is checked first (entry by entry), then the content digest is
    compared against ``store.plan.checksums`` — a plan without recorded
    checksums (pre-PR-8, or synthetic) gets structure-only verification."""
    recorded = dict(getattr(store.plan, "checksums", None) or {})
    by_role: dict[str, list] = {}
    for e in store:
        by_role.setdefault(e.role, []).append(e)
    for role in sorted(by_role):
        entries = sorted(by_role[role], key=lambda e: (e.layer, e.expert))
        err: Optional[IntegrityError] = None
        try:
            for e in entries:
                d = e.data
                if e.kind == "bitmap":
                    check_bitmap_structure(role, e.layer, d.counts, d.offsets,
                                           d.row_ids, d.blocks,
                                           d.n, d.k, d.bn, d.bk)
                elif e.kind == "nm":
                    check_nm_structure(role, e.layer, d.values, d.indices,
                                       d.n, d.k, d.n_sel, d.m_group)
            if role in recorded:
                h = hashlib.sha256()
                for e in entries:
                    _digest_entry(h, e)
                if h.hexdigest() != recorded[role]:
                    err = IntegrityError(role, "checksum_mismatch",
                                         detail="payload bytes differ from "
                                                "the digest recorded at "
                                                "compress time")
        except IntegrityError as e:
            err = e
        yield role, err


def _stacked_role_errors(stacked
                         ) -> Iterator[tuple[str, Optional[IntegrityError]]]:
    """(role, first violation or None) for a layer-stacked store.

    Dense-kind roles carry no stacked payload (they ride in the params
    pytree) and are skipped; kernel-backed roles re-derive each layer's
    logical encoding from the padded slices, so the recorded per-layer
    digests still apply."""
    recorded = dict(getattr(stacked.plan, "checksums", None) or {})
    for role in sorted(stacked.roles):
        sr = stacked.roles[role]
        if sr.data is None:
            continue
        err: Optional[IntegrityError] = None
        try:
            h = hashlib.sha256()
            for layer in range(stacked.n_layers):
                if sr.kind == "bitmap":
                    counts = np.asarray(sr.data["counts"][layer])
                    offsets = np.asarray(sr.data["offsets"][layer])
                    row_ids = np.asarray(sr.data["row_ids"][layer])
                    blocks = np.asarray(sr.data["blocks"][layer])
                    check_bitmap_structure(role, layer, counts, offsets,
                                           row_ids, blocks,
                                           sr.n, sr.k, sr.bn, sr.bk)
                    _digest_bitmap(h, layer, -1, counts, offsets, row_ids,
                                   blocks, sr.n, sr.k, sr.bn, sr.bk)
                else:
                    values = np.asarray(sr.data["values"][layer])
                    indices = np.asarray(sr.data["indices"][layer])
                    check_nm_structure(role, layer, values, indices,
                                       sr.n, sr.k, sr.n_sel, sr.m_group)
                    _digest_nm(h, layer, -1, values, indices,
                               sr.n, sr.k, sr.n_sel, sr.m_group)
            if role in recorded and h.hexdigest() != recorded[role]:
                err = IntegrityError(role, "checksum_mismatch",
                                     detail="stacked payload bytes differ "
                                            "from the digest recorded at "
                                            "compress time")
        except IntegrityError as e:
            err = e
        yield role, err


def role_errors(store_or_stacked
                ) -> Iterator[tuple[str, Optional[IntegrityError]]]:
    """Dispatch on store flavor: per-layer stores have ``entries``."""
    if hasattr(store_or_stacked, "entries"):
        return _store_role_errors(store_or_stacked)
    return _stacked_role_errors(store_or_stacked)


def verify(store_or_stacked) -> dict[str, str]:
    """Verify every role; raise the first :class:`IntegrityError`.

    Returns ``{role: "ok"}`` on success (roles a stacked store cannot
    check — dense-kind — are simply absent)."""
    out: dict[str, str] = {}
    for role, err in role_errors(store_or_stacked):
        if err is not None:
            raise err
        out[role] = "ok"
    return out


def verify_report(store_or_stacked) -> dict[str, str]:
    """Non-raising verify: ``{role: "ok" | reason}`` for every role."""
    return {role: "ok" if err is None else err.reason
            for role, err in role_errors(store_or_stacked)}
