"""Serving-runtime robustness: integrity, fault injection, guarded decode.

  * :mod:`repro.runtime.integrity` — payload checksums + structural
    invariants for the compressed stores (:class:`IntegrityError`);
  * :mod:`repro.runtime.inject`    — deterministic, seeded fault injection
    (bit flips, structural corruption, NaN poison, kernel failure);
  * :mod:`repro.runtime.guard`     — the guarded serving path: verify →
    demote → retry → degrade to dense, reported as a
    :class:`HealthReport`;
  * :mod:`repro.runtime.fault`     — step retry / straggler detection /
    elastic re-mesh primitives shared with the train plane.
"""

from repro.runtime.fault import (FailureEvent, StepGuard, StragglerMonitor,
                                 elastic_remesh)
from repro.runtime.guard import (HealthReport, NonFiniteError,
                                 guarded_generate)
from repro.runtime.integrity import (IntegrityError, checksum_store, verify,
                                     verify_report)

__all__ = [
    "FailureEvent", "StepGuard", "StragglerMonitor", "elastic_remesh",
    "HealthReport", "NonFiniteError", "guarded_generate",
    "IntegrityError", "checksum_store", "verify", "verify_report",
]
