"""Fault tolerance + straggler mitigation + elastic re-meshing.

Shared primitives, designed for the 1000+-node regime:

  * :class:`StepGuard` — bounded retry around an effectful step.  In
    training it restores the last checkpoint and replays the data stream
    (the pipeline is counter-based, so replay is exact); the guarded
    serving path (:mod:`repro.runtime.guard`) runs every prefill/decode
    step through it and degrades to the dense model on exhaustion.
  * :class:`StragglerMonitor` — per-step wall-time EWMA + spike detection;
    in a real deployment the flagged hosts are cordoned and the job
    re-meshed, here it surfaces the decision signal and records events.
  * :func:`elastic_remesh` — given surviving device count, proposes the
    largest (data × model) mesh that preserves the model axis (TP degree
    must not change — param layout depends on it) and shrinks data
    parallelism; global batch is re-sliced across the new data axis.
    :func:`repro.launch.mesh.degraded_serve_mesh` builds a serving mesh
    from the proposal.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np


@dataclasses.dataclass
class FailureEvent:
    step: int
    error: str
    action: str               # "retry" | "restore"


@dataclasses.dataclass
class StepGuard:
    """Retry wrapper around an effectful step function."""

    max_retries: int = 2
    on_restore: Optional[Callable[[], None]] = None
    events: list = dataclasses.field(default_factory=list)

    def run(self, step: int, fn: Callable[[], Any]) -> Any:
        for attempt in range(self.max_retries + 1):
            try:
                return fn()
            except (jax.errors.JaxRuntimeError, RuntimeError, OSError) as e:
                if attempt < self.max_retries:
                    self.events.append(FailureEvent(step, repr(e), "retry"))
                    continue
                self.events.append(FailureEvent(step, repr(e), "restore"))
                if self.on_restore is not None:
                    self.on_restore()
                    return None
                raise
        return None


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA-based step-time anomaly detection.

    A step slower than ``threshold × ewma`` is flagged; persistent flags on
    the same host indicate a straggler (in multi-host: compare per-host
    timings via an all-gather of wall-times — here single-host, we track the
    global step time and expose the cordon signal)."""

    alpha: float = 0.1
    threshold: float = 2.0
    warmup: int = 5
    ewma: float = 0.0
    n: int = 0
    flagged: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.n <= self.warmup:
            self.ewma = dt if self.ewma == 0 else \
                (1 - self.alpha) * self.ewma + self.alpha * dt
            return False
        slow = dt > self.threshold * self.ewma
        if slow:
            self.flagged.append((step, dt, self.ewma))
        else:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return slow

    def should_remesh(self, window: int = 20, tolerance: int = 5) -> bool:
        """Persistent straggling → cordon + elastic re-mesh."""
        recent = [s for s, _, _ in self.flagged[-tolerance:]]
        return len(recent) >= tolerance and \
            (recent[-1] - recent[0]) <= window


def elastic_remesh(n_devices: int, model_parallel: int,
                   pod_size: Optional[int] = None) -> tuple[int, ...]:
    """Largest legal mesh after losing nodes.

    TP degree is pinned (parameter layout); DP shrinks to the largest
    multiple that fits.  Returns (pod, data, model) or (data, model)."""
    if n_devices < model_parallel:
        raise ValueError(
            f"{n_devices} devices cannot sustain TP={model_parallel}")
    data = n_devices // model_parallel
    if pod_size:
        pods = max(n_devices // pod_size, 1)
        data = (n_devices // pods) // model_parallel
        return (pods, data, model_parallel)
    return (data, model_parallel)


def replay_steps(last_ckpt_step: int, failed_step: int) -> range:
    """Steps to replay after restore — exact because the data pipeline is a
    pure function of the step index."""
    return range(last_ckpt_step, failed_step)
