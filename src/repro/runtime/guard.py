"""Guarded serving: verify → demote → retry → degrade to dense.

:func:`guarded_generate` wraps the serving driver's prefill + greedy decode
loop (:mod:`repro.launch.serve`) with the robustness layer:

  1. **Verify before dispatch** — both store representations
     (:meth:`CompressedStore.verify` / :meth:`StackedStore.verify`); roles
     that fail are demoted to dense weights (``CompressedModel.demoted``)
     and recorded as ``integrity_violation`` fallbacks.  One corrupt role
     costs its compression ratio, not the batch.
  2. **Kernel-failure guard** — :func:`repro.exec.dispatch.kernel_guard`
     turns kernel dispatch exceptions (real or injected) into per-role
     dense fallbacks at trace time, recorded as ``kernel_failure``.
  3. **Step guard** — every prefill/decode step runs under the (previously
     train-only) :class:`repro.runtime.fault.StepGuard`: bounded retry on
     runtime errors AND on non-finite logits (:class:`NonFiniteError`);
     persistent failure switches the request to the dense model for the
     REST of the generation (``nonfinite_logits`` / ``step_failure``).
     The decode step is jitted WITHOUT cache donation so the pre-step
     cache survives for the retry — that, plus the per-step finite check,
     is the guarded path's measured overhead (``bench_serve``'s
     ``serve_guarded_vs_unguarded`` row).
  4. **Deadline** — an optional per-request wall-clock budget checked each
     decode step; on expiry the tail is padded with ``pad_id`` and the
     report says so (``deadline_exceeded``).

Runtime fallbacks reuse the plan-time :class:`FallbackReason` machinery
with the runtime codes documented there.  Everything observable lands in
the :class:`HealthReport` returned alongside the tokens; its
:meth:`HealthReport.stable_dict` projection (timings dropped) is
deterministic for a fixed seed — CI diffs two guarded runs on it.

Dense fallbacks are CORRECT, not merely safe: serving runs on the pruned
parameter tree, so the dense einsum computes exactly what the compressed
kernel encodes — guarded greedy decode is bit-identical to dense at fp32
on bitmap plans, faults injected or not.
"""

from __future__ import annotations

import dataclasses
import functools
import json
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.runtime import fault, integrity


class NonFiniteError(RuntimeError):
    """Logits came back NaN/Inf.  A ``RuntimeError`` so the train-plane
    :class:`~repro.runtime.fault.StepGuard` retries it like any other
    step failure."""


class _NoPrefill(Exception):
    """Internal: the model has no one-pass prefill (token-by-token ingest
    instead).  Deliberately NOT a RuntimeError — ``NotImplementedError``
    is one, and the StepGuard must not burn retries on a capability."""


@dataclasses.dataclass
class HealthReport:
    """Everything the guarded serving path observed for one request batch.

    ``fallbacks`` rows are ``{"role", "layer", "code", "detail"}`` with
    role ``"*"`` for whole-step events; ``verify`` maps each planned role
    to ``"ok"`` or the :class:`IntegrityError` reason.
    ``switched_to_dense_at`` is the decode position where the request
    degraded to the dense model (``-1`` = during prefill, ``None`` =
    never).  Under mixed traffic (:mod:`repro.launch.mixer`) one report
    is produced PER REQUEST: ``request_id`` names it and ``eos_hit``
    records an EOS-terminated generation (``steps`` < ``gen`` with no
    deadline).  ``trace_id`` links the report to its spans in the active
    :class:`repro.obs.trace.Tracer` (None when tracing was off — the id
    is deterministic, derived from the request id or a tracer counter).
    Timings are wall-clock seconds; everything else is deterministic for
    a fixed seed — :meth:`stable_dict` drops the timings so two runs can
    be diffed exactly, and :meth:`timings_dict` is the complementary
    projection (``stable_dict() | timings_dict() == to_dict()``)."""

    verify: dict = dataclasses.field(default_factory=dict)
    fallbacks: list = dataclasses.field(default_factory=list)
    retries: int = 0
    dense_steps: int = 0
    switched_to_dense_at: Optional[int] = None
    deadline_hit: bool = False
    eos_hit: bool = False
    steps: int = 0
    gen: int = 0
    request_id: Optional[str] = None
    trace_id: Optional[str] = None
    t_prefill_s: float = 0.0
    t_decode_s: float = 0.0
    t_total_s: float = 0.0

    def record_fallback(self, role: str, code: str, detail: str = "",
                        layer: Optional[int] = None) -> None:
        self.fallbacks.append({"role": role, "layer": layer,
                               "code": code, "detail": detail})

    def fallback_counts(self) -> dict[str, int]:
        """Occurrences by reason code (same shape as
        :meth:`ExecPlan.fallback_counts`)."""
        out: dict[str, int] = {}
        for fb in self.fallbacks:
            out[fb["code"]] = out.get(fb["code"], 0) + 1
        return out

    def fallback_reasons(self) -> list:
        """The fallbacks as plan-plane :class:`FallbackReason` values."""
        from repro.exec.plans import FallbackReason
        return [FallbackReason(fb["code"], fb["detail"])
                for fb in self.fallbacks]

    @property
    def healthy(self) -> bool:
        """No fallbacks, no retries, nothing non-ok in verify."""
        return (not self.fallbacks and not self.retries
                and not self.deadline_hit
                and all(v == "ok" for v in self.verify.values()))

    @property
    def latency_per_token_s(self) -> float:
        return self.t_decode_s / self.steps if self.steps else 0.0

    # -- JSON ---------------------------------------------------------------
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    _TIMING_KEYS = ("t_prefill_s", "t_decode_s", "t_total_s")

    def stable_dict(self) -> dict:
        """The deterministic projection: everything except wall-clock."""
        out = self.to_dict()
        for k in self._TIMING_KEYS:
            del out[k]
        return out

    def timings_dict(self) -> dict:
        """The wall-clock half :meth:`stable_dict` drops, structured:
        ``stable_dict() | timings_dict()`` reconstructs :meth:`to_dict`
        exactly (round-trip pinned in ``tests/test_obs.py``)."""
        return {k: getattr(self, k) for k in self._TIMING_KEYS}

    def to_json(self, indent: Optional[int] = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @staticmethod
    def from_dict(d: dict) -> "HealthReport":
        return HealthReport(**d)

    @staticmethod
    def from_json(s: str) -> "HealthReport":
        return HealthReport.from_dict(json.loads(s))


def _finite(x: jax.Array) -> bool:
    return bool(jnp.isfinite(x).all())


def _failure_code(error_repr: str) -> str:
    return "nonfinite_logits" if "NonFiniteError" in error_repr \
        else "step_failure"


def guarded_generate(model, params, prompts: jax.Array, gen: int,
                     max_len: Optional[int] = None, *,
                     dense_model=None, verify: bool = True,
                     deadline_s: Optional[float] = None,
                     max_retries: int = 1, pad_id: int = -1,
                     eos_id: Optional[int] = None,
                     mesh=None) -> tuple[jax.Array, HealthReport]:
    """Greedy batched generation with the full robustness layer.

    ``model`` is a :class:`CompressedModel` (the usual case) or a dense
    ``Model``; ``dense_model`` is the degradation target (defaults to the
    compressed model's own inner dense model — correct because serving
    runs on the pruned tree).  Returns ``(tokens (B, gen) int32,
    HealthReport)``; positions not produced before ``deadline_s`` hold
    ``pad_id``.  With ``eos_id``, a row's tokens after its EOS hold
    ``pad_id`` and decode stops early once EVERY row has emitted EOS
    (``report.eos_hit``) instead of burning the remaining steps."""
    from repro.exec.dispatch import CompressedModel
    from repro.launch.mesh import axis_map_for
    from repro.models.sharding import logical_axis_rules, named_sharding

    t_start = time.perf_counter()
    tid = obs_trace.trace_id()
    report = HealthReport(gen=gen, trace_id=tid)
    if max_len is None:
        max_len = prompts.shape[1] + gen

    cm = model
    compressed = isinstance(model, CompressedModel)
    if compressed and dense_model is None:
        dense_model = model.model
    with obs_trace.span("guarded_request", trace_id=tid,
                        batch=int(prompts.shape[0]), gen=gen,
                        compressed=compressed):
        if compressed and verify:
            statuses: dict[str, str] = {}
            errors: dict[str, integrity.IntegrityError] = {}
            with obs_trace.span("verify", trace_id=tid):
                for source in (cm.store, cm.stacked):
                    for role, err in integrity.role_errors(source):
                        statuses.setdefault(role, "ok")
                        if err is not None and statuses[role] == "ok":
                            statuses[role] = err.reason
                            errors[role] = err
            report.verify = statuses
            if errors:
                for role in sorted(errors):
                    err = errors[role]
                    report.record_fallback(role, "integrity_violation",
                                           detail=err.reason, layer=err.layer)
                    obs_trace.event("demote", trace_id=tid, role=role,
                                    code="integrity_violation",
                                    reason=err.reason)
                cm = cm.demoted(errors)

        if mesh is not None:
            with mesh, logical_axis_rules(axis_map_for(mesh)):
                prompts = jax.device_put(prompts,
                                         named_sharding(mesh, "batch", None))
                toks = _drive(cm, dense_model, params, prompts, gen, max_len,
                              report, deadline_s, max_retries, pad_id,
                              t_start, compressed, eos_id)
        else:
            toks = _drive(cm, dense_model, params, prompts, gen, max_len,
                          report, deadline_s, max_retries, pad_id, t_start,
                          compressed, eos_id)
    report.t_total_s = time.perf_counter() - t_start
    reg = obs_metrics.current_metrics()
    if reg is not None:
        obs_metrics.ingest_health(reg, report)
    return toks, report


def _drive(cm, dense, params, prompts, gen: int, max_len: int,
           report: HealthReport, deadline_s: Optional[float],
           max_retries: int, pad_id: int, t_start: float,
           compressed: bool, eos_id: Optional[int] = None) -> jax.Array:
    import contextlib

    import numpy as np

    from repro.exec.dispatch import kernel_guard

    b, plen = prompts.shape
    tid = report.trace_id
    demoted_roles: set[str] = set()

    def sink(role: str, exc: Exception) -> None:
        # trace-time kernel failures re-report per traced function; one
        # fallback row per role is the useful signal
        if role not in demoted_roles:
            demoted_roles.add(role)
            report.record_fallback(role, "kernel_failure", detail=repr(exc))
            obs_trace.event("demote", trace_id=tid, role=role,
                            code="kernel_failure")

    # the pre-step cache must survive a retry AND the dense fallback's
    # re-step, so — unlike the unguarded driver — no donate_argnums here
    step_c = jax.jit(cm.decode_step)
    step_d = None
    if dense is not None and dense is not cm:
        step_d = jax.jit(dense.decode_step)
    guard = fault.StepGuard(max_retries=max_retries,
                            on_restore=lambda: None)
    dense_guard = fault.StepGuard(max_retries=max_retries,
                                  on_restore=lambda: None)
    use_dense = False

    def attempt(fn, cache, tok, pos: int):
        lg, nc = fn(params, cache, tok, jnp.asarray(pos, jnp.int32))
        if not _finite(lg):
            raise NonFiniteError(f"non-finite logits at position {pos}")
        return lg, nc

    def _note_retries(g, n0: int, pos: int) -> None:
        for ev in g.events[n0:]:
            if ev.action == "retry":
                obs_trace.event("retry", trace_id=tid, pos=pos,
                                code=_failure_code(ev.error))

    def guarded_step(pos: int, cache, tok):
        nonlocal use_dense
        if not use_dense:
            n0 = len(guard.events)
            res = guard.run(pos, lambda: attempt(step_c, cache, tok, pos))
            _note_retries(guard, n0, pos)
            if res is not None:
                return res
            last = guard.events[-1].error
            if step_d is None:
                raise RuntimeError(
                    f"guarded decode failed at position {pos} with no "
                    f"dense fallback available: {last}")
            use_dense = True
            report.switched_to_dense_at = pos
            report.record_fallback("*", _failure_code(last), detail=last)
            obs_trace.event("dense_switch", trace_id=tid, pos=pos,
                            code=_failure_code(last))
        n1 = len(dense_guard.events)
        res = dense_guard.run(pos, lambda: attempt(step_d, cache, tok, pos))
        _note_retries(dense_guard, n1, pos)
        if res is None:
            raise RuntimeError(
                f"dense fallback failed at position {pos}: "
                f"{dense_guard.events[-1].error}")
        report.dense_steps += 1
        return res

    guard_ctx = kernel_guard(sink) if compressed else contextlib.nullcontext()
    with guard_ctx:
        # ---- prefill (guarded; falls back to guarded token ingest) --------
        with obs_trace.span("prefill", trace_id=tid, batch=b, plen=plen):
            t0 = time.perf_counter()
            prefill_c = jax.jit(functools.partial(cm.prefill,
                                                  max_len=max_len))

            def attempt_prefill():
                try:
                    all_lg, c = prefill_c(params, prompts)
                except NotImplementedError as e:
                    raise _NoPrefill() from e
                lg = all_lg[:, -1]
                if not _finite(lg):
                    raise NonFiniteError("non-finite prefill logits")
                return lg, c

            try:
                n0 = len(guard.events)
                res = guard.run(-1, attempt_prefill)
                _note_retries(guard, n0, -1)
                if res is None:
                    last = guard.events[-1].error
                    if step_d is None:
                        raise RuntimeError(
                            f"guarded prefill failed with no dense fallback "
                            f"available: {last}")
                    use_dense = True
                    report.switched_to_dense_at = -1
                    report.record_fallback("*", _failure_code(last),
                                           detail=last)
                    obs_trace.event("dense_switch", trace_id=tid, pos=-1,
                                    code=_failure_code(last))
                    prefill_d = jax.jit(functools.partial(dense.prefill,
                                                          max_len=max_len))
                    all_lg, cache = prefill_d(params, prompts)
                    logits = all_lg[:, -1]
                    if not _finite(logits):
                        raise NonFiniteError(
                            "dense prefill logits non-finite")
                else:
                    logits, cache = res
            except _NoPrefill:
                # ring windows / hybrid / ssm / encdec: exact decode-path
                # ingest, every step under the same guard
                cache = cm.init_cache(b, max_len)
                logits = None
                for t in range(plen):
                    logits, cache = guarded_step(t, cache, prompts[:, t])
            jax.block_until_ready(logits)
            report.t_prefill_s = time.perf_counter() - t0

        # ---- greedy decode ------------------------------------------------
        with obs_trace.span("decode", trace_id=tid, batch=b, gen=gen):
            out = []
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            done = np.zeros(b, bool)      # rows that already emitted EOS
            t1 = time.perf_counter()
            for t in range(plen, plen + gen):
                if deadline_s is not None and \
                        time.perf_counter() - t_start > deadline_s:
                    report.deadline_hit = True
                    report.record_fallback(
                        "*", "deadline_exceeded",
                        detail=f"{len(out)}/{gen} tokens within "
                               f"{deadline_s}s")
                    obs_trace.event("deadline", trace_id=tid, pos=t)
                    break
                if eos_id is None:
                    out.append(tok)
                else:
                    # the EOS token itself is emitted; everything AFTER a
                    # row's EOS holds pad_id (the deadline tail's
                    # semantics), and once every row is done the remaining
                    # steps are skipped entirely instead of decoded and
                    # discarded
                    out.append(jnp.where(jnp.asarray(done), pad_id, tok))
                    done |= np.asarray(tok) == eos_id
                    if done.all():
                        report.eos_hit = True
                        break
                logits, cache = guarded_step(t, cache, tok)
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            if out:
                jax.block_until_ready(out[-1])
            report.t_decode_s = time.perf_counter() - t1

    report.steps = len(out)
    report.retries = sum(1 for e in guard.events if e.action == "retry") + \
        sum(1 for e in dense_guard.events if e.action == "retry")
    if len(out) < gen:
        pad = jnp.full((b,), pad_id, jnp.int32)
        out.extend([pad] * (gen - len(out)))
    return jnp.stack(out, axis=1)
