"""Deterministic fault injection for the compressed serving plane.

Every detection and recovery path in :mod:`repro.runtime.integrity` /
:mod:`repro.runtime.guard` is exercised by tests through this harness, not
hoped for.  All corruption is seeded (``np.random.default_rng(seed)``) and
PURE: store-level injectors return a NEW store sharing the original plan
(whose recorded checksums are deliberately left stale — that is what
verification catches); context managers restore state on exit.

Fault classes:

  * :func:`bitflip_payload`      — flip one payload bit (checksum catch);
  * :func:`poison_payload_nan`   — NaN one payload value (checksum catch,
    or — with verification off — the guarded decode's non-finite logit
    guard and dense retry);
  * :func:`corrupt_structure`    — break a structural invariant (truncated
    offsets, inflated counts, out-of-range row/N:M indices), caught with
    no reference digest at all;
  * :func:`bitflip_stacked`      — same bit-flip against the layer-stacked
    serving representation;
  * :func:`poison_activations`   — NaN/Inf a projection's output on the
    COMPRESSED path only (the dense fallback stays clean, so recovery is
    observable);
  * :func:`kernel_failure`       — raise from the sparse-kernel dispatch
    hook (:func:`repro.kernels.ops.kernel_fault_hook`), simulating a
    lowering/launch failure the dispatchers' ``kernel_guard`` demotes
    per role.
"""

from __future__ import annotations

import contextlib
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.models import layers as L


def _payload_array(entry) -> np.ndarray:
    """The consequential payload of a store entry (real blocks only)."""
    if entry.kind == "bitmap":
        nnzb = int(np.asarray(entry.data.counts).sum())
        if nnzb == 0:
            raise ValueError(f"role {entry.role!r} layer {entry.layer} has "
                             "an empty payload; nothing to corrupt")
        return np.array(np.asarray(entry.data.blocks)[:nnzb])
    if entry.kind == "nm":
        return np.array(np.asarray(entry.data.values))
    return np.array(np.asarray(entry.data))


def _with_payload(entry, payload: np.ndarray):
    """``entry`` with its payload replaced (padding re-attached for bitmap)."""
    if entry.kind == "bitmap":
        blocks = np.array(np.asarray(entry.data.blocks))
        blocks[:payload.shape[0]] = payload
        data = dataclasses.replace(entry.data, blocks=jnp.asarray(blocks))
    elif entry.kind == "nm":
        data = dataclasses.replace(entry.data, values=jnp.asarray(payload))
    else:
        data = jnp.asarray(payload)
    return dataclasses.replace(entry, data=data)


def _replace_entry(store, key, entry):
    entries = dict(store.entries)
    entries[key] = entry
    return type(store)(store.plan, entries)


def _flip_bit(arr: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    flat = arr.reshape(-1).copy()
    as_bytes = flat.view(np.uint8)
    bit = int(rng.integers(0, as_bytes.size * 8))
    as_bytes[bit // 8] ^= np.uint8(1 << (bit % 8))
    return flat.reshape(arr.shape)


def bitflip_payload(store, role: str, layer: int = 0, expert: int = -1,
                    seed: int = 0):
    """A new store with ONE seeded bit flipped in (layer, role)'s payload.

    The plan's recorded checksums are untouched, so ``store.verify()``
    reports ``checksum_mismatch`` for the role."""
    key = (layer, role, expert)
    entry = store.entries[key]
    rng = np.random.default_rng(seed)
    return _replace_entry(store, key,
                          _with_payload(entry, _flip_bit(_payload_array(entry),
                                                         rng)))


def poison_payload_nan(store, role: str, layer: int = 0, expert: int = -1,
                       seed: int = 0):
    """A new store with one seeded payload value of (layer, role) → NaN.

    Undetectable structurally; with verification skipped, the NaN reaches
    the logits and the guarded decode's non-finite guard must recover."""
    key = (layer, role, expert)
    entry = store.entries[key]
    payload = _payload_array(entry)
    if not np.issubdtype(payload.dtype, np.floating):
        raise ValueError(f"role {role!r} payload is {payload.dtype}, "
                         "cannot hold NaN")
    rng = np.random.default_rng(seed)
    flat = payload.reshape(-1)
    flat[int(rng.integers(0, flat.size))] = np.nan
    return _replace_entry(store, key, _with_payload(entry, payload))


#: corruption mode → the integrity reason it must be detected as
STRUCTURAL_MODES = {
    "truncate_offsets": "offsets_not_cumsum",
    "inflate_counts": "count_exceeds_blocks",
    "row_ids_oob": "row_id_out_of_range",
    "nm_indices_oob": "nm_index_out_of_range",
}


def corrupt_structure(store, role: str, mode: str, layer: int = 0,
                      expert: int = -1):
    """A new store with (layer, role)'s METADATA structurally broken.

    These violations are caught by the invariant checks alone — strip the
    plan's checksums in tests to prove it.  Modes: see
    :data:`STRUCTURAL_MODES` (keys are modes, values the expected
    ``IntegrityError.reason``)."""
    key = (layer, role, expert)
    entry = store.entries[key]
    d = entry.data
    if mode == "truncate_offsets":
        # a truncated/shifted offset table misaligns against the counts;
        # off-by-one the tail so the break is consequential for ANY counts
        # (zeroing the tail is a no-op when the leading counts are zero)
        offsets = np.array(np.asarray(d.offsets))
        offsets[-1] += 1
        data = dataclasses.replace(d, offsets=jnp.asarray(offsets))
    elif mode == "inflate_counts":
        counts = np.array(np.asarray(d.counts))
        counts[0] = d.n // d.bn + 1            # more blocks than grid rows
        data = dataclasses.replace(d, counts=jnp.asarray(counts))
    elif mode == "row_ids_oob":
        row_ids = np.array(np.asarray(d.row_ids))
        row_ids[0] = d.n // d.bn               # one past the grid
        data = dataclasses.replace(d, row_ids=jnp.asarray(row_ids))
    elif mode == "nm_indices_oob":
        indices = np.array(np.asarray(d.indices))
        indices.reshape(-1)[0] = d.m_group     # one past the group
        data = dataclasses.replace(d, indices=jnp.asarray(indices))
    else:
        raise ValueError(f"unknown corruption mode {mode!r}: "
                         f"{sorted(STRUCTURAL_MODES)}")
    return _replace_entry(store, key, dataclasses.replace(entry, data=data))


def bitflip_stacked(stacked, role: str, layer: int = 0, seed: int = 0):
    """A new :class:`StackedStore` with one seeded bit flipped in ``role``'s
    stacked payload at ``layer`` (within the layer's real, un-padded
    blocks) — ``stacked.verify()`` must catch the serving representation
    itself, not just the per-layer store it came from."""
    sr = stacked.roles[role]
    rng = np.random.default_rng(seed)
    data = dict(sr.data)
    if sr.kind == "bitmap":
        nnzb = int(np.asarray(data["counts"][layer]).sum())
        blocks = np.array(np.asarray(data["blocks"]))
        blocks[layer, :nnzb] = _flip_bit(blocks[layer, :nnzb], rng)
        data["blocks"] = jnp.asarray(blocks)
    else:
        values = np.array(np.asarray(data["values"]))
        values[layer] = _flip_bit(values[layer], rng)
        data["values"] = jnp.asarray(values)
    roles = dict(stacked.roles)
    roles[role] = dataclasses.replace(sr, data=data)
    return type(stacked)(plan=stacked.plan, n_layers=stacked.n_layers,
                         roles=roles)


@contextlib.contextmanager
def poison_activations(role: str, mode: str = "nan"):
    """Poison one projection role's OUTPUT with NaN/Inf — compressed path
    only.

    Rebinds :func:`repro.models.layers.proj` so the poison applies only
    while a dispatch hook is installed (i.e. inside a ``CompressedModel``
    forward); the dense model — and therefore the guarded serving path's
    dense retry — computes clean values, making recovery testable."""
    bad = {"nan": np.nan, "inf": np.inf}[mode]
    orig = L.proj

    def poisoned(x, w, r):
        y = orig(x, w, r)
        if r == role and L._PROJ_HOOK is not None:
            y = y.at[..., 0].set(jnp.asarray(bad, y.dtype))
        return y

    L.proj = poisoned
    try:
        yield
    finally:
        L.proj = orig


@contextlib.contextmanager
def kernel_failure(kinds=("bitmap", "nm"), message: str = "injected kernel "
                   "failure"):
    """Make every sparse-kernel dispatch of the given kinds raise.

    Surfaces exactly where a real lowering/launch failure would (the
    kernel wrapper call, i.e. trace time under jit); with
    :func:`repro.exec.dispatch.kernel_guard` active the failure demotes
    the affected roles to dense instead of killing the forward."""

    def hook(kind: str) -> None:
        if kind in kinds:
            raise RuntimeError(f"{message}: {kind}")

    with kops.kernel_fault_hook(hook):
        yield
