"""Import all architecture configs to populate the registry."""

import repro.configs.chatglm3_6b        # noqa: F401
import repro.configs.deepseek_coder_33b  # noqa: F401
import repro.configs.gemma3_27b         # noqa: F401
import repro.configs.granite_moe_3b     # noqa: F401
import repro.configs.internlm2_20b      # noqa: F401
import repro.configs.mamba2_780m        # noqa: F401
import repro.configs.pixtral_12b        # noqa: F401
import repro.configs.qwen3_moe_30b      # noqa: F401
import repro.configs.recurrentgemma_2b  # noqa: F401
import repro.configs.whisper_tiny       # noqa: F401
