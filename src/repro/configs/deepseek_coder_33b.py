"""deepseek-coder-33b [dense]: llama-arch GQA.  [arXiv:2401.14196]"""

from repro.configs.base import ModelConfig, register


@register("deepseek-coder-33b")
def deepseek_coder_33b() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b",
        family="dense",
        n_layers=62,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=19_200,
        vocab=32_256,
        rope_base=100_000.0,
        sparse_ffn=True,
    )
