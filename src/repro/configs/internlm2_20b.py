"""internlm2-20b [dense]: GQA.  [arXiv:2403.17297]"""

from repro.configs.base import ModelConfig, register


@register("internlm2-20b")
def internlm2_20b() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b",
        family="dense",
        n_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16_384,
        vocab=92_544,
        rope_base=1_000_000.0,
        sparse_ffn=True,
    )
