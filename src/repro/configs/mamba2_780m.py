"""mamba2-780m [ssm]: SSD (state-space duality), attention-free.
[arXiv:2405.21060]"""

from repro.configs.base import ModelConfig, SSMCfg, register


@register("mamba2-780m")
def mamba2_780m() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m",
        family="ssm",
        n_layers=48,
        d_model=1536,
        n_heads=0,                 # attention-free
        n_kv_heads=0,
        d_ff=0,
        vocab=50_280,
        ssm=SSMCfg(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
        sub_quadratic=True,
    )
