"""whisper-tiny [audio]: enc-dec, conv frontend stubbed (precomputed frame
embeddings via input_specs).  [arXiv:2212.04356]"""

from repro.configs.base import ModelConfig, register


@register("whisper-tiny")
def whisper_tiny() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family="encdec",
        n_layers=4,                # decoder layers
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        d_ff=1536,
        vocab=51_865,
        enc_layers=4,
        enc_seq=1500,              # 30s audio → 1500 frames after conv stub
        frontend="audio",
        rope_fraction=0.0,         # whisper uses learned/sinusoidal positions
        sub_quadratic=False,
    )
