"""granite-moe-3b-a800m [moe]: 40 experts, top-8.  [hf:ibm-granite]"""

from repro.configs.base import ModelConfig, MoECfg, register


@register("granite-moe-3b-a800m")
def granite_moe_3b() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        d_ff=512,                 # per-expert hidden
        vocab=49_155,
        moe=MoECfg(n_experts=40, top_k=8, d_expert=512),
        sparse_ffn=True,
    )
