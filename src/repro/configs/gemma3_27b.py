"""gemma3-27b [dense]: 5:1 local:global attention, 128k context.
62 = 10×(5 local + 1 global) + 2 local.  [hf:google/gemma-3]"""

from repro.configs.base import HybridCfg, ModelConfig, register


@register("gemma3-27b")
def gemma3_27b() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b",
        family="dense",
        n_layers=62,
        d_model=5376,
        n_heads=32,
        n_kv_heads=16,
        d_ff=21_504,
        vocab=262_144,
        d_head=128,
        hybrid=HybridCfg(block=("local",) * 5 + ("global",),
                         tail=("local", "local")),
        window=1024,               # sliding window for local layers
        rope_base=1_000_000.0,
        sparse_ffn=True,
        # local-attention-dominant: long_500k runs (global layers hold
        # full-length KV; decode is linear in S) — DESIGN.md §5
        sub_quadratic=True,
    )
