"""Model configuration schema + registry for the assigned architectures.

Every architecture is a :class:`ModelConfig`; ``reduced()`` returns the
smoke-test size (same family, tiny extents).  Input shapes are the four
assigned cells (train_4k / prefill_32k / decode_32k / long_500k).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_expert: int               # per-expert FFN hidden size
    capacity_factor: float = 1.25
    router_group: int = 256     # tokens per routing group (GShard dispatch)


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256            # SSD chunk length


@dataclasses.dataclass(frozen=True)
class HybridCfg:
    """Layer pattern for hybrid/interleaved stacks.

    ``block`` is the repeating unit, e.g. ("rec", "rec", "attn") for
    RecurrentGemma's 1:2 or ("local",)*5 + ("global",) for Gemma3's 5:1.
    ``tail`` covers layers left over after full blocks.
    """
    block: tuple[str, ...]
    tail: tuple[str, ...] = ()


@dataclasses.dataclass(frozen=True)
class MatmulRole:
    """One per-layer projection weight: role name + W[N, K] extents.

    ``fanout`` > 1 means the layer holds that many identically-shaped
    weights under the role (MoE experts)."""

    role: str
    n: int                      # contraction extent (weight rows)
    k: int                      # output extent (weight cols)
    fanout: int = 1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    hybrid: Optional[HybridCfg] = None
    # attention details
    window: int = 0             # sliding-window size for local attention
    rope_fraction: float = 1.0  # chatglm applies RoPE to half the head dim
    rope_base: float = 10_000.0
    # encoder-decoder
    enc_layers: int = 0
    enc_seq: int = 0            # fixed encoder length (whisper: 1500 frames)
    frontend: Optional[str] = None   # "audio" | "vision" stub note
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    # sparsity hooks (SnipSnap integration)
    sparse_ffn: bool = False    # run FFN matmuls through compressed kernels
    # long-context applicability (full-attention archs skip long_500k)
    sub_quadratic: bool = False

    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def layer_kinds(self) -> tuple[str, ...]:
        """Per-layer kind sequence for hybrid stacks ('attn' default)."""
        if self.family == "ssm":
            return tuple(["ssm"] * self.n_layers)
        if self.hybrid is None:
            return tuple(["attn"] * self.n_layers)
        out: list[str] = []
        blk = self.hybrid.block
        while len(out) + len(blk) <= self.n_layers - len(self.hybrid.tail):
            out.extend(blk)
        out.extend(self.hybrid.tail)
        assert len(out) == self.n_layers, (len(out), self.n_layers)
        return tuple(out)

    def matmul_roles(self) -> tuple["MatmulRole", ...]:
        """Per-layer projection weights as executable matmul roles.

        Role names match the dispatch hooks in :mod:`repro.models.layers` /
        :mod:`repro.models.attention` (``attn.wq`` … ``ffn.w_down``); MoE
        FFNs fan out ``fanout = n_experts`` (one weight per expert, same
        shape) under ``moe.*`` names.  ``n`` is the contraction extent
        (weight rows), ``k`` the output extent — the execution plane's
        W[N, K] convention."""
        d, h = self.d_model, self.head_dim
        nh = self.n_heads
        nk = max(self.n_kv_heads, 1)
        roles = [
            MatmulRole("attn.wq", d, nh * h),
            MatmulRole("attn.wk", d, nk * h),
            MatmulRole("attn.wv", d, nk * h),
            MatmulRole("attn.wo", nh * h, d),
        ]
        if self.moe:
            e, f = self.moe.n_experts, self.moe.d_expert
            roles += [
                MatmulRole("moe.w_gate", d, f, fanout=e),
                MatmulRole("moe.w_up", d, f, fanout=e),
                MatmulRole("moe.w_down", f, d, fanout=e),
            ]
        elif self.d_ff:
            f = self.d_ff
            roles += [
                MatmulRole("ffn.w_gate", d, f),
                MatmulRole("ffn.w_up", d, f),
                MatmulRole("ffn.w_down", f, d),
            ]
        return tuple(roles)

    def params_count(self) -> float:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, h = self.d_model, self.head_dim
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_attn = d * (self.n_heads * h) + 2 * d * (self.n_kv_heads * h) \
            + (self.n_heads * h) * d
        if self.moe:
            per_ffn = self.moe.n_experts * 3 * d * self.moe.d_expert \
                + d * self.moe.n_experts
        else:
            per_ffn = 3 * d * self.d_ff if self.d_ff else 0
        kinds = self.layer_kinds
        total = float(emb)
        for k in kinds:
            if k in ("attn", "local", "global"):
                total += per_attn + per_ffn
            elif k == "rec":
                dr = d  # RG-LRU width ≈ d_model
                total += 3 * d * dr + per_ffn
            elif k == "ssm":
                di = d * (self.ssm.expand if self.ssm else 2)
                total += 2 * d * di + di * d
        total += self.enc_layers * (per_attn + per_ffn)
        return total

    def active_params_count(self) -> float:
        """Params touched per token (MoE: only routed experts)."""
        if not self.moe:
            return self.params_count()
        d = self.d_model
        dense = self.params_count() - self.n_layers * (
            self.moe.n_experts * 3 * d * self.moe.d_expert)
        return dense + self.n_layers * self.moe.top_k * 3 * d * self.moe.d_expert

    def reduced(self) -> "ModelConfig":
        """Smoke-test configuration: same family, tiny extents."""
        changes: dict = dict(
            n_layers=min(self.n_layers, 2 + (len(self.hybrid.block)
                                             if self.hybrid else 0)),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            d_head=32,
            enc_layers=min(self.enc_layers, 2),
            enc_seq=min(self.enc_seq, 16),
            window=min(self.window, 64) if self.window else 0,
        )
        if self.moe:
            changes["moe"] = MoECfg(n_experts=8, top_k=2, d_expert=64,
                                    router_group=16)
        if self.ssm:
            changes["ssm"] = SSMCfg(d_state=16, d_conv=4, expand=2,
                                    head_dim=32, chunk=16)
        if self.hybrid:
            changes["n_layers"] = len(self.hybrid.block) + len(self.hybrid.tail)
        return dataclasses.replace(self, **changes)


_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    import repro.configs.archs  # noqa: F401  (populate registry)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> list[str]:
    import repro.configs.archs  # noqa: F401
    return sorted(_REGISTRY)
