"""qwen3-moe-30b-a3b [moe]: 128 experts, top-8.  [hf:Qwen/Qwen3-30B-A3B]"""

from repro.configs.base import ModelConfig, MoECfg, register


@register("qwen3-moe-30b-a3b")
def qwen3_moe_30b() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        d_ff=768,                 # per-expert hidden
        vocab=151_936,
        moe=MoECfg(n_experts=128, top_k=8, d_expert=768),
        rope_base=1_000_000.0,
        sparse_ffn=True,
    )
