from repro.configs.base import (ModelConfig, MoECfg, SSMCfg, HybridCfg,
                                ShapeCfg, SHAPES, get_config, list_archs)

__all__ = ["ModelConfig", "MoECfg", "SSMCfg", "HybridCfg", "ShapeCfg",
           "SHAPES", "get_config", "list_archs"]
