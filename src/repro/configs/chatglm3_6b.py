"""chatglm3-6b [dense]: 2D/partial RoPE (half the head dim), GQA kv=2.
[arXiv:2406.12793]"""

from repro.configs.base import ModelConfig, register


@register("chatglm3-6b")
def chatglm3_6b() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b",
        family="dense",
        n_layers=28,
        d_model=4096,
        n_heads=32,
        n_kv_heads=2,
        d_ff=13_696,
        vocab=65_024,
        rope_fraction=0.5,
        sparse_ffn=True,
    )
