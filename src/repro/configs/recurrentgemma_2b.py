"""recurrentgemma-2b [hybrid]: RG-LRU + local attention, 1 attn : 2 recurrent.
26 = 8×(rec, rec, attn) + 2 rec.  [arXiv:2402.19427]"""

from repro.configs.base import HybridCfg, ModelConfig, register


@register("recurrentgemma-2b")
def recurrentgemma_2b() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        d_ff=7680,
        vocab=256_000,
        hybrid=HybridCfg(block=("rec", "rec", "attn"), tail=("rec", "rec")),
        window=2048,               # local attention window
        sub_quadratic=True,
    )
