"""pixtral-12b [vlm]: mistral-nemo backbone; pixtral-ViT frontend STUBBED —
input_specs() provides precomputed patch embeddings.  [hf:mistralai]"""

from repro.configs.base import ModelConfig, register


@register("pixtral-12b")
def pixtral_12b() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b",
        family="vlm",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14_336,
        vocab=131_072,
        d_head=128,
        frontend="vision",
        rope_base=1_000_000.0,
        sparse_ffn=True,
    )
