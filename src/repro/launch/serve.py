"""Batched serving driver: one-pass prefill + KV-cache greedy decode, for
the dense :class:`~repro.models.transformer.Model` AND the execution
plane's :class:`~repro.exec.dispatch.CompressedModel` (same surface), with
per-phase tokens/sec(/device) reporting and optional mesh sharding.

:func:`generate` prefers the batched ``prefill`` path (one compiled
full-sequence forward fills the whole cache); families without it — ring
windows, hybrid/SSM/encdec states — keep the exact token-by-token decode
ingest.  With a mesh (``make_serve_mesh``), the request batch shards over
the data axis and the model zoo's logical-axis annotations bind to it.

CPU quickstart (reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch chatglm3-6b --reduced \
      --batch 4 --prompt-len 32 --gen 16 [--compressed] [--mesh]
"""

from __future__ import annotations

import argparse
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import axis_map_for, make_serve_mesh, mesh_axis_sizes
from repro.models.sharding import logical_axis_rules, named_sharding
from repro.models.transformer import Model


def _generate(model, params, prompts: jax.Array, gen: int, max_len: int):
    b, plen = prompts.shape
    step = jax.jit(model.decode_step, donate_argnums=(1,))

    t0 = time.perf_counter()
    try:
        prefill = jax.jit(functools.partial(model.prefill, max_len=max_len))
        all_logits, cache = prefill(params, prompts)
        logits = all_logits[:, -1]
        jax.block_until_ready(logits)
    except NotImplementedError:
        # ring windows / hybrid / ssm / encdec: exact decode-path ingest
        cache = model.init_cache(b, max_len)
        logits = None
        for t in range(plen):
            logits, cache = step(params, cache, prompts[:, t],
                                 jnp.asarray(t, jnp.int32))
        jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    out = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t1 = time.perf_counter()
    for t in range(plen, plen + gen):
        out.append(tok)
        logits, cache = step(params, cache, tok, jnp.asarray(t, jnp.int32))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    jax.block_until_ready(logits)
    t_gen = time.perf_counter() - t1
    return jnp.stack(out, axis=1), t_prefill, t_gen


def generate(model, params, prompts: jax.Array, gen: int, max_len: int,
             mesh=None, guarded: bool = False, **guard_kwargs):
    """Greedy decode for a batch of equal-length prompts.

    ``model`` is anything with the serving surface (``prefill`` /
    ``init_cache`` / ``decode_step``): the dense Model or a
    CompressedModel.  Returns (tokens (B, gen), t_prefill_s, t_gen_s).
    With ``mesh``, requests shard over the data axis and the models'
    logical-axis annotations bind for the whole prefill+decode scope.

    ``guarded=True`` routes through the robustness layer
    (:func:`repro.runtime.guard.guarded_generate`: store verification,
    per-role dense demotion, NaN/Inf retry, deadline) and appends the
    :class:`~repro.runtime.guard.HealthReport` to the return tuple;
    ``guard_kwargs`` (``verify=``, ``deadline_s=``, ``max_retries=``,
    ``dense_model=``, ``pad_id=``) pass through."""
    if guarded:
        from repro.runtime.guard import guarded_generate
        toks, report = guarded_generate(model, params, prompts, gen, max_len,
                                        mesh=mesh, **guard_kwargs)
        return toks, report.t_prefill_s, report.t_decode_s, report
    if mesh is None:
        return _generate(model, params, prompts, gen, max_len)
    with mesh, logical_axis_rules(axis_map_for(mesh)):
        prompts = jax.device_put(prompts,
                                 named_sharding(mesh, "batch", None))
        return _generate(model, params, prompts, gen, max_len)


def _fast_plan(cfg, tokens: int):
    """A small-budget co-searched plan for CLI/demo serving."""
    from repro.core.cosearch import CoSearchConfig
    from repro.core.engine import EngineConfig
    from repro.core.sparsity import BlockBernoulli
    from repro.exec import build_exec_plan
    scfg = CoSearchConfig(objective="edp",
                          engine=EngineConfig(max_levels=2,
                                              max_allocs_per_pattern=16),
                          spatial_top=2, max_pairs=6)
    return build_exec_plan(cfg, BlockBernoulli(0.5, 32 * 32),
                           tokens=tokens, search_cfg=scfg, value_bits=32)


def compressed_model(cfg, params, tokens: int = 64):
    """Plan → prune → compress → :class:`CompressedModel` in one call
    (shared by the CLI and the serving examples).  Returns
    (compressed_model, pruned_params) — serve with the PRUNED tree."""
    from repro.exec import (CompressedModel, compress_params, prune_params)
    model = Model(cfg)
    plan = _fast_plan(cfg, tokens)
    pruned = prune_params(params, plan, cfg)
    store = compress_params(pruned, plan, cfg)
    return CompressedModel(model, store), pruned


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--compressed", action="store_true",
                    help="co-search a plan and serve the compressed store")
    ap.add_argument("--mesh", action="store_true",
                    help="shard the request batch over available devices")
    ap.add_argument("--guarded", action="store_true",
                    help="serve through the robustness layer (verify + "
                         "retry + dense degradation) and print the health "
                         "report")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request wall-clock budget in seconds "
                         "(guarded mode)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    label = cfg.name
    if args.compressed:
        model, params = compressed_model(cfg, params)
        ratio = model.store.achieved_ratio()
        fb = model.store.plan.fallback_counts()
        label += f" [compressed: ratio={ratio:.3f} fallbacks={fb or 'none'}]"
    mesh = make_serve_mesh(args.batch) if args.mesh else None
    ndev = int(np.prod(list(mesh_axis_sizes(mesh).values()))) if mesh else 1

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)

    report = None
    if args.guarded:
        toks, t_prefill, t_gen, report = generate(
            model, params, prompts, args.gen, args.prompt_len + args.gen,
            mesh=mesh, guarded=True, deadline_s=args.deadline)
    else:
        toks, t_prefill, t_gen = generate(
            model, params, prompts, args.gen, args.prompt_len + args.gen,
            mesh=mesh)
    n_pref = args.batch * args.prompt_len
    n_gen = args.batch * args.gen
    print(f"[serve] {label}: batch={args.batch} devices={ndev}")
    print(f"  prefill {n_pref} tok in {t_prefill:.2f}s "
          f"({n_pref / t_prefill:.1f} tok/s, "
          f"{n_pref / t_prefill / ndev:.1f} tok/s/dev)")
    print(f"  decode  {n_gen} tok in {t_gen:.2f}s "
          f"({n_gen / t_gen:.1f} tok/s, "
          f"{n_gen / t_gen / ndev:.1f} tok/s/dev)")
    print(f"  sample out: {np.asarray(toks[0, :8])}")
    if report is not None:
        print(f"  health: healthy={report.healthy} "
              f"verify={report.verify or 'skipped'} "
              f"fallbacks={report.fallback_counts() or 'none'} "
              f"retries={report.retries} dense_steps={report.dense_steps} "
              f"deadline_hit={report.deadline_hit} "
              f"steps={report.steps}/{report.gen}")


if __name__ == "__main__":
    main()
