"""Batched serving driver: prompt ingestion + greedy generation against the
decode caches, with per-phase throughput reporting.

CPU quickstart (reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch chatglm3-6b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.transformer import Model


def generate(model: Model, params, prompts: jax.Array, gen: int,
             max_len: int):
    """Greedy decode for a batch of equal-length prompts.

    Prompts are ingested token-by-token through the decode path (exact KV
    semantics for every family, incl. ring buffers and SSM states)."""
    b, plen = prompts.shape
    cache = model.init_cache(b, max_len)
    step = jax.jit(model.decode_step, donate_argnums=(1,))

    t0 = time.perf_counter()
    logits = None
    for t in range(plen):
        logits, cache = step(params, cache, prompts[:, t],
                             jnp.asarray(t, jnp.int32))
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    out = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t1 = time.perf_counter()
    for t in range(plen, plen + gen):
        out.append(tok)
        logits, cache = step(params, cache, tok, jnp.asarray(t, jnp.int32))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    jax.block_until_ready(logits)
    t_gen = time.perf_counter() - t1
    return jnp.stack(out, axis=1), t_prefill, t_gen


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)

    toks, t_prefill, t_gen = generate(
        model, params, prompts, args.gen, args.prompt_len + args.gen)
    n_pref = args.batch * args.prompt_len
    n_gen = args.batch * args.gen
    print(f"[serve] {cfg.name}: batch={args.batch}")
    print(f"  ingest  {n_pref} tok in {t_prefill:.2f}s "
          f"({n_pref / t_prefill:.1f} tok/s)")
    print(f"  decode  {n_gen} tok in {t_gen:.2f}s "
          f"({n_gen / t_gen:.1f} tok/s)")
    print(f"  sample out: {np.asarray(toks[0, :8])}")


if __name__ == "__main__":
    main()
