"""Batched serving driver: one-pass prefill + KV-cache greedy decode, for
the dense :class:`~repro.models.transformer.Model` AND the execution
plane's :class:`~repro.exec.dispatch.CompressedModel` (same surface), with
per-phase tokens/sec(/device) reporting and optional mesh sharding.

:func:`generate` prefers the batched ``prefill`` path (one compiled
full-sequence forward fills the whole cache); families without it — ring
windows, hybrid/SSM/encdec states — keep the exact token-by-token decode
ingest.  LEFT-padded ragged prompts are supported via ``prompt_pad_id``
(each row is prefilled alone at its real length and decoded with a
per-row position vector — the mixer's admission primitive); ``eos_id``
stops decode early once every row has emitted EOS, padding the tail with
``pad_id``.  With a mesh (``make_serve_mesh``), the request batch shards
over the data axis and the model zoo's logical-axis annotations bind to
it.  For continuous batching over a request STREAM (admit/evict into a
running decode batch, sampled decoding) see :mod:`repro.launch.mixer` and
the ``--mixer`` CLI mode.

CPU quickstart (reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch chatglm3-6b --reduced \
      --batch 4 --prompt-len 32 --gen 16 [--compressed] [--mesh] \
      [--mixer --slots 2 --temperature 0.8 --top-k 20 --eos 7]
"""

from __future__ import annotations

import argparse
import contextlib
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import axis_map_for, make_serve_mesh, mesh_axis_sizes
from repro.models.sharding import logical_axis_rules, named_sharding
from repro.models.transformer import Model
from repro.obs import metrics as omet
from repro.obs import trace as otr


def _rate(n: float, t: float) -> float:
    """tokens / seconds with a floor on the denominator: a tiny
    ``--reduced --gen 1`` run can legitimately time ~0s, which must not
    turn the report into a ZeroDivisionError (or an inf row)."""
    return n / max(t, 1e-9)


def _prompt_offsets(prompts: jax.Array, prompt_pad_id: Optional[int]
                    ) -> np.ndarray:
    """Per-row first-real-token offsets of a LEFT-padded prompt batch.

    With ``prompt_pad_id`` None every prompt is taken as unpadded (offset
    0).  Otherwise each row must be ``[pad... real...]`` with at least one
    real token — pads after the first real token (right/interior padding)
    are rejected loudly instead of silently mis-positioning the row."""
    b, plen = prompts.shape
    if prompt_pad_id is None:
        return np.zeros(b, np.int64)
    pn = np.asarray(prompts)
    real = pn != prompt_pad_id
    offsets = np.argmax(real, axis=1)
    for r in range(b):
        if not real[r].any():
            raise ValueError(f"prompt row {r} is all padding "
                             f"(pad_id={prompt_pad_id})")
        if not real[r, offsets[r]:].all():
            raise ValueError(
                f"prompt row {r} has pad tokens after its first real "
                f"token; prompts must be LEFT-padded (pad_id="
                f"{prompt_pad_id})")
    return offsets


def _generate(model, params, prompts: jax.Array, gen: int, max_len: int,
              eos_id: Optional[int] = None, pad_id: int = -1,
              prompt_pad_id: Optional[int] = None):
    b, plen = prompts.shape
    if plen > max_len or plen + gen > max_len:
        raise ValueError(f"prompt ({plen}) + gen ({gen}) exceeds "
                         f"max_len ({max_len})")
    offsets = _prompt_offsets(prompts, prompt_pad_id)
    step = jax.jit(model.decode_step, donate_argnums=(1,))
    tid = otr.trace_id()

    t0 = time.perf_counter()
    with otr.span("prefill", trace_id=tid, batch=b, plen=plen,
                  ragged=bool(offsets.any())):
        if offsets.any():
            # ragged left-padded rows: admit each row alone at its REAL
            # length (batch-1 prefill or exact token ingest) into its slot
            # of the shared cache, then decode with a per-row position
            # vector — the continuous-batching admission primitive
            # (launch.mixer)
            from repro.launch import mixer as mixer_mod
            cache = model.init_cache(b, max_len)
            write = jax.jit(mixer_mod.write_slot, donate_argnums=(0,))
            lasts = []
            for r in range(b):
                with otr.span("admit", trace_id=tid, row=r,
                              prompt_len=plen - int(offsets[r])):
                    last, rcache = mixer_mod.prefill_request(
                        model, params, prompts[r:r + 1, int(offsets[r]):],
                        max_len)
                    cache = write(cache, rcache, jnp.asarray(r, jnp.int32))
                lasts.append(last)
            logits = jnp.stack(lasts)
            pos = jnp.asarray(plen - offsets, jnp.int32)   # per-row (B,)
            jax.block_until_ready(logits)
        else:
            pos = None                                     # lockstep scalar
            try:
                prefill = jax.jit(functools.partial(model.prefill,
                                                    max_len=max_len))
                all_logits, cache = prefill(params, prompts)
                logits = all_logits[:, -1]
                jax.block_until_ready(logits)
            except NotImplementedError:
                # ring windows / hybrid / ssm / encdec: exact decode-path
                # ingest
                cache = model.init_cache(b, max_len)
                logits = None
                for t in range(plen):
                    logits, cache = step(params, cache, prompts[:, t],
                                         jnp.asarray(t, jnp.int32))
                jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    out = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    done = np.zeros(b, bool)              # rows that already emitted EOS
    t1 = time.perf_counter()
    with otr.span("decode", trace_id=tid, batch=b, gen=gen):
        for i, t in enumerate(range(plen, plen + gen)):
            if eos_id is None:
                out.append(tok)
            else:
                # a row's EOS token is emitted; everything after it holds
                # pad_id, and once EVERY row is done the remaining steps
                # are skipped instead of decoded and thrown away
                out.append(jnp.where(jnp.asarray(done), pad_id, tok))
                done |= np.asarray(tok) == eos_id
                if done.all():
                    break
            cur = jnp.asarray(t, jnp.int32) if pos is None else pos + i
            logits, cache = step(params, cache, tok, cur)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(out[-1] if out else logits)
    t_gen = time.perf_counter() - t1
    omet.counter_inc("serve_static_tokens_total", b * len(out))
    if len(out) < gen:
        pad = jnp.full((b,), pad_id, jnp.int32)
        out.extend([pad] * (gen - len(out)))
    return jnp.stack(out, axis=1), t_prefill, t_gen


def generate(model, params, prompts: jax.Array, gen: int, max_len: int,
             mesh=None, guarded: bool = False,
             eos_id: Optional[int] = None, pad_id: int = -1,
             prompt_pad_id: Optional[int] = None, **guard_kwargs):
    """Greedy decode for a batch of prompts.

    ``model`` is anything with the serving surface (``prefill`` /
    ``init_cache`` / ``decode_step``): the dense Model or a
    CompressedModel.  Returns (tokens (B, gen), t_prefill_s, t_gen_s).
    Prompts are equal-length by default; pass ``prompt_pad_id`` to serve
    LEFT-padded ragged rows (each row prefills alone at its real length
    and decodes at its own position).  ``eos_id`` ends rows early — the
    EOS token is emitted, later positions hold ``pad_id``, and decode
    stops once every row is done.  With ``mesh``, requests shard over the
    data axis and the models' logical-axis annotations bind for the whole
    prefill+decode scope.

    ``guarded=True`` routes through the robustness layer
    (:func:`repro.runtime.guard.guarded_generate`: store verification,
    per-role dense demotion, NaN/Inf retry, deadline) and appends the
    :class:`~repro.runtime.guard.HealthReport` to the return tuple;
    ``guard_kwargs`` (``verify=``, ``deadline_s=``, ``max_retries=``,
    ``dense_model=``) pass through."""
    if guarded:
        from repro.runtime.guard import guarded_generate
        if prompt_pad_id is not None:
            raise NotImplementedError(
                "guarded serving takes equal-length prompts; serve ragged "
                "streams through repro.launch.mixer")
        toks, report = guarded_generate(model, params, prompts, gen, max_len,
                                        mesh=mesh, eos_id=eos_id,
                                        pad_id=pad_id, **guard_kwargs)
        return toks, report.t_prefill_s, report.t_decode_s, report
    if mesh is None:
        return _generate(model, params, prompts, gen, max_len,
                         eos_id=eos_id, pad_id=pad_id,
                         prompt_pad_id=prompt_pad_id)
    with mesh, logical_axis_rules(axis_map_for(mesh)):
        prompts = jax.device_put(prompts,
                                 named_sharding(mesh, "batch", None))
        return _generate(model, params, prompts, gen, max_len,
                         eos_id=eos_id, pad_id=pad_id,
                         prompt_pad_id=prompt_pad_id)


def _fast_plan(cfg, tokens: int):
    """A small-budget co-searched plan for CLI/demo serving."""
    from repro.core.cosearch import CoSearchConfig
    from repro.core.engine import EngineConfig
    from repro.core.sparsity import BlockBernoulli
    from repro.exec import build_exec_plan
    scfg = CoSearchConfig(objective="edp",
                          engine=EngineConfig(max_levels=2,
                                              max_allocs_per_pattern=16),
                          spatial_top=2, max_pairs=6)
    return build_exec_plan(cfg, BlockBernoulli(0.5, 32 * 32),
                           tokens=tokens, search_cfg=scfg, value_bits=32)


def compressed_model(cfg, params, tokens: int = 64):
    """Plan → prune → compress → :class:`CompressedModel` in one call
    (shared by the CLI and the serving examples).  Returns
    (compressed_model, pruned_params) — serve with the PRUNED tree."""
    from repro.exec import (CompressedModel, compress_params, prune_params)
    model = Model(cfg)
    plan = _fast_plan(cfg, tokens)
    pruned = prune_params(params, plan, cfg)
    store = compress_params(pruned, plan, cfg)
    return CompressedModel(model, store), pruned


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--compressed", action="store_true",
                    help="co-search a plan and serve the compressed store")
    ap.add_argument("--mesh", action="store_true",
                    help="shard the request batch over available devices")
    ap.add_argument("--guarded", action="store_true",
                    help="serve through the robustness layer (verify + "
                         "retry + dense degradation) and print the health "
                         "report")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request wall-clock budget in seconds "
                         "(guarded / mixer modes)")
    ap.add_argument("--mixer", action="store_true",
                    help="continuous batching: serve a mixed-length request "
                         "stream through repro.launch.mixer instead of one "
                         "static lockstep batch")
    ap.add_argument("--slots", type=int, default=None,
                    help="decode slots for --mixer (default: --batch)")
    ap.add_argument("--eos", type=int, default=None,
                    help="EOS token id: rows/requests stop early once it is "
                         "emitted (tail padded with pad_id)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature for --mixer requests "
                         "(0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k cutoff for sampled --mixer decoding "
                         "(0 = full vocab)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="capture a span trace of the run and write Chrome "
                         "trace-event JSON (load in chrome://tracing) plus "
                         "PATH.stable.json, the deterministic projection")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="collect serving metrics and write a JSON snapshot "
                         "to PATH plus Prometheus text exposition to "
                         "PATH.prom")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    label = cfg.name
    ratio = None
    if args.compressed:
        model, params = compressed_model(cfg, params)
        ratio = model.store.achieved_ratio()
        fb = model.store.plan.fallback_counts()
        label += f" [compressed: ratio={ratio:.3f} fallbacks={fb or 'none'}]"
    mesh = make_serve_mesh(args.batch) if args.mesh else None
    ndev = int(np.prod(list(mesh_axis_sizes(mesh).values()))) if mesh else 1

    # telemetry (--trace / --metrics): contexts wrap the serving run only —
    # model build and planning stay outside so the exports tell the
    # REQUESTS' story
    tel = contextlib.ExitStack()
    tracer = None
    registry = None
    exec_counters = None
    if args.trace is not None:
        tracer = otr.Tracer()
        tel.enter_context(otr.tracing(tracer))
    if args.metrics is not None:
        registry = omet.MetricsRegistry()
        tel.enter_context(omet.collecting(registry))
    if tracer is not None or registry is not None:
        from repro.obs.profile import kernel_timer
        tel.enter_context(kernel_timer(registry=registry, tracer=tracer))
        if args.compressed:
            from repro.exec import dispatch as exec_dispatch
            exec_counters = tel.enter_context(exec_dispatch.instrument())

    def _telemetry_done(mx=None) -> None:
        """Close the capture contexts, fold the passive sources in, export."""
        tel.close()
        if registry is not None:
            if exec_counters is not None:
                omet.ingest_instrument(registry, exec_counters)
            omet.collect_caches(registry)
            if mx is not None:
                omet.ingest_straggler(registry, mx.straggler)
            if ratio is not None:
                registry.gauge_set("serve_achieved_compression_ratio", ratio)
            registry.save(args.metrics)
            with open(args.metrics + ".prom", "w") as fh:
                fh.write(registry.prometheus_text())
            print(f"  metrics: {args.metrics} (+ {args.metrics}.prom)")
        if tracer is not None:
            tracer.save_chrome(args.trace)
            tracer.save_stable(args.trace + ".stable.json")
            print(f"  trace: {args.trace} ({len(tracer.events)} events; "
                  f"stable projection at {args.trace}.stable.json)")

    rng = np.random.default_rng(0)

    if args.mixer:
        from repro.launch.mixer import Mixer, Request
        slots = args.slots or args.batch
        max_len = args.prompt_len + args.gen
        # mixed-length stream: prompt lengths cycle below --prompt-len so
        # admissions land at distinct positions (the point of the mixer)
        reqs = []
        for i in range(args.batch):
            plen = max(1, args.prompt_len - (i % 4) * (args.prompt_len // 5))
            reqs.append(Request(
                uid=f"req{i}",
                prompt=jnp.asarray(
                    rng.integers(0, cfg.vocab, (plen,)), jnp.int32),
                max_new=args.gen, temperature=args.temperature,
                top_k=args.top_k, seed=i))
        mx = Mixer(model, params, slots=slots, max_len=max_len,
                   eos_id=args.eos, deadline_s=args.deadline)
        results = mx.run(reqs)
        st = mx.stats()
        print(f"[serve/mixer] {label}: slots={slots} devices={ndev} "
              f"requests={len(reqs)}")
        plens = {r.uid: len(r.prompt) for r in reqs}
        for res in results:
            print(f"  {res.uid}: prompt={plens[res.uid]} "
                  f"tok={res.n_tokens}/{len(res.tokens)} slot={res.slot} "
                  f"admit_step={res.admit_step} "
                  f"eos={res.report.eos_hit} out={res.tokens[:6]}")
        print(f"  decode  {st['tokens']} tok in {st['t_decode_s']:.2f}s "
              f"over {st['steps']} steps "
              f"({_rate(st['tokens'], st['t_decode_s']):.1f} tok/s, "
              f"{_rate(st['tokens'], st['t_decode_s']) / ndev:.1f} "
              f"tok/s/dev) slot_reuse_admits={st['slot_reuse_admits']}")
        _telemetry_done(mx)
        return

    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)

    report = None
    if args.guarded:
        toks, t_prefill, t_gen, report = generate(
            model, params, prompts, args.gen, args.prompt_len + args.gen,
            mesh=mesh, guarded=True, deadline_s=args.deadline,
            eos_id=args.eos)
    else:
        toks, t_prefill, t_gen = generate(
            model, params, prompts, args.gen, args.prompt_len + args.gen,
            mesh=mesh, eos_id=args.eos)
    n_pref = args.batch * args.prompt_len
    n_gen = args.batch * args.gen
    print(f"[serve] {label}: batch={args.batch} devices={ndev}")
    print(f"  prefill {n_pref} tok in {t_prefill:.2f}s "
          f"({_rate(n_pref, t_prefill):.1f} tok/s, "
          f"{_rate(n_pref, t_prefill) / ndev:.1f} tok/s/dev)")
    print(f"  decode  {n_gen} tok in {t_gen:.2f}s "
          f"({_rate(n_gen, t_gen):.1f} tok/s, "
          f"{_rate(n_gen, t_gen) / ndev:.1f} tok/s/dev)")
    print(f"  sample out: {np.asarray(toks[0, :8])}")
    if report is not None:
        print(f"  health: healthy={report.healthy} "
              f"verify={report.verify or 'skipped'} "
              f"fallbacks={report.fallback_counts() or 'none'} "
              f"retries={report.retries} dense_steps={report.dense_steps} "
              f"deadline_hit={report.deadline_hit} "
              f"steps={report.steps}/{report.gen}")
    _telemetry_done()


if __name__ == "__main__":
    main()
