"""Trip-count-aware cost analysis over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE — for
scan-over-layers models that under-reports FLOPs/bytes/collectives by a
factor of n_layers.  This module re-derives the roofline terms from
``compiled.as_text()``:

  * parses every computation and its instructions (result dtype/shape);
  * walks the call graph from ENTRY, multiplying by
    ``backend_config={"known_trip_count":{"n":...}}`` at each while;
  * FLOPs: ``dot`` ops → 2 · |result| · |contracting dims| (via operand
    shape lookup);
  * bytes: a PERFECT-FUSION roofline model — the CPU backend emits every
    elementwise op as its own kernel, which would overcount TPU HBM traffic
    ~30× (XLA-TPU fuses elementwise/transpose/broadcast chains into matmul
    epilogues).  We count bytes where traffic is structural: dot operands +
    results (weights/activations/KV streams), the moved slice of
    gather/scatter/dynamic-(update-)slice (cache reads/writes, embeddings),
    and reduce/concatenate results;
  * collective bytes: result bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute.

All numbers are PER-DEVICE (post-SPMD-partitioning HLO).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

_DTYPE_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s8": 1, "u8": 1,
                "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPNAME = re.compile(r"([a-z][\w\-]*)\(")
_CALLED_ONE = re.compile(r"(?:to_apply|calls|body|condition)=%?([\w.\-]+)")
_CALLED_MANY = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
# perfect-fusion byte model: traffic counted only at these ops
_DOT_OPS = {"dot", "convolution"}
_SLICE_OPS = {"dynamic-update-slice", "scatter"}      # moved update only
_RESULT_OPS = {"gather", "dynamic-slice", "reduce", "reduce-window",
               "concatenate", "sort", "select-and-scatter"}


def _shape_bytes_elems(text: str) -> tuple[float, float]:
    """Total (bytes, elems) of every shape token in ``text``."""
    bts = elems = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1.0
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        elems += n
        bts += n * _DTYPE_BYTES[dt]
    return bts, elems


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})
    coll_count: float = 0.0

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for c in COLLECTIVES:
            self.coll[c] += other.coll[c] * mult
        self.coll_count += other.coll_count * mult

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


@dataclasses.dataclass
class _Instr:
    name: str
    op: str
    result: str            # result type+shape prefix of the rhs
    rhs: str
    called: list
    trip: Optional[int]


def _parse(text: str):
    comps: dict[str, list[_Instr]] = {}
    entry = None
    cur: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR.match(line.strip())
        if hdr and ("->" in line) and line.strip().endswith("{"):
            cur = hdr.group(1)
            comps[cur] = []
            if line.strip().startswith("ENTRY"):
                entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        opm = _OPNAME.search(rhs)
        if not opm:
            continue
        op = opm.group(1)
        called = [cm.group(1) for cm in _CALLED_ONE.finditer(rhs)]
        for cm in _CALLED_MANY.finditer(rhs):
            for c in cm.group(1).split(","):
                called.append(c.strip().lstrip("%"))
        tm = _TRIP.search(rhs)
        trip = int(tm.group(1)) if tm else None
        result = rhs[: opm.start()]
        comps[cur].append(_Instr(name, op, result, rhs, called, trip))
    return comps, entry


def _dot_flops(instr: _Instr, symtab: dict[str, str]) -> float:
    res_bytes, res_elems = _shape_bytes_elems(instr.result)
    # contracting dims sizes from the lhs operand's shape
    args = instr.rhs[instr.rhs.index("("):]
    arg_names = re.findall(r"%([\w.\-]+)", args.split(")")[0])
    cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rhs)
    contract = 1.0
    if cdims and arg_names:
        lhs_shape = symtab.get(arg_names[0], "")
        m = _SHAPE_RE.search(lhs_shape)
        if m:
            dims = [int(d) for d in m.group(2).split(",") if d]
            for ci in cdims.group(1).split(","):
                if ci and int(ci) < len(dims):
                    contract *= dims[int(ci)]
    # batch dims are part of the result; 2·|out|·|contract| is the classic count
    return 2.0 * res_elems * contract


def analyze(text: str) -> Cost:
    comps, entry = _parse(text)
    if entry is None:
        return Cost()

    # symbol tables: instr name → result prefix (for operand shape lookup)
    symtabs = {cname: {i.name: i.result for i in instrs}
               for cname, instrs in comps.items()}

    # computations referenced inside fusions: flops counted, bytes NOT
    fusion_roots = set()
    for cname, instrs in comps.items():
        for i in instrs:
            if i.op == "fusion":
                fusion_roots.update(i.called)

    memo: dict[tuple[str, bool], Cost] = {}

    def cost_of(cname: str, in_fusion: bool) -> Cost:
        key = (cname, in_fusion)
        if key in memo:
            return memo[key]
        total = Cost()
        memo[key] = total              # cycle guard (shouldn't happen)
        symtab = symtabs.get(cname, {})
        for i in comps.get(cname, []):
            if i.op == "dot":
                total.flops += _dot_flops(i, symtab)
            if i.op in COLLECTIVES or any(
                    i.op == c + "-start" for c in COLLECTIVES):
                base = i.op.replace("-start", "")
                if base in COLLECTIVES:
                    b, _ = _shape_bytes_elems(i.result)
                    # ring-cost weighting: an all-reduce IS a reduce-scatter
                    # + all-gather — it moves ~2× its result bytes per link
                    if base == "all-reduce":
                        b *= 2.0
                    total.coll[base] += b
                    total.coll_count += 1
            # perfect-fusion byte model (see module docstring)
            if i.op in _DOT_OPS:
                rb, _ = _shape_bytes_elems(i.result)
                ob = 0.0
                args = i.rhs[i.rhs.index("("):].split(")")[0]
                for an in re.findall(r"%([\w.\-]+)", args):
                    if an in symtab:
                        b, _ = _shape_bytes_elems(symtab[an])
                        ob += b
                total.bytes += rb + ob
            elif i.op in _SLICE_OPS:
                # in-place update: traffic = the update operand (2nd arg)
                args = i.rhs[i.rhs.index("("):].split(")")[0]
                names = re.findall(r"%([\w.\-]+)", args)
                if len(names) >= 2 and names[1] in symtab:
                    b, _ = _shape_bytes_elems(symtab[names[1]])
                    total.bytes += b
            elif i.op in _RESULT_OPS:
                rb, _ = _shape_bytes_elems(i.result)
                total.bytes += rb
            # recurse into called computations
            mult = float(i.trip) if i.trip else 1.0
            child_fusion = in_fusion or i.op == "fusion"
            for cn in i.called:
                if cn in comps:
                    # reductions' tiny to_apply lambdas: skip (scalar ops)
                    if i.op in ("reduce", "all-reduce", "reduce-scatter",
                                "reduce-window", "scatter", "sort", "map",
                                "select-and-scatter"):
                        continue
                    total.add(cost_of(cn, child_fusion), mult)
        memo[key] = total
        return total

    return cost_of(entry, False)


def analyze_compiled(compiled) -> dict:
    c = analyze(compiled.as_text())
    return {"flops": c.flops, "bytes": c.bytes, "coll": dict(c.coll),
            "coll_bytes": c.coll_bytes, "coll_count": c.coll_count}
