"""End-to-end training driver.

Production loop shape: sharded train step (pjit when a mesh is available),
deterministic resumable data pipeline, periodic atomic checkpoints carrying
pipeline state, bounded-retry fault handling, straggler monitoring, optional
int8 gradient compression for cross-pod DP.

CPU quickstart (reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch deepseek-coder-33b \
      --reduced --steps 50 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import functools
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.data.pipeline import PipelineState, TokenPipeline
from repro.launch.mesh import axis_map_for, make_small_mesh, mesh_axis_sizes
from repro.models.partition import batch_specs, param_specs
from repro.models.sharding import logical_axis_rules
from repro.models.transformer import Model
from repro.optim import adamw
from repro.runtime.fault import StepGuard, StragglerMonitor


def build_train_step(model: Model, opt_cfg: adamw.AdamWConfig):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state = adamw.apply(params, grads, opt_state, opt_cfg)
        return loss, params, opt_state
    return train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                                warmup_steps=min(20, args.steps // 5),
                                grad_compress=args.grad_compress)

    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq,
                         global_batch=args.batch,
                         enc_seq=cfg.enc_seq if cfg.family == "encdec" else 0,
                         d_model=cfg.d_model)

    params = model.init(jax.random.key(0))
    opt_state = adamw.init(params, opt_cfg)
    pstate = PipelineState()

    if args.resume and args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        tree = {"params": params, "opt": opt_state}
        restored, extra = ckpt.restore(args.ckpt_dir, tree)
        params, opt_state = restored["params"], restored["opt"]
        pstate = PipelineState.from_dict(extra["pipeline"])
        print(f"[resume] step {pstate.step}")

    mesh = make_small_mesh(data=min(2, len(jax.devices())), model=1) \
        if len(jax.devices()) > 1 else None
    step_fn = build_train_step(model, opt_cfg)
    if mesh is not None:
        axes = mesh_axis_sizes(mesh)
        p_specs = param_specs(jax.eval_shape(lambda: model.init(
            jax.random.key(0))), axes)
        step_fn = jax.jit(step_fn)
    else:
        step_fn = jax.jit(step_fn)

    monitor = StragglerMonitor()
    last_good = pstate.step

    def do_restore():
        nonlocal params, opt_state, pstate
        if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
            tree = {"params": params, "opt": opt_state}
            restored, extra = ckpt.restore(args.ckpt_dir, tree)
            params, opt_state = restored["params"], restored["opt"]
            pstate = PipelineState.from_dict(extra["pipeline"])

    guard = StepGuard(max_retries=1, on_restore=do_restore)
    ctx = mesh if mesh is not None else _null_ctx()
    losses = []
    with ctx:
        rules = logical_axis_rules(axis_map_for(mesh)) if mesh is not None \
            else _null_ctx()
        with rules:
            while pstate.step < args.steps:
                batch = pipe.batch_at(pstate.step)
                t0 = time.perf_counter()

                def one_step():
                    out = step_fn(params, opt_state, batch)
                    jax.block_until_ready(out[0])   # honest step timing
                    return out

                out = guard.run(pstate.step, one_step)
                if out is None:
                    continue            # restored; replay from ckpt step
                loss, params, opt_state = out
                dt = time.perf_counter() - t0
                slow = monitor.observe(pstate.step, dt)
                pstate = PipelineState(pstate.step + 1)
                losses.append(float(loss))
                if pstate.step % args.log_every == 0 or pstate.step == 1:
                    print(f"step {pstate.step:5d} loss {float(loss):.4f} "
                          f"({dt*1e3:.0f} ms{' STRAGGLER' if slow else ''})",
                          flush=True)
                if args.ckpt_dir and pstate.step % args.ckpt_every == 0:
                    ckpt.save(args.ckpt_dir, pstate.step,
                              {"params": params, "opt": opt_state},
                              extra={"pipeline": pstate.to_dict()})
                    ckpt.prune_old(args.ckpt_dir, keep=3)
                    last_good = pstate.step

    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, pstate.step,
                  {"params": params, "opt": opt_state},
                  extra={"pipeline": pstate.to_dict()})
    n = max(len(losses) // 10, 1)
    print(f"[done] first-10 mean loss {sum(losses[:n])/n:.4f} → "
          f"last-10 mean {sum(losses[-n:])/n:.4f}")


class _null_ctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
