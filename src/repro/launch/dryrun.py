import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input-shape ×
mesh) cell against the production mesh, with NO real allocation
(ShapeDtypeStruct stand-ins everywhere).

The two lines above MUST precede every other import — jax locks the device
count at first init.  Do not set that flag globally: smoke tests and benches
must see 1 device.

Per cell this script records:
  * compiled.memory_analysis()  — proves the cell fits per-device HBM;
  * compiled.cost_analysis()    — HLO FLOPs / bytes for §Roofline;
  * collective operand bytes parsed from the compiled HLO text
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute) — cost_analysis does not report these;
  * lower/compile wall times.

Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>.json and are the
single source of truth for EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch deepseek-coder-33b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both] [--skip-existing]
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, list_archs
from repro.configs.base import ModelConfig, ShapeCfg
from repro.launch import hlo_cost
from repro.launch.mesh import (axis_map_for, data_axes_of,
                               make_production_mesh, mesh_axis_sizes)
from repro.models.partition import batch_specs, cache_specs, param_specs
from repro.models.sharding import logical_axis_rules
from repro.models.transformer import Model, input_specs
from repro.optim import adamw

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")

# long_500k is skipped for pure full-attention architectures (DESIGN.md §5).
def cell_applicable(cfg: ModelConfig, shape: ShapeCfg) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: no sub-quadratic mechanism"
    return True, ""


def _named(tree, mesh):
    return jax.tree.map(lambda spec: NamedSharding(mesh, spec), tree,
                        is_leaf=lambda x: isinstance(x, P))


def build_cell(cfg: ModelConfig, shape: ShapeCfg, mesh):
    """Returns (fn, abstract_args, in_shardings) for one cell."""
    model = Model(cfg)
    axes = mesh_axis_sizes(mesh)
    data_axes = data_axes_of(mesh)
    params_abs = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    p_specs = param_specs(params_abs, axes, data_axes,
                          kv_heads=cfg.n_kv_heads or None)
    inputs = input_specs(cfg, shape)
    b_specs = batch_specs(inputs, axes, data_axes)

    if shape.kind == "train":
        opt_cfg = adamw.AdamWConfig()
        opt_abs = jax.eval_shape(lambda: adamw.init(params_abs, opt_cfg))
        # ZeRO-style: optimizer moments shard exactly like their params
        o_specs = adamw.OptState(P(), p_specs, p_specs, None)

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
            params, opt_state = adamw.apply(params, grads, opt_state, opt_cfg)
            return loss, params, opt_state

        args = (params_abs, opt_abs, inputs)
        shardings = (_named(p_specs, mesh), _named(o_specs, mesh),
                     _named(b_specs, mesh))
        return train_step, args, shardings

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            return model.hidden_states(params, batch["tokens"],
                                       batch.get("enc_frames"), remat=False)
        args = (params_abs, inputs)
        return prefill_step, args, (_named(p_specs, mesh),
                                    _named(b_specs, mesh))

    # decode
    from repro.models import optflags
    if optflags.enabled("bf16params"):
        params_abs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
            if jnp.issubdtype(s.dtype, jnp.floating) else s, params_abs)
    cache_abs = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    c_specs = cache_specs(cache_abs, axes, data_axes)

    def serve_step(params, cache, batch):
        return model.decode_step(params, cache, batch["tokens"], batch["pos"])

    args = (params_abs, cache_abs, inputs)
    shardings = (_named(p_specs, mesh), _named(c_specs, mesh),
                 _named(b_specs, mesh))
    return serve_step, args, shardings


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             artifact_dir: str = ARTIFACT_DIR,
             opts: tuple[str, ...] = ()) -> dict:
    from repro.models import optflags
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    record: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "ok": False, "opts": list(opts)}
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        record.update(skipped=True, reason=why, ok=True)
        return record
    if "sparseffn" in opts and shape.kind != "decode":
        record.update(skipped=True, ok=True,
                      reason="sparseffn applies to serve cells only")
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.perf_counter()
    try:
        with optflags.optimizations(opts), mesh, \
                logical_axis_rules(axis_map_for(mesh)):
            fn, args, shardings = build_cell(cfg, shape, mesh)
            lowered = jax.jit(fn, in_shardings=shardings).lower(*args)
            t_lower = time.perf_counter() - t0
            t1 = time.perf_counter()
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t1

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0] if cost else {}
        # trip-count-aware per-device terms (XLA's cost_analysis counts
        # while bodies once — useless for scan-over-layers models)
        deep = hlo_cost.analyze_compiled(compiled)
        record.update(
            ok=True,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            flops=deep["flops"],
            hlo_bytes=deep["bytes"],
            collectives=dict(deep["coll"],
                             count=deep["coll_count"]),
            coll_bytes=deep["coll_bytes"],
            xla_flops_raw=float(cost.get("flops", -1.0)),
            xla_bytes_raw=float(cost.get("bytes accessed", -1.0)),
            devices=int(mesh.devices.size),
            memory_analysis=_mem_to_dict(mem),
            params_count=cfg.params_count(),
            active_params=cfg.active_params_count(),
        )
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        record.update(error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
    return record


def _mem_to_dict(mem) -> dict:
    if mem is None:
        return {"available": False}
    out = {"available": True}
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes", "peak_memory_in_bytes"):
        if hasattr(mem, attr):
            out[attr] = int(getattr(mem, attr))
    if len(out) == 1:
        out["repr"] = str(mem)[:2000]
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    ap.add_argument("--opts", default="",
                    help="comma-separated optflags (padheads,replkv,"
                         "saveremat,maskedkv,sparseffn); artifacts get an "
                         "__opt-<flags> suffix")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    opts = tuple(o for o in args.opts.split(",") if o)
    suffix = f"__opt-{'-'.join(opts)}" if opts else ""

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                mesh_name = "multi" if multi else "single"
                path = os.path.join(
                    args.out, f"{arch}__{shape}__{mesh_name}{suffix}.json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("ok"):
                            print(f"[skip] {arch} {shape} {mesh_name}")
                            continue
                print(f"[cell] {arch} {shape} {mesh_name} opts={opts} ...",
                      flush=True)
                rec = run_cell(arch, shape, multi, args.out, opts=opts)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if rec.get("skipped"):
                    print(f"  skipped: {rec['reason']}")
                elif rec["ok"]:
                    print(f"  ok: lower={rec['lower_s']}s "
                          f"compile={rec['compile_s']}s "
                          f"flops={rec['flops']:.3e} "
                          f"coll={rec['collectives']['count']}", flush=True)
                else:
                    n_fail += 1
                    print(f"  FAIL: {rec['error']}", flush=True)
    print(f"done; failures={n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
