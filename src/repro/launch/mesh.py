"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.  Single pod: (data=16, model=16) = 256 chips;
multi-pod: (pod=2, data=16, model=16) = 512 chips.  The ``pod`` axis only
ever carries data-parallel all-reduces (DCN-crossing traffic); TP stays
intra-pod on ICI.
"""

from __future__ import annotations

import math
from typing import Optional

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def data_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_map_for(mesh) -> dict:
    """Logical→physical axis binding for the model zoo's annotations."""
    data = data_axes_of(mesh)
    return {
        "batch": data if len(data) > 1 else data[0],
        "model": "model",
        "vocab": "model",
        "expert": "model",
    }


def make_small_mesh(data: int = 1, model: int = 1) -> Optional[object]:
    """Tiny mesh for CPU smoke/integration runs (1 device → None)."""
    n = data * model
    if len(jax.devices()) < n:
        return None
    return jax.make_mesh((data, model), ("data", "model"))


def make_serve_mesh(batch: int, model: int = 1) -> Optional[object]:
    """Serving mesh for batch-of-requests traffic on whatever is available.

    The data axis takes the largest divisor of ``batch`` that fits the
    devices left after TP (requests shard evenly, no padding); returns
    ``None`` when that degenerates to a single device — serving then runs
    unsharded, the same no-op path the model zoo's annotations take
    outside a mesh."""
    avail = len(jax.devices())
    if model < 1 or avail < model:
        return None
    data = math.gcd(max(batch, 1), avail // model)
    if data * model <= 1:
        return None
    return jax.make_mesh((data, model), ("data", "model"))


def degraded_serve_mesh(batch: int, lost: int, model: int = 1
                        ) -> Optional[object]:
    """Serving mesh after losing ``lost`` devices (elastic re-mesh).

    The straggler/fault path: :func:`repro.runtime.fault.elastic_remesh`
    proposes the largest (data × model) shape the survivors sustain — TP
    degree pinned, data parallelism shrunk — and the mesh is built over an
    explicit device subset (the survivors; here simply the first ``avail``
    devices, since a real deployment passes the cordon list).  Raises
    ``ValueError`` when the survivors cannot sustain the TP degree;
    returns ``None`` when the proposal degenerates to one device, the
    same unsharded path :func:`make_serve_mesh` takes."""
    import numpy as np

    from repro.runtime.fault import elastic_remesh

    devices = jax.devices()
    avail = len(devices) - lost
    if avail < 1:
        raise ValueError(f"lost {lost} of {len(devices)} devices: "
                         "nothing left to serve on")
    data, model = elastic_remesh(avail, model)
    data = math.gcd(max(batch, 1), data)
    if data * model <= 1:
        return None
    grid = np.array(devices[:data * model]).reshape(data, model)
    return jax.sharding.Mesh(grid, ("data", "model"))
