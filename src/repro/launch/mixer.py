"""Continuous-batching request mixer over the (compressed) serving plane.

The static driver (:mod:`repro.launch.serve`) serves one lockstep batch:
every request enters together, decodes in step, and leaves together.  The
mixer serves a STREAM: variable-length prompts are admitted into free
*slots* of one running decode batch, decode advances all occupied slots in
a single compiled :meth:`decode_step` call per token, and slots are
evicted (EOS / token budget / deadline) and immediately refilled from the
queue — the production shape under which compressed-weight bandwidth
savings are actually realized per request (mixed traffic, not fixed
batches).

Slot model — no new cache layout, the batch axis IS the slot axis:

  * ``model.init_cache(slots, max_len)`` allocates one KV (or SSM/ring
    state) region per slot; per-slot position counters live host-side.
  * **Admission** prefill runs at batch 1 (one-pass ``model.prefill``
    where the family supports it; exact token-by-token decode ingest
    otherwise) and the resulting single-row cache is written into the
    slot with :func:`write_slot` — the same primitive
    ``launch.serve.generate`` uses for ragged left-padded prompts.
  * **Decode** calls ``decode_step`` with a ``(B_slots,)`` position
    VECTOR: RoPE, cache writes, and the causal mask all follow each
    row's own position (:func:`repro.models.attention
    .attention_decode_block`), so the step stays ONE compiled function
    for every slot occupancy.  Free slots ride along pinned at position
    0 with a pad token; their writes land below any successor's prompt
    and per-slot length masking keeps them (and any stale KV an evicted
    request left behind) out of every softmax.
  * **Eviction** frees the slot without clearing it — isolation comes
    from the mask, and is pinned by ``tests/test_mixer.py``.

Works for the dense :class:`~repro.models.transformer.Model` and the
execution plane's :class:`~repro.exec.dispatch.CompressedModel` alike
(same serving surface).  Greedy decode of a request through the mixer is
token-identical to the request served alone through the static driver at
fp32 (the acceptance contract); sampled decode (temperature / top-k) is
seeded per request and keyed by token index, so a replayed stream
reproduces exactly regardless of slot placement.

Known limits: encoder-decoder families are not admitted (prefill needs
encoder frames); non-uniform cache families (ring windows, hybrid, SSM)
ingest prompts token-by-token on admission — one decode step per prompt
token — until their one-pass prefill lands (ROADMAP).
"""

from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import metrics as omet
from repro.obs import trace as otr
from repro.runtime.fault import StragglerMonitor
from repro.runtime.guard import HealthReport


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request in the mixer's stream.

    ``temperature <= 0`` decodes greedy; otherwise tokens are sampled from
    ``softmax(logits / temperature)`` restricted to the ``top_k`` highest
    logits (0 = full vocabulary), seeded per request (``seed``) and keyed
    by token index — deterministic across runs and slot placements."""

    uid: str
    prompt: Sequence[int]
    max_new: int
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0


@dataclasses.dataclass
class RequestResult:
    """Per-request outcome: ``tokens`` is (max_new,) int32 with ``pad_id``
    after EOS / deadline expiry (the static driver's tail semantics);
    ``report`` is the per-request :class:`HealthReport` (request_id set,
    admission time in ``t_prefill_s``, decode residency in
    ``t_decode_s``)."""

    uid: str
    tokens: np.ndarray
    slot: int
    admit_step: int
    report: HealthReport

    @property
    def n_tokens(self) -> int:
        return int(self.report.steps)


# ---------------------------------------------------------------------------
# Admission primitives (shared with launch.serve's ragged-prompt path)
# ---------------------------------------------------------------------------

def prefill_request(model, params, prompt: jax.Array, max_len: int,
                    prefill_fn=None, step_fn=None):
    """Batch-1 prefill of one request: (last_logits (V,), single-row cache).

    Prefers the one-pass ``model.prefill``; families without it (ring
    windows, hybrid, SSM) fall back to the exact token-by-token decode
    ingest.  ``prefill_fn`` / ``step_fn`` accept pre-jitted callables so
    the mixer's per-admission traces are cached across requests."""
    if prompt.ndim != 2 or prompt.shape[0] != 1 or prompt.shape[1] < 1:
        raise ValueError(f"prefill_request wants a (1, plen>=1) prompt; "
                         f"got {prompt.shape}")
    try:
        fn = prefill_fn or functools.partial(model.prefill, max_len=max_len)
        logits, cache = fn(params, prompt)
        return logits[0, -1], cache
    except NotImplementedError:
        step = step_fn or model.decode_step
        cache = model.init_cache(1, max_len)
        lg = None
        for t in range(prompt.shape[1]):
            lg, cache = step(params, cache, prompt[:, t],
                             jnp.asarray(t, jnp.int32))
        return lg[0], cache


def write_slot(cache, row_cache, slot):
    """Write a batch-1 cache into batch row ``slot`` of a slotted cache.

    Every non-scalar cache leaf carries batch on axis 1 (layer-stacked
    layouts: KV (L, B, S, nk, hd), SSM states, ring conv tails); scalar
    leaves are shared and kept.  ``slot`` may be traced."""
    def upd(c, r):
        if c.ndim < 2:
            return c
        return jax.lax.dynamic_update_slice_in_dim(
            c, r.astype(c.dtype), slot, axis=1)
    return jax.tree.map(upd, cache, row_cache)


def sample_token(logits: jax.Array, req: Request, index: int) -> int:
    """Greedy or seeded temperature/top-k sampling of one token.

    The PRNG key is ``fold_in(key(req.seed), index)`` — a pure function of
    the request and its token index, so the draw does not depend on slot
    placement, batch composition, or wall-clock."""
    if req.temperature <= 0.0:
        return int(jnp.argmax(logits))
    lg = logits.astype(jnp.float32) / req.temperature
    if req.top_k:
        kth = jax.lax.top_k(lg, min(req.top_k, lg.shape[-1]))[0][-1]
        lg = jnp.where(lg >= kth, lg, -jnp.inf)
    key = jax.random.fold_in(jax.random.key(req.seed), index)
    return int(jax.random.categorical(key, lg))


# ---------------------------------------------------------------------------
# The mixer
# ---------------------------------------------------------------------------

class Mixer:
    """Continuous-batching scheduler: ``slots`` concurrent requests over
    one slotted decode cache.

    ``model`` is anything with the serving surface (``prefill`` /
    ``init_cache`` / ``decode_step``): the dense Model or a
    CompressedModel.  ``eos_id`` ends a request when sampled; ``pad_id``
    fills result tails; ``deadline_s`` (optional) evicts requests that
    exceed their wall-clock budget, tail padded — same semantics as the
    guarded static driver.

    Telemetry (zero-cost when off): with an ambient tracer
    (:func:`repro.obs.trace.tracing`) every request emits admit / prefill
    / slot-write spans, per-token decode events, and an evict event, all
    linked by the ``trace_id`` its :class:`HealthReport` carries; with an
    ambient registry (:func:`repro.obs.metrics.collecting`) the stream's
    admission/eviction/token counters, per-step decode latency histogram
    and slot-occupancy gauge record live, and each finished request's
    report is folded in (so ``serve_tokens_generated_total`` equals the
    reports' summed ``steps``).  ``straggler`` (default: a fresh
    :class:`~repro.runtime.fault.StragglerMonitor`) watches every decode
    step's wall time; spikes land in the metrics snapshot
    (``mixer_straggler_spikes_total``) and the trace (as unstable
    events — excluded from ``stable_trace`` since they are timing-derived,
    not stream-determined)."""

    def __init__(self, model, params, *, slots: int, max_len: int,
                 eos_id: Optional[int] = None, pad_id: int = -1,
                 deadline_s: Optional[float] = None,
                 straggler: Optional[StragglerMonitor] = None):
        if slots < 1:
            raise ValueError(f"need at least one slot, got {slots}")
        if getattr(model.cfg, "family", None) == "encdec":
            raise NotImplementedError(
                "mixer: encoder-decoder families need per-request encoder "
                "frames; not supported yet")
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.deadline_s = deadline_s
        self.straggler = straggler if straggler is not None \
            else StragglerMonitor()

        self.cache = model.init_cache(slots, max_len)
        for leaf in jax.tree.leaves(self.cache):
            if leaf.ndim >= 2 and leaf.shape[1] != slots:
                raise NotImplementedError(
                    f"mixer: cache leaf {leaf.shape} does not carry the "
                    f"slot axis at position 1; family unsupported")
        self._step_fn = jax.jit(model.decode_step, donate_argnums=(1,))
        self._prefill_fn = jax.jit(
            functools.partial(model.prefill, max_len=max_len))
        self._ingest_fn = jax.jit(model.decode_step)
        self._write_fn = jax.jit(write_slot, donate_argnums=(0,))

        # host-side per-slot state
        self.pos = np.zeros(slots, np.int64)        # next decode position
        self.pending = np.zeros(slots, np.int64)    # next token to consume
        self.active = np.zeros(slots, bool)
        self._req: list[Optional[Request]] = [None] * slots
        self._emitted: list[list[int]] = [[] for _ in range(slots)]
        self._admit_step = np.zeros(slots, np.int64)
        self._t_admitted = np.zeros(slots, float)
        self._reports: list[Optional[HealthReport]] = [None] * slots

        # stream accounting
        self.step_count = 0
        self.tokens_out = 0
        self.t_admit = 0.0
        self.t_decode = 0.0
        self.events: list[dict] = []
        self.results: dict[str, RequestResult] = {}

    # -- admission -----------------------------------------------------------
    def admit(self, req: Request) -> int:
        """Prefill ``req`` into the lowest free slot; returns the slot.
        Raises if no slot is free or the request cannot fit ``max_len``."""
        free = np.nonzero(~self.active)[0]
        if free.size == 0:
            raise RuntimeError("mixer: no free slot (use run() to queue)")
        slot = int(free[0])
        prompt = jnp.asarray(np.asarray(req.prompt, np.int32).reshape(1, -1))
        plen = int(prompt.shape[1])
        if req.max_new < 1:
            raise ValueError(f"request {req.uid!r}: max_new must be >= 1")
        if plen + req.max_new > self.max_len:
            raise ValueError(
                f"request {req.uid!r}: prompt ({plen}) + max_new "
                f"({req.max_new}) exceeds max_len ({self.max_len})")
        if req.uid in self.results or any(
                r is not None and r.uid == req.uid for r in self._req):
            raise ValueError(f"duplicate request uid {req.uid!r}")

        tid = otr.trace_id(req.uid)
        t0 = time.perf_counter()
        with otr.span("admit", trace_id=tid, request_id=req.uid, slot=slot,
                      prompt_len=plen, step=self.step_count):
            with otr.span("prefill", trace_id=tid, request_id=req.uid):
                last, rcache = prefill_request(
                    self.model, self.params, prompt, self.max_len,
                    prefill_fn=self._prefill_fn, step_fn=self._ingest_fn)
            with otr.span("slot_write", trace_id=tid, request_id=req.uid,
                          slot=slot):
                self.cache = self._write_fn(self.cache, rcache,
                                            jnp.asarray(slot, jnp.int32))
        report = HealthReport(gen=req.max_new, request_id=str(req.uid),
                              trace_id=tid)
        report.t_prefill_s = time.perf_counter() - t0
        self.t_admit += report.t_prefill_s
        omet.counter_inc("mixer_admissions_total")
        omet.counter_inc("mixer_tokens_admitted_total", plen)

        self.active[slot] = True
        self._req[slot] = req
        self._emitted[slot] = []
        self.pos[slot] = plen
        self._admit_step[slot] = self.step_count
        self._t_admitted[slot] = time.perf_counter()
        self._reports[slot] = report
        self.events.append({"event": "admit", "uid": req.uid, "slot": slot,
                            "step": self.step_count, "prompt_len": plen})
        omet.gauge_set("mixer_slot_occupancy", int(self.active.sum()))
        # the first token comes straight from prefill logits
        self._emit(slot, sample_token(last, req, 0))
        return slot

    # -- decode --------------------------------------------------------------
    def _step(self) -> None:
        """One decode token for every occupied slot (free slots ride along
        at position 0; their output is discarded)."""
        t0 = time.perf_counter()
        with otr.span("decode_step", step=self.step_count,
                      occupied=int(self.active.sum())):
            toks = jnp.asarray(self.pending, jnp.int32)
            pos = jnp.asarray(self.pos, jnp.int32)
            logits, self.cache = self._step_fn(self.params, self.cache,
                                               toks, pos)
            greedy = np.asarray(jnp.argmax(logits, axis=-1))  # one host sync
        self.step_count += 1
        dt = time.perf_counter() - t0
        omet.counter_inc("mixer_decode_steps_total")
        omet.observe("mixer_decode_step_seconds", dt)
        if self.straggler.observe(self.step_count, dt):
            # timing-derived, hence stable=False: two runs of the same
            # stream may legitimately spike at different steps
            otr.event("straggler_spike", stable=False,
                      step=self.step_count, dt_s=dt)
            omet.counter_inc("mixer_straggler_spikes_total")
        now = time.perf_counter()
        for slot in np.nonzero(self.active)[0]:
            slot = int(slot)
            req = self._req[slot]
            self.pos[slot] += 1
            if self.deadline_s is not None and \
                    now - self._t_admitted[slot] > self.deadline_s:
                rep = self._reports[slot]
                rep.deadline_hit = True
                rep.record_fallback(
                    "*", "deadline_exceeded",
                    detail=f"{len(self._emitted[slot])}/{req.max_new} "
                           f"tokens within {self.deadline_s}s")
                self._evict(slot, "deadline")
                continue
            if req.temperature > 0.0:
                tok = sample_token(logits[slot], req,
                                   len(self._emitted[slot]))
            else:
                tok = int(greedy[slot])
            self._emit(slot, tok)
        self.t_decode += time.perf_counter() - t0

    def _emit(self, slot: int, tok: int) -> None:
        req = self._req[slot]
        self._emitted[slot].append(tok)
        self.tokens_out += 1
        otr.event("token", trace_id=self._reports[slot].trace_id,
                  request_id=req.uid, index=len(self._emitted[slot]) - 1)
        if self.eos_id is not None and tok == self.eos_id:
            self._reports[slot].eos_hit = True
            self._evict(slot, "eos")
        elif len(self._emitted[slot]) >= req.max_new:
            self._evict(slot, "budget")
        else:
            self.pending[slot] = tok

    def _evict(self, slot: int, reason: str) -> None:
        """Free the slot (KV left in place; per-slot length masking keeps
        it out of every successor's softmax) and finalize the result."""
        req = self._req[slot]
        rep = self._reports[slot]
        emitted = self._emitted[slot]
        rep.steps = len(emitted)
        rep.t_decode_s = time.perf_counter() - self._t_admitted[slot]
        rep.t_total_s = rep.t_prefill_s + rep.t_decode_s
        tokens = np.full(req.max_new, self.pad_id, np.int32)
        tokens[: len(emitted)] = emitted
        self.results[req.uid] = RequestResult(
            uid=req.uid, tokens=tokens, slot=slot,
            admit_step=int(self._admit_step[slot]), report=rep)
        self.events.append({"event": "evict", "uid": req.uid, "slot": slot,
                            "step": self.step_count, "reason": reason,
                            "tokens": len(emitted)})
        otr.event("evict", trace_id=rep.trace_id, request_id=req.uid,
                  slot=slot, reason=reason, tokens=len(emitted))
        omet.counter_inc("mixer_evictions_total", reason=reason)
        reg = omet.current_metrics()
        if reg is not None:
            omet.ingest_health(reg, rep)
        self.active[slot] = False
        self._req[slot] = None
        self._reports[slot] = None
        self.pending[slot] = 0
        self.pos[slot] = 0
        omet.gauge_set("mixer_slot_occupancy", int(self.active.sum()))

    # -- scheduler loop ------------------------------------------------------
    def run(self, requests: Sequence[Request]) -> list[RequestResult]:
        """Serve the whole stream: admit into free slots (FIFO, lowest slot
        first), decode until queue and slots drain.  Results come back in
        request order."""
        queue = deque(requests)
        order = [r.uid for r in requests]
        if len(set(order)) != len(order):
            raise ValueError("request uids must be unique")
        while queue or self.active.any():
            while queue and not self.active.all():
                self.admit(queue.popleft())
            if self.active.any():
                self._step()
        return [self.results[uid] for uid in order]

    def stats(self) -> dict:
        """Stream-level accounting for benchmarks and the CLI."""
        admits = sum(1 for e in self.events if e["event"] == "admit")
        evicts = sum(1 for e in self.events if e["event"] == "evict")
        reused = sum(1 for e in self.events
                     if e["event"] == "admit" and e["step"] > 0)
        return {"steps": self.step_count, "tokens": self.tokens_out,
                "admits": admits, "evictions": evicts,
                "slot_reuse_admits": reused,
                "t_admit_s": self.t_admit, "t_decode_s": self.t_decode,
                "straggler_spikes": len(self.straggler.flagged),
                "step_ewma_s": self.straggler.ewma}
